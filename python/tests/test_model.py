"""Layer-2 model tests: assembled butterfly_block vs oracle, shape/dtype
contracts, and consistency identities (Σb_u = Σb_v = 4·total... etc.)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import butterfly_block


def rand_block(m, n, density, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((m, n)) < density).astype(np.float32))


@pytest.mark.parametrize("n", [8, 16, 64])
def test_model_matches_ref(n):
    a = rand_block(n, n, 0.4, 11)
    bu, bv, s, total = butterfly_block(a)
    rbu, rbv, rs, rtotal = ref.butterfly_block_ref(a)
    np.testing.assert_array_equal(np.asarray(bu), np.asarray(rbu))
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(rbv))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    assert float(total) == float(rtotal)


@settings(max_examples=10, deadline=None)
@given(density=st.floats(0.05, 0.95), seed=st.integers(0, 2**31))
def test_model_identities(density, seed):
    a = rand_block(16, 16, density, seed)
    bu, bv, s, total = butterfly_block(a)
    # every butterfly has 2 U vertices, 2 V vertices, 4 edges
    assert float(bu.sum()) == 2 * float(total)
    assert float(bv.sum()) == 2 * float(total)
    assert float(s.sum()) == 4 * float(total)


def test_model_under_jit_and_counts_are_integral():
    a = rand_block(64, 64, 0.3, 5)
    bu, bv, s, total = jax.jit(butterfly_block)(a)
    for arr in (bu, bv, s):
        x = np.asarray(arr)
        np.testing.assert_array_equal(x, np.round(x))
    assert float(total) == round(float(total))


def test_model_empty_block():
    a = jnp.zeros((8, 8), jnp.float32)
    bu, bv, s, total = butterfly_block(a)
    assert float(total) == 0
    assert float(np.asarray(s).sum()) == 0


def test_model_shapes():
    a = rand_block(64, 128, 0.2, 3)
    bu, bv, s, total = butterfly_block(a)
    assert bu.shape == (64,)
    assert bv.shape == (128,)
    assert s.shape == (64, 128)
    assert total.shape == ()
