"""Layer-1 kernel tests: Pallas vs pure-jnp oracle (exact — counts are
integers in f32), plus hypothesis sweeps over shapes and densities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import butterfly as K
from compile.kernels import ref


def rand_block(m, n, density, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((m, n)) < density).astype(np.float32))


# ---------- matmul kernel ----------

@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 64, 128)])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 3, (m, k)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (k, n)).astype(np.float32))
    got = K.matmul(x, y, tile=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.matmul_ref(x, y)))


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 32]),
    k=st.sampled_from([8, 16, 40]),
    n=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 2**31),
)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 4, (m, k)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, (k, n)).astype(np.float32))
    got = K.matmul(x, y, tile=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.matmul_ref(x, y)))


def test_matmul_rejects_ragged_tiles():
    x = jnp.ones((10, 8), jnp.float32)
    y = jnp.ones((8, 8), jnp.float32)
    with pytest.raises(AssertionError):
        K.matmul(x, y, tile=8)


# ---------- choose2 off-diagonal row-sum ----------

@pytest.mark.parametrize("n", [8, 16, 64])
def test_choose2_matches_ref(n):
    a = rand_block(n, n, 0.4, 7)
    wu, _ = ref.wedge_matrices(a)
    got = K.choose2_offdiag_rowsum(wu, tile=8)
    want = ref.choose2(wu).sum(axis=1) - ref.choose2(jnp.diagonal(wu))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), density=st.floats(0.1, 0.9), seed=st.integers(0, 2**31))
def test_choose2_hypothesis(n, density, seed):
    a = rand_block(n, n, density, seed)
    wu, _ = ref.wedge_matrices(a)
    got = K.choose2_offdiag_rowsum(wu, tile=8)
    want = ref.choose2(wu).sum(axis=1) - ref.choose2(jnp.diagonal(wu))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


# ---------- edge support ----------

@pytest.mark.parametrize("m,n", [(8, 8), (16, 8), (64, 32)])
def test_edge_support_matches_ref(m, n):
    a = rand_block(m, n, 0.5, 3)
    wu, wv = ref.wedge_matrices(a)
    wa = ref.matmul_ref(wu, a)
    got = K.edge_support(a, wa, jnp.diagonal(wu), jnp.diagonal(wv), tile=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.per_edge_ref(a)))


def test_edge_support_zero_on_non_edges():
    a = rand_block(16, 16, 0.3, 9)
    wu, wv = ref.wedge_matrices(a)
    wa = ref.matmul_ref(wu, a)
    s = np.asarray(K.edge_support(a, wa, jnp.diagonal(wu), jnp.diagonal(wv), tile=8))
    np.testing.assert_array_equal(s[np.asarray(a) == 0], 0.0)


# ---------- oracle's oracle: ref vs literal enumeration ----------

@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 7),
    n=st.integers(2, 7),
    density=st.floats(0.2, 0.9),
    seed=st.integers(0, 2**31),
)
def test_ref_matches_enumeration(m, n, density, seed):
    a = rand_block(m, n, density, seed)
    bu, bv, s, total = ref.butterfly_block_ref(a)
    ebu, ebv, es, etotal = ref.enumerate_butterflies(a)
    np.testing.assert_array_equal(np.asarray(bu), ebu)
    np.testing.assert_array_equal(np.asarray(bv), ebv)
    np.testing.assert_array_equal(np.asarray(s), es)
    assert float(total) == etotal


def test_ref_biclique_closed_form():
    # K_{a,b}: total = C(a,2)C(b,2); per-edge = (a-1)(b-1)
    a_, b_ = 4, 5
    a = jnp.ones((a_, b_), jnp.float32)
    bu, bv, s, total = ref.butterfly_block_ref(a)
    assert float(total) == 6 * 10
    np.testing.assert_array_equal(np.asarray(s), np.full((a_, b_), (a_ - 1) * (b_ - 1), np.float32))
    np.testing.assert_array_equal(np.asarray(bu), np.full(a_, 10 * (a_ - 1), np.float32))
    np.testing.assert_array_equal(np.asarray(bv), np.full(b_, 6 * (b_ - 1), np.float32))
