"""AOT path tests: lowering to HLO text succeeds and the text parses
back into an XlaComputation (what the rust runtime will do via the
xla crate's HLO text parser)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.aot import lower_block, to_hlo_text  # noqa: E402
from compile.model import butterfly_block  # noqa: E402


def test_lower_block_produces_hlo_text():
    text = lower_block(8)
    assert "HloModule" in text
    assert "ROOT" in text


def test_hlo_has_tuple_root():
    # rust unwraps a tuple: lowering must use return_tuple=True
    text = lower_block(8)
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
    assert root_lines, "expected a tuple root in the entry computation"


def test_module_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--sizes", "8"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    assert (out / "butterfly_block_8.hlo.txt").exists()
    assert (out / "manifest.txt").read_text().startswith("butterfly_block_8")
