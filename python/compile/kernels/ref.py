"""Pure-jnp oracle for the butterfly-block kernels.

The CORE correctness signal: every Pallas kernel and the assembled model
are asserted allclose (exactly equal — counts are integers in f32)
against these definitions, and these in turn are checked against a naive
O(M²N²) butterfly enumeration in the tests.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def choose2(w):
    return w * (w - 1.0) * 0.5


def wedge_matrices(a):
    """(Wu, Wv): pairwise common-neighbor counts, diagonal = degrees."""
    wu = a @ a.T
    wv = a.T @ a
    return wu, wv


def per_vertex_ref(a):
    """(b_u, b_v): per-vertex butterfly counts of a dense block."""
    wu, wv = wedge_matrices(a)
    bu = choose2(wu).sum(axis=1) - choose2(jnp.diagonal(wu))
    bv = choose2(wv).sum(axis=1) - choose2(jnp.diagonal(wv))
    return bu, bv


def per_edge_ref(a):
    """S[u,v] = #butterflies containing edge (u,v); 0 on non-edges."""
    wu, _ = wedge_matrices(a)
    du = a.sum(axis=1)
    dv = a.sum(axis=0)
    s = wu @ a - du[:, None] - dv[None, :] + 1.0
    return jnp.where(a > 0.0, s, 0.0)


def total_ref(a):
    """Total butterflies in the block: Σ_{i<j} C(Wu[i,j], 2)."""
    bu, _ = per_vertex_ref(a)
    return bu.sum() * 0.5


def butterfly_block_ref(a):
    """Full reference output: (b_u, b_v, S, total)."""
    bu, bv = per_vertex_ref(a)
    return bu, bv, per_edge_ref(a), bu.sum() * 0.5


def enumerate_butterflies(a):
    """O(M²N²) literal enumeration — the oracle's oracle (tiny blocks).

    Returns (b_u, b_v, S, total) as numpy arrays.
    """
    import numpy as np

    a = np.asarray(a)
    m, n = a.shape
    bu = np.zeros(m)
    bv = np.zeros(n)
    s = np.zeros((m, n))
    total = 0
    for i in range(m):
        for j in range(i + 1, m):
            for p in range(n):
                for q in range(p + 1, n):
                    if a[i, p] and a[i, q] and a[j, p] and a[j, q]:
                        total += 1
                        bu[i] += 1
                        bu[j] += 1
                        bv[p] += 1
                        bv[q] += 1
                        for (x, y) in ((i, p), (i, q), (j, p), (j, q)):
                            s[x, y] += 1
    return bu, bv, s, float(total)
