"""Layer-1 Pallas kernels for dense-block butterfly counting.

The paper's hot spot is butterfly discovery. On a dense biadjacency block
A (f32[M, N], entries in {0,1}) the whole counting pipeline is matmul
shaped — ideal MXU work on TPU:

    Wu = A · Aᵀ          (U-side wedge counts)
    Wv = Aᵀ · A          (V-side wedge counts)
    b_u[i] = Σ_{j≠i} C(Wu[i,j], 2)          per-vertex butterflies
    S = A ⊙ (Wu·A − d_u − d_v + 1)          per-edge butterflies

All kernels are written for TPU-style tiling (BlockSpec over VMEM-sized
tiles, f32 accumulation) but are executed with ``interpret=True`` in this
environment: the CPU PJRT plugin cannot run Mosaic custom-calls, so
interpret mode is the correctness path and the TPU mapping is documented
in DESIGN.md §Hardware-Adaptation.

Counts are integers; f32 is exact up to 2^24, far beyond any value a
block of side ≤ 2048 can produce (max wedge count = N ≤ 2048, max C(w,2)
≈ 2M, max per-vertex sum < 2^24 for the block sizes we AOT).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: (128, 128) f32 tiles are 64 KiB — three live tiles per
# kernel instance stay far below the ~16 MiB VMEM budget of a TPU core.
DEFAULT_TILE = 64


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: full-K contraction.

    x block: (bm, K); y block: (K, bn). K is kept whole per tile — for
    the block sizes this library AOT-compiles (≤ 512) the three tiles fit
    VMEM comfortably; the grid walks output tiles only.
    """
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


def matmul(x: jax.Array, y: jax.Array, *, tile: int = DEFAULT_TILE) -> jax.Array:
    """Tiled Pallas matmul ``x @ y`` (f32), grid over output tiles."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = min(tile, m)
    bn = min(tile, n)
    assert m % bm == 0 and n % bn == 0, "matmul: shape must divide tile"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _choose2_offdiag_kernel(w_ref, o_ref, *, bm: int, n: int):
    """Row-block reduction: o[i] = Σ_{j≠i} C(w[i,j], 2)."""
    i0 = pl.program_id(0) * bm
    w = w_ref[...]
    c2 = w * (w - 1.0) * 0.5
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 0) + i0
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    c2 = jnp.where(rows == cols, 0.0, c2)
    o_ref[...] = jnp.sum(c2, axis=1)


def choose2_offdiag_rowsum(w: jax.Array, *, tile: int = DEFAULT_TILE) -> jax.Array:
    """Per-vertex butterfly counts from a wedge matrix: Σ_{j≠i} C(w_ij, 2).

    The C(·,2) map and the row reduction are fused into the tile visit so
    the wedge matrix is read exactly once.
    """
    m, n = w.shape
    assert m == n, "wedge matrix must be square"
    bm = min(tile, m)
    assert m % bm == 0
    return pl.pallas_call(
        partial(_choose2_offdiag_kernel, bm=bm, n=n),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(w)


def _edge_support_kernel(a_ref, wa_ref, du_ref, dv_ref, o_ref):
    """S = A ⊙ (WA − d_u − d_v + 1), one (bm, bn) tile."""
    a = a_ref[...]
    s = wa_ref[...] - du_ref[...][:, None] - dv_ref[...][None, :] + 1.0
    o_ref[...] = jnp.where(a > 0.0, s, 0.0)


def edge_support(
    a: jax.Array,
    wa: jax.Array,
    du: jax.Array,
    dv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
) -> jax.Array:
    """Per-edge butterfly counts: S[u,v] = (Wu·A)[u,v] − d_u − d_v + 1 on
    edges, 0 elsewhere. Elementwise tile kernel fused with the mask."""
    m, n = a.shape
    bm = min(tile, m)
    bn = min(tile, n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _edge_support_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, wa, du, dv)
