"""AOT lowering: butterfly_block → HLO text artifacts for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--sizes 64,128]

Writes ``butterfly_block_<n>.hlo.txt`` per size plus a manifest.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import butterfly_block


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(butterfly_block).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="64,128")
    # kept for Makefile compatibility: --out <file> writes the first size
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for n in sizes:
        text = lower_block(n)
        path = os.path.join(args.out_dir, f"butterfly_block_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"butterfly_block_{n}.hlo.txt {n} {n}")
        print(f"wrote {path} ({len(text)} chars)")
    if args.out:
        with open(args.out, "w") as f:
            f.write(lower_block(sizes[0]))
        print(f"wrote {args.out}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
