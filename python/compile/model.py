"""Layer-2 JAX model: dense-block butterfly counting.

``butterfly_block(A)`` is the compute graph the rust coordinator executes
through PJRT: given a biadjacency block A (f32[M, N], {0,1} entries) it
returns

    (b_u, b_v, S, total)

— per-U-vertex butterfly counts, per-V-vertex counts, per-edge supports,
and the block's total butterfly count. The heavy products run through the
Layer-1 Pallas kernels (`kernels.butterfly`); Wu is computed once and
shared between the per-vertex and per-edge outputs (no recomputation —
§Perf L2 target).

The rust side uses this artifact to initialize peeling supports for
dense partitions and to cross-validate its own counting paths; Python is
never on the request path (AOT via compile/aot.py).
"""

import jax.numpy as jnp

from .kernels import butterfly as K


def butterfly_block(a):
    """Count butterflies of a dense biadjacency block.

    Args:
      a: f32[M, N] biadjacency block with {0, 1} entries.

    Returns:
      (b_u f32[M], b_v f32[N], S f32[M, N], total f32[]) — all counts are
      exact integers in f32 (< 2^24 for AOT block sizes).
    """
    at = a.T
    wu = K.matmul(a, at)  # U-side wedge counts (diag = degrees)
    wv = K.matmul(at, a)  # V-side wedge counts
    bu = K.choose2_offdiag_rowsum(wu)
    bv = K.choose2_offdiag_rowsum(wv)
    wa = K.matmul(wu, a)
    du = jnp.diagonal(wu)
    dv = jnp.diagonal(wv)
    s = K.edge_support(a, wa, du, dv)
    total = bu.sum() * 0.5
    return bu, bv, s, total
