//! Minimal CLI argument parsing (the offline registry has no clap).
//!
//! Supports `--flag`, `--key value`, `-k value`, and positionals, with
//! typed getters and an unknown-argument check.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    seen: std::cell::RefCell<std::collections::HashSet<String>>,
}

/// Flags that take no value.
const BOOL_FLAGS: &[&str] = &[
    "allow-empty-baseline",
    "no-batch",
    "no-deletes",
    "full",
    "help",
    "ignore-time",
    "levels",
    "list",
    "quiet",
    "trace",
    "verify",
];

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut it: I) -> Result<Args> {
        let mut args = Args::default();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--").or_else(|| tok.strip_prefix('-')) {
                let key = key.to_string();
                if BOOL_FLAGS.contains(&key.as_str()) {
                    args.flags.insert(key, "true".to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{key} expects a value"))?;
                    args.flags.insert(key, val);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).is_some()
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u16(&self, key: &str, default: u16) -> Result<u16> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a 16-bit integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a float, got '{v}'")),
        }
    }

    /// Error out on flags that no getter consulted (typo protection).
    pub fn check_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_positionals_and_flags() {
        let a = parse(&["wing", "g.tsv", "--threads", "4", "--no-batch"]);
        assert_eq!(a.positional, vec!["wing", "g.tsv"]);
        assert_eq!(a.get_usize("threads", 1).unwrap(), 4);
        assert!(a.flag("no-batch"));
        assert!(!a.flag("no-deletes"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--threads"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse(&["--bogus", "1"]);
        assert!(a.check_unknown().is_err());
        let b = parse(&["--threads", "2"]);
        let _ = b.get_usize("threads", 1);
        assert!(b.check_unknown().is_ok());
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--tau", "0.02", "--p", "64", "--port", "7878"]);
        assert_eq!(a.get_f64("tau", 1.0).unwrap(), 0.02);
        assert_eq!(a.get_usize("p", 1).unwrap(), 64);
        assert!(a.get_usize("absent", 7).unwrap() == 7);
        assert_eq!(a.get_u16("port", 0).unwrap(), 7878);
        assert_eq!(a.get_u16("missing-port", 0).unwrap(), 0);
        let b = parse(&["--port", "70000"]);
        assert!(b.get_u16("port", 0).is_err(), "out of u16 range");
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["--threads", "x"]);
        assert!(a.get_usize("threads", 1).is_err());
    }
}
