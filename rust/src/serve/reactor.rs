//! Single-threaded poll-based reactor: one thread drives every session.
//!
//! No `epoll`/`kqueue` and no dependencies — the listener and every
//! accepted socket run in non-blocking mode, and a small readiness loop
//! sweeps them: accept burst, per-connection write-flush / read /
//! line-extract / respond, idle sweep, then a short park
//! ([`super::ServerConfig::poll_interval`]) when nothing made progress.
//! For an index server whose replies are computed in microseconds this
//! trades a syscall-perfect wakeup for zero platform surface; thousands
//! of mostly-idle sessions cost one buffer pair each, not a thread.
//!
//! Admission control happens at accept time: when the global or per-IP
//! connection cap is reached the new socket is shed with a one-frame
//! `ERR busy` reply (counted in `server.rejected`) and closed, so
//! clients fail fast instead of hanging in the backlog. Sessions pin
//! their snapshot `Arc` at accept; a concurrent
//! [`super::SnapshotStore::publish`] never stalls or retargets them.

use super::snapshot::{Snapshot, SnapshotStore};
use super::{proto, ServerConfig};
use crate::obs::Registry;
use crate::par::Counter;
use std::io::{self, Read, Write};
use std::net::{IpAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

struct Counters {
    rejected: Arc<Counter>,
    idle_closed: Arc<Counter>,
    session_errors: Arc<Counter>,
    connections: Arc<Counter>,
}

impl Counters {
    fn new() -> Counters {
        let reg = Registry::global();
        Counters {
            rejected: reg.counter("server.rejected"),
            idle_closed: reg.counter("server.idle_closed"),
            session_errors: reg.counter("server.session_errors"),
            connections: reg.counter("server.connections"),
        }
    }
}

struct Conn {
    stream: TcpStream,
    ip: IpAddr,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    last_active: Instant,
    snap: Arc<Snapshot>,
    /// Flush the remaining `wbuf`, then close (set by `quit`, protocol
    /// violations, and the idle sweep).
    closing: bool,
}

/// Outcome of one sweep over a connection.
enum Tick {
    Progress,
    Idle,
    Close,
    Error(io::Error),
}

impl Conn {
    fn admit(
        stream: TcpStream,
        ip: IpAddr,
        store: &SnapshotStore,
        cfg: &ServerConfig,
    ) -> io::Result<Conn> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        let snap = store.load();
        let mut wbuf = Vec::new();
        wbuf.extend_from_slice(proto::greeting(&snap, cfg.proto).as_bytes());
        wbuf.push(b'\n');
        Ok(Conn {
            stream,
            ip,
            rbuf: Vec::new(),
            wbuf,
            last_active: Instant::now(),
            snap,
            closing: false,
        })
    }

    /// Write as much of `wbuf` as the socket accepts right now.
    /// `Ok((made_progress, peer_closed))`.
    fn flush(&mut self) -> io::Result<(bool, bool)> {
        let mut progress = false;
        while !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => return Ok((progress, true)),
                Ok(n) => {
                    self.wbuf.drain(..n);
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok((progress, false))
    }

    fn tick(&mut self, cfg: &ServerConfig, store: &SnapshotStore) -> Tick {
        let mut progress = match self.flush() {
            Ok((p, true)) => return if p { Tick::Progress } else { Tick::Close },
            Ok((p, false)) => p,
            Err(e) => return Tick::Error(e),
        };
        if self.closing {
            return if self.wbuf.is_empty() {
                Tick::Close
            } else if progress {
                Tick::Progress
            } else {
                Tick::Idle
            };
        }
        // drain the socket into rbuf
        let mut buf = [0u8; 4096];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => return Tick::Close, // EOF
                Ok(n) => {
                    self.rbuf.extend_from_slice(&buf[..n]);
                    progress = true;
                    if self.rbuf.len() > cfg.max_line {
                        break; // bounded: stop reading, handled below
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Tick::Error(e),
            }
        }
        // answer every complete line
        while let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let mut line: Vec<u8> = self.rbuf.drain(..=pos).collect();
            line.pop(); // \n
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            let line = String::from_utf8_lossy(&line).into_owned();
            self.last_active = Instant::now();
            progress = true;
            if let Some((reply, quit)) = proto::respond(store, &self.snap, cfg.proto, &line) {
                self.wbuf.extend_from_slice(reply.as_bytes());
                if quit {
                    self.closing = true;
                    break;
                }
            }
        }
        // a line longer than max_line without a newline would buffer
        // without bound — reject it and drop the session
        if !self.closing && self.rbuf.len() > cfg.max_line {
            self.rbuf.clear();
            self.wbuf
                .extend_from_slice(b"ERR line too long\nEND\n");
            self.closing = true;
        }
        // push out what this tick produced before yielding
        match self.flush() {
            Ok((p, true)) => return if p || progress { Tick::Progress } else { Tick::Close },
            Ok((p, false)) => progress |= p,
            Err(e) => return Tick::Error(e),
        }
        if self.closing && self.wbuf.is_empty() {
            return Tick::Close;
        }
        if progress {
            Tick::Progress
        } else {
            Tick::Idle
        }
    }
}

/// Best-effort one-frame rejection of a connection over the cap. The
/// accepted socket is still in blocking mode (accepted sockets do not
/// inherit the listener's non-blocking flag on Linux), so the write
/// either lands immediately or fails — we never buffer for shed peers.
fn shed(mut stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let _ = stream.write_all(b"ERR busy (connection limit reached)\nEND\n");
    // drop closes the socket
}

/// Drive the listener until `stop` is set. Called by
/// [`super::Server::run`] / [`super::Server::run_on`].
pub(crate) fn run(
    cfg: &ServerConfig,
    store: &Arc<SnapshotStore>,
    listener: TcpListener,
    stop: &AtomicBool,
) -> io::Result<()> {
    listener.set_nonblocking(true)?;
    let counters = Counters::new();
    let mut conns: Vec<Conn> = Vec::new();
    // ORDERING: Acquire pairs with the Release store made by whoever
    // holds `Server::stop_handle`, so a shutdown requested from
    // another thread is seen along with its preceding writes.
    while !stop.load(Ordering::Acquire) {
        let mut progress = false;
        // accept burst
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    progress = true;
                    let ip = peer.ip();
                    let global_full = cfg.max_conns > 0 && conns.len() >= cfg.max_conns;
                    let ip_full = cfg.per_ip > 0
                        && conns.iter().filter(|c| c.ip == ip).count() >= cfg.per_ip;
                    if global_full || ip_full {
                        counters.rejected.add(1);
                        shed(stream);
                        continue;
                    }
                    match Conn::admit(stream, ip, store, cfg) {
                        Ok(conn) => {
                            counters.connections.add(1);
                            conns.push(conn);
                        }
                        Err(e) => {
                            counters.session_errors.add(1);
                            eprintln!("pbng serve: failed to admit {peer}: {e}");
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        // sweep every connection
        let mut i = 0;
        while i < conns.len() {
            match conns[i].tick(cfg, store) {
                Tick::Progress => {
                    progress = true;
                    i += 1;
                }
                Tick::Idle => i += 1,
                Tick::Close => {
                    conns.swap_remove(i);
                    progress = true;
                }
                Tick::Error(e) => {
                    counters.session_errors.add(1);
                    eprintln!("pbng serve: session error from {}: {e}", conns[i].ip);
                    conns.swap_remove(i);
                    progress = true;
                }
            }
        }
        // idle sweep
        if !cfg.idle_timeout.is_zero() {
            let mut i = 0;
            while i < conns.len() {
                if !conns[i].closing && conns[i].last_active.elapsed() >= cfg.idle_timeout {
                    counters.idle_closed.add(1);
                    conns.swap_remove(i);
                    progress = true;
                } else {
                    i += 1;
                }
            }
        }
        if !progress {
            std::thread::sleep(cfg.poll_interval);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::index::query::QueryEngine;
    use crate::peel::bup::wing_bup;
    use std::io::BufRead;
    use std::time::Duration;

    fn store() -> Arc<SnapshotStore> {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        SnapshotStore::new(QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1)))
    }

    fn spawn_reactor(
        cfg: ServerConfig,
    ) -> (std::net::SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            let store = store();
            std::thread::spawn(move || run(&cfg, &store, listener, &stop).unwrap())
        };
        (addr, stop, handle)
    }

    fn client(addr: std::net::SocketAddr) -> TcpStream {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s
    }

    /// Read lines until an `END` terminator (or EOF/error), returning
    /// the frame.
    fn read_frame(reader: &mut impl BufRead) -> String {
        let mut frame = String::new();
        loop {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return frame,
                Ok(_) => {}
            }
            if line.trim_end() == "END" {
                return frame;
            }
            frame.push_str(&line);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real TCP sockets — unsupported under Miri
    fn reactor_round_trip_v2() {
        let (addr, stop, handle) = spawn_reactor(ServerConfig::new());
        let mut s = client(addr);
        let mut reader = std::io::BufReader::new(s.try_clone().unwrap());
        let hello = read_frame(&mut reader);
        assert!(hello.starts_with("OK hello"), "{hello}");
        s.write_all(b"summary\nquit\n").unwrap();
        let summary = read_frame(&mut reader);
        assert!(summary.starts_with("OK summary\nlevel "), "{summary}");
        let bye = read_frame(&mut reader);
        assert!(bye.starts_with("OK quit"), "{bye}");
        // session closes after quit
        let mut rest = String::new();
        std::io::Read::read_to_string(&mut reader, &mut rest).unwrap();
        assert!(rest.is_empty(), "{rest}");
        stop.store(true, Ordering::Release);
        handle.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real TCP sockets — unsupported under Miri
    fn reactor_sheds_over_global_cap() {
        let (addr, stop, handle) = spawn_reactor(ServerConfig::new().max_conns(1));
        let rejected = Registry::global().counter("server.rejected");
        let before = rejected.get();
        let s1 = client(addr);
        let mut r1 = std::io::BufReader::new(s1.try_clone().unwrap());
        assert!(read_frame(&mut r1).starts_with("OK hello"));
        // connection 2 is over the cap: one ERR busy frame, then EOF
        let s2 = client(addr);
        let mut text = String::new();
        std::io::Read::read_to_string(&mut std::io::BufReader::new(s2), &mut text).unwrap();
        assert!(text.starts_with("ERR busy"), "{text}");
        assert!(text.ends_with("END\n"), "{text}");
        assert!(rejected.get() > before);
        drop(s1);
        stop.store(true, Ordering::Release);
        handle.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real TCP sockets — unsupported under Miri
    fn reactor_sheds_over_per_ip_cap() {
        let (addr, stop, handle) =
            spawn_reactor(ServerConfig::new().max_conns(64).per_ip(1));
        let s1 = client(addr);
        let mut r1 = std::io::BufReader::new(s1.try_clone().unwrap());
        assert!(read_frame(&mut r1).starts_with("OK hello"));
        let s2 = client(addr); // same IP (loopback) — over the per-IP cap
        let mut text = String::new();
        std::io::Read::read_to_string(&mut std::io::BufReader::new(s2), &mut text).unwrap();
        assert!(text.starts_with("ERR busy"), "{text}");
        drop(s1);
        stop.store(true, Ordering::Release);
        handle.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real TCP sockets — unsupported under Miri
    fn reactor_closes_idle_connections() {
        let (addr, stop, handle) = spawn_reactor(
            ServerConfig::new().idle_timeout(Duration::from_millis(50)),
        );
        let idle_closed = Registry::global().counter("server.idle_closed");
        let before = idle_closed.get();
        let s = client(addr);
        let mut reader = std::io::BufReader::new(s);
        assert!(read_frame(&mut reader).starts_with("OK hello"));
        // send nothing: the idle sweep should drop us (EOF on read)
        let mut rest = String::new();
        std::io::Read::read_to_string(&mut reader, &mut rest).unwrap();
        assert!(idle_closed.get() > before);
        stop.store(true, Ordering::Release);
        handle.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real TCP sockets — unsupported under Miri
    fn reactor_rejects_overlong_lines() {
        let (addr, stop, handle) = spawn_reactor(ServerConfig::new().max_line(64));
        let mut s = client(addr);
        let mut reader = std::io::BufReader::new(s.try_clone().unwrap());
        assert!(read_frame(&mut reader).starts_with("OK hello"));
        // 600 bytes, no newline, > max_line — small enough that loopback
        // delivers it in one read, so the server's close stays graceful
        s.write_all(&[b'x'; 600]).unwrap();
        let frame = read_frame(&mut reader);
        assert!(frame.contains("ERR line too long"), "{frame}");
        stop.store(true, Ordering::Release);
        handle.join().unwrap();
    }
}
