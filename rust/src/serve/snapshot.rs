//! MVCC-style snapshot slot: an immutable `Arc<QueryEngine>` behind an
//! atomically swappable cell, versioned by a monotonically increasing
//! epoch.
//!
//! Readers call [`SnapshotStore::load`] — one short mutex lock to clone
//! an `Arc` (arc-swap style; the lock is held for a pointer copy, never
//! across a query, so readers never wait on a rebuild). Writers build
//! the replacement engine entirely off to the side and then
//! [`SnapshotStore::publish`] it: old snapshots stay alive for as long
//! as any session holds their `Arc`, so in-flight queries on a retired
//! epoch complete against exactly the data they started with.
//!
//! Query/cache meters are per-engine and would reset on every swap; the
//! store absorbs each retiring engine's meters into a lifetime
//! accumulator ([`SnapshotStore::lifetime_meters`]) so `stats` /
//! `metrics` report cumulative traffic across epochs.

use crate::index::query::QueryEngine;
use crate::metrics::IndexMeters;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One immutable serving version: the engine plus its epoch number.
pub struct Snapshot {
    pub engine: Arc<QueryEngine>,
    pub epoch: u64,
}

/// The swappable slot plus the updater rendezvous state (reload
/// requests, attachment flag) and the cross-epoch meter accumulator.
pub struct SnapshotStore {
    slot: Mutex<Arc<Snapshot>>,
    epoch: AtomicU64,
    reload_requested: AtomicBool,
    updater_attached: AtomicBool,
    /// Meters of every *retired* engine, folded in at publish time.
    retired: IndexMeters,
    /// Durable ingestion sink (set when serving with `--wal`); protocol
    /// sessions route the `ingest` verb here.
    ingest: Mutex<Option<Arc<super::updater::WalSink>>>,
}

impl SnapshotStore {
    /// Wrap an engine as epoch 1.
    pub fn new(engine: QueryEngine) -> Arc<SnapshotStore> {
        Arc::new(SnapshotStore {
            slot: Mutex::new(Arc::new(Snapshot {
                engine: Arc::new(engine),
                epoch: 1,
            })),
            epoch: AtomicU64::new(1),
            reload_requested: AtomicBool::new(false),
            updater_attached: AtomicBool::new(false),
            retired: IndexMeters::new(),
            ingest: Mutex::new(None),
        })
    }

    /// Attach the durable ingestion sink (serve `--wal` startup).
    pub fn attach_ingest(&self, sink: Arc<super::updater::WalSink>) {
        *self.ingest.lock().unwrap_or_else(|e| e.into_inner()) = Some(sink);
    }

    /// The attached ingestion sink, if serving with `--wal`.
    pub fn ingest_sink(&self) -> Option<Arc<super::updater::WalSink>> {
        self.ingest.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// The current snapshot. Cheap (one `Arc` clone under a short lock);
    /// hold the result for the duration of a session to get a stable
    /// view across swaps.
    pub fn load(&self) -> Arc<Snapshot> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Epoch of the current snapshot without touching the slot.
    pub fn epoch(&self) -> u64 {
        // ORDERING: Acquire pairs with the Release store in `publish`,
        // so a caller that observes epoch N and then calls `load` is
        // guaranteed a snapshot at least that new (the slot mutex alone
        // already orders slot access; the pair keeps the lock-free
        // epoch probe consistent with it).
        self.epoch.load(Ordering::Acquire)
    }

    /// Swap in a new engine as the next epoch; returns the new epoch.
    /// The outgoing engine's meters are absorbed into the lifetime
    /// accumulator before it retires. Existing `Arc<Snapshot>` holders
    /// are untouched.
    pub fn publish(&self, engine: QueryEngine) -> u64 {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        let next = slot.epoch + 1;
        self.retired.absorb(&slot.engine.meters);
        *slot = Arc::new(Snapshot {
            engine: Arc::new(engine),
            epoch: next,
        });
        // ORDERING: Release pairs with the Acquire load in `epoch`;
        // stored after the slot swap (under the mutex) so an observed
        // epoch never runs ahead of the published snapshot.
        self.epoch.store(next, Ordering::Release);
        next
    }

    /// Ask the attached updater (if any) to refresh its source now.
    /// Returns whether an updater is attached to honor the request.
    pub fn request_reload(&self) -> bool {
        if !self.has_updater() {
            return false;
        }
        // ORDERING: Release pairs with the AcqRel swap in
        // `take_reload_request`, so work the requester did before
        // asking (e.g. writing the new index file) is visible to the
        // updater that honors the request.
        self.reload_requested.store(true, Ordering::Release);
        true
    }

    /// Consume a pending reload request (updater side).
    pub fn take_reload_request(&self) -> bool {
        // ORDERING: AcqRel — Acquire pairs with the Release store in
        // `request_reload` (see there); Release keeps the consuming RMW
        // ordered before the updater's subsequent publish.
        self.reload_requested.swap(false, Ordering::AcqRel)
    }

    /// Mark that an [`super::Updater`] is polling this store.
    pub fn attach_updater(&self) {
        // ORDERING: Release pairs with the Acquire in `has_updater`,
        // so a requester that sees the flag also sees the updater's
        // initialization.
        self.updater_attached.store(true, Ordering::Release);
    }

    pub fn has_updater(&self) -> bool {
        // ORDERING: Acquire pairs with the Release in `attach_updater`.
        self.updater_attached.load(Ordering::Acquire)
    }

    /// Cumulative `(queries, cache_hits, cache_misses)` across every
    /// epoch: retired engines plus the live one.
    pub fn lifetime_meters(&self) -> [(&'static str, u64); 3] {
        let live = self.load();
        let mut out = self.retired.pairs();
        for (slot, (_, v)) in out.iter_mut().zip(live.engine.meters.pairs()) {
            slot.1 += v;
        }
        out
    }

    /// Publish cumulative meters into a registry under `index.*` names
    /// (the v2 `metrics` verb calls this instead of the live engine's
    /// [`IndexMeters::publish`], which only sees its own epoch).
    pub fn publish_lifetime_meters(&self, reg: &crate::obs::Registry) {
        for (n, v) in self.lifetime_meters() {
            reg.counter(&format!("index.{n}")).set(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::peel::bup::wing_bup;

    fn engine_for(seed: u64) -> QueryEngine {
        let g = gen::zipf(24, 24, 140, 1.2, 1.2, seed);
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1))
    }

    fn body(engine: &QueryEngine, line: &str) -> String {
        crate::index::server::dispatch(engine, line).body.unwrap()
    }

    /// Small enough (K_{2,2}) to run under Miri: exercises the
    /// publish/epoch/pin protocol without the zipf generators.
    #[test]
    fn pinned_snapshot_keeps_its_epoch_across_publish() {
        fn tiny_engine() -> QueryEngine {
            let g = gen::biclique(2, 2);
            let (idx, _) = BeIndex::build(&g, 1);
            let theta = wing_bup(&g).theta;
            QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1))
        }
        let store = SnapshotStore::new(tiny_engine());
        let pinned = store.load();
        assert_eq!(pinned.epoch, 1);
        let e2 = store.publish(tiny_engine());
        assert_eq!(e2, 2);
        assert_eq!(store.epoch(), 2);
        // the pinned session still sees epoch 1; fresh loads see 2
        assert_eq!(pinned.epoch, 1);
        assert_eq!(store.load().epoch, 2);
        // updater rendezvous flags round-trip
        assert!(!store.request_reload());
        store.attach_updater();
        assert!(store.request_reload());
        assert!(store.take_reload_request());
        assert!(!store.take_reload_request());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // zipf graph + full forest build — too slow under Miri
    fn publish_bumps_epoch_and_new_loads_see_it() {
        let store = SnapshotStore::new(engine_for(1));
        assert_eq!(store.epoch(), 1);
        assert_eq!(store.load().epoch, 1);
        let e2 = store.publish(engine_for(2));
        assert_eq!(e2, 2);
        assert_eq!(store.epoch(), 2);
        assert_eq!(store.load().epoch, 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // zipf graph + full forest build — too slow under Miri
    fn in_flight_snapshot_survives_a_publish_byte_identically() {
        let store = SnapshotStore::new(engine_for(7));
        let old = store.load(); // a session pins this epoch
        let before = body(&old.engine, "components 1");
        store.publish(engine_for(8));
        // the retired snapshot still answers, byte-identical to a fresh
        // engine over the same inputs
        let after = body(&old.engine, "components 1");
        assert_eq!(before, after);
        let fresh = engine_for(7);
        assert_eq!(after, body(&fresh, "components 1"));
        // while new loads serve the new epoch's data
        let newer = store.load();
        assert_eq!(newer.epoch, 2);
        let fresh8 = engine_for(8);
        assert_eq!(
            body(&newer.engine, "components 1"),
            body(&fresh8, "components 1")
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // zipf graph + full forest build — too slow under Miri
    fn lifetime_meters_accumulate_across_swaps() {
        let store = SnapshotStore::new(engine_for(3));
        // k=0 maps to the smallest existing level, so the miss/hit
        // pattern below holds for any generated graph
        let _ = store.load().engine.components(0); // 1 query, 1 miss
        store.publish(engine_for(4));
        let _ = store.load().engine.components(0);
        let _ = store.load().engine.components(0); // hit on the live epoch
        let pairs = store.lifetime_meters();
        assert_eq!(pairs[0], ("queries", 3));
        assert_eq!(pairs[1].0, "cache_hits");
        assert_eq!(pairs[1].1, 1);
        assert_eq!(pairs[2], ("cache_misses", 2));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // zipf graph + full forest build — too slow under Miri
    fn reload_requests_need_an_updater() {
        let store = SnapshotStore::new(engine_for(5));
        assert!(!store.request_reload());
        assert!(!store.take_reload_request());
        store.attach_updater();
        assert!(store.request_reload());
        assert!(store.take_reload_request());
        assert!(!store.take_reload_request()); // consumed
    }
}
