//! Wire protocol framing for the serving layer.
//!
//! **v2** (default): every session starts with a greeting frame
//! (`OK hello`, an info line, `END`), and every reply is one frame —
//! first line `OK <verb>` (the verb as typed) or `ERR <reason>`, then
//! the body, then `END` on its own line. Blank lines are ignored
//! silently. `stats` appends `protocol 2` and the session's pinned
//! snapshot `epoch`; `metrics` dumps the registry *including* histogram
//! summaries (`hist <name> count <c> sum <s> max <b>`) and cumulative
//! cross-epoch index meters; `reload` asks the attached [`super::Updater`]
//! to rebuild the snapshot.
//!
//! **v1** (deprecated, one release): the exact wire format of the old
//! `serve_*` functions — `READY …` greeting without `END`, bare bodies
//! (errors as `ERR <reason>` body lines) followed by `END`, `BYE` on
//! quit, and an `ERR` reply to blank lines. Byte-for-byte compatible so
//! existing scripts keep working behind `--proto v1`.
//!
//! | | v1 | v2 |
//! |---|---|---|
//! | greeting | `READY kind=… …` (no END) | `OK hello` + info + `END` |
//! | reply | body + `END` | `OK <verb>` + body + `END` |
//! | error | `ERR <reason>` + `END` | `ERR <reason>` + `END` |
//! | blank line | `ERR empty command` | ignored |
//! | quit | `BYE`, close | `OK quit` + `END`, close |
//! | reload | — | `OK reload` / `ERR reload unavailable` |

use super::snapshot::{Snapshot, SnapshotStore};
use crate::index::server::{dispatch, handle_command, Reply};
use crate::obs::Registry;

/// Wire protocol version of a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoVersion {
    /// Legacy `READY`/`BYE` framing, kept for one release.
    V1,
    /// `OK <verb>`/`ERR <reason>` framed replies (default).
    V2,
}

impl ProtoVersion {
    /// Parse a CLI spelling (`v1`, `1`, `v2`, `2`; case-insensitive).
    pub fn parse(s: &str) -> Option<ProtoVersion> {
        match s.to_ascii_lowercase().as_str() {
            "v1" | "1" => Some(ProtoVersion::V1),
            "v2" | "2" => Some(ProtoVersion::V2),
            _ => None,
        }
    }

    pub fn number(self) -> u32 {
        match self {
            ProtoVersion::V1 => 1,
            ProtoVersion::V2 => 2,
        }
    }
}

/// Session greeting, without the trailing newline (callers `writeln!`).
pub fn greeting(snap: &Snapshot, proto: ProtoVersion) -> String {
    let f = snap.engine.forest();
    match proto {
        ProtoVersion::V1 => format!(
            "READY kind={} entities={} nodes={} levels={}",
            f.kind.name(),
            f.n_entities(),
            f.n_nodes(),
            f.levels.len()
        ),
        ProtoVersion::V2 => format!(
            "OK hello\nproto 2 kind {} entities {} nodes {} levels {} epoch {}\nEND",
            f.kind.name(),
            f.n_entities(),
            f.n_nodes(),
            f.levels.len(),
            snap.epoch
        ),
    }
}

/// Answer one protocol line against the session's pinned snapshot.
/// Returns `None` for lines that get no reply (blank lines in v2), else
/// the complete newline-terminated reply and whether the session should
/// close after sending it.
pub fn respond(
    store: &SnapshotStore,
    snap: &Snapshot,
    proto: ProtoVersion,
    line: &str,
) -> Option<(String, bool)> {
    match proto {
        ProtoVersion::V1 => respond_v1(snap, line),
        ProtoVersion::V2 => respond_v2(store, snap, line),
    }
}

fn respond_v1(snap: &Snapshot, line: &str) -> Option<(String, bool)> {
    match handle_command(&snap.engine, line) {
        Reply::Quit => Some(("BYE\n".to_string(), true)),
        Reply::Body(b) => Some((format!("{b}\nEND\n"), false)),
    }
}

fn err_frame(reason: &str) -> String {
    format!("ERR {reason}\nEND\n")
}

/// Parse `ingest (+|-) <u> <v> [(+|-) <u> <v> ...]` into delta ops.
fn parse_ingest_ops(trimmed: &str) -> Result<Vec<crate::graph::dynamic::DeltaOp>, String> {
    use crate::graph::dynamic::DeltaOp;
    let usage = "usage: ingest (+|-) <u> <v> [(+|-) <u> <v> ...]";
    let rest: Vec<&str> = trimmed.split_whitespace().skip(1).collect();
    if rest.is_empty() || rest.len() % 3 != 0 {
        return Err(usage.to_string());
    }
    let mut ops = Vec::with_capacity(rest.len() / 3);
    for t in rest.chunks_exact(3) {
        let u: u32 = t[1].parse().map_err(|_| format!("bad u '{}' ({usage})", t[1]))?;
        let v: u32 = t[2].parse().map_err(|_| format!("bad v '{}' ({usage})", t[2]))?;
        match t[0] {
            "+" => ops.push(DeltaOp::Insert(u, v)),
            "-" => ops.push(DeltaOp::Remove(u, v)),
            s => return Err(format!("bad op sign '{s}' ({usage})")),
        }
    }
    Ok(ops)
}

fn respond_v2(store: &SnapshotStore, snap: &Snapshot, line: &str) -> Option<(String, bool)> {
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return None;
    }
    // `reload` is a store-level verb (it concerns the *next* snapshot,
    // not the pinned one), so it is intercepted before dispatch; it is
    // still a real command and counts in `server.commands`.
    if trimmed.eq_ignore_ascii_case("reload") {
        Registry::global().counter("server.commands").add(1);
        let reply = if store.request_reload() {
            "OK reload\nreload requested; new sessions will see the next epoch\nEND\n"
                .to_string()
        } else {
            err_frame("reload unavailable (no updater attached to this server)")
        };
        return Some((reply, false));
    }
    // `ingest` writes to the durable log (the next snapshot), not the
    // pinned one, so it is intercepted before dispatch too.
    let verb = trimmed.split_whitespace().next().unwrap_or("");
    if verb.eq_ignore_ascii_case("ingest") {
        Registry::global().counter("server.commands").add(1);
        let reply = match store.ingest_sink() {
            None => err_frame("ingest unavailable (serve with --wal)"),
            Some(sink) => match parse_ingest_ops(trimmed) {
                Err(e) => err_frame(&e),
                Ok(ops) => match sink.submit(&ops) {
                    Err(e) => err_frame(&format!("ingest rejected: {e:#}")),
                    // the reply is the durability ack: seq is on disk
                    Ok(seq) => format!("OK ingest\nseq {seq} ops {}\nEND\n", ops.len()),
                },
            },
        };
        return Some((reply, false));
    }
    let d = dispatch(&snap.engine, line);
    if d.quit {
        return Some(("OK quit\nEND\n".to_string(), true));
    }
    Some(match d.body {
        Err(e) => (err_frame(&e), false),
        Ok(mut body) => {
            match d.verb.as_str() {
                "stats" => {
                    body.push_str(&format!("\nprotocol 2\nepoch {}", snap.epoch));
                }
                "metrics" => {
                    // dispatch published the live engine's meters; override
                    // with the cumulative cross-epoch values and rebuild
                    // the dump with histogram summaries appended
                    let reg = Registry::global();
                    store.publish_lifetime_meters(reg);
                    let mut lines: Vec<String> = reg
                        .counter_snapshot()
                        .iter()
                        .map(|(n, v)| format!("{n} {v}"))
                        .collect();
                    for (n, c, s, m) in reg.histogram_snapshot() {
                        lines.push(format!("hist {n} count {c} sum {s} max {m}"));
                    }
                    body = lines.join("\n");
                }
                "help" => {
                    body.push_str(
                        "\n  reload           rebuild the snapshot from the attached source",
                    );
                    body.push_str(
                        "\n  ingest (+|-) <u> <v> ...   durably append edge deltas (--wal servers)",
                    );
                }
                _ => {}
            }
            (format!("OK {}\n{body}\nEND\n", d.verb), false)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::index::query::QueryEngine;
    use crate::peel::bup::wing_bup;
    use std::sync::Arc;

    fn store() -> Arc<SnapshotStore> {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        SnapshotStore::new(QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1)))
    }

    #[test]
    fn parse_accepts_both_spellings() {
        assert_eq!(ProtoVersion::parse("v1"), Some(ProtoVersion::V1));
        assert_eq!(ProtoVersion::parse("1"), Some(ProtoVersion::V1));
        assert_eq!(ProtoVersion::parse("V2"), Some(ProtoVersion::V2));
        assert_eq!(ProtoVersion::parse("2"), Some(ProtoVersion::V2));
        assert_eq!(ProtoVersion::parse("v3"), None);
        assert_eq!(ProtoVersion::V1.number(), 1);
        assert_eq!(ProtoVersion::V2.number(), 2);
    }

    #[test]
    fn greetings_match_both_protocols() {
        let s = store();
        let snap = s.load();
        let g1 = greeting(&snap, ProtoVersion::V1);
        assert!(g1.starts_with("READY kind=wing entities="), "{g1}");
        assert!(!g1.contains("END"), "{g1}");
        let g2 = greeting(&snap, ProtoVersion::V2);
        assert!(g2.starts_with("OK hello\nproto 2 kind wing"), "{g2}");
        assert!(g2.ends_with("epoch 1\nEND"), "{g2}");
    }

    #[test]
    fn v2_frames_ok_err_and_quit() {
        let s = store();
        let snap = s.load();
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "kwing 2").unwrap();
        assert!(r.starts_with("OK kwing\ncomponents "), "{r}");
        assert!(r.ends_with("\nEND\n"), "{r}");
        assert!(!q);
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "frobnicate").unwrap();
        assert!(r.starts_with("ERR unknown command"), "{r}");
        assert!(r.ends_with("\nEND\n"), "{r}");
        assert!(!q);
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "quit").unwrap();
        assert_eq!(r, "OK quit\nEND\n");
        assert!(q);
        assert!(respond(&s, &snap, ProtoVersion::V2, "   ").is_none());
    }

    #[test]
    fn v2_stats_reports_protocol_and_epoch() {
        let s = store();
        let snap = s.load();
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "stats").unwrap();
        assert!(r.contains("\nprotocol 2\n"), "{r}");
        assert!(r.contains("\nepoch 1\n"), "{r}");
        let (h, _) = respond(&s, &snap, ProtoVersion::V2, "help").unwrap();
        assert!(h.contains("reload"), "{h}");
    }

    #[test]
    fn v2_metrics_includes_histogram_summaries() {
        let s = store();
        let snap = s.load();
        Registry::global().histogram("test.proto.lat").record(640);
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "metrics").unwrap();
        assert!(r.starts_with("OK metrics\n"), "{r}");
        assert!(r.contains("index.queries "), "{r}");
        assert!(
            r.lines().any(|l| l.starts_with("hist test.proto.lat count ")),
            "{r}"
        );
    }

    #[test]
    fn v2_reload_requires_an_updater() {
        let s = store();
        let snap = s.load();
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "reload").unwrap();
        assert!(r.starts_with("ERR reload unavailable"), "{r}");
        assert!(!q);
        s.attach_updater();
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "RELOAD").unwrap();
        assert!(r.starts_with("OK reload\n"), "{r}");
        assert!(s.take_reload_request());
    }

    #[test]
    fn v2_ingest_requires_a_wal_sink_and_validates_grammar() {
        let s = store();
        let snap = s.load();
        // no sink attached: shed with a pointer at --wal
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "ingest + 0 0").unwrap();
        assert!(r.starts_with("ERR ingest unavailable"), "{r}");
        assert!(!q);
        // attach a sink over a real wal file (paper_fig1 is 9x12)
        let tmp = crate::testkit::TempDir::new("proto-ingest").unwrap();
        let log = tmp.path().join("g.wal");
        let w = crate::wal::Writer::create(&log).unwrap();
        s.attach_ingest(super::super::updater::WalSink::new(w, 9, 12));
        // bad grammar never reaches the log
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "ingest + 0").unwrap();
        assert!(r.starts_with("ERR usage: ingest"), "{r}");
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "ingest * 0 0").unwrap();
        assert!(r.starts_with("ERR bad op sign"), "{r}");
        // out-of-universe ops are rejected before becoming durable
        let (r, _) = respond(&s, &snap, ProtoVersion::V2, "ingest + 500 0").unwrap();
        assert!(r.starts_with("ERR ingest rejected:"), "{r}");
        assert!(crate::wal::replay(&log).unwrap().records.is_empty());
        // a good batch is acked with its durable sequence number
        let (r, q) = respond(&s, &snap, ProtoVersion::V2, "ingest + 0 0 - 1 2").unwrap();
        assert_eq!(r, "OK ingest\nseq 1 ops 2\nEND\n");
        assert!(!q);
        let tail = crate::wal::replay(&log).unwrap();
        assert_eq!(tail.records.len(), 1);
        assert_eq!(
            tail.records[0].ops,
            vec![
                crate::graph::dynamic::DeltaOp::Insert(0, 0),
                crate::graph::dynamic::DeltaOp::Remove(1, 2),
            ]
        );
        // help mentions the verb
        let (h, _) = respond(&s, &snap, ProtoVersion::V2, "help").unwrap();
        assert!(h.contains("ingest"), "{h}");
    }

    #[test]
    fn v1_has_no_ingest_verb() {
        let s = store();
        let snap = s.load();
        let (r, _) = respond(&s, &snap, ProtoVersion::V1, "ingest + 0 0").unwrap();
        assert!(r.starts_with("ERR unknown command"), "{r}");
    }

    #[test]
    fn v1_is_byte_compatible_with_the_old_session_loop() {
        let s = store();
        let snap = s.load();
        let (r, q) = respond(&s, &snap, ProtoVersion::V1, "").unwrap();
        assert_eq!(r, "ERR empty command (try: help)\nEND\n");
        assert!(!q);
        let (r, q) = respond(&s, &snap, ProtoVersion::V1, "quit").unwrap();
        assert_eq!(r, "BYE\n");
        assert!(q);
        let (r, _) = respond(&s, &snap, ProtoVersion::V1, "summary").unwrap();
        assert!(r.starts_with("level "), "{r}");
        assert!(r.ends_with("\nEND\n"), "{r}");
        // v1 has no reload verb — it falls through to dispatch as unknown
        let (r, _) = respond(&s, &snap, ProtoVersion::V1, "reload").unwrap();
        assert!(r.starts_with("ERR unknown command"), "{r}");
    }
}
