//! Background snapshot updater: turns an external source of change —
//! a re-written index file, a growing delta log, or a durable WAL —
//! into freshly built [`QueryEngine`]s published through a
//! [`SnapshotStore`].
//!
//! The updater runs on its own thread and never touches live sessions:
//! it builds the replacement engine completely off to the side (full
//! codec reload, or [`crate::engine::incremental`] maintenance plus an
//! index rebuild), pre-warms the deepest level caches, and only then
//! swaps the store's slot. Readers keep answering on their pinned
//! snapshot throughout; the swap is one `Arc` store.
//!
//! Refresh triggers: a `reload` protocol command
//! ([`SnapshotStore::request_reload`]) forces a rebuild on the next
//! poll; otherwise [`SnapshotSource::IndexFile`] rebuilds when the file
//! changes on disk (length/mtime/content checksum),
//! [`SnapshotSource::DeltaLog`] rebuilds when the log has grown past
//! the ops already consumed, and [`SnapshotSource::Wal`] tails the
//! binary log from a committed byte offset, stages fresh ops in a
//! coalescing [`Pool`], and rebuilds when a batch-formation trigger
//! (size, latency deadline, or forced reload) fires.
//!
//! Outcomes are observable in the registry: `server.reloads` /
//! `server.reload_errors` / `server.log_rotated` counters, the
//! `server.reload_ns` build latency histogram, and the `ingest.*`
//! family for the WAL path. A failed reload keeps the previous
//! snapshot serving — errors shed work, never availability. Source
//! errors on unforced polls are rate-limited to one count per distinct
//! error, so a persistently garbled log is visible without flooding
//! the counter.

use super::snapshot::SnapshotStore;
use crate::beindex::BeIndex;
use crate::engine::incremental::IncrementalState;
use crate::graph::dynamic::{load_deltas, DeltaBatch, DeltaOp};
use crate::index::query::QueryEngine;
use crate::index::{build_tip_forest, build_wing_forest, codec, ForestKind};
use crate::ingest::{AdaptiveFallback, Pool};
use crate::obs::Registry;
use crate::par::Counter;
use crate::wal;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Where new snapshots come from.
pub enum SnapshotSource {
    /// A persisted index (`pbng index` output): re-loaded through
    /// [`codec::load`] whenever the file changes or a reload is forced.
    IndexFile(PathBuf),
    /// A delta log (`+ u v` / `- u v` lines, see
    /// [`crate::graph::dynamic::load_deltas`]) maintained through the
    /// incremental engine; ops beyond the consumed prefix are applied in
    /// batches of `batch` and the index is rebuilt from the maintained θ.
    DeltaLog {
        state: IncrementalState,
        path: PathBuf,
        batch: usize,
        threads: usize,
    },
    /// A durable binary write-ahead log ([`crate::wal`]): tailed from a
    /// committed byte offset (no re-parse of consumed records), staged
    /// through a coalescing [`Pool`], applied with the full-rebuild
    /// threshold steered by an [`AdaptiveFallback`] controller.
    Wal {
        state: IncrementalState,
        path: PathBuf,
        pool: Pool,
        ctl: AdaptiveFallback,
        threads: usize,
        /// Byte offset of the first unconsumed record (recovery hands
        /// the updater the position just past everything it replayed).
        start_offset: u64,
        /// Sequence number of the last record already folded into
        /// `state` (0 when starting from scratch).
        start_seq: u64,
    },
}

/// Handle to the updater thread; dropping it (or calling
/// [`Updater::stop`]) stops and joins the thread.
pub struct Updater {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// How many deepest levels to pre-materialize before publishing, so the
/// first queries after a swap don't pay the rebuild cost.
const WARM_LEVELS: usize = 2;

/// Rebuild a query engine from the incremental state's maintained θ.
/// Public so `pbng serve --watch` can build the initial snapshot from
/// the same state it hands to the updater.
pub fn engine_from_state(state: &IncrementalState, threads: usize) -> QueryEngine {
    match state.kind() {
        ForestKind::Wing => {
            let g = state.graph();
            let (idx, _) = BeIndex::build(g, threads);
            QueryEngine::new(build_wing_forest(g, &idx, state.theta(), threads))
        }
        // tip graphs are oriented peel-side-as-U; θ is per peel vertex
        kind => QueryEngine::new(build_tip_forest(state.theta(), kind)),
    }
}

/// `(len, mtime, fnv64(content))` fingerprint used to detect index-file
/// rewrites. The content checksum is what catches a same-length rewrite
/// landing within the filesystem's mtime granularity — `(len, mtime)`
/// alone missed those, leaving a stale snapshot serving indefinitely.
fn fingerprint(path: &std::path::Path) -> Option<(u64, std::time::SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    let sum = codec::fnv64(&std::fs::read(path).ok()?);
    Some((meta.len(), meta.modified().ok()?, sum))
}

/// Count a source error once per distinct message: repeating the same
/// failure on every poll would make the counter useless as a rate
/// signal, but the *first* occurrence must be visible so operators can
/// tell a wedged pipeline from a quiet one.
fn note_reload_error(errors: &Counter, msg: &str, last: &mut Option<String>) {
    if last.as_deref() == Some(msg) {
        return;
    }
    errors.add(1);
    eprintln!("pbng serve: source error (keeping snapshot): {msg}");
    *last = Some(msg.to_string());
}

/// Durable ingestion handle shared with protocol sessions: the `ingest`
/// verb appends client batches here, and the [`SnapshotSource::Wal`]
/// updater picks them up by tailing the same file. `Ok(seq)` is the
/// durability acknowledgment — the record is fsynced before it returns.
pub struct WalSink {
    writer: Mutex<wal::Writer>,
    nu: usize,
    nv: usize,
}

impl WalSink {
    pub fn new(writer: wal::Writer, nu: usize, nv: usize) -> Arc<WalSink> {
        Arc::new(WalSink {
            writer: Mutex::new(writer),
            nu,
            nv,
        })
    }

    /// `(nu, nv)` bounds enforced on submitted ops.
    pub fn universe(&self) -> (usize, usize) {
        (self.nu, self.nv)
    }

    /// Validate and durably append one client batch. Validation happens
    /// *before* the append so a bad op is never made durable — the WAL
    /// only ever holds ops the engine will accept on replay.
    pub fn submit(&self, ops: &[DeltaOp]) -> anyhow::Result<u64> {
        for &op in ops {
            let (u, v) = op.key();
            anyhow::ensure!(
                (u as usize) < self.nu && (v as usize) < self.nv,
                "op ({u}, {v}) outside universe {}x{}",
                self.nu,
                self.nv
            );
        }
        let t0 = Instant::now();
        let seq = {
            let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
            w.append(ops).map_err(anyhow::Error::new)?
        };
        let reg = Registry::global();
        reg.counter("ingest.records").add(1);
        reg.counter("ingest.ops").add(ops.len() as u64);
        reg.histogram("ingest.append_ns").record_duration(t0.elapsed());
        Ok(seq)
    }
}

impl Updater {
    /// Start polling `source` every `interval`, publishing into `store`.
    /// Marks the store as having an updater, which enables the protocol
    /// `reload` verb.
    pub fn spawn(
        mut source: SnapshotSource,
        store: Arc<SnapshotStore>,
        interval: Duration,
    ) -> Updater {
        store.attach_updater();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let reg = Registry::global();
                let reloads = reg.counter("server.reloads");
                let errors = reg.counter("server.reload_errors");
                let latency = reg.histogram("server.reload_ns");
                // baseline: the initial snapshot already reflects the
                // current source state
                let mut seen = match &source {
                    SnapshotSource::IndexFile(p) => IndexSeen::File(fingerprint(p)),
                    SnapshotSource::DeltaLog { path, .. } => IndexSeen::Ops {
                        consumed: load_deltas(path).map(|o| o.len()).unwrap_or(0),
                        last_error: None,
                    },
                    SnapshotSource::Wal {
                        start_offset,
                        start_seq,
                        ..
                    } => IndexSeen::Wal {
                        offset: *start_offset,
                        next_seq: *start_seq + 1,
                        last_error: None,
                    },
                };
                // ORDERING: Acquire pairs with the Release store in
                // `shutdown`, giving the loop a clean exit hand-off.
                while !stop.load(Ordering::Acquire) {
                    let forced = store.take_reload_request();
                    let t0 = Instant::now();
                    match refresh(&mut source, &mut seen, forced) {
                        Ok(None) => {}
                        Ok(Some(engine)) => {
                            engine.warm_deepest(WARM_LEVELS);
                            let epoch = store.publish(engine);
                            reloads.add(1);
                            latency.record_duration(t0.elapsed());
                            eprintln!("pbng serve: published snapshot epoch {epoch}");
                        }
                        Err(e) => {
                            errors.add(1);
                            eprintln!("pbng serve: reload failed (keeping snapshot): {e:#}");
                        }
                    }
                    std::thread::sleep(interval);
                }
            })
        };
        Updater {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop and join the updater thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // ORDERING: Release pairs with the Acquire load in the poll
        // loop; the join below is the full synchronization point.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for Updater {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// What the updater last saw in its source.
enum IndexSeen {
    File(Option<(u64, std::time::SystemTime, u64)>),
    Ops {
        consumed: usize,
        last_error: Option<String>,
    },
    Wal {
        /// Byte offset of the first unconsumed record.
        offset: u64,
        /// Sequence number the next fresh record must carry.
        next_seq: u64,
        last_error: Option<String>,
    },
}

/// Check the source once; `Ok(Some)` is a freshly built engine to
/// publish, `Ok(None)` means no change (and no forced reload).
fn refresh(
    source: &mut SnapshotSource,
    seen: &mut IndexSeen,
    forced: bool,
) -> anyhow::Result<Option<QueryEngine>> {
    match (source, seen) {
        (SnapshotSource::IndexFile(path), IndexSeen::File(last)) => {
            let now = fingerprint(path);
            let changed = now.is_some() && now != *last;
            if !(forced || changed) {
                return Ok(None);
            }
            let forest = codec::load(path)?;
            *last = now;
            Ok(Some(QueryEngine::new(forest)))
        }
        (
            SnapshotSource::DeltaLog {
                state,
                path,
                batch,
                threads,
            },
            IndexSeen::Ops {
                consumed,
                last_error,
            },
        ) => {
            let ops = match load_deltas(path) {
                Ok(ops) => {
                    *last_error = None;
                    ops
                }
                // a missing/garbled log is fatal only when the client
                // explicitly asked for a reload; otherwise surface it
                // (once per distinct error) and keep serving
                Err(e) if forced => return Err(e),
                Err(e) => {
                    note_reload_error(
                        &Registry::global().counter("server.reload_errors"),
                        &format!("{e:#}"),
                        last_error,
                    );
                    return Ok(None);
                }
            };
            if ops.len() < *consumed {
                // the log shrank under us (truncated or rotated):
                // re-sync to its new length instead of slicing out of
                // bounds on `ops[*consumed..]`
                Registry::global().counter("server.log_rotated").add(1);
                eprintln!(
                    "pbng serve: delta log truncated/rotated ({} ops on disk, {} consumed); re-syncing",
                    ops.len(),
                    *consumed
                );
                *consumed = ops.len();
            }
            let fresh = ops.len() - *consumed;
            if fresh == 0 && !forced {
                return Ok(None);
            }
            let chunk = (*batch).max(1);
            for ops in ops[*consumed..].chunks(chunk) {
                state.apply(&DeltaBatch::new(ops.to_vec()));
            }
            *consumed = ops.len();
            Ok(Some(engine_from_state(state, *threads)))
        }
        (
            SnapshotSource::Wal {
                state,
                path,
                pool,
                ctl,
                threads,
                ..
            },
            IndexSeen::Wal {
                offset,
                next_seq,
                last_error,
            },
        ) => {
            let reg = Registry::global();
            let now = Instant::now();
            match wal::read_from(path, *offset) {
                Ok(tail) => {
                    // records at or below the applied sequence are
                    // replayed history (post-rotation catch-up); the
                    // rest must continue the numbering exactly — a gap
                    // means records were lost and replaying past it
                    // would silently diverge θ
                    let mut fresh: Vec<DeltaOp> = Vec::new();
                    let mut expect = *next_seq;
                    let mut stale = 0u64;
                    let mut gap = None;
                    for rec in &tail.records {
                        if rec.seq < expect {
                            stale += 1;
                            continue;
                        }
                        if rec.seq != expect {
                            gap = Some((rec.seq, expect));
                            break;
                        }
                        fresh.extend_from_slice(&rec.ops);
                        expect += 1;
                    }
                    if let Some((got, want)) = gap {
                        let msg =
                            format!("wal sequence gap: found record {got} where {want} expected");
                        if forced {
                            anyhow::bail!(msg);
                        }
                        note_reload_error(
                            &reg.counter("server.reload_errors"),
                            &msg,
                            last_error,
                        );
                        // do not advance: the next poll re-examines the
                        // same region, so nothing is skipped silently
                        return Ok(None);
                    }
                    if stale > 0 {
                        reg.counter("ingest.stale_records").add(stale);
                    }
                    // the WAL is validated on append, but a foreign log
                    // could carry out-of-universe ops; the engine would
                    // assert on them, so shed instead
                    let (nu, nv) = state.universe();
                    let mut rejected = 0u64;
                    for op in fresh {
                        let (u, v) = op.key();
                        if (u as usize) < nu && (v as usize) < nv {
                            pool.push(op, now);
                        } else {
                            rejected += 1;
                        }
                    }
                    if rejected > 0 {
                        reg.counter("ingest.rejected").add(rejected);
                        eprintln!(
                            "pbng serve: dropped {rejected} wal op(s) outside universe {nu}x{nv}"
                        );
                    }
                    *offset = tail.end_offset;
                    *next_seq = expect;
                    *last_error = None;
                }
                Err(wal::WalError::Rotated { offset: at, len }) => {
                    // compacted/replaced under us: restart from the
                    // head; already-applied records are skipped by
                    // sequence number on the next poll
                    reg.counter("server.log_rotated").add(1);
                    eprintln!(
                        "pbng serve: wal rotated (offset {at} past length {len}); re-reading from head"
                    );
                    *offset = wal::HEADER_LEN;
                    *last_error = None;
                }
                Err(e) => {
                    let msg = e.to_string();
                    if forced {
                        return Err(anyhow::Error::new(e));
                    }
                    note_reload_error(&reg.counter("server.reload_errors"), &msg, last_error);
                    return Ok(None);
                }
            }
            match pool.take_ready(now, forced) {
                Some((batches, lag)) => {
                    reg.histogram("ingest.lag_ns").record_duration(lag);
                    let batch_ops = reg.histogram("ingest.batch_ops");
                    let batches_ctr = reg.counter("ingest.batches");
                    let rebuilds = reg.counter("ingest.full_rebuilds");
                    for b in &batches {
                        batch_ops.record(b.ops.len() as u64);
                        let up = state.apply(b);
                        let t = ctl.observe(&up);
                        state.set_fallback_fraction(t);
                        batches_ctr.add(1);
                        if up.full_rebuild {
                            rebuilds.add(1);
                        }
                    }
                    let st = pool.stats();
                    reg.counter("ingest.staged").set(st.staged);
                    reg.counter("ingest.coalesced").set(st.coalesced);
                    reg.counter("ingest.cancelled").set(st.cancelled);
                    Ok(Some(engine_from_state(state, *threads)))
                }
                // a forced reload always republishes, even with nothing
                // staged (parity with the other sources)
                None if forced => Ok(Some(engine_from_state(state, *threads))),
                None => Ok(None),
            }
        }
        _ => unreachable!("seen state always matches the source variant"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::incremental::IncrementalConfig;
    use crate::graph::gen;
    use crate::ingest::PoolConfig;
    use crate::peel::bup::wing_bup;
    use crate::testkit::TempDir;
    use std::io::Write as _;

    fn engine_for(g: &crate::graph::BipartiteGraph) -> QueryEngine {
        let (idx, _) = BeIndex::build(g, 1);
        let theta = wing_bup(g).theta;
        QueryEngine::new(build_wing_forest(g, &idx, &theta, 1))
    }

    fn wait_for_epoch(store: &SnapshotStore, want: u64) {
        let deadline = Instant::now() + Duration::from_secs(30);
        while store.epoch() < want {
            assert!(Instant::now() < deadline, "epoch never reached {want}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn index_file_source_reloads_on_request() {
        let tmp = TempDir::new("serve-updater-idx").unwrap();
        let path = tmp.path().join("g.idx");
        let g1 = gen::zipf(20, 20, 110, 1.2, 1.2, 5);
        let (idx1, _) = BeIndex::build(&g1, 1);
        let t1 = wing_bup(&g1).theta;
        codec::save(&build_wing_forest(&g1, &idx1, &t1, 1), &path).unwrap();
        let store = SnapshotStore::new(engine_for(&g1));
        assert!(!store.has_updater());
        let upd = Updater::spawn(
            SnapshotSource::IndexFile(path.clone()),
            store.clone(),
            Duration::from_millis(5),
        );
        assert!(store.has_updater());
        // overwrite the index with a different graph, then force a reload
        let g2 = gen::zipf(22, 18, 120, 1.3, 1.1, 9);
        let (idx2, _) = BeIndex::build(&g2, 1);
        let t2 = wing_bup(&g2).theta;
        codec::save(&build_wing_forest(&g2, &idx2, &t2, 1), &path).unwrap();
        store.request_reload();
        wait_for_epoch(&store, 2);
        let snap = store.load();
        assert_eq!(
            snap.engine.forest().n_entities(),
            g2.m(),
            "new epoch serves the rewritten index"
        );
        upd.stop();
    }

    #[test]
    fn delta_log_source_applies_new_ops_and_republishes() {
        let tmp = TempDir::new("serve-updater-log").unwrap();
        let log = tmp.path().join("deltas.txt");
        std::fs::write(&log, "").unwrap();
        let g = gen::zipf(16, 14, 80, 1.2, 1.2, 3);
        let state = IncrementalState::new(&g, ForestKind::Wing, IncrementalConfig::default());
        let store = SnapshotStore::new(engine_for(&g));
        let upd = Updater::spawn(
            SnapshotSource::DeltaLog {
                state,
                path: log.clone(),
                batch: 4,
                threads: 1,
            },
            store.clone(),
            Duration::from_millis(5),
        );
        // grow the log: the updater should pick it up without a reload
        // command and publish a snapshot matching a from-scratch build
        std::fs::write(&log, "+ 0 0\n+ 1 13\n+ 2 11\n").unwrap();
        wait_for_epoch(&store, 2);
        // GraphBuilder dedups, so edges already present in g are harmless
        let g2 = crate::graph::GraphBuilder::new()
            .nu(g.nu())
            .nv(g.nv())
            .edges(g.edges())
            .edges(&[(0, 0), (1, 13), (2, 11)])
            .build();
        let snap = store.load();
        let fresh = engine_for(&g2);
        assert_eq!(
            crate::index::server::dispatch(&snap.engine, "summary").body.unwrap(),
            crate::index::server::dispatch(&fresh, "summary").body.unwrap(),
            "incrementally republished snapshot answers like a fresh build"
        );
        upd.stop();
    }

    #[test]
    fn failed_reload_keeps_the_old_snapshot() {
        let tmp = TempDir::new("serve-updater-bad").unwrap();
        let path = tmp.path().join("missing.idx");
        let g = gen::zipf(12, 12, 60, 1.2, 1.2, 2);
        let store = SnapshotStore::new(engine_for(&g));
        let errors = Registry::global().counter("server.reload_errors");
        let before = errors.get();
        let upd = Updater::spawn(
            SnapshotSource::IndexFile(path),
            store.clone(),
            Duration::from_millis(5),
        );
        store.request_reload();
        let deadline = Instant::now() + Duration::from_secs(30);
        while errors.get() == before {
            assert!(Instant::now() < deadline, "reload error never counted");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(store.epoch(), 1, "failed reload must not publish");
        upd.stop();
    }

    // --- regression: the three watch-path bugs this PR fixes ---

    #[test]
    fn truncated_delta_log_no_longer_panics_on_forced_reload() {
        let tmp = TempDir::new("serve-updater-trunc").unwrap();
        let log = tmp.path().join("deltas.txt");
        std::fs::write(&log, "+ 0 0\n").unwrap();
        let g = gen::zipf(10, 10, 40, 1.2, 1.2, 4);
        let mut source = SnapshotSource::DeltaLog {
            state: IncrementalState::new(&g, ForestKind::Wing, IncrementalConfig::default()),
            path: log,
            batch: 4,
            threads: 1,
        };
        // pretend a longer incarnation of the log had already been
        // consumed, then the file was truncated/rotated under us —
        // this used to slice `ops[5..]` out of a 1-op vec and panic
        let mut seen = IndexSeen::Ops {
            consumed: 5,
            last_error: None,
        };
        let rotated = Registry::global().counter("server.log_rotated");
        let before = rotated.get();
        let out = refresh(&mut source, &mut seen, true).unwrap();
        assert!(out.is_some(), "forced reload publishes after re-sync");
        assert!(rotated.get() > before, "rotation is a counted event");
        match &seen {
            IndexSeen::Ops { consumed, .. } => assert_eq!(*consumed, 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn garbled_delta_log_is_counted_not_silently_swallowed() {
        let tmp = TempDir::new("serve-updater-garbled").unwrap();
        let log = tmp.path().join("deltas.txt");
        std::fs::write(&log, "+ 0 0\nthis is not a delta\n").unwrap();
        let g = gen::zipf(10, 10, 40, 1.2, 1.2, 4);
        let mut source = SnapshotSource::DeltaLog {
            state: IncrementalState::new(&g, ForestKind::Wing, IncrementalConfig::default()),
            path: log,
            batch: 4,
            threads: 1,
        };
        let mut seen = IndexSeen::Ops {
            consumed: 0,
            last_error: None,
        };
        let errors = Registry::global().counter("server.reload_errors");
        let before = errors.get();
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        let first = match &seen {
            IndexSeen::Ops { last_error, .. } => {
                last_error.clone().expect("error recorded for rate-limiting")
            }
            _ => unreachable!(),
        };
        assert!(errors.get() >= before + 1, "garbled log increments the counter");
        // same error again: still Ok(None), error string unchanged (the
        // count is rate-limited per distinct message)
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        match &seen {
            IndexSeen::Ops { last_error, .. } => {
                assert_eq!(last_error.as_deref(), Some(first.as_str()))
            }
            _ => unreachable!(),
        }
        // forced reload surfaces it as a hard error
        assert!(refresh(&mut source, &mut seen, true).is_err());
    }

    #[test]
    fn note_reload_error_rate_limits_per_distinct_error() {
        // a test-only counter name keeps this deterministic under
        // parallel tests (nothing else touches it)
        let c = Registry::global().counter("test.updater.note_rate_limit");
        let mut last = None;
        note_reload_error(&c, "boom", &mut last);
        assert_eq!(c.get(), 1);
        note_reload_error(&c, "boom", &mut last);
        assert_eq!(c.get(), 1, "repeat of the same error is not re-counted");
        note_reload_error(&c, "other", &mut last);
        assert_eq!(c.get(), 2, "a distinct error is counted");
        note_reload_error(&c, "boom", &mut last);
        assert_eq!(c.get(), 3, "alternating errors are each distinct");
    }

    #[test]
    fn fingerprint_detects_same_length_rewrites() {
        let tmp = TempDir::new("serve-updater-fp").unwrap();
        let p = tmp.path().join("f.bin");
        std::fs::write(&p, b"aaaa").unwrap();
        let f1 = fingerprint(&p).unwrap();
        std::fs::write(&p, b"aaab").unwrap();
        let f2 = fingerprint(&p).unwrap();
        assert_eq!(f1.0, f2.0, "lengths agree by construction");
        assert_ne!(
            f1.2, f2.2,
            "content checksum distinguishes same-length rewrites even when mtime does not"
        );
    }

    // --- the WAL source ---

    #[test]
    fn wal_source_tails_batches_and_survives_torn_tail_and_rotation() {
        let tmp = TempDir::new("serve-updater-wal").unwrap();
        let log = tmp.path().join("g.wal");
        let g = gen::zipf(16, 14, 80, 1.2, 1.2, 3);
        let mut w = wal::Writer::create(&log).unwrap();
        let state = IncrementalState::new(&g, ForestKind::Wing, IncrementalConfig::default());
        let start_offset = w.end_offset();
        let mut source = SnapshotSource::Wal {
            state,
            path: log.clone(),
            pool: Pool::new(PoolConfig {
                max_batch: 4,
                max_delay: Duration::ZERO, // drain whenever non-empty
            }),
            ctl: AdaptiveFallback::new(0.25),
            threads: 1,
            start_offset,
            start_seq: 0,
        };
        let mut seen = IndexSeen::Wal {
            offset: start_offset,
            next_seq: 1,
            last_error: None,
        };
        // empty log: nothing to publish
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        // two records; one op is outside the universe and must be shed
        // before it reaches the engine (which would assert)
        w.append(&[DeltaOp::Insert(0, 0), DeltaOp::Insert(1, 13)]).unwrap();
        w.append(&[DeltaOp::Insert(2, 11), DeltaOp::Insert(500, 1)]).unwrap();
        let eng = refresh(&mut source, &mut seen, false)
            .unwrap()
            .expect("deadline-zero pool publishes");
        let g2 = crate::graph::GraphBuilder::new()
            .nu(g.nu())
            .nv(g.nv())
            .edges(g.edges())
            .edges(&[(0, 0), (1, 13), (2, 11)])
            .build();
        assert_eq!(
            crate::index::server::dispatch(&eng, "summary").body.unwrap(),
            crate::index::server::dispatch(&engine_for(&g2), "summary").body.unwrap(),
            "wal-maintained snapshot answers like a fresh build"
        );
        // offset committed: an immediate re-poll is a no-op
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        // a torn append (crash mid-write) is ignored until completed
        let mut f = std::fs::OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(&[13, 0]).unwrap();
        drop(f);
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        match &seen {
            IndexSeen::Wal { next_seq, .. } => assert_eq!(*next_seq, 3),
            _ => unreachable!(),
        }
        // compaction rotates the file under the tailing reader: counted,
        // offset resets, and subsequent polls stay healthy
        let rotated = Registry::global().counter("server.log_rotated");
        let before = rotated.get();
        wal::compact(&log, 2).unwrap();
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        assert!(rotated.get() > before, "wal rotation is a counted event");
        match &seen {
            IndexSeen::Wal { offset, .. } => assert_eq!(*offset, wal::HEADER_LEN),
            _ => unreachable!(),
        }
        assert!(refresh(&mut source, &mut seen, false).unwrap().is_none());
        // forced reload with nothing staged still republishes
        assert!(refresh(&mut source, &mut seen, true).unwrap().is_some());
    }

    #[test]
    fn wal_sink_validates_before_making_ops_durable() {
        let tmp = TempDir::new("serve-walsink").unwrap();
        let log = tmp.path().join("g.wal");
        let w = wal::Writer::create(&log).unwrap();
        let sink = WalSink::new(w, 10, 10);
        assert_eq!(sink.universe(), (10, 10));
        let err = sink
            .submit(&[DeltaOp::Insert(1, 1), DeltaOp::Insert(100, 0)])
            .unwrap_err();
        assert!(err.to_string().contains("outside universe"), "{err}");
        let seq = sink.submit(&[DeltaOp::Insert(1, 2)]).unwrap();
        assert_eq!(seq, 1, "rejected batch burned no sequence number");
        let tail = wal::replay(&log).unwrap();
        assert_eq!(tail.records.len(), 1, "rejected batch never hit the disk");
        assert_eq!(tail.records[0].ops, vec![DeltaOp::Insert(1, 2)]);
    }
}
