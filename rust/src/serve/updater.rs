//! Background snapshot updater: turns an external source of change —
//! a re-written index file or a growing delta log — into freshly built
//! [`QueryEngine`]s published through a [`SnapshotStore`].
//!
//! The updater runs on its own thread and never touches live sessions:
//! it builds the replacement engine completely off to the side (full
//! codec reload, or [`crate::engine::incremental`] maintenance plus an
//! index rebuild), pre-warms the deepest level caches, and only then
//! swaps the store's slot. Readers keep answering on their pinned
//! snapshot throughout; the swap is one `Arc` store.
//!
//! Refresh triggers: a `reload` protocol command
//! ([`SnapshotStore::request_reload`]) forces a rebuild on the next
//! poll; otherwise [`SnapshotSource::IndexFile`] rebuilds when the file
//! changes on disk (length/mtime) and [`SnapshotSource::DeltaLog`]
//! rebuilds when the log has grown past the ops already consumed.
//!
//! Outcomes are observable in the registry: `server.reloads` /
//! `server.reload_errors` counters and the `server.reload_ns` build
//! latency histogram. A failed reload keeps the previous snapshot
//! serving — errors shed work, never availability.

use super::snapshot::SnapshotStore;
use crate::beindex::BeIndex;
use crate::engine::incremental::IncrementalState;
use crate::graph::dynamic::{load_deltas, DeltaBatch};
use crate::index::query::QueryEngine;
use crate::index::{build_tip_forest, build_wing_forest, codec, ForestKind};
use crate::obs::Registry;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Where new snapshots come from.
pub enum SnapshotSource {
    /// A persisted index (`pbng index` output): re-loaded through
    /// [`codec::load`] whenever the file changes or a reload is forced.
    IndexFile(PathBuf),
    /// A delta log (`+ u v` / `- u v` lines, see
    /// [`crate::graph::dynamic::load_deltas`]) maintained through the
    /// incremental engine; ops beyond the consumed prefix are applied in
    /// batches of `batch` and the index is rebuilt from the maintained θ.
    DeltaLog {
        state: IncrementalState,
        path: PathBuf,
        batch: usize,
        threads: usize,
    },
}

/// Handle to the updater thread; dropping it (or calling
/// [`Updater::stop`]) stops and joins the thread.
pub struct Updater {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// How many deepest levels to pre-materialize before publishing, so the
/// first queries after a swap don't pay the rebuild cost.
const WARM_LEVELS: usize = 2;

/// Rebuild a query engine from the incremental state's maintained θ.
/// Public so `pbng serve --watch` can build the initial snapshot from
/// the same state it hands to the updater.
pub fn engine_from_state(state: &IncrementalState, threads: usize) -> QueryEngine {
    match state.kind() {
        ForestKind::Wing => {
            let g = state.graph();
            let (idx, _) = BeIndex::build(g, threads);
            QueryEngine::new(build_wing_forest(g, &idx, state.theta(), threads))
        }
        // tip graphs are oriented peel-side-as-U; θ is per peel vertex
        kind => QueryEngine::new(build_tip_forest(state.theta(), kind)),
    }
}

/// `(len, mtime)` fingerprint used to detect index-file rewrites.
fn fingerprint(path: &std::path::Path) -> Option<(u64, std::time::SystemTime)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.len(), meta.modified().ok()?))
}

impl Updater {
    /// Start polling `source` every `interval`, publishing into `store`.
    /// Marks the store as having an updater, which enables the protocol
    /// `reload` verb.
    pub fn spawn(
        mut source: SnapshotSource,
        store: Arc<SnapshotStore>,
        interval: Duration,
    ) -> Updater {
        store.attach_updater();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let reg = Registry::global();
                let reloads = reg.counter("server.reloads");
                let errors = reg.counter("server.reload_errors");
                let latency = reg.histogram("server.reload_ns");
                // baseline: the initial snapshot already reflects the
                // current file state
                let mut seen = match &source {
                    SnapshotSource::IndexFile(p) => IndexSeen::File(fingerprint(p)),
                    SnapshotSource::DeltaLog { path, .. } => {
                        IndexSeen::Ops(load_deltas(path).map(|o| o.len()).unwrap_or(0))
                    }
                };
                // ORDERING: Acquire pairs with the Release store in
                // `shutdown`, giving the loop a clean exit hand-off.
                while !stop.load(Ordering::Acquire) {
                    let forced = store.take_reload_request();
                    let t0 = Instant::now();
                    match refresh(&mut source, &mut seen, forced) {
                        Ok(None) => {}
                        Ok(Some(engine)) => {
                            engine.warm_deepest(WARM_LEVELS);
                            let epoch = store.publish(engine);
                            reloads.add(1);
                            latency.record_duration(t0.elapsed());
                            eprintln!("pbng serve: published snapshot epoch {epoch}");
                        }
                        Err(e) => {
                            errors.add(1);
                            eprintln!("pbng serve: reload failed (keeping snapshot): {e:#}");
                        }
                    }
                    std::thread::sleep(interval);
                }
            })
        };
        Updater {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop and join the updater thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // ORDERING: Release pairs with the Acquire load in the poll
        // loop; the join below is the full synchronization point.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for Updater {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// What the updater last saw in its source.
enum IndexSeen {
    File(Option<(u64, std::time::SystemTime)>),
    Ops(usize),
}

/// Check the source once; `Ok(Some)` is a freshly built engine to
/// publish, `Ok(None)` means no change (and no forced reload).
fn refresh(
    source: &mut SnapshotSource,
    seen: &mut IndexSeen,
    forced: bool,
) -> anyhow::Result<Option<QueryEngine>> {
    match (source, seen) {
        (SnapshotSource::IndexFile(path), IndexSeen::File(last)) => {
            let now = fingerprint(path);
            let changed = now.is_some() && now != *last;
            if !(forced || changed) {
                return Ok(None);
            }
            let forest = codec::load(path)?;
            *last = now;
            Ok(Some(QueryEngine::new(forest)))
        }
        (
            SnapshotSource::DeltaLog {
                state,
                path,
                batch,
                threads,
            },
            IndexSeen::Ops(consumed),
        ) => {
            let ops = match load_deltas(path) {
                Ok(ops) => ops,
                // a missing/garbled log is only an error when the client
                // explicitly asked for a reload; otherwise keep waiting
                Err(e) if forced => return Err(e),
                Err(_) => return Ok(None),
            };
            let fresh = ops.len().saturating_sub(*consumed);
            if fresh == 0 && !forced {
                return Ok(None);
            }
            let chunk = (*batch).max(1);
            for ops in ops[*consumed..].chunks(chunk) {
                state.apply(&DeltaBatch::new(ops.to_vec()));
            }
            *consumed = ops.len();
            Ok(Some(engine_from_state(state, *threads)))
        }
        _ => unreachable!("seen state always matches the source variant"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::incremental::IncrementalConfig;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::testkit::TempDir;

    fn engine_for(g: &crate::graph::BipartiteGraph) -> QueryEngine {
        let (idx, _) = BeIndex::build(g, 1);
        let theta = wing_bup(g).theta;
        QueryEngine::new(build_wing_forest(g, &idx, &theta, 1))
    }

    fn wait_for_epoch(store: &SnapshotStore, want: u64) {
        let deadline = Instant::now() + Duration::from_secs(30);
        while store.epoch() < want {
            assert!(Instant::now() < deadline, "epoch never reached {want}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn index_file_source_reloads_on_request() {
        let tmp = TempDir::new("serve-updater-idx");
        let path = tmp.path().join("g.idx");
        let g1 = gen::zipf(20, 20, 110, 1.2, 1.2, 5);
        let (idx1, _) = BeIndex::build(&g1, 1);
        let t1 = wing_bup(&g1).theta;
        codec::save(&build_wing_forest(&g1, &idx1, &t1, 1), &path).unwrap();
        let store = SnapshotStore::new(engine_for(&g1));
        assert!(!store.has_updater());
        let upd = Updater::spawn(
            SnapshotSource::IndexFile(path.clone()),
            store.clone(),
            Duration::from_millis(5),
        );
        assert!(store.has_updater());
        // overwrite the index with a different graph, then force a reload
        let g2 = gen::zipf(22, 18, 120, 1.3, 1.1, 9);
        let (idx2, _) = BeIndex::build(&g2, 1);
        let t2 = wing_bup(&g2).theta;
        codec::save(&build_wing_forest(&g2, &idx2, &t2, 1), &path).unwrap();
        store.request_reload();
        wait_for_epoch(&store, 2);
        let snap = store.load();
        assert_eq!(
            snap.engine.forest().n_entities(),
            g2.m(),
            "new epoch serves the rewritten index"
        );
        upd.stop();
    }

    #[test]
    fn delta_log_source_applies_new_ops_and_republishes() {
        let tmp = TempDir::new("serve-updater-log");
        let log = tmp.path().join("deltas.txt");
        std::fs::write(&log, "").unwrap();
        let g = gen::zipf(16, 14, 80, 1.2, 1.2, 3);
        let state = IncrementalState::new(&g, ForestKind::Wing, IncrementalConfig::default());
        let store = SnapshotStore::new(engine_for(&g));
        let upd = Updater::spawn(
            SnapshotSource::DeltaLog {
                state,
                path: log.clone(),
                batch: 4,
                threads: 1,
            },
            store.clone(),
            Duration::from_millis(5),
        );
        // grow the log: the updater should pick it up without a reload
        // command and publish a snapshot matching a from-scratch build
        std::fs::write(&log, "+ 0 0\n+ 1 13\n+ 2 11\n").unwrap();
        wait_for_epoch(&store, 2);
        // GraphBuilder dedups, so edges already present in g are harmless
        let g2 = crate::graph::GraphBuilder::new()
            .nu(g.nu())
            .nv(g.nv())
            .edges(g.edges())
            .edges(&[(0, 0), (1, 13), (2, 11)])
            .build();
        let snap = store.load();
        let fresh = engine_for(&g2);
        assert_eq!(
            crate::index::server::dispatch(&snap.engine, "summary").body.unwrap(),
            crate::index::server::dispatch(&fresh, "summary").body.unwrap(),
            "incrementally republished snapshot answers like a fresh build"
        );
        upd.stop();
    }

    #[test]
    fn failed_reload_keeps_the_old_snapshot() {
        let tmp = TempDir::new("serve-updater-bad");
        let path = tmp.path().join("missing.idx");
        let g = gen::zipf(12, 12, 60, 1.2, 1.2, 2);
        let store = SnapshotStore::new(engine_for(&g));
        let errors = Registry::global().counter("server.reload_errors");
        let before = errors.get();
        let upd = Updater::spawn(
            SnapshotSource::IndexFile(path),
            store.clone(),
            Duration::from_millis(5),
        );
        store.request_reload();
        let deadline = Instant::now() + Duration::from_secs(30);
        while errors.get() == before {
            assert!(Instant::now() < deadline, "reload error never counted");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(store.epoch(), 1, "failed reload must not publish");
        upd.stop();
    }
}
