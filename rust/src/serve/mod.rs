//! Async serving layer: poll-based reactor, admission control, and
//! hot-swappable index snapshots.
//!
//! The thread-per-connection loops in [`crate::index::server`] scale to
//! tens of sessions, not millions: every connection pins a thread, and
//! the single immutable [`QueryEngine`] means any re-peel requires a
//! restart. This module replaces them with:
//!
//! * **A single-threaded poll-based reactor** ([`reactor`]) — a
//!   non-blocking `TcpListener` plus per-connection read/write buffers
//!   and line framing, driven by a small readiness loop (no `libc`, no
//!   new dependencies). One thread serves every session.
//! * **Admission control** — global ([`ServerConfig::max_conns`]) and
//!   per-IP ([`ServerConfig::per_ip`]) connection caps with graceful
//!   `ERR busy` shedding (counted in `server.rejected`), idle timeouts
//!   (`server.idle_closed`), and a bounded line length.
//! * **MVCC snapshot serving** ([`snapshot`]) — queries run against an
//!   immutable `Arc<QueryEngine>` loaded from an atomically swappable
//!   slot; a background [`updater`] drains a delta file through
//!   [`crate::engine::incremental`] (or re-reads a persisted index on
//!   `reload`) and publishes a new epoch. Readers never block on
//!   writes: a session pins its snapshot at accept time, in-flight
//!   queries on the old `Arc` complete untouched, and new sessions see
//!   the new epoch.
//! * **Protocol v2** ([`proto`]) — every reply starts `OK <verb>` or
//!   `ERR <reason>` and ends `END`; `stats` reports `protocol 2` and
//!   the snapshot epoch. Protocol v1 stays available for one release
//!   behind [`ServerConfig::proto`] (`--proto v1` on the CLI).
//!
//! # Quick start
//!
//! ```no_run
//! use pbng::serve::{Server, ServerConfig, SnapshotStore};
//! # let forest = pbng::index::codec::load(std::path::Path::new("g.idx")).unwrap();
//! let store = SnapshotStore::new(pbng::index::query::QueryEngine::new(forest));
//! let cfg = ServerConfig::new()
//!     .addr("127.0.0.1:7878")
//!     .max_conns(1024)
//!     .per_ip(32)
//!     .idle_timeout(std::time::Duration::from_secs(300));
//! Server::new(cfg, store).run().unwrap();
//! ```
//!
//! The old free functions (`serve_stdin` / `serve_tcp` /
//! `serve_listener`) remain as deprecated thin wrappers over protocol
//! v1 for one release.

pub mod proto;
pub mod reactor;
pub mod snapshot;
pub mod updater;

pub use proto::ProtoVersion;
pub use snapshot::{Snapshot, SnapshotStore};
pub use updater::{SnapshotSource, Updater, WalSink};

use crate::index::query::QueryEngine;
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

/// Builder-style server configuration: bind address, admission-control
/// limits, timeouts, and the wire protocol version. Snapshot *sources*
/// are configured separately (see [`SnapshotStore`] / [`Updater`]) so
/// one store can outlive many listener configurations.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub(crate) addr: Option<String>,
    pub(crate) max_conns: usize,
    pub(crate) per_ip: usize,
    pub(crate) idle_timeout: Duration,
    pub(crate) poll_interval: Duration,
    pub(crate) max_line: usize,
    pub(crate) proto: ProtoVersion,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: None,
            max_conns: 1024,
            per_ip: 32,
            idle_timeout: Duration::from_secs(300),
            poll_interval: Duration::from_millis(1),
            max_line: 64 * 1024,
            proto: ProtoVersion::V2,
        }
    }
}

impl ServerConfig {
    pub fn new() -> ServerConfig {
        ServerConfig::default()
    }

    /// TCP bind address (e.g. `127.0.0.1:7878`; port `0` picks an
    /// ephemeral port). Without an address, [`Server::run`] serves one
    /// blocking session over stdin/stdout.
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.addr = Some(addr.into());
        self
    }

    /// Global connection cap: connection `n+1` is shed with `ERR busy`
    /// and counted in `server.rejected`. 0 disables the cap.
    pub fn max_conns(mut self, n: usize) -> Self {
        self.max_conns = n;
        self
    }

    /// Per-IP connection cap (muta-style "limit connections from same
    /// ip"); shed the same way as the global cap. 0 disables the cap.
    pub fn per_ip(mut self, n: usize) -> Self {
        self.per_ip = n;
        self
    }

    /// Close connections with no complete command for this long
    /// (counted in `server.idle_closed`). Zero disables the timeout.
    pub fn idle_timeout(mut self, d: Duration) -> Self {
        self.idle_timeout = d;
        self
    }

    /// How long the reactor parks when no connection made progress.
    pub fn poll_interval(mut self, d: Duration) -> Self {
        self.poll_interval = d;
        self
    }

    /// Reject (and close) connections that send a line longer than this
    /// many bytes without a newline.
    pub fn max_line(mut self, n: usize) -> Self {
        self.max_line = n.max(1);
        self
    }

    /// Wire protocol version served to every session (default v2).
    pub fn proto(mut self, p: ProtoVersion) -> Self {
        self.proto = p;
        self
    }
}

/// A configured server over a snapshot store. [`Server::run`] blocks on
/// the reactor (or the stdin session); [`Server::stop_handle`] lets
/// another thread request a graceful exit.
pub struct Server {
    cfg: ServerConfig,
    store: Arc<SnapshotStore>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(cfg: ServerConfig, store: Arc<SnapshotStore>) -> Server {
        Server {
            cfg,
            store,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Flag checked once per reactor iteration; setting it makes
    /// [`Server::run`] return after the current sweep.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Bind the configured address and serve until stopped (TCP), or
    /// serve one session over stdin/stdout when no address is set.
    /// Prints `LISTENING <addr>` on stdout once the socket is bound so
    /// scripts can discover ephemeral ports.
    pub fn run(self) -> std::io::Result<()> {
        match self.cfg.addr.clone() {
            Some(addr) => {
                let listener = TcpListener::bind(&addr)?;
                self.run_on(listener)
            }
            None => self.run_stdin(),
        }
    }

    /// Serve an already-bound listener (tests and embedders pick their
    /// own ephemeral ports).
    pub fn run_on(self, listener: TcpListener) -> std::io::Result<()> {
        let local = listener.local_addr()?;
        println!("LISTENING {local}");
        std::io::stdout().flush().ok();
        reactor::run(&self.cfg, &self.store, listener, &self.stop)
    }

    /// One blocking session over stdin/stdout (the `pbng serve` default
    /// without `--port`), speaking the configured protocol version. The
    /// snapshot is pinned at session start, like any other session.
    fn run_stdin(self) -> std::io::Result<()> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        let snap = self.store.load();
        crate::obs::Registry::global().counter("server.connections").add(1);
        writeln!(out, "{}", proto::greeting(&snap, self.cfg.proto))?;
        out.flush()?;
        for line in stdin.lock().lines() {
            let line = line?;
            match proto::respond(&self.store, &snap, self.cfg.proto, &line) {
                None => continue,
                Some((reply, quit)) => {
                    write!(out, "{reply}")?;
                    out.flush()?;
                    if quit {
                        break;
                    }
                }
            }
        }
        Ok(())
    }
}

/// Session-level protocol driver shared by the stdin path and unit
/// tests: runs a full session over any `BufRead`/`Write` pair against a
/// pinned snapshot. The reactor inlines the same logic over its
/// non-blocking buffers.
pub fn session_over<R: BufRead, W: Write>(
    store: &SnapshotStore,
    proto_version: ProtoVersion,
    reader: R,
    mut writer: W,
) -> std::io::Result<()> {
    let snap = store.load();
    crate::obs::Registry::global().counter("server.connections").add(1);
    writeln!(writer, "{}", proto::greeting(&snap, proto_version))?;
    writer.flush()?;
    for line in reader.lines() {
        let line = line?;
        match proto::respond(store, &snap, proto_version, &line) {
            None => continue,
            Some((reply, quit)) => {
                write!(writer, "{reply}")?;
                writer.flush()?;
                if quit {
                    break;
                }
            }
        }
    }
    Ok(())
}

/// Convenience for one-shot embedders: wrap an engine in a store and
/// answer a single command in the configured protocol's framing.
pub fn one_shot(engine: QueryEngine, proto_version: ProtoVersion, line: &str) -> String {
    let store = SnapshotStore::new(engine);
    let snap = store.load();
    match proto::respond(&store, &snap, proto_version, line) {
        None => String::new(),
        Some((reply, _)) => reply,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::peel::bup::wing_bup;

    fn engine() -> QueryEngine {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1))
    }

    #[test]
    fn config_builder_chains() {
        let cfg = ServerConfig::new()
            .addr("127.0.0.1:0")
            .max_conns(7)
            .per_ip(2)
            .idle_timeout(Duration::from_secs(9))
            .max_line(128)
            .proto(ProtoVersion::V1);
        assert_eq!(cfg.addr.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.max_conns, 7);
        assert_eq!(cfg.per_ip, 2);
        assert_eq!(cfg.idle_timeout, Duration::from_secs(9));
        assert_eq!(cfg.max_line, 128);
        assert_eq!(cfg.proto, ProtoVersion::V1);
    }

    #[test]
    fn session_over_in_memory_pipe_speaks_v2() {
        let store = SnapshotStore::new(engine());
        let input = b"stats\n\nkwing 2\nquit\nnever-reached\n".to_vec();
        let mut out = Vec::new();
        session_over(&store, ProtoVersion::V2, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("OK hello"), "{text}");
        // greeting + stats + kwing + quit = 4 frames; the blank line is
        // ignored silently in v2
        assert_eq!(text.matches("\nEND\n").count(), 4, "{text}");
        assert!(text.contains("OK stats"), "{text}");
        assert!(text.contains("protocol 2"), "{text}");
        assert!(text.contains("epoch 1"), "{text}");
        assert!(text.contains("OK kwing"), "{text}");
        assert!(text.contains("OK quit"), "{text}");
        assert!(!text.contains("never-reached"));
    }

    #[test]
    fn session_over_in_memory_pipe_speaks_v1() {
        let store = SnapshotStore::new(engine());
        let input = b"stats\nquit\n".to_vec();
        let mut out = Vec::new();
        session_over(&store, ProtoVersion::V1, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("READY kind=wing"), "{text}");
        assert!(text.trim_end().ends_with("BYE"), "{text}");
        assert!(!text.contains("protocol 2"), "{text}");
    }

    #[test]
    fn one_shot_frames_a_single_reply() {
        let r = one_shot(engine(), ProtoVersion::V2, "summary");
        assert!(r.starts_with("OK summary\n"), "{r}");
        assert!(r.ends_with("END\n"), "{r}");
    }
}
