//! Cache- and SIMD-conscious kernel primitives shared by the counting
//! and peeling hot loops.
//!
//! Three building blocks live here:
//!
//! - [`KernelConfig`] — the knob set plumbed through
//!   [`crate::engine::EngineConfig`]: wedge-order policy
//!   ([`OrderPolicy`]), SIMD dispatch ([`SimdPolicy`]), and the
//!   support-update strategy ([`UpdateKernel`]).
//! - Sorted-intersection kernels ([`intersect_values`],
//!   [`intersect_pairs`]) over strictly-increasing label lists: scalar
//!   two-pointer merge, galloping when the lengths are lopsided, and an
//!   AVX2 8×8 block kernel (compiled only under
//!   `target_feature = "avx2"`, with the scalar path as the mandatory
//!   fallback and a `PBNG_SIMD=scalar` runtime override).
//! - [`flush_runs`] — the per-lane sort-then-aggregate flush that
//!   replaces scattered atomic `sub_clamped` storms in the batch
//!   peeling kernels: each lane's `(entity, delta)` log is sorted,
//!   equal-key runs are summed, and one atomic update per distinct
//!   entity is applied. Correct because clamped subtraction to a common
//!   floor is associative *and* commutative:
//!   `max(max(x-a, f)-b, f) = max(x-a-b, f)`.

use super::order::OrderPolicy;
use crate::par::{spmd, Counter, ScratchSet};
use std::sync::{Arc, OnceLock};

/// SIMD dispatch policy for the sorted-intersection inner loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdPolicy {
    /// Use AVX2 when compiled in (`target_feature = "avx2"`) and not
    /// overridden by `PBNG_SIMD=scalar`; otherwise scalar.
    #[default]
    Auto,
    /// Always the scalar kernel, even when AVX2 is compiled in.
    Scalar,
}

/// How batch peeling applies support updates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum UpdateKernel {
    /// Per-lane `(entity, delta)` logs, sorted and run-summed, flushed
    /// once per batch ([`flush_runs`]) — one atomic op per distinct
    /// entity per lane.
    #[default]
    Aggregated,
    /// One atomic `sub_clamped` per discovered update (the pre-kernel
    /// behavior; kept as the measurable baseline).
    Scattered,
}

/// Kernel selection, plumbed from [`crate::engine::EngineConfig`] down
/// into counting ([`super::CountOptions::kernel`]) and batch peeling.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelConfig {
    /// Wedge-enumeration order ([`super::order`] cost model).
    pub order: OrderPolicy,
    /// SIMD dispatch for sorted intersections.
    pub simd: SimdPolicy,
    /// Support-update strategy for the batch peel kernels.
    pub updates: UpdateKernel,
}

/// Whether the AVX2 kernel exists in this build.
pub fn simd_compiled() -> bool {
    cfg!(all(target_arch = "x86_64", target_feature = "avx2"))
}

/// `PBNG_SIMD=scalar` forces the scalar kernel at runtime (read once).
fn forced_scalar() -> bool {
    static F: OnceLock<bool> = OnceLock::new();
    *F.get_or_init(|| {
        std::env::var("PBNG_SIMD")
            .map(|v| v.eq_ignore_ascii_case("scalar"))
            .unwrap_or(false)
    })
}

/// Resolve a [`SimdPolicy`] against the build and the environment.
pub fn simd_active(policy: SimdPolicy) -> bool {
    match policy {
        SimdPolicy::Scalar => false,
        SimdPolicy::Auto => simd_compiled() && !forced_scalar(),
    }
}

/// When one list is at least this factor shorter, binary-search it into
/// the longer one instead of merging.
const GALLOP_FACTOR: usize = 16;

/// Intersect two strictly-increasing `u32` slices, calling `f` once per
/// common value, in ascending order. `simd` selects the AVX2 block
/// kernel when it is compiled in (pass [`simd_active`]'s verdict).
pub fn intersect_values(a: &[u32], b: &[u32], simd: bool, mut f: impl FnMut(u32)) {
    if simd {
        #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
        {
            avx2::intersect(a, b, &mut f);
            return;
        }
    }
    scalar_intersect(a, b, &mut f);
}

fn scalar_intersect(a: &[u32], b: &[u32], f: &mut impl FnMut(u32)) {
    if a.len() > b.len() {
        scalar_intersect(b, a, f);
        return;
    }
    if a.is_empty() {
        return;
    }
    if a.len() * GALLOP_FACTOR < b.len() {
        // gallop: binary-search each short-side value into the suffix
        // of the long side that can still contain it
        let mut rest = b;
        for &x in a {
            let p = rest.partition_point(|&y| y < x);
            if p == rest.len() {
                return;
            }
            if rest[p] == x {
                f(x);
                rest = &rest[p + 1..];
            } else {
                rest = &rest[p..];
            }
        }
        return;
    }
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                f(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// Intersect two strictly-increasing label lists carrying positional
/// edge ids, calling `f(label, a_eid, b_eid)` once per common label in
/// ascending order. Positional payloads keep this kernel scalar (the
/// documented dispatch policy: SIMD applies to the label-only path).
pub fn intersect_pairs(
    a_lab: &[u32],
    a_eid: &[u32],
    b_lab: &[u32],
    b_eid: &[u32],
    f: &mut impl FnMut(u32, u32, u32),
) {
    debug_assert_eq!(a_lab.len(), a_eid.len());
    debug_assert_eq!(b_lab.len(), b_eid.len());
    if a_lab.len() * GALLOP_FACTOR < b_lab.len() {
        let mut j = 0usize;
        for (i, &x) in a_lab.iter().enumerate() {
            j += b_lab[j..].partition_point(|&y| y < x);
            if j == b_lab.len() {
                return;
            }
            if b_lab[j] == x {
                f(x, a_eid[i], b_eid[j]);
                j += 1;
            }
        }
        return;
    }
    if b_lab.len() * GALLOP_FACTOR < a_lab.len() {
        let mut i = 0usize;
        for (j, &y) in b_lab.iter().enumerate() {
            i += a_lab[i..].partition_point(|&x| x < y);
            if i == a_lab.len() {
                return;
            }
            if a_lab[i] == y {
                f(y, a_eid[i], b_eid[j]);
                i += 1;
            }
        }
        return;
    }
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_lab.len() && j < b_lab.len() {
        match a_lab[i].cmp(&b_lab[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                f(a_lab[i], a_eid[i], b_eid[j]);
                i += 1;
                j += 1;
            }
        }
    }
}

#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
mod avx2 {
    use std::arch::x86_64::*;

    /// 8×8 block intersection of strictly-increasing `u32` slices:
    /// compare an 8-lane block of `a` against all 8 rotations of an
    /// 8-lane block of `b`, collect the match mask, then advance the
    /// block whose maximum is exhausted. Each common value is emitted
    /// exactly once, ascending (matches of the current block pair lie
    /// below `min(amax, bmax)`; both cursors only move forward).
    pub fn intersect(a: &[u32], b: &[u32], f: &mut impl FnMut(u32)) {
        let (mut i, mut j) = (0usize, 0usize);
        while i + 8 <= a.len() && j + 8 <= b.len() {
            // disjoint block ranges: skip without comparing
            if a[i + 7] < b[j] {
                i += 8;
                continue;
            }
            if b[j + 7] < a[i] {
                j += 8;
                continue;
            }
            // SAFETY: this module only compiles when AVX2 is statically
            // enabled (the `target_feature = "avx2"` cfg on `mod avx2`),
            // so every intrinsic's CPU requirement holds; the two
            // unaligned loads read exactly 8 u32s each, in bounds by the
            // loop conditions `i + 8 <= a.len()` and `j + 8 <= b.len()`.
            let mask = unsafe {
                let va = _mm256_loadu_si256(a.as_ptr().add(i).cast());
                let vb = _mm256_loadu_si256(b.as_ptr().add(j).cast());
                let rot1 = _mm256_setr_epi32(1, 2, 3, 4, 5, 6, 7, 0);
                // lane k of `hits` becomes all-ones iff a[i+k] occurs
                // anywhere in the b block
                let mut rot = vb;
                let mut hits = _mm256_cmpeq_epi32(va, rot);
                for _ in 0..7 {
                    rot = _mm256_permutevar8x32_epi32(rot, rot1);
                    hits = _mm256_or_si256(hits, _mm256_cmpeq_epi32(va, rot));
                }
                _mm256_movemask_ps(_mm256_castsi256_ps(hits)) as u32 & 0xff
            };
            let mut m = mask;
            while m != 0 {
                let k = m.trailing_zeros() as usize;
                f(a[i + k]);
                m &= m - 1;
            }
            let (amax, bmax) = (a[i + 7], b[j + 7]);
            // no remaining element of an exhausted block can match a
            // later block of the other list (strict monotonicity)
            if amax <= bmax {
                i += 8;
            }
            if bmax <= amax {
                j += 8;
            }
        }
        super::scalar_intersect(&a[i..], &b[j..], f);
    }
}

/// Cached handle for the aggregation-flush batch-size histogram (the
/// registry lookup scans under a lock; resolve it once per process).
fn flush_hist() -> &'static Arc<crate::obs::Histogram> {
    static H: OnceLock<Arc<crate::obs::Histogram>> = OnceLock::new();
    H.get_or_init(|| crate::obs::Registry::global().histogram("kernel.flush_batch"))
}

/// Cached side-choice counters, indexed by [`OrderPolicy::side_code`].
fn side_counters() -> &'static [Arc<Counter>; 3] {
    static C: OnceLock<[Arc<Counter>; 3]> = OnceLock::new();
    C.get_or_init(|| {
        let r = crate::obs::Registry::global();
        [
            r.counter("kernel.side.degree"),
            r.counter("kernel.side.u"),
            r.counter("kernel.side.v"),
        ]
    })
}

/// Record one counting call's resolved side choice into the global
/// registry (`kernel.side.{degree,u,v}`).
pub fn note_side_choice(code: u64) {
    side_counters()[code as usize].add(1);
}

/// Flush every lane's `(entity, delta)` log: sort by entity, sum
/// equal-key runs, and `apply` one aggregate per distinct entity per
/// lane. Lanes flush in parallel; cross-lane duplicates are fine
/// because the underlying clamped subtraction commutes (module docs).
/// Logs are cleared; batch sizes land in the `kernel.flush_batch`
/// histogram.
pub fn flush_runs(scratch: &ScratchSet, apply: impl Fn(u32, u64) + Sync) {
    let lanes = scratch.lanes();
    spmd(lanes, |t| {
        // SAFETY: `spmd(lanes, ..)` drives each lane id `t < lanes` on
        // exactly one thread per region and this set holds `lanes`
        // slots, so slot `t` is exclusively this thread's; no other
        // guard to it is live.
        let mut sc = unsafe { scratch.lane(t) };
        if sc.pairs.is_empty() {
            return;
        }
        flush_hist().record(sc.pairs.len() as u64);
        sc.pairs.sort_unstable_by_key(|&(e, _)| e);
        let mut i = 0usize;
        while i < sc.pairs.len() {
            let key = sc.pairs[i].0;
            let mut sum = 0u64;
            while i < sc.pairs.len() && sc.pairs[i].0 == key {
                sum += sc.pairs[i].1;
                i += 1;
            }
            apply(key, sum);
        }
        sc.pairs.clear();
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| b.contains(x)).copied().collect()
    }

    fn sorted_set(rng: &mut crate::testkit::Rng, n: usize, universe: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n).map(|_| rng.usize_below(universe) as u32).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    #[test]
    fn intersect_values_matches_naive_scalar_and_simd() {
        let mut rng = crate::testkit::Rng::new(0x51AD);
        for _ in 0..40 {
            let a = sorted_set(&mut rng, 1 + rng.usize_below(60), 90);
            let b = sorted_set(&mut rng, 1 + rng.usize_below(60), 90);
            let want = naive_intersect(&a, &b);
            for simd in [false, simd_active(SimdPolicy::Auto)] {
                let mut got = Vec::new();
                intersect_values(&a, &b, simd, |x| got.push(x));
                assert_eq!(got, want, "simd={simd} a={a:?} b={b:?}");
            }
        }
    }

    #[test]
    fn intersect_values_handles_lopsided_gallop() {
        let a: Vec<u32> = vec![7, 500, 900];
        let b: Vec<u32> = (0..1000).collect();
        let mut got = Vec::new();
        intersect_values(&a, &b, false, |x| got.push(x));
        assert_eq!(got, vec![7, 500, 900]);
        // and with the roles swapped
        let mut got = Vec::new();
        intersect_values(&b, &a, false, |x| got.push(x));
        assert_eq!(got, vec![7, 500, 900]);
    }

    #[test]
    fn intersect_pairs_reports_positions_from_both_sides() {
        let a_lab = [2u32, 4, 9, 30];
        let a_eid = [20u32, 40, 90, 300];
        let b_lab = [4u32, 9, 10, 31];
        let b_eid = [104u32, 109, 110, 131];
        let mut got = Vec::new();
        intersect_pairs(&a_lab, &a_eid, &b_lab, &b_eid, &mut |l, ea, eb| {
            got.push((l, ea, eb));
        });
        assert_eq!(got, vec![(4, 40, 104), (9, 90, 109)]);
    }

    #[test]
    fn intersect_pairs_gallops_both_directions() {
        let long_lab: Vec<u32> = (0..800).map(|x| x * 2).collect();
        let long_eid: Vec<u32> = (0..800).collect();
        let short_lab = [6u32, 700, 1400];
        let short_eid = [1u32, 2, 3];
        let mut ab = Vec::new();
        intersect_pairs(&short_lab, &short_eid, &long_lab, &long_eid, &mut |l, ea, eb| {
            ab.push((l, ea, eb));
        });
        assert_eq!(ab, vec![(6, 1, 3), (700, 2, 350), (1400, 3, 700)]);
        let mut ba = Vec::new();
        intersect_pairs(&long_lab, &long_eid, &short_lab, &short_eid, &mut |l, ea, eb| {
            ba.push((l, ea, eb));
        });
        assert_eq!(ba, vec![(6, 3, 1), (700, 350, 2), (1400, 700, 3)]);
    }

    #[test]
    fn flush_runs_aggregates_per_entity() {
        let mut scratch = ScratchSet::take(2);
        let mut lane = 0;
        scratch.for_each(|sl| {
            if lane == 0 {
                sl.pairs.extend([(3u32, 5u64), (1, 2), (3, 7), (0, 0)]);
            } else {
                sl.pairs.extend([(1u32, 1u64), (1, 1)]);
            }
            lane += 1;
        });
        let acc: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        let applies = AtomicU64::new(0);
        flush_runs(&scratch, |k, d| {
            // ORDERING: Relaxed — test-local accumulation, joined below.
            acc[k as usize].fetch_add(d, Ordering::Relaxed);
            applies.fetch_add(1, Ordering::Relaxed);
        });
        let got: Vec<u64> = acc.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        assert_eq!(got, vec![0, 4, 0, 12]);
        // one apply per distinct key per lane: {0,1,3} + {1}
        assert_eq!(applies.load(Ordering::Relaxed), 4);
        // logs cleared for freelist reuse
        scratch.for_each(|sl| assert!(sl.pairs.is_empty()));
    }

    #[test]
    fn simd_policy_resolution() {
        assert!(!simd_active(SimdPolicy::Scalar));
        if simd_active(SimdPolicy::Auto) {
            assert!(simd_compiled());
        }
        let d = KernelConfig::default();
        assert_eq!(d.order, OrderPolicy::Degree);
        assert_eq!(d.simd, SimdPolicy::Auto);
        assert_eq!(d.updates, UpdateKernel::Aggregated);
    }
}
