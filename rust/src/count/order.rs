//! Wedge-enumeration side selection for [`super::pve_bcnt`].
//!
//! The vertex-priority traversal is correct under *any* total vertex
//! order: a butterfly is counted exactly once, at the endpoint pair
//! whose `last` carries the globally minimal label, and every per-entity
//! contribution is an order-independent sum. That freedom is what a
//! cost model can exploit (Shi & Shun, "Parallel Algorithms for
//! Butterfly Computations"):
//!
//! - [`OrderPolicy::Degree`] — the paper's whole-`W` degree order
//!   ([`BipartiteGraph::priority_labels`]); wedge work is bounded by
//!   `Σ_e min(du, dv)` (Chiba–Nishizeki).
//! - [`OrderPolicy::SideU`] / [`OrderPolicy::SideV`] — *side-major*
//!   orders: every vertex of the chosen endpoint side gets a lower
//!   label than any vertex of the other side (degree-descending within
//!   each side). Wedges then always retire at endpoint pairs on the
//!   chosen side, the other side's starts break after one probe per
//!   mid, and the real wedge work is exactly
//!   [`BipartiteGraph::wedge_count`] for that side.
//! - [`OrderPolicy::Auto`] — pick whichever of the three bounds is
//!   smallest for this graph.

use crate::graph::{BipartiteGraph, Side};

/// Which total vertex order the counting traversal uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OrderPolicy {
    /// Whole-`W` degree-descending priority order (the paper's Alg. 1).
    #[default]
    Degree,
    /// Side-major order with U as the endpoint (retirement) side.
    SideU,
    /// Side-major order with V as the endpoint (retirement) side.
    SideV,
    /// Choose per graph by the cost model in [`OrderPolicy::resolve`].
    Auto,
}

impl OrderPolicy {
    /// Resolve `Auto` against the graph's wedge-work bounds; concrete
    /// policies pass through unchanged. Never returns `Auto`.
    ///
    /// Ties prefer `Degree` (the paper's order, tightest constant in
    /// practice), then `SideU`, for determinism.
    pub fn resolve(self, g: &BipartiteGraph) -> OrderPolicy {
        match self {
            OrderPolicy::Auto => {
                let degree = g.count_workload_bound();
                let side_u = g.wedge_count(Side::U);
                let side_v = g.wedge_count(Side::V);
                if degree <= side_u && degree <= side_v {
                    OrderPolicy::Degree
                } else if side_u <= side_v {
                    OrderPolicy::SideU
                } else {
                    OrderPolicy::SideV
                }
            }
            p => p,
        }
    }

    /// Stable numeric code for observability (span attribute / bench
    /// side-mix field): 0 = degree, 1 = side-U, 2 = side-V.
    ///
    /// # Panics
    /// On `Auto` — call [`OrderPolicy::resolve`] first.
    pub fn side_code(self) -> u64 {
        match self {
            OrderPolicy::Degree => 0,
            OrderPolicy::SideU => 1,
            OrderPolicy::SideV => 2,
            OrderPolicy::Auto => panic!("side_code on unresolved OrderPolicy::Auto"),
        }
    }
}

/// Priority labels for a *resolved* policy: `label[wid]`, label 0 =
/// highest priority. For the side-major orders the endpoint side
/// occupies labels `0..n_side` (degree-descending, wid-ascending ties
/// within the side) and the mid side the rest, so the traversal retires
/// every wedge at an endpoint pair on the chosen side.
pub fn labels(g: &BipartiteGraph, policy: OrderPolicy) -> Vec<u32> {
    let nw = g.nw();
    match policy {
        OrderPolicy::Degree => g.priority_labels(),
        OrderPolicy::Auto => panic!("labels on unresolved OrderPolicy::Auto"),
        OrderPolicy::SideU | OrderPolicy::SideV => {
            // Side-major: sort each side by degree desc (wid-asc ties),
            // then concatenate low side first.
            let nu = g.nu();
            let mut order: Vec<u32> = (0..nw as u32).collect();
            let low_is_u = policy == OrderPolicy::SideU;
            order.sort_unstable_by(|&a, &b| {
                let (au, bu) = ((a as usize) < nu, (b as usize) < nu);
                // chosen endpoint side sorts strictly first
                (au != low_is_u)
                    .cmp(&(bu != low_is_u))
                    .then_with(|| g.deg_w(b as usize).cmp(&g.deg_w(a as usize)))
                    .then(a.cmp(&b))
            });
            let mut label = vec![0u32; nw];
            for (rank, &w) in order.iter().enumerate() {
                label[w as usize] = rank as u32;
            }
            label
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, GraphBuilder};

    #[test]
    fn resolve_picks_cheapest_bound() {
        // Star from one U hub: SideU wedges route through V mids (all
        // degree 1 → cost 0); SideV routes through the hub (C(12,2) =
        // 66); the degree bound is Σ_e min(du, dv) = m = 12. Auto must
        // take the free SideU order.
        let edges: Vec<(u32, u32)> = (0..12).map(|v| (0, v)).collect();
        let g = GraphBuilder::new().edges(&edges).build();
        assert_eq!(g.wedge_count(Side::U), 0);
        assert!(g.wedge_count(Side::V) > 0);
        assert!(g.count_workload_bound() > 0);
        assert_eq!(OrderPolicy::Auto.resolve(&g), OrderPolicy::SideU);
        // concrete policies pass through
        assert_eq!(OrderPolicy::SideV.resolve(&g), OrderPolicy::SideV);
        assert_eq!(OrderPolicy::Degree.resolve(&g), OrderPolicy::Degree);
    }

    #[test]
    fn side_major_labels_partition_sides() {
        let g = gen::zipf(30, 40, 150, 1.2, 1.2, 9);
        let nu = g.nu();
        let lab_u = labels(&g, OrderPolicy::SideU);
        for w in 0..g.nw() {
            if w < nu {
                assert!((lab_u[w] as usize) < nu, "U wid {w} got high label");
            } else {
                assert!((lab_u[w] as usize) >= nu, "V wid {w} got low label");
            }
        }
        let lab_v = labels(&g, OrderPolicy::SideV);
        for w in 0..g.nw() {
            if w < nu {
                assert!((lab_v[w] as usize) >= g.nv(), "U wid {w} got low label");
            } else {
                assert!((lab_v[w] as usize) < g.nv(), "V wid {w} got high label");
            }
        }
    }

    #[test]
    fn labels_are_a_permutation() {
        let g = gen::zipf(25, 25, 120, 1.3, 1.3, 4);
        for p in [OrderPolicy::Degree, OrderPolicy::SideU, OrderPolicy::SideV] {
            let lab = labels(&g, p);
            let mut seen = vec![false; g.nw()];
            for &l in &lab {
                assert!(!seen[l as usize], "duplicate label under {p:?}");
                seen[l as usize] = true;
            }
        }
    }

    #[test]
    fn side_codes_are_stable() {
        assert_eq!(OrderPolicy::Degree.side_code(), 0);
        assert_eq!(OrderPolicy::SideU.side_code(), 1);
        assert_eq!(OrderPolicy::SideV.side_code(), 2);
    }
}
