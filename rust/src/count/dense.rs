//! Dense-block butterfly counting: pack a (sub)graph region into a dense
//! biadjacency block and count through the AOT-compiled XLA artifact
//! (L1 Pallas kernels under the hood) or the in-rust fallback.
//!
//! This is the L3↔runtime integration point: the tip re-counting
//! optimization (§5.1) and the examples route *dense* regions here —
//! Chiba–Nishizeki wedge enumeration is optimal for sparse graphs, but a
//! near-biclique block of side n costs `O(n³)` wedges while two MXU
//! matmuls cost the same FLOPs at vastly higher throughput on TPU.

use crate::graph::BipartiteGraph;
use crate::runtime::{butterfly_block_cpu, BlockCounts, Runtime};

/// Counter with an optional PJRT-backed fast path.
pub struct DenseCounter {
    runtime: Option<Runtime>,
}

impl DenseCounter {
    /// Try to attach the runtime; falls back to pure rust when the
    /// artifacts or the PJRT client are unavailable.
    pub fn new() -> Self {
        let runtime = Runtime::new(Runtime::default_dir())
            .ok()
            .filter(|r| !r.available_sizes().is_empty());
        DenseCounter { runtime }
    }

    pub fn with_runtime(runtime: Runtime) -> Self {
        DenseCounter {
            runtime: Some(runtime),
        }
    }

    pub fn cpu_only() -> Self {
        DenseCounter { runtime: None }
    }

    pub fn has_accelerator(&self) -> bool {
        self.runtime.is_some()
    }

    /// Count butterflies of the subgraph induced on `us × vs`.
    ///
    /// Returns counts indexed by position in `us` / `vs`, per-edge counts
    /// row-major over (us, vs), and the block total. Uses the XLA
    /// artifact when a compiled size fits, else the rust fallback.
    pub fn count_block(&self, g: &BipartiteGraph, us: &[u32], vs: &[u32]) -> BlockCounts {
        let m = us.len();
        let n = vs.len();
        // position map for vs
        let mut vpos = std::collections::HashMap::with_capacity(n);
        for (j, &v) in vs.iter().enumerate() {
            vpos.insert(v, j);
        }
        let side = m.max(n);
        if let Some(rt) = &self.runtime {
            if let Some(size) = rt.pick_size(side) {
                // pad into a size×size block
                let mut block = vec![0f32; size * size];
                for (i, &u) in us.iter().enumerate() {
                    for &(v, _) in g.nbrs_u(u) {
                        if let Some(&j) = vpos.get(&v) {
                            block[i * size + j] = 1.0;
                        }
                    }
                }
                if let Ok(c) = rt.butterfly_block(&block, size) {
                    // strip padding
                    let per_edge = (0..m)
                        .flat_map(|i| (0..n).map(move |j| (i, j)))
                        .map(|(i, j)| c.per_edge[i * size + j])
                        .collect();
                    return BlockCounts {
                        per_u: c.per_u[..m].to_vec(),
                        per_v: c.per_v[..n].to_vec(),
                        per_edge,
                        total: c.total,
                    };
                }
            }
        }
        // fallback: exact same math in rust
        let mut block = vec![0f32; m * n];
        for (i, &u) in us.iter().enumerate() {
            for &(v, _) in g.nbrs_u(u) {
                if let Some(&j) = vpos.get(&v) {
                    block[i * n + j] = 1.0;
                }
            }
        }
        butterfly_block_cpu(&block, m, n)
    }
}

impl Default for DenseCounter {
    fn default() -> Self {
        DenseCounter::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn cpu_block_matches_sparse_counting_on_subregion() {
        let g = gen::planted_blocks(
            60,
            60,
            80,
            &[gen::Block { rows: 8, cols: 8, density: 1.0 }],
            3,
        );
        let dc = DenseCounter::cpu_only();
        let us: Vec<u32> = (0..8).collect();
        let vs: Vec<u32> = (0..8).collect();
        let c = dc.count_block(&g, &us, &vs);
        // the fully dense 8x8 block: total = C(8,2)^2
        assert_eq!(c.total, 28 * 28);
        assert!(c.per_edge.iter().all(|&x| x == 49));
    }

    #[test]
    fn block_counts_restrict_to_selected_vertices() {
        let g = gen::biclique(4, 4);
        let dc = DenseCounter::cpu_only();
        // only a 2x2 corner: exactly 1 butterfly
        let c = dc.count_block(&g, &[0, 1], &[0, 1]);
        assert_eq!(c.total, 1);
        assert_eq!(c.per_u, vec![1, 1]);
    }
}
