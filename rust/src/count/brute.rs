//! Brute-force butterfly counting and decomposition oracles.
//!
//! Quadratic/cubic reference implementations used only in tests and in
//! the property harness: they follow the definitions directly (no
//! priority tricks, no BE-Index), so any agreement bug in the fast paths
//! shows up against these.

use super::Counts;
use crate::graph::{BipartiteGraph, Side};

/// Common-neighbor count between two U vertices.
fn common_u(g: &BipartiteGraph, a: u32, b: u32) -> u64 {
    let (na, nb) = (g.nbrs_u(a), g.nbrs_u(b));
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < na.len() && j < nb.len() {
        match na[i].0.cmp(&nb[j].0) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

fn choose2(c: u64) -> u64 {
    c * c.saturating_sub(1) / 2
}

/// O(n²·d) reference counts (per-vertex, per-edge, total).
pub fn brute_counts(g: &BipartiteGraph) -> Counts {
    let nu = g.nu();
    let nv = g.nv();
    let mut per_u = vec![0u64; nu];
    let mut per_v = vec![0u64; nv];
    let mut per_edge = vec![0u64; g.m()];
    let mut total = 0u64;
    for a in 0..nu as u32 {
        for b in (a + 1)..nu as u32 {
            let c = common_u(g, a, b);
            let bf = choose2(c);
            total += bf;
            per_u[a as usize] += bf;
            per_u[b as usize] += bf;
        }
    }
    let t = g.transposed();
    for a in 0..nv as u32 {
        for b in (a + 1)..nv as u32 {
            let c = common_u(&t, a, b);
            per_v[a as usize] += choose2(c);
            per_v[b as usize] += choose2(c);
        }
    }
    for e in 0..g.m() as u32 {
        let (u, v) = g.edge(e);
        let mut s = 0u64;
        for &(u2, _) in g.nbrs_v(v) {
            if u2 == u {
                continue;
            }
            let c = common_u(g, u, u2);
            s += c.saturating_sub(1);
        }
        per_edge[e as usize] = s;
    }
    Counts {
        per_u,
        per_v,
        per_edge,
        total,
    }
}

/// Brute-force wing decomposition: literal bottom-up peeling with
/// recount-from-scratch after every single peel. O(m² · count) — tiny
/// graphs only. This is the *definitionally correct* oracle.
pub fn brute_wing_numbers(g: &BipartiteGraph) -> Vec<u64> {
    let m = g.m();
    let mut alive = vec![true; m];
    let mut theta = vec![0u64; m];
    let mut remaining = m;
    let mut level = 0u64;
    while remaining > 0 {
        let sup = edge_support_restricted(g, &alive);
        let min = (0..m)
            .filter(|&e| alive[e])
            .map(|e| sup[e])
            .min()
            .unwrap();
        level = level.max(min);
        // peel ONE minimum edge (definition order); ties by id
        let e = (0..m)
            .filter(|&e| alive[e] && sup[e] == min)
            .next()
            .unwrap();
        theta[e] = level;
        alive[e] = false;
        remaining -= 1;
    }
    theta
}

/// Brute-force tip decomposition of side U (peel one vertex at a time,
/// recount from scratch).
pub fn brute_tip_numbers(g: &BipartiteGraph, side: Side) -> Vec<u64> {
    let g = match side {
        Side::U => g.clone(),
        Side::V => g.transposed(),
    };
    let n = g.nu();
    let mut alive = vec![true; n];
    let mut theta = vec![0u64; n];
    let mut remaining = n;
    let mut level = 0u64;
    while remaining > 0 {
        let sup = vertex_support_restricted(&g, &alive);
        let min = (0..n).filter(|&u| alive[u]).map(|u| sup[u]).min().unwrap();
        level = level.max(min);
        let u = (0..n)
            .filter(|&u| alive[u] && sup[u] == min)
            .next()
            .unwrap();
        theta[u] = level;
        alive[u] = false;
        remaining -= 1;
    }
    theta
}

/// Per-edge butterfly counts restricted to alive edges.
pub fn edge_support_restricted(g: &BipartiteGraph, alive: &[bool]) -> Vec<u64> {
    let m = g.m();
    let mut sup = vec![0u64; m];
    // enumerate butterflies (u<u', v<v') where all 4 edges alive
    for u in 0..g.nu() as u32 {
        for &(v, e_uv) in g.nbrs_u(u) {
            if !alive[e_uv as usize] {
                continue;
            }
            for &(v2, e_uv2) in g.nbrs_u(u) {
                if v2 <= v || !alive[e_uv2 as usize] {
                    continue;
                }
                for &(u2, e_u2v) in g.nbrs_v(v) {
                    if u2 <= u || !alive[e_u2v as usize] {
                        continue;
                    }
                    if let Some(e_u2v2) = g.edge_id(u2, v2) {
                        if alive[e_u2v2 as usize] {
                            sup[e_uv as usize] += 1;
                            sup[e_uv2 as usize] += 1;
                            sup[e_u2v as usize] += 1;
                            sup[e_u2v2 as usize] += 1;
                        }
                    }
                }
            }
        }
    }
    sup
}

/// Per-U-vertex butterfly counts restricted to alive U vertices
/// (V is never peeled in tip decomposition).
pub fn vertex_support_restricted(g: &BipartiteGraph, alive: &[bool]) -> Vec<u64> {
    let n = g.nu();
    let mut sup = vec![0u64; n];
    for a in 0..n as u32 {
        if !alive[a as usize] {
            continue;
        }
        for b in (a + 1)..n as u32 {
            if !alive[b as usize] {
                continue;
            }
            let c = common_u(g, a, b);
            let bf = choose2(c);
            sup[a as usize] += bf;
            sup[b as usize] += bf;
        }
    }
    sup
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn brute_total_biclique() {
        let g = gen::biclique(3, 3);
        let c = brute_counts(&g);
        assert_eq!(c.total, 3 * 3 * 1 * 1); // C(3,2)^2 = 9
    }

    #[test]
    fn brute_wing_biclique_uniform() {
        // In K_{3,3} every edge has support 4; peeling is uniform so all
        // wing numbers equal... peel one edge: others drop; final θ must be
        // the degeneracy level. Check all equal and consistent.
        let g = gen::biclique(3, 3);
        let th = brute_wing_numbers(&g);
        assert!(th.iter().all(|&t| t == th[0]));
        assert!(th[0] >= 1);
    }

    #[test]
    fn brute_wing_single_butterfly() {
        let g = gen::biclique(2, 2);
        assert_eq!(brute_wing_numbers(&g), vec![1, 1, 1, 1]);
    }

    #[test]
    fn brute_tip_single_butterfly() {
        let g = gen::biclique(2, 2);
        assert_eq!(brute_tip_numbers(&g, Side::U), vec![1, 1]);
        assert_eq!(brute_tip_numbers(&g, Side::V), vec![1, 1]);
    }

    #[test]
    fn restricted_support_equals_full_when_all_alive() {
        let g = gen::erdos(12, 12, 50, 4);
        let alive = vec![true; g.m()];
        let sup = edge_support_restricted(&g, &alive);
        let c = brute_counts(&g);
        assert_eq!(sup, c.per_edge);
    }

    #[test]
    fn wing_numbers_monotone_under_edge_removal() {
        // removing an edge can only lower (or keep) other edges' θ
        let g = gen::erdos(8, 8, 30, 11);
        let th = brute_wing_numbers(&g);
        let edges: Vec<(u32, u32)> = g
            .edges()
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != 0)
            .map(|(_, &e)| e)
            .collect();
        let g2 = crate::graph::GraphBuilder::new()
            .nu(g.nu())
            .nv(g.nv())
            .edges(&edges)
            .build();
        let th2 = brute_wing_numbers(&g2);
        for e2 in 0..g2.m() as u32 {
            let (u, v) = g2.edge(e2);
            let e1 = g.edge_id(u, v).unwrap();
            assert!(
                th2[e2 as usize] <= th[e1 as usize],
                "θ increased after removal"
            );
        }
    }
}
