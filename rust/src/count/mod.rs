//! Vertex-priority butterfly counting (Alg. 1, Chiba–Nishizeki [7] /
//! Wang et al. [66] / Shi–Shun [54]) with optional embedded bloom
//! discovery for the BE-Index (§2.3).
//!
//! Vertices are relabeled in decreasing order of degree (label 0 = highest
//! priority); adjacency is sorted by increasing label; a wedge
//! `start → mid → last` is traversed iff `label(last) < label(mid)` and
//! `label(last) < label(start)`. Wedges sharing endpoints `(start, last)`
//! combine into `C(c, 2)` butterflies, and each such endpoint pair with
//! `c ≥ 2` is exactly one *maximal priority bloom*.
//!
//! Complexity: `O(Σ_{(u,v)∈E} min(du, dv)) = O(α·m)` wedges.

pub mod brute;
pub mod dense;

use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use crate::par::{parallel_for_chunked, SupportCell};

/// Butterfly counts produced by [`pve_bcnt`].
#[derive(Clone, Debug)]
pub struct Counts {
    /// Per-U-vertex butterfly count.
    pub per_u: Vec<u64>,
    /// Per-V-vertex butterfly count.
    pub per_v: Vec<u64>,
    /// Per-edge butterfly count (empty unless requested).
    pub per_edge: Vec<u64>,
    /// Total butterflies in G.
    pub total: u64,
}

/// Bloom data harvested during counting, consumed by
/// [`crate::beindex::BeIndex::from_raw`].
///
/// Bloom `b` covers twin-edge pairs `pairs[offs[b]..offs[b+1]]`; its bloom
/// number is `offs[b+1] - offs[b]` (= the wedge count `k ≥ 2`).
#[derive(Clone, Debug, Default)]
pub struct RawBlooms {
    pub offs: Vec<usize>,
    /// `(e1, e2)`: the two twin edges of one wedge of the bloom.
    pub pairs: Vec<(u32, u32)>,
}

impl RawBlooms {
    pub fn n_blooms(&self) -> usize {
        self.offs.len().saturating_sub(1)
    }
}

/// Options for a counting pass.
#[derive(Clone, Copy, Debug)]
pub struct CountOptions {
    pub per_edge: bool,
    pub build_blooms: bool,
    pub threads: usize,
}

impl Default for CountOptions {
    fn default() -> Self {
        CountOptions {
            per_edge: true,
            build_blooms: false,
            threads: 1,
        }
    }
}

/// Relabeled view used by the wedge traversal: vertex id == priority rank.
struct Relabeled {
    /// CSR offsets per label.
    offs: Vec<usize>,
    /// `(nbr_label, edge_id)`, ascending by label.
    adj: Vec<(u32, u32)>,
    /// label -> wid (to map counts back).
    unlab: Vec<u32>,
}

fn relabel(g: &BipartiteGraph) -> Relabeled {
    let nw = g.nw();
    let lab = g.priority_labels();
    let mut unlab = vec![0u32; nw];
    for (w, &l) in lab.iter().enumerate() {
        unlab[l as usize] = w as u32;
    }
    let mut offs = vec![0usize; nw + 1];
    for l in 0..nw {
        offs[l + 1] = offs[l] + g.deg_w(unlab[l] as usize);
    }
    let mut adj = vec![(0u32, 0u32); g.m() * 2];
    for l in 0..nw {
        let w = unlab[l] as usize;
        let (nbrs, wid_base) = g.nbrs_w(w);
        let dst = &mut adj[offs[l]..offs[l + 1]];
        for (i, &(n, e)) in nbrs.iter().enumerate() {
            dst[i] = (lab[wid_base + n as usize], e);
        }
        dst.sort_unstable();
    }
    Relabeled { offs, adj, unlab }
}

/// Per-vertex (and optionally per-edge) butterfly counting; optionally
/// harvests blooms for the BE-Index in the same pass.
pub fn pve_bcnt(
    g: &BipartiteGraph,
    opts: CountOptions,
    meters: Option<&Meters>,
) -> (Counts, RawBlooms) {
    let nw = g.nw();
    let r = relabel(g);
    let per_w: Vec<SupportCell> = (0..nw).map(|_| SupportCell::new(0)).collect();
    let per_edge: Vec<SupportCell> = if opts.per_edge {
        (0..g.m()).map(|_| SupportCell::new(0)).collect()
    } else {
        Vec::new()
    };
    let total = crate::par::Counter::new();

    let threads = opts.threads.max(1);
    let lanes = crate::par::max_lanes(threads);
    // Per-lane bloom harvests, merged afterwards.
    let mut harvests: Vec<crate::par::RacyCell<RawBloomsLocal>> = (0..lanes)
        .map(|_| crate::par::RacyCell::new(RawBloomsLocal::default()))
        .collect();
    // Per-lane scratch (wedge counts indexed by label).
    let scratch: Vec<crate::par::RacyCell<Scratch>> = (0..lanes)
        .map(|_| crate::par::RacyCell::new(Scratch::new(nw)))
        .collect();

    parallel_for_chunked(nw, threads, 64, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so slot `t` is exclusively ours inside this chunk.
        let mut sc = unsafe { scratch[t].get_mut() };
        // SAFETY: as above — harvest cell `t` is exclusively ours too.
        let mut hv = unsafe { harvests[t].get_mut() };
        let mut local_total = 0u64;
        let mut local_wedges = 0u64;
        for start in lo..hi {
            process_start(
                start as u32,
                &r,
                &per_w,
                &per_edge,
                opts,
                &mut sc,
                &mut hv,
                &mut local_total,
                &mut local_wedges,
            );
        }
        total.add(local_total);
        if let Some(m) = meters {
            m.wedges.add(local_wedges);
        }
    });

    // Gather per-vertex counts back to U/V order.
    let mut per_u = vec![0u64; g.nu()];
    let mut per_v = vec![0u64; g.nv()];
    for l in 0..nw {
        let w = r.unlab[l] as usize;
        let c = per_w[l].get();
        if w < g.nu() {
            per_u[w] = c;
        } else {
            per_v[w - g.nu()] = c;
        }
    }
    let per_edge: Vec<u64> = per_edge.iter().map(|c| c.get()).collect();

    // Merge bloom harvests.
    let mut raw = RawBlooms {
        offs: vec![0],
        pairs: Vec::new(),
    };
    if opts.build_blooms {
        for h in harvests.iter_mut() {
            let h = h.as_mut(); // region over: exclusive access
            for b in 0..h.ks.len() {
                let s = h.offs[b];
                let e = h.offs[b + 1];
                raw.pairs.extend_from_slice(&h.pairs[s..e]);
                raw.offs.push(raw.pairs.len());
            }
        }
    }

    (
        Counts {
            per_u,
            per_v,
            per_edge,
            total: total.get(),
        },
        raw,
    )
}

#[derive(Default)]
struct RawBloomsLocal {
    ks: Vec<u32>,
    offs: Vec<usize>,
    pairs: Vec<(u32, u32)>,
}

impl RawBloomsLocal {
    fn ensure_init(&mut self) {
        if self.offs.is_empty() {
            self.offs.push(0);
        }
    }
}

struct Scratch {
    wedge_count: Vec<u32>,
    /// distinct `last` labels touched for the current start
    touched: Vec<u32>,
    /// wedge list: (mid, last, e1, e2)
    nzw: Vec<(u32, u32, u32, u32)>,
    /// per-last local bloom slot (index into this start's bloom list)
    bloom_slot: Vec<u32>,
}

impl Scratch {
    fn new(nw: usize) -> Self {
        Scratch {
            wedge_count: vec![0; nw],
            touched: Vec::new(),
            nzw: Vec::new(),
            bloom_slot: vec![u32::MAX; nw],
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn process_start(
    start: u32,
    r: &Relabeled,
    per_w: &[SupportCell],
    per_edge: &[SupportCell],
    opts: CountOptions,
    sc: &mut Scratch,
    hv: &mut RawBloomsLocal,
    local_total: &mut u64,
    local_wedges: &mut u64,
) {
    sc.touched.clear();
    sc.nzw.clear();
    let s = start as usize;
    for &(mid, e1) in &r.adj[r.offs[s]..r.offs[s + 1]] {
        let m = mid as usize;
        for &(last, e2) in &r.adj[r.offs[m]..r.offs[m + 1]] {
            *local_wedges += 1;
            // adjacency ascends by label: once last >= min(mid, start),
            // every further neighbor fails the priority test too.
            if last >= mid || last >= start {
                break;
            }
            let l = last as usize;
            if sc.wedge_count[l] == 0 {
                sc.touched.push(last);
            }
            sc.wedge_count[l] += 1;
            sc.nzw.push((mid, last, e1, e2));
        }
    }
    // per-vertex endpoint contributions + total + bloom allocation
    for (ti, &last) in sc.touched.iter().enumerate() {
        let c = sc.wedge_count[last as usize] as u64;
        if c >= 2 {
            let bcnt = c * (c - 1) / 2;
            *local_total += bcnt;
            per_w[s].add(bcnt);
            per_w[last as usize].add(bcnt);
            if opts.build_blooms {
                hv.ensure_init();
                sc.bloom_slot[last as usize] = hv.ks.len() as u32;
                hv.ks.push(c as u32);
                // reserve: pairs appended in the nzw sweep below
                let _ = ti;
            }
        }
    }
    // mid + edge contributions; bloom pair harvest
    if opts.build_blooms {
        // two-pass: group pairs per bloom. Count first (already have c),
        // then append in bloom order using cursors.
        // Simpler: append into per-bloom Vecs is costly; instead sort-free
        // approach: iterate touched lasts in order, scan nzw once per
        // start collecting into a staging buffer bucketed by last.
        // nzw is small (bounded by wedges of this start), so an extra
        // pass is fine.
    }
    for &(mid, last, e1, e2) in &sc.nzw {
        let c = sc.wedge_count[last as usize] as u64;
        if c >= 2 {
            per_w[mid as usize].add(c - 1);
            if opts.per_edge {
                per_edge[e1 as usize].add(c - 1);
                per_edge[e2 as usize].add(c - 1);
            }
        }
    }
    if opts.build_blooms && !sc.nzw.is_empty() {
        hv.ensure_init();
        // Stable bucket append: blooms for this start were allocated in
        // `touched` order; nzw pairs are appended per bloom via slots.
        // We need contiguous pairs per bloom in hv.pairs; collect counts
        // then place with cursors.
        let base_pairs = hv.pairs.len();
        let first_new_bloom = hv.offs.len() - 1;
        let mut new_pairs = 0usize;
        for &last in &sc.touched {
            let c = sc.wedge_count[last as usize] as usize;
            if c >= 2 {
                new_pairs += c;
            }
        }
        hv.pairs
            .resize(base_pairs + new_pairs, (u32::MAX, u32::MAX));
        // cursor per bloom: reuse bloom_slot -> running index
        let mut cursors: Vec<usize> = Vec::new();
        {
            let mut acc = base_pairs;
            for &last in &sc.touched {
                let c = sc.wedge_count[last as usize] as usize;
                if c >= 2 {
                    cursors.push(acc);
                    acc += c;
                }
            }
        }
        // map bloom slot -> cursor index: slots were assigned in touched
        // order counting only c>=2 blooms, so the k-th qualifying touched
        // last has slot (first_new_bloom + k).
        for &(_, last, e1, e2) in &sc.nzw {
            let slot = sc.bloom_slot[last as usize];
            if slot == u32::MAX {
                continue; // c < 2, no bloom
            }
            let k = slot as usize - first_new_bloom;
            hv.pairs[cursors[k]] = (e1, e2);
            cursors[k] += 1;
        }
        // close offsets
        let mut acc = base_pairs;
        for &last in &sc.touched {
            let c = sc.wedge_count[last as usize] as usize;
            if c >= 2 {
                acc += c;
                hv.offs.push(acc);
            }
        }
        debug_assert_eq!(acc, hv.pairs.len());
    }
    // reset scratch
    for &last in &sc.touched {
        sc.wedge_count[last as usize] = 0;
        sc.bloom_slot[last as usize] = u32::MAX;
    }
}

/// Convenience: total butterflies only.
pub fn total_butterflies(g: &BipartiteGraph, threads: usize) -> u64 {
    pve_bcnt(
        g,
        CountOptions {
            per_edge: false,
            build_blooms: false,
            threads,
        },
        None,
    )
    .0
    .total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testkit::check_property;

    fn assert_counts_match_brute(g: &BipartiteGraph) {
        let (c, _) = pve_bcnt(
            g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 2,
            },
            None,
        );
        let b = brute::brute_counts(g);
        assert_eq!(c.total, b.total, "total mismatch");
        assert_eq!(c.per_u, b.per_u, "per-u mismatch");
        assert_eq!(c.per_v, b.per_v, "per-v mismatch");
        assert_eq!(c.per_edge, b.per_edge, "per-edge mismatch");
    }

    #[test]
    fn biclique_counts() {
        // K_{a,b}: total = C(a,2)*C(b,2); per edge = (a-1)(b-1)
        let g = gen::biclique(4, 5);
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 6 * 10);
        assert!(c.per_edge.iter().all(|&x| x == 12));
        // per u vertex: C(b,2)*(a-1) = 10*3 = 30
        assert!(c.per_u.iter().all(|&x| x == 30));
        // per v vertex: C(a,2)*(b-1) = 6*4 = 24
        assert!(c.per_v.iter().all(|&x| x == 24));
    }

    #[test]
    fn single_butterfly() {
        let g = gen::biclique(2, 2);
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 1);
        assert_eq!(c.per_u, vec![1, 1]);
        assert_eq!(c.per_v, vec![1, 1]);
        assert_eq!(c.per_edge, vec![1, 1, 1, 1]);
    }

    #[test]
    fn no_butterflies_in_tree() {
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 1), (2, 1)])
            .build();
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 0);
        assert!(c.per_edge.iter().all(|&x| x == 0));
    }

    #[test]
    fn matches_brute_on_random_graphs() {
        check_property("count-vs-brute", 0xC0047, 12, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let nu = 4 + rng.usize_below(20);
            let nv = 4 + rng.usize_below(20);
            let m = 10 + rng.usize_below(120);
            let g = gen::erdos(nu, nv, m, seed);
            let (c, _) = pve_bcnt(
                &g,
                CountOptions {
                    per_edge: true,
                    build_blooms: false,
                    threads: 2,
                },
                None,
            );
            let b = brute::brute_counts(&g);
            if c.total != b.total || c.per_u != b.per_u || c.per_v != b.per_v || c.per_edge != b.per_edge
            {
                return Err(format!("mismatch on graph m={}", g.m()));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_on_skewed_graph() {
        let g = gen::zipf(40, 40, 220, 1.3, 1.3, 77);
        assert_counts_match_brute(&g);
    }

    #[test]
    fn matches_brute_on_fig1() {
        let g = gen::paper_fig1();
        assert_counts_match_brute(&g);
    }

    #[test]
    fn parallel_matches_serial() {
        let g = gen::zipf(100, 100, 800, 1.2, 1.2, 5);
        let (c1, _) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 1,
            },
            None,
        );
        let (c4, _) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 4,
            },
            None,
        );
        assert_eq!(c1.total, c4.total);
        assert_eq!(c1.per_edge, c4.per_edge);
        assert_eq!(c1.per_u, c4.per_u);
    }

    #[test]
    fn wedge_meter_is_bounded_by_alpha_m() {
        let g = gen::zipf(60, 60, 400, 1.2, 1.2, 6);
        let meters = Meters::new();
        pve_bcnt(
            &g,
            CountOptions {
                per_edge: false,
                build_blooms: false,
                threads: 1,
            },
            Some(&meters),
        );
        // traversed wedges <= Σ_e min(du,dv) + m (one break-probe per list)
        let bound = g.count_workload_bound() + 2 * g.m() as u64;
        assert!(
            meters.wedges.get() <= bound,
            "wedges {} > bound {}",
            meters.wedges.get(),
            bound
        );
    }

    #[test]
    fn raw_blooms_sum_matches_total() {
        let g = gen::zipf(50, 50, 300, 1.2, 1.2, 8);
        let (c, raw) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: true,
                threads: 2,
            },
            None,
        );
        // Σ_blooms C(k,2) == total butterflies (Property 1 + 2)
        let total: u64 = (0..raw.n_blooms())
            .map(|b| {
                let k = (raw.offs[b + 1] - raw.offs[b]) as u64;
                k * (k - 1) / 2
            })
            .sum();
        assert_eq!(total, c.total);
        // no pair slot left unfilled
        assert!(raw.pairs.iter().all(|&(a, b)| a != u32::MAX && b != u32::MAX));
    }
}
