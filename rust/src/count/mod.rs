//! Vertex-priority butterfly counting (Alg. 1, Chiba–Nishizeki [7] /
//! Wang et al. [66] / Shi–Shun [54]) with optional embedded bloom
//! discovery for the BE-Index (§2.3).
//!
//! Vertices are relabeled by a priority order (label 0 = highest
//! priority); adjacency is sorted by increasing label; a wedge
//! `start → mid → last` is traversed iff `label(last) < label(mid)` and
//! `label(last) < label(start)`. Wedges sharing endpoints `(start, last)`
//! combine into `C(c, 2)` butterflies, and each such endpoint pair with
//! `c ≥ 2` is exactly one *maximal priority bloom*.
//!
//! The traversal is correct under any total vertex order (each butterfly
//! retires at the pair whose `last` has the globally minimal label);
//! [`order`] exploits that with a per-graph cost model, and [`kernel`]
//! supplies the blocked/SIMD intersection and aggregated-update
//! primitives. Under the default degree order the complexity is
//! `O(Σ_{(u,v)∈E} min(du, dv)) = O(α·m)` wedges.

pub mod brute;
pub mod dense;
pub mod kernel;
pub mod order;

pub use kernel::{KernelConfig, SimdPolicy, UpdateKernel};
pub use order::OrderPolicy;

use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use crate::par::{parallel_for_chunked, SupportCell};

/// Butterfly counts produced by [`pve_bcnt`].
#[derive(Clone, Debug)]
pub struct Counts {
    /// Per-U-vertex butterfly count.
    pub per_u: Vec<u64>,
    /// Per-V-vertex butterfly count.
    pub per_v: Vec<u64>,
    /// Per-edge butterfly count (empty unless requested).
    pub per_edge: Vec<u64>,
    /// Total butterflies in G.
    pub total: u64,
}

/// Bloom data harvested during counting, consumed by
/// [`crate::beindex::BeIndex::from_raw`].
///
/// Bloom `b` covers twin-edge pairs `pairs[offs[b]..offs[b+1]]`; its bloom
/// number is `offs[b+1] - offs[b]` (= the wedge count `k ≥ 2`).
#[derive(Clone, Debug, Default)]
pub struct RawBlooms {
    pub offs: Vec<usize>,
    /// `(e1, e2)`: the two twin edges of one wedge of the bloom.
    pub pairs: Vec<(u32, u32)>,
}

impl RawBlooms {
    pub fn n_blooms(&self) -> usize {
        self.offs.len().saturating_sub(1)
    }
}

/// Options for a counting pass.
#[derive(Clone, Copy, Debug)]
pub struct CountOptions {
    pub per_edge: bool,
    pub build_blooms: bool,
    pub threads: usize,
    /// Kernel selection (order policy / SIMD dispatch); the update
    /// strategy member only affects the peeling kernels.
    pub kernel: KernelConfig,
}

impl Default for CountOptions {
    fn default() -> Self {
        CountOptions {
            per_edge: true,
            build_blooms: false,
            threads: 1,
            kernel: KernelConfig::default(),
        }
    }
}

/// Relabeled view used by the wedge traversal: vertex id == priority
/// rank. Struct-of-arrays: the discovery loop scans only the contiguous
/// `labels` array (cache-resident, SIMD-friendly); `eids` is touched
/// only by the positional intersection paths.
struct Relabeled {
    /// CSR offsets per label.
    offs: Vec<usize>,
    /// Neighbor labels, ascending within each list.
    labels: Vec<u32>,
    /// Edge id carried by the same-index `labels` slot.
    eids: Vec<u32>,
    /// label -> wid (to map counts back).
    unlab: Vec<u32>,
}

fn relabel(g: &BipartiteGraph, lab: &[u32]) -> Relabeled {
    let nw = g.nw();
    let mut unlab = vec![0u32; nw];
    for (w, &l) in lab.iter().enumerate() {
        unlab[l as usize] = w as u32;
    }
    let mut offs = vec![0usize; nw + 1];
    for l in 0..nw {
        offs[l + 1] = offs[l] + g.deg_w(unlab[l] as usize);
    }
    let mut labels = vec![0u32; g.m() * 2];
    let mut eids = vec![0u32; g.m() * 2];
    let mut tmp: Vec<(u32, u32)> = Vec::new();
    for l in 0..nw {
        let w = unlab[l] as usize;
        let (nbrs, wid_base) = g.nbrs_w(w);
        tmp.clear();
        tmp.extend(nbrs.iter().map(|&(n, e)| (lab[wid_base + n as usize], e)));
        tmp.sort_unstable();
        for (i, &(nl, e)) in tmp.iter().enumerate() {
            labels[offs[l] + i] = nl;
            eids[offs[l] + i] = e;
        }
    }
    Relabeled {
        offs,
        labels,
        eids,
        unlab,
    }
}

/// Per-vertex (and optionally per-edge) butterfly counting; optionally
/// harvests blooms for the BE-Index in the same pass.
pub fn pve_bcnt(
    g: &BipartiteGraph,
    opts: CountOptions,
    meters: Option<&Meters>,
) -> (Counts, RawBlooms) {
    let nw = g.nw();
    let resolved = opts.kernel.order.resolve(g);
    kernel::note_side_choice(resolved.side_code());
    // SIMD serves the label-only path; positional edge payloads
    // (per-edge counts, bloom harvest) keep the pairs path scalar.
    let simd = kernel::simd_active(opts.kernel.simd) && !opts.per_edge && !opts.build_blooms;
    let _span = crate::obs::span(
        crate::obs::Kind::CountKernel,
        nw as u64,
        resolved.side_code(),
        simd as u64,
    );
    let r = relabel(g, &order::labels(g, resolved));
    let per_w: Vec<SupportCell> = (0..nw).map(|_| SupportCell::new(0)).collect();
    let per_edge: Vec<SupportCell> = if opts.per_edge {
        (0..g.m()).map(|_| SupportCell::new(0)).collect()
    } else {
        Vec::new()
    };
    let total = crate::par::Counter::new();

    let threads = opts.threads.max(1);
    let lanes = crate::par::max_lanes(threads);
    // Per-lane bloom harvests, merged afterwards.
    let mut harvests: Vec<crate::par::RacyCell<RawBloomsLocal>> = (0..lanes)
        .map(|_| crate::par::RacyCell::new(RawBloomsLocal::default()))
        .collect();
    // Per-lane scratch (wedge counts indexed by label).
    let scratch: Vec<crate::par::RacyCell<Scratch>> = (0..lanes)
        .map(|_| crate::par::RacyCell::new(Scratch::new(nw)))
        .collect();

    parallel_for_chunked(nw, threads, 64, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so slot `t` is exclusively ours inside this chunk.
        let mut sc = unsafe { scratch[t].get_mut() };
        // SAFETY: as above — harvest cell `t` is exclusively ours too.
        let mut hv = unsafe { harvests[t].get_mut() };
        let mut local_total = 0u64;
        let mut local_wedges = 0u64;
        for start in lo..hi {
            process_start(
                start as u32,
                &r,
                &per_w,
                &per_edge,
                opts,
                simd,
                &mut sc,
                &mut hv,
                &mut local_total,
                &mut local_wedges,
            );
        }
        total.add(local_total);
        if let Some(m) = meters {
            m.wedges.add(local_wedges);
        }
    });

    // Gather per-vertex counts back to U/V order.
    let mut per_u = vec![0u64; g.nu()];
    let mut per_v = vec![0u64; g.nv()];
    for l in 0..nw {
        let w = r.unlab[l] as usize;
        let c = per_w[l].get();
        if w < g.nu() {
            per_u[w] = c;
        } else {
            per_v[w - g.nu()] = c;
        }
    }
    let per_edge: Vec<u64> = per_edge.iter().map(|c| c.get()).collect();

    // Merge bloom harvests.
    let mut raw = RawBlooms {
        offs: vec![0],
        pairs: Vec::new(),
    };
    if opts.build_blooms {
        for h in harvests.iter_mut() {
            let h = h.as_mut(); // region over: exclusive access
            for b in 0..h.ks.len() {
                let s = h.offs[b];
                let e = h.offs[b + 1];
                raw.pairs.extend_from_slice(&h.pairs[s..e]);
                raw.offs.push(raw.pairs.len());
            }
        }
    }

    (
        Counts {
            per_u,
            per_v,
            per_edge,
            total: total.get(),
        },
        raw,
    )
}

#[derive(Default)]
struct RawBloomsLocal {
    ks: Vec<u32>,
    offs: Vec<usize>,
    pairs: Vec<(u32, u32)>,
}

impl RawBloomsLocal {
    fn ensure_init(&mut self) {
        if self.offs.is_empty() {
            self.offs.push(0);
        }
    }
}

struct Scratch {
    wedge_count: Vec<u32>,
    /// distinct `last` labels touched for the current start
    touched: Vec<u32>,
}

impl Scratch {
    fn new(nw: usize) -> Self {
        Scratch {
            wedge_count: vec![0; nw],
            touched: Vec::new(),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn process_start(
    start: u32,
    r: &Relabeled,
    per_w: &[SupportCell],
    per_edge: &[SupportCell],
    opts: CountOptions,
    simd: bool,
    sc: &mut Scratch,
    hv: &mut RawBloomsLocal,
    local_total: &mut u64,
    local_wedges: &mut u64,
) {
    sc.touched.clear();
    let s = start as usize;
    let s_labs = &r.labels[r.offs[s]..r.offs[s + 1]];
    // Wedge discovery: one contiguous label scan per mid, counting
    // wedges per `last` endpoint. The wedge meter ticks once per probe,
    // including the probe that breaks.
    for &mid in s_labs {
        let m = mid as usize;
        for &last in &r.labels[r.offs[m]..r.offs[m + 1]] {
            *local_wedges += 1;
            // adjacency ascends by label: once last >= min(mid, start),
            // every further neighbor fails the priority test too.
            if last >= mid || last >= start {
                break;
            }
            let l = last as usize;
            if sc.wedge_count[l] == 0 {
                sc.touched.push(last);
            }
            sc.wedge_count[l] += 1;
        }
    }
    // Harvest per endpoint pair: the qualifying mids of `(start, last)`
    // are exactly the common neighbors with label > last — a suffix
    // intersection of the two sorted adjacency lists, which replaces
    // the scattered wedge-list sweep with blocked sequential scans.
    let pairs_path = opts.per_edge || opts.build_blooms;
    let s_eids = &r.eids[r.offs[s]..r.offs[s + 1]];
    for &last in &sc.touched {
        let l = last as usize;
        let c = sc.wedge_count[l] as u64;
        sc.wedge_count[l] = 0; // restore the slot's zero invariant
        if c < 2 {
            continue;
        }
        let bcnt = c * (c - 1) / 2;
        *local_total += bcnt;
        per_w[s].add(bcnt);
        per_w[l].add(bcnt);
        let l_labs = &r.labels[r.offs[l]..r.offs[l + 1]];
        let ps = s_labs.partition_point(|&x| x <= last);
        let pl = l_labs.partition_point(|&x| x <= last);
        let mut found = 0u64;
        if pairs_path {
            let l_eids = &r.eids[r.offs[l]..r.offs[l + 1]];
            if opts.build_blooms {
                hv.ensure_init();
                hv.ks.push(c as u32);
            }
            kernel::intersect_pairs(
                &s_labs[ps..],
                &s_eids[ps..],
                &l_labs[pl..],
                &l_eids[pl..],
                &mut |mid, e1, e2| {
                    found += 1;
                    per_w[mid as usize].add(c - 1);
                    if opts.per_edge {
                        per_edge[e1 as usize].add(c - 1);
                        per_edge[e2 as usize].add(c - 1);
                    }
                    if opts.build_blooms {
                        hv.pairs.push((e1, e2));
                    }
                },
            );
            if opts.build_blooms {
                hv.offs.push(hv.pairs.len());
            }
        } else {
            kernel::intersect_values(&s_labs[ps..], &l_labs[pl..], simd, |mid| {
                found += 1;
                per_w[mid as usize].add(c - 1);
            });
        }
        debug_assert_eq!(
            found, c,
            "pair (start={start}, last={last}): intersection disagrees with discovery"
        );
    }
}

/// Convenience: total butterflies only.
pub fn total_butterflies(g: &BipartiteGraph, threads: usize) -> u64 {
    pve_bcnt(
        g,
        CountOptions {
            per_edge: false,
            build_blooms: false,
            threads,
            kernel: KernelConfig::default(),
        },
        None,
    )
    .0
    .total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testkit::check_property;

    fn assert_counts_match_brute(g: &BipartiteGraph) {
        let (c, _) = pve_bcnt(
            g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 2,
                kernel: KernelConfig::default(),
            },
            None,
        );
        let b = brute::brute_counts(g);
        assert_eq!(c.total, b.total, "total mismatch");
        assert_eq!(c.per_u, b.per_u, "per-u mismatch");
        assert_eq!(c.per_v, b.per_v, "per-v mismatch");
        assert_eq!(c.per_edge, b.per_edge, "per-edge mismatch");
    }

    #[test]
    fn biclique_counts() {
        // K_{a,b}: total = C(a,2)*C(b,2); per edge = (a-1)(b-1)
        let g = gen::biclique(4, 5);
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 6 * 10);
        assert!(c.per_edge.iter().all(|&x| x == 12));
        // per u vertex: C(b,2)*(a-1) = 10*3 = 30
        assert!(c.per_u.iter().all(|&x| x == 30));
        // per v vertex: C(a,2)*(b-1) = 6*4 = 24
        assert!(c.per_v.iter().all(|&x| x == 24));
    }

    #[test]
    fn single_butterfly() {
        let g = gen::biclique(2, 2);
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 1);
        assert_eq!(c.per_u, vec![1, 1]);
        assert_eq!(c.per_v, vec![1, 1]);
        assert_eq!(c.per_edge, vec![1, 1, 1, 1]);
    }

    #[test]
    fn no_butterflies_in_tree() {
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 1), (2, 1)])
            .build();
        let (c, _) = pve_bcnt(&g, CountOptions::default(), None);
        assert_eq!(c.total, 0);
        assert!(c.per_edge.iter().all(|&x| x == 0));
    }

    #[test]
    fn matches_brute_on_random_graphs() {
        check_property("count-vs-brute", 0xC0047, 12, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let nu = 4 + rng.usize_below(20);
            let nv = 4 + rng.usize_below(20);
            let m = 10 + rng.usize_below(120);
            let g = gen::erdos(nu, nv, m, seed);
            let (c, _) = pve_bcnt(
                &g,
                CountOptions {
                    per_edge: true,
                    build_blooms: false,
                    threads: 2,
                    kernel: KernelConfig::default(),
                },
                None,
            );
            let b = brute::brute_counts(&g);
            if c.total != b.total || c.per_u != b.per_u || c.per_v != b.per_v || c.per_edge != b.per_edge
            {
                return Err(format!("mismatch on graph m={}", g.m()));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_on_skewed_graph() {
        let g = gen::zipf(40, 40, 220, 1.3, 1.3, 77);
        assert_counts_match_brute(&g);
    }

    #[test]
    fn matches_brute_on_fig1() {
        let g = gen::paper_fig1();
        assert_counts_match_brute(&g);
    }

    #[test]
    fn parallel_matches_serial() {
        let g = gen::zipf(100, 100, 800, 1.2, 1.2, 5);
        let (c1, _) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 1,
                kernel: KernelConfig::default(),
            },
            None,
        );
        let (c4, _) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: false,
                threads: 4,
                kernel: KernelConfig::default(),
            },
            None,
        );
        assert_eq!(c1.total, c4.total);
        assert_eq!(c1.per_edge, c4.per_edge);
        assert_eq!(c1.per_u, c4.per_u);
    }

    #[test]
    fn wedge_meter_is_bounded_by_alpha_m() {
        let g = gen::zipf(60, 60, 400, 1.2, 1.2, 6);
        let meters = Meters::new();
        pve_bcnt(
            &g,
            CountOptions {
                per_edge: false,
                build_blooms: false,
                threads: 1,
                kernel: KernelConfig::default(),
            },
            Some(&meters),
        );
        // traversed wedges <= Σ_e min(du,dv) + m (one break-probe per list)
        let bound = g.count_workload_bound() + 2 * g.m() as u64;
        assert!(
            meters.wedges.get() <= bound,
            "wedges {} > bound {}",
            meters.wedges.get(),
            bound
        );
    }

    #[test]
    fn raw_blooms_sum_matches_total() {
        let g = gen::zipf(50, 50, 300, 1.2, 1.2, 8);
        let (c, raw) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: true,
                threads: 2,
                kernel: KernelConfig::default(),
            },
            None,
        );
        // Σ_blooms C(k,2) == total butterflies (Property 1 + 2)
        let total: u64 = (0..raw.n_blooms())
            .map(|b| {
                let k = (raw.offs[b + 1] - raw.offs[b]) as u64;
                k * (k - 1) / 2
            })
            .sum();
        assert_eq!(total, c.total);
        // every pair slot was filled by the intersection harvest
        assert_eq!(*raw.offs.last().unwrap(), raw.pairs.len());
    }

    #[test]
    fn order_policies_agree_on_counts() {
        let g = gen::zipf(45, 55, 350, 1.25, 1.2, 13);
        let base = pve_bcnt(&g, CountOptions::default(), None).0;
        for order in [OrderPolicy::SideU, OrderPolicy::SideV, OrderPolicy::Auto] {
            let opts = CountOptions {
                kernel: KernelConfig {
                    order,
                    ..KernelConfig::default()
                },
                ..CountOptions::default()
            };
            let c = pve_bcnt(&g, opts, None).0;
            assert_eq!(c.total, base.total, "{order:?} total");
            assert_eq!(c.per_u, base.per_u, "{order:?} per-u");
            assert_eq!(c.per_v, base.per_v, "{order:?} per-v");
            assert_eq!(c.per_edge, base.per_edge, "{order:?} per-edge");
        }
    }

    #[test]
    fn side_orders_harvest_valid_blooms() {
        // The bloom *partition* legitimately differs per order (each
        // order retires butterflies at different endpoint pairs), but
        // every harvest must satisfy Σ_blooms C(k,2) == total and agree
        // on the order-independent counts.
        let g = gen::zipf(40, 40, 260, 1.2, 1.3, 31);
        let opts = |order| CountOptions {
            per_edge: true,
            build_blooms: true,
            threads: 2,
            kernel: KernelConfig {
                order,
                ..KernelConfig::default()
            },
        };
        let (cd, _) = pve_bcnt(&g, opts(OrderPolicy::Degree), None);
        for order in [OrderPolicy::SideU, OrderPolicy::SideV] {
            let (c, r) = pve_bcnt(&g, opts(order), None);
            assert_eq!(c.total, cd.total);
            assert_eq!(c.per_edge, cd.per_edge);
            let bloom_total: u64 = (0..r.n_blooms())
                .map(|b| {
                    let k = (r.offs[b + 1] - r.offs[b]) as u64;
                    k * (k - 1) / 2
                })
                .sum();
            assert_eq!(bloom_total, c.total, "{order:?} bloom sum");
        }
    }
}
