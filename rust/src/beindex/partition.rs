//! BE-Index partitioning for PBNG FD (Alg. 5, lines 12–25).
//!
//! For partition `E_i`, a link `(e, B)` is preserved iff `e ∈ E_i` and
//! `p(twin(e,B)) ≥ i`; the local bloom number `k_B(I_i)` is the number of
//! wedges of `B` whose *both* edges lie in `E_{≥i}` (computed as a suffix
//! sum of per-partition wedge counts). This makes each `I_i` a standalone
//! index over the "universe ≥ i": peeling `E_i` with `I_i` produces
//! exactly the same support updates BUP would (Theorem 2), and each link
//! of `I` lands in at most one `I_i`, so collective space is `O(α·m)`
//! (Theorem 5).

use super::BeIndex;
use crate::par::RacyBuf;

/// Per-partition BE-Index with global edge ids and local bloom ids.
#[derive(Debug, Default)]
pub struct PartIndex {
    /// Adjusted bloom numbers for this partition's universe.
    pub bloom_k: Vec<u32>,
    /// CSR offsets into `bloom_entries`.
    pub bloom_offs: Vec<usize>,
    /// `(edge, twin)` links preserved for this partition (global edge ids).
    pub bloom_entries: Vec<(u32, u32)>,
    /// CSR offsets into `edge_links`, indexed by *local* edge id.
    pub edge_offs: Vec<usize>,
    /// `(local_bloom, twin_edge)` links of each local edge.
    pub edge_links: Vec<(u32, u32)>,
}

/// Output of [`partition_be_index`]: partition indices plus the global
/// edge→local-id map (each edge belongs to exactly one partition).
pub struct Partitioned {
    pub parts: Vec<PartIndex>,
    /// `edges_of[i]` = global edge ids of `E_i` (ascending).
    pub edges_of: Vec<Vec<u32>>,
    /// `local_of[e]` = index of `e` within its partition's `edges_of`.
    pub local_of: Vec<u32>,
}

/// Partition the original BE-Index given the CD partition assignment
/// `part_of[e] ∈ [0, p)`.
pub fn partition_be_index(idx: &BeIndex, part_of: &[u32], p: usize) -> Partitioned {
    let m = part_of.len();
    // edge lists + local ids
    let mut edges_of: Vec<Vec<u32>> = vec![Vec::new(); p];
    for e in 0..m as u32 {
        edges_of[part_of[e as usize] as usize].push(e);
    }
    let mut local_of = vec![0u32; m];
    for es in &edges_of {
        for (i, &e) in es.iter().enumerate() {
            local_of[e as usize] = i as u32;
        }
    }

    // Pass over blooms, bucketing kept links per partition.
    // Parallelizable (disjoint per-thread builders); sequential sweep with
    // a small per-bloom scratch is fast enough and deterministic.
    struct Builder {
        bloom_k: Vec<u32>,
        bloom_offs: Vec<usize>,
        bloom_entries: Vec<(u32, u32)>,
    }
    let mut builders: Vec<Builder> = (0..p)
        .map(|_| Builder {
            bloom_k: Vec::new(),
            bloom_offs: vec![0],
            bloom_entries: Vec::new(),
        })
        .collect();

    // scratch: per-partition wedge counts and kept links for one bloom
    let mut touched: Vec<u32> = Vec::new(); // partition ids touched
    let mut wedge_cnt: Vec<u32> = vec![0; p];
    let mut kept: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];

    for b in 0..idx.n_blooms() as u32 {
        let ents = idx.entries(b);
        for &(e, t) in ents {
            let pe = part_of[e as usize];
            let pt = part_of[t as usize];
            // link (e,B) kept in partition pe iff p(t) >= p(e)
            if pt >= pe {
                if kept[pe as usize].is_empty() && wedge_cnt[pe as usize] == 0 {
                    touched.push(pe);
                }
                kept[pe as usize].push((e, t));
                // wedge counted once at its min partition
                if pt > pe || (pt == pe && t < e) {
                    wedge_cnt[pe as usize] += 1;
                }
            } else {
                // wedge's min partition is pt; counted from t's orientation
                // (p(e) > p(t) there). Nothing kept for e.
            }
        }
        if touched.is_empty() {
            continue;
        }
        touched.sort_unstable();
        // suffix-sum bloom numbers: k_B(I_i) = Σ_{j >= i} wedge_cnt[j].
        // Only partitions with kept links get a local bloom.
        let mut suffix = 0u32;
        // iterate descending
        for idx_t in (0..touched.len()).rev() {
            let i = touched[idx_t] as usize;
            suffix += wedge_cnt[i];
            if !kept[i].is_empty() {
                let bld = &mut builders[i];
                bld.bloom_k.push(suffix);
                bld.bloom_entries.extend_from_slice(&kept[i]);
                bld.bloom_offs.push(bld.bloom_entries.len());
            }
        }
        for &i in &touched {
            wedge_cnt[i as usize] = 0;
            kept[i as usize].clear();
        }
        touched.clear();
    }

    // Build per-partition edge-side CSR in parallel (disjoint partitions).
    let parts_buf = RacyBuf::new((0..p).map(|_| PartIndex::default()).collect::<Vec<_>>());
    let builders_ref = &builders;
    let edges_ref = &edges_of;
    let local_ref = &local_of;
    crate::par::parallel_for(p, 1, |_, i| {
        let bld = &builders_ref[i];
        let n_local = edges_ref[i].len();
        let mut deg = vec![0usize; n_local];
        for &(e, _) in &bld.bloom_entries {
            deg[local_ref[e as usize] as usize] += 1;
        }
        let mut edge_offs = vec![0usize; n_local + 1];
        for j in 0..n_local {
            edge_offs[j + 1] = edge_offs[j] + deg[j];
        }
        let mut edge_links = vec![(0u32, 0u32); bld.bloom_entries.len()];
        let mut cur = edge_offs.clone();
        for lb in 0..bld.bloom_k.len() {
            for k in bld.bloom_offs[lb]..bld.bloom_offs[lb + 1] {
                let (e, t) = bld.bloom_entries[k];
                let le = local_ref[e as usize] as usize;
                edge_links[cur[le]] = (lb as u32, t);
                cur[le] += 1;
            }
        }
        // SAFETY: each index `i` is visited exactly once, so element `i`
        // of the shared buffer is exclusively this iteration's.
        unsafe {
            parts_buf.set(
                i,
                PartIndex {
                    bloom_k: bld.bloom_k.clone(),
                    bloom_offs: bld.bloom_offs.clone(),
                    bloom_entries: bld.bloom_entries.clone(),
                    edge_offs,
                    edge_links,
                },
            )
        };
    });
    let parts = parts_buf.into_inner();

    Partitioned {
        parts,
        edges_of,
        local_of,
    }
}

impl PartIndex {
    pub fn n_blooms(&self) -> usize {
        self.bloom_k.len()
    }
    #[inline]
    pub fn links_of(&self, local_e: usize) -> &[(u32, u32)] {
        &self.edge_links[self.edge_offs[local_e]..self.edge_offs[local_e + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    /// With a single partition, the partitioned index must be equivalent
    /// to the original: same bloom multiset, same k values.
    #[test]
    fn single_partition_is_identity() {
        let g = gen::zipf(40, 40, 250, 1.2, 1.2, 31);
        let (idx, _) = BeIndex::build(&g, 1);
        let part_of = vec![0u32; g.m()];
        let pt = partition_be_index(&idx, &part_of, 1);
        assert_eq!(pt.parts.len(), 1);
        let p0 = &pt.parts[0];
        let mut orig: Vec<(u32, usize)> = (0..idx.n_blooms())
            .map(|b| (idx.bloom_k[b], idx.entries(b as u32).len()))
            .collect();
        let mut new: Vec<(u32, usize)> = (0..p0.n_blooms())
            .map(|b| {
                (
                    p0.bloom_k[b],
                    p0.bloom_offs[b + 1] - p0.bloom_offs[b],
                )
            })
            .collect();
        orig.sort_unstable();
        new.sort_unstable();
        assert_eq!(orig, new);
        // every link preserved
        assert_eq!(p0.bloom_entries.len(), idx.n_links());
    }

    /// Each original link appears in at most one partition (Theorem 5).
    #[test]
    fn links_land_in_at_most_one_partition() {
        let g = gen::zipf(40, 40, 250, 1.2, 1.2, 32);
        let (idx, _) = BeIndex::build(&g, 1);
        let part_of: Vec<u32> = (0..g.m() as u32).map(|e| e % 3).collect();
        let pt = partition_be_index(&idx, &part_of, 3);
        let total: usize = pt.parts.iter().map(|p| p.bloom_entries.len()).sum();
        assert!(total <= idx.n_links());
        // kept link (e,t): p(t) >= p(e) — verify
        for (i, p) in pt.parts.iter().enumerate() {
            for &(e, t) in &p.bloom_entries {
                assert_eq!(part_of[e as usize] as usize, i);
                assert!(part_of[t as usize] as usize >= i);
            }
        }
    }

    /// Bloom number of a local bloom counts wedges fully inside the >= i
    /// universe.
    #[test]
    fn bloom_numbers_are_suffix_counts() {
        let g = gen::biclique(2, 5); // one bloom, k = 5
        let (idx, _) = BeIndex::build(&g, 1);
        assert_eq!(idx.n_blooms(), 1);
        // Edges: (u0,v),(u1,v) pairs are twins. Assign one twin pair to
        // partition 0 and the rest to partition 1.
        let ents = idx.entries(0);
        let (e0, t0) = ents[0];
        let mut part_of = vec![1u32; g.m()];
        part_of[e0 as usize] = 0;
        part_of[t0 as usize] = 0;
        let pt = partition_be_index(&idx, &part_of, 2);
        // partition 1 sees k = 4 wedges (one wedge dropped to partition 0)
        let p1 = &pt.parts[1];
        assert_eq!(p1.n_blooms(), 1);
        assert_eq!(p1.bloom_k[0], 4);
        // partition 0 sees all 5 wedges in its universe (0 ∪ 1)
        let p0 = &pt.parts[0];
        assert_eq!(p0.n_blooms(), 1);
        assert_eq!(p0.bloom_k[0], 5);
        // but partition 0 keeps only its own edges' links
        assert_eq!(p0.bloom_entries.len(), 2);
    }

    #[test]
    fn edge_links_consistent_with_bloom_entries() {
        let g = gen::zipf(30, 30, 200, 1.1, 1.1, 33);
        let (idx, _) = BeIndex::build(&g, 1);
        let part_of: Vec<u32> = (0..g.m() as u32).map(|e| e % 4).collect();
        let pt = partition_be_index(&idx, &part_of, 4);
        for (i, p) in pt.parts.iter().enumerate() {
            for (le, &e) in pt.edges_of[i].iter().enumerate() {
                for &(lb, t) in p.links_of(le) {
                    let s = p.bloom_offs[lb as usize];
                    let eend = p.bloom_offs[lb as usize + 1];
                    assert!(p.bloom_entries[s..eend].contains(&(e, t)));
                }
            }
        }
    }
}
