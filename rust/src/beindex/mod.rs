//! BE-Index: the Bloom-Edge index of Wang et al. [67] (§2.3).
//!
//! A *maximal priority bloom* is a (2,k)-biclique whose dominant vertex
//! pair contains the bloom's highest-priority vertex; every butterfly of G
//! lives in exactly one bloom (Property 2), and an edge `e` of a k-bloom
//! shares all `k−1` of its in-bloom butterflies with its *twin*
//! `twin(e, B)` and exactly one with every other edge of the bloom
//! (Property 1). The index is bipartite: blooms on one side, edges on the
//! other, each link labeled with the twin.
//!
//! Peeling state lives here too: `bloom_k` (current bloom numbers) and an
//! active-length per bloom for the dynamic link-deletion optimization
//! (§5.2). Edge-side lists are immutable; staleness is detected through
//! the peel-epoch array owned by the peeling algorithms.

pub mod partition;

use crate::count::RawBlooms;
use crate::graph::BipartiteGraph;
use crate::par::parallel_for;

/// Immutable topology + mutable peeling state of the BE-Index.
#[derive(Debug)]
pub struct BeIndex {
    /// CSR offsets into `bloom_entries`, length `nb + 1`.
    pub bloom_offs: Vec<usize>,
    /// `(edge, twin)` — 2k entries per k-bloom (both orientations of each
    /// twin pair).
    pub bloom_entries: Vec<(u32, u32)>,
    /// Active prefix length of each bloom's entry slice (compaction for
    /// dynamic deletes, §5.2). Initially `2k`.
    pub bloom_len: Vec<u32>,
    /// Current bloom number `k_B` (active wedges). Initially `k`.
    pub bloom_k: Vec<u32>,
    /// CSR offsets into `edge_blooms`, length `m + 1`.
    pub edge_offs: Vec<usize>,
    /// `(bloom, twin_edge)` links of each edge.
    pub edge_blooms: Vec<(u32, u32)>,
}

impl BeIndex {
    pub fn n_blooms(&self) -> usize {
        self.bloom_k.len()
    }

    /// Total bloom-edge links `|E(I)|`.
    pub fn n_links(&self) -> usize {
        self.bloom_entries.len()
    }

    /// Build from counting harvest. `O(α·m)` space and time.
    pub fn from_raw(g: &BipartiteGraph, raw: &RawBlooms) -> BeIndex {
        let nb = raw.n_blooms();
        let m = g.m();
        let mut bloom_offs = Vec::with_capacity(nb + 1);
        let mut bloom_entries = Vec::with_capacity(raw.pairs.len() * 2);
        let mut bloom_k = Vec::with_capacity(nb);
        bloom_offs.push(0usize);
        for b in 0..nb {
            let s = raw.offs[b];
            let e = raw.offs[b + 1];
            for &(e1, e2) in &raw.pairs[s..e] {
                bloom_entries.push((e1, e2));
                bloom_entries.push((e2, e1));
            }
            bloom_k.push((e - s) as u32);
            bloom_offs.push(bloom_entries.len());
        }
        let bloom_len: Vec<u32> = (0..nb)
            .map(|b| (bloom_offs[b + 1] - bloom_offs[b]) as u32)
            .collect();
        // edge-side CSR
        let mut deg = vec![0usize; m];
        for &(e, _) in &bloom_entries {
            deg[e as usize] += 1;
        }
        let mut edge_offs = vec![0usize; m + 1];
        for i in 0..m {
            edge_offs[i + 1] = edge_offs[i] + deg[i];
        }
        let mut edge_blooms = vec![(0u32, 0u32); bloom_entries.len()];
        let mut cur = edge_offs.clone();
        for b in 0..nb {
            for i in bloom_offs[b]..bloom_offs[b + 1] {
                let (e, t) = bloom_entries[i];
                edge_blooms[cur[e as usize]] = (b as u32, t);
                cur[e as usize] += 1;
            }
        }
        BeIndex {
            bloom_offs,
            bloom_entries,
            bloom_len,
            bloom_k,
            edge_offs,
            edge_blooms,
        }
    }

    /// Build directly from a graph (counting pass included) with the
    /// default counting kernel.
    pub fn build(g: &BipartiteGraph, threads: usize) -> (BeIndex, Vec<u64>) {
        Self::build_with(g, threads, crate::count::KernelConfig::default())
    }

    /// Build directly from a graph with an explicit counting-kernel
    /// configuration (wedge-side policy, SIMD policy). The index is valid
    /// for any wedge-side order — bloom partitions differ across orders,
    /// but `Σ_B C(k_B, 2)` and the per-edge counts are invariant.
    pub fn build_with(
        g: &BipartiteGraph,
        threads: usize,
        kernel: crate::count::KernelConfig,
    ) -> (BeIndex, Vec<u64>) {
        let (counts, raw) = crate::count::pve_bcnt(
            g,
            crate::count::CountOptions {
                per_edge: true,
                build_blooms: true,
                threads,
                kernel,
            },
            None,
        );
        (BeIndex::from_raw(g, &raw), counts.per_edge)
    }

    /// Active `(edge, twin)` entries of bloom `b`.
    #[inline]
    pub fn entries(&self, b: u32) -> &[(u32, u32)] {
        let s = self.bloom_offs[b as usize];
        &self.bloom_entries[s..s + self.bloom_len[b as usize] as usize]
    }

    /// All `(bloom, twin)` links of edge `e` (may contain stale links —
    /// callers must check the twin's peel state).
    #[inline]
    pub fn links_of(&self, e: u32) -> &[(u32, u32)] {
        &self.edge_blooms[self.edge_offs[e as usize]..self.edge_offs[e as usize + 1]]
    }

    /// Per-edge butterfly count recomputed from the index:
    /// `⋈_e = Σ_{B ∋ e} (k_B − 1)` (Property 1). Used to validate the
    /// index against per-edge counting.
    pub fn edge_counts_from_index(&self, m: usize, threads: usize) -> Vec<u64> {
        let out: Vec<crate::par::SupportCell> =
            (0..m).map(|_| crate::par::SupportCell::new(0)).collect();
        parallel_for(m, threads, |_, e| {
            let mut s = 0u64;
            for &(b, _) in self.links_of(e as u32) {
                s += (self.bloom_k[b as usize] - 1) as u64;
            }
            out[e].set(s);
        });
        out.iter().map(|c| c.get()).collect()
    }

    /// Checks structural invariants (tests / debug only).
    pub fn validate(&self, g: &BipartiteGraph) -> Result<(), String> {
        for b in 0..self.n_blooms() as u32 {
            let k = self.bloom_k[b as usize] as usize;
            let ents = self.entries(b);
            if ents.len() != 2 * k {
                return Err(format!("bloom {b}: {} entries for k={k}", ents.len()));
            }
            for &(e, t) in ents {
                if e as usize >= g.m() || t as usize >= g.m() {
                    return Err(format!("bloom {b}: edge id out of range"));
                }
                // twin symmetry
                if !ents.contains(&(t, e)) {
                    return Err(format!("bloom {b}: twin pair ({e},{t}) not symmetric"));
                }
                // e and t must share exactly one vertex on the non-dominant
                // side: they form a wedge.
                let (u1, v1) = g.edge(e);
                let (u2, v2) = g.edge(t);
                if u1 != u2 && v1 != v2 {
                    return Err(format!("bloom {b}: twins ({e},{t}) do not share a vertex"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{pve_bcnt, CountOptions};
    use crate::graph::gen;

    fn build(g: &BipartiteGraph) -> (BeIndex, Vec<u64>) {
        BeIndex::build(g, 2)
    }

    #[test]
    fn biclique_has_one_bloom() {
        // K_{2,3}: dominant pair = the two U vertices (deg 3 each);
        // one bloom with k = 3.
        let g = gen::biclique(2, 3);
        let (idx, _) = build(&g);
        assert_eq!(idx.n_blooms(), 1);
        assert_eq!(idx.bloom_k[0], 3);
        assert_eq!(idx.entries(0).len(), 6);
        idx.validate(&g).unwrap();
    }

    #[test]
    fn k33_bloom_structure() {
        let g = gen::biclique(3, 3);
        let (idx, per_edge) = build(&g);
        idx.validate(&g).unwrap();
        // Σ C(k,2) over blooms = total butterflies = 9
        let total: u64 = idx
            .bloom_k
            .iter()
            .map(|&k| (k as u64) * (k as u64 - 1) / 2)
            .sum();
        assert_eq!(total, 9);
        // per-edge counts from index must match counting
        let from_idx = idx.edge_counts_from_index(g.m(), 2);
        assert_eq!(from_idx, per_edge);
    }

    #[test]
    fn index_counts_match_on_random_graphs() {
        crate::testkit::check_property("beindex-counts", 0xBE1, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let nu = 5 + rng.usize_below(25);
            let nv = 5 + rng.usize_below(25);
            let m = 20 + rng.usize_below(150);
            let g = gen::erdos(nu, nv, m, seed);
            let (idx, per_edge) = build(&g);
            if let Err(e) = idx.validate(&g) {
                return Err(e);
            }
            let from_idx = idx.edge_counts_from_index(g.m(), 1);
            if from_idx != per_edge {
                return Err("per-edge counts via index mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn edge_side_links_are_consistent() {
        let g = gen::zipf(30, 30, 150, 1.2, 1.2, 13);
        let (idx, _) = build(&g);
        for e in 0..g.m() as u32 {
            for &(b, t) in idx.links_of(e) {
                assert!(idx.entries(b).contains(&(e, t)));
            }
        }
    }

    #[test]
    fn bloom_count_parallel_matches_serial() {
        let g = gen::zipf(50, 50, 300, 1.2, 1.2, 21);
        let (c1, r1) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: true,
                threads: 1,
                kernel: crate::count::KernelConfig::default(),
            },
            None,
        );
        let (c4, r4) = pve_bcnt(
            &g,
            CountOptions {
                per_edge: true,
                build_blooms: true,
                threads: 4,
                kernel: crate::count::KernelConfig::default(),
            },
            None,
        );
        assert_eq!(c1.total, c4.total);
        // bloom sets may be ordered differently across thread counts but
        // the multiset of bloom sizes must match
        let mut k1: Vec<usize> = (0..r1.n_blooms()).map(|b| r1.offs[b + 1] - r1.offs[b]).collect();
        let mut k4: Vec<usize> = (0..r4.n_blooms()).map(|b| r4.offs[b + 1] - r4.offs[b]).collect();
        k1.sort_unstable();
        k4.sort_unstable();
        assert_eq!(k1, k4);
    }

    #[test]
    fn empty_graph_index() {
        let g = crate::graph::GraphBuilder::new().nu(3).nv(3).build();
        let (idx, _) = build(&g);
        assert_eq!(idx.n_blooms(), 0);
        assert_eq!(idx.n_links(), 0);
    }
}
