//! Workload metrics — the paper's comparison currency.
//!
//! Table 3/4 compare algorithms on execution time, **support updates**
//! (wing), **wedges traversed** (tip), and **ρ** — the number of parallel
//! peeling iterations, which equals the number of thread synchronizations.
//! Every peeling algorithm in this crate reports a [`PeelStats`].

use crate::par::Counter;
use std::time::{Duration, Instant};

/// Pipeline phases (Fig. 7 / Fig. 10 breakdowns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Butterfly counting for support initialization (+ BE-Index build).
    Count,
    /// Coarse-grained decomposition (PBNG CD).
    Coarse,
    /// BE-Index / induced-subgraph partitioning.
    Partition,
    /// Fine-grained decomposition (PBNG FD).
    Fine,
    /// Incremental update bookkeeping ([`crate::engine::incremental`]):
    /// delta application, θ/count remapping, and invalidation analysis.
    /// The re-peel of the affected sub-universe records the usual
    /// Count/Coarse/Partition/Fine phases after this one.
    Incremental,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::Count,
        Phase::Coarse,
        Phase::Partition,
        Phase::Fine,
        Phase::Incremental,
    ];
    pub fn name(self) -> &'static str {
        match self {
            Phase::Count => "count+index",
            Phase::Coarse => "coarse(CD)",
            Phase::Partition => "partition",
            Phase::Fine => "fine(FD)",
            Phase::Incremental => "incremental",
        }
    }
}

/// Live counters, shared across threads during a run.
#[derive(Default)]
pub struct Meters {
    /// Support-update operations applied (wing currency).
    pub updates: Counter,
    /// Wedge / bloom-edge-link traversal steps (tip currency; also used to
    /// measure BE-Index traversal for the Fig. 6 ablation).
    pub wedges: Counter,
    /// Parallel peeling iterations == thread synchronizations (ρ).
    pub rho: Counter,
    /// OS threads spawned by the runtime pool during the recorded run.
    /// With the persistent pool this is bounded by the pool size (and is
    /// zero once the pool is warm) no matter how large ρ gets — the
    /// [`Recorder`] fills it in from [`crate::par::total_spawns`].
    pub spawns: Counter,
    /// CD partitions whose support interval was invalidated by dynamic
    /// edge deltas ([`crate::engine::incremental`]); zero for static
    /// runs.
    pub invalidated_parts: Counter,
}

impl Meters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Point-in-time copy of the counters (the values CI gates on).
    pub fn snapshot(&self) -> MetersSnapshot {
        MetersSnapshot {
            updates: self.updates.get(),
            wedges: self.wedges.get(),
            rho: self.rho.get(),
            spawns: self.spawns.get(),
            invalidated_parts: self.invalidated_parts.get(),
        }
    }

    /// Stable JSON form of [`Meters::snapshot`].
    pub fn to_json(&self) -> crate::jsonio::Value {
        self.snapshot().to_json()
    }
}

/// Immutable [`Meters`] snapshot with a schema-stable JSON form.
///
/// The bench subsystem ([`crate::bench`]) embeds this object in
/// `BENCH_<suite>.json` and `bench compare` gates on its members, so the
/// key set and order below are part of the report schema: additions are
/// fine, renames/removals require a `report::SCHEMA_VERSION` bump.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetersSnapshot {
    pub updates: u64,
    pub wedges: u64,
    pub rho: u64,
    /// Pool threads spawned during the run (process-dependent: non-zero
    /// only for the run that first warms the pool). Excluded from the
    /// bench-report counter section, which gates deterministic values.
    pub spawns: u64,
    /// CD partitions invalidated by incremental updates (0 when static).
    pub invalidated_parts: u64,
}

/// The one counter-section serializer: emits `pairs` as a JSON object in
/// the given order. [`MetersSnapshot::to_json`] and the bench report's
/// counter section (`bench::report`) both route through this with
/// [`MetersSnapshot::core_pairs`] as the shared prefix, so the two forms
/// cannot silently diverge on the deterministic counters.
pub fn counters_to_json(pairs: &[(&str, u64)]) -> crate::jsonio::Value {
    let mut obj = crate::jsonio::Value::obj();
    for &(k, v) in pairs {
        obj = obj.with(k, v);
    }
    obj
}

impl MetersSnapshot {
    /// The deterministic core counters in schema order — the prefix
    /// every counter section starts with. `spawns` (process-dependent)
    /// and `invalidated_parts` are appended only where the schema wants
    /// them.
    pub fn core_pairs(&self) -> [(&'static str, u64); 3] {
        [
            ("updates", self.updates),
            ("wedges", self.wedges),
            ("rho", self.rho),
        ]
    }

    /// JSON object `{updates, wedges, rho, spawns, invalidated_parts}` —
    /// fixed key order (appending keys is schema-compatible).
    pub fn to_json(&self) -> crate::jsonio::Value {
        counters_to_json(&self.core_pairs())
            .with("spawns", self.spawns)
            .with("invalidated_parts", self.invalidated_parts)
    }
}

/// Final, immutable result of one decomposition run.
#[derive(Clone, Debug, Default)]
pub struct PeelStats {
    pub updates: u64,
    pub wedges: u64,
    pub rho: u64,
    /// Pool threads spawned while this run was recorded (≤ pool size).
    pub spawns: u64,
    /// CD partitions invalidated by incremental updates (0 when static).
    pub invalidated_parts: u64,
    pub total: Duration,
    /// (phase, duration, phase-local updates, phase-local wedges)
    pub phases: Vec<(Phase, Duration, u64, u64)>,
}

impl PeelStats {
    /// The final counter values as a [`MetersSnapshot`] (bench reports).
    pub fn meters_snapshot(&self) -> MetersSnapshot {
        MetersSnapshot {
            updates: self.updates,
            wedges: self.wedges,
            rho: self.rho,
            spawns: self.spawns,
            invalidated_parts: self.invalidated_parts,
        }
    }

    pub fn phase_time(&self, p: Phase) -> Duration {
        self.phases
            .iter()
            .filter(|(ph, ..)| *ph == p)
            .map(|(_, d, ..)| *d)
            .sum()
    }
    pub fn phase_updates(&self, p: Phase) -> u64 {
        self.phases
            .iter()
            .filter(|(ph, ..)| *ph == p)
            .map(|(_, _, u, _)| *u)
            .sum()
    }
    pub fn phase_wedges(&self, p: Phase) -> u64 {
        self.phases
            .iter()
            .filter(|(ph, ..)| *ph == p)
            .map(|(.., w)| *w)
            .sum()
    }

    /// Thin-view publish into an [`crate::obs::Registry`]: the final
    /// counters land as `peel.*` gauges and every phase duration is
    /// recorded into a log-scale latency histogram `phase.<name>_ns`.
    /// [`Recorder::finish`] calls this against the global registry when
    /// tracing is enabled.
    pub fn publish(&self, reg: &crate::obs::Registry) {
        for (n, v) in [
            ("updates", self.updates),
            ("wedges", self.wedges),
            ("rho", self.rho),
            ("invalidated_parts", self.invalidated_parts),
        ] {
            reg.counter(&format!("peel.{n}")).set(v);
        }
        for (p, d, _, _) in &self.phases {
            reg.histogram(&format!("phase.{}_ns", p.name())).record_duration(*d);
        }
    }
}

/// Records phase boundaries against a [`Meters`], producing [`PeelStats`].
pub struct Recorder<'a> {
    meters: &'a Meters,
    start: Instant,
    /// Pool spawn count when recording started; the delta at `finish`
    /// proves worker reuse across the run's parallel regions.
    spawns0: u64,
    phase_start: Instant,
    phase_updates0: u64,
    phase_wedges0: u64,
    current: Option<Phase>,
    phases: Vec<(Phase, Duration, u64, u64)>,
}

impl<'a> Recorder<'a> {
    pub fn new(meters: &'a Meters) -> Self {
        let now = Instant::now();
        Recorder {
            meters,
            start: now,
            spawns0: crate::par::total_spawns(),
            phase_start: now,
            phase_updates0: 0,
            phase_wedges0: 0,
            current: None,
            phases: Vec::new(),
        }
    }

    /// The meters this recorder attributes phases to (the engine records
    /// phases against the caller's recorder without owning the meters).
    pub fn meters(&self) -> &'a Meters {
        self.meters
    }

    pub fn enter(&mut self, p: Phase) {
        self.close_phase();
        self.current = Some(p);
        self.phase_start = Instant::now();
        self.phase_updates0 = self.meters.updates.get();
        self.phase_wedges0 = self.meters.wedges.get();
    }

    fn close_phase(&mut self) {
        if let Some(p) = self.current.take() {
            self.phases.push((
                p,
                self.phase_start.elapsed(),
                self.meters.updates.get() - self.phase_updates0,
                self.meters.wedges.get() - self.phase_wedges0,
            ));
        }
    }

    pub fn finish(mut self) -> PeelStats {
        self.close_phase();
        self.meters.spawns.add(crate::par::total_spawns() - self.spawns0);
        let stats = PeelStats {
            updates: self.meters.updates.get(),
            wedges: self.meters.wedges.get(),
            rho: self.meters.rho.get(),
            spawns: self.meters.spawns.get(),
            invalidated_parts: self.meters.invalidated_parts.get(),
            total: self.start.elapsed(),
            phases: self.phases,
        };
        if crate::obs::enabled() {
            stats.publish(crate::obs::Registry::global());
        }
        stats
    }
}

/// Live counters of the index/query-serving subsystem
/// ([`crate::index::query::QueryEngine`]): request volume and level-cache
/// effectiveness. Shared across serving threads; relaxed atomics.
#[derive(Default)]
pub struct IndexMeters {
    /// Queries answered (all verbs).
    pub queries: Counter,
    /// Level materializations answered from the LRU cache.
    pub cache_hits: Counter,
    /// Level materializations computed from the forest.
    pub cache_misses: Counter,
}

impl IndexMeters {
    pub fn new() -> Self {
        Self::default()
    }

    /// The counters as `(name, value)` pairs in stable order — consumed
    /// by the server `STATS`/`METRICS` verbs and by [`Self::publish`],
    /// which is what makes these counters readable rather than
    /// write-only.
    pub fn pairs(&self) -> [(&'static str, u64); 3] {
        [
            ("queries", self.queries.get()),
            ("cache_hits", self.cache_hits.get()),
            ("cache_misses", self.cache_misses.get()),
        ]
    }

    /// Thin-view publish into an [`crate::obs::Registry`] under
    /// `index.*` names.
    pub fn publish(&self, reg: &crate::obs::Registry) {
        for (n, v) in self.pairs() {
            reg.counter(&format!("index.{n}")).set(v);
        }
    }

    /// Fold another meter set into this one (atomic adds).
    ///
    /// Each snapshot epoch owns a fresh [`crate::index::query::QueryEngine`]
    /// with zeroed meters; the serving layer's
    /// [`crate::serve::SnapshotStore`] absorbs a retiring engine's meters
    /// into a lifetime accumulator at swap time so `stats`/`metrics`
    /// report cumulative traffic, not just the live epoch's.
    pub fn absorb(&self, other: &IndexMeters) {
        self.queries.add(other.queries.get());
        self.cache_hits.add(other.cache_hits.get());
        self.cache_misses.add(other.cache_misses.get());
    }
}

/// Human-size formatting for counters (paper prints billions).
pub fn human(x: u64) -> String {
    let f = x as f64;
    if f >= 1e12 {
        format!("{:.2}T", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2}B", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.2}K", f / 1e3)
    } else {
        format!("{}", x)
    }
}

/// Fixed-width row printer shared by the bench mains.
pub fn print_row(cols: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (i, c) in cols.iter().enumerate() {
        let w = widths.get(i).copied().unwrap_or(12);
        line.push_str(&format!("{:>w$} ", c, w = w));
    }
    println!("{}", line.trim_end());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_tracks_phases() {
        let m = Meters::new();
        let mut r = Recorder::new(&m);
        r.enter(Phase::Count);
        m.updates.add(5);
        r.enter(Phase::Coarse);
        m.updates.add(7);
        m.rho.add(2);
        let s = r.finish();
        assert_eq!(s.updates, 12);
        assert_eq!(s.rho, 2);
        assert_eq!(s.phase_updates(Phase::Count), 5);
        assert_eq!(s.phase_updates(Phase::Coarse), 7);
        assert_eq!(s.phases.len(), 2);
    }

    #[test]
    fn snapshot_to_json_is_stable() {
        let m = Meters::new();
        m.updates.add(7);
        m.wedges.add(9);
        m.rho.add(2);
        m.spawns.add(3);
        let text = m.to_json().to_pretty();
        assert_eq!(text, m.to_json().to_pretty());
        let back = crate::jsonio::Value::parse(&text).unwrap();
        assert_eq!(back.req_u64("updates").unwrap(), 7);
        assert_eq!(back.req_u64("wedges").unwrap(), 9);
        assert_eq!(back.req_u64("rho").unwrap(), 2);
        assert_eq!(back.req_u64("spawns").unwrap(), 3);
        assert_eq!(m.snapshot(), m.snapshot());
    }

    #[test]
    fn peel_stats_snapshot_mirrors_counters() {
        let m = Meters::new();
        let mut r = Recorder::new(&m);
        r.enter(Phase::Fine);
        m.updates.add(4);
        m.rho.add(1);
        let s = r.finish();
        let snap = s.meters_snapshot();
        assert_eq!(snap, m.snapshot());
        assert_eq!(snap.updates, 4);
        assert_eq!(snap.rho, 1);
    }

    #[test]
    fn counters_to_json_preserves_order() {
        let v = counters_to_json(&[("b", 2), ("a", 1)]);
        let text = v.to_pretty();
        assert!(text.find("\"b\"").unwrap() < text.find("\"a\"").unwrap());
        assert_eq!(v.req_u64("a").unwrap(), 1);
        assert_eq!(v.req_u64("b").unwrap(), 2);
    }

    #[test]
    fn core_pairs_match_snapshot_json_prefix() {
        let snap = MetersSnapshot {
            updates: 1,
            wedges: 2,
            rho: 3,
            spawns: 4,
            invalidated_parts: 5,
        };
        let [(uk, uv), (wk, wv), (rk, rv)] = snap.core_pairs();
        assert_eq!((uk, uv), ("updates", 1));
        assert_eq!((wk, wv), ("wedges", 2));
        assert_eq!((rk, rv), ("rho", 3));
        let j = snap.to_json();
        assert_eq!(j.req_u64("spawns").unwrap(), 4);
        assert_eq!(j.req_u64("invalidated_parts").unwrap(), 5);
    }

    #[test]
    fn index_meters_absorb_accumulates() {
        let life = IndexMeters::new();
        let epoch1 = IndexMeters::new();
        epoch1.queries.add(5);
        epoch1.cache_hits.add(2);
        life.absorb(&epoch1);
        let epoch2 = IndexMeters::new();
        epoch2.queries.add(1);
        epoch2.cache_misses.add(3);
        life.absorb(&epoch2);
        assert_eq!(
            life.pairs(),
            [("queries", 6), ("cache_hits", 2), ("cache_misses", 3)]
        );
    }

    #[test]
    fn index_meters_pairs_are_readable() {
        let m = IndexMeters::new();
        m.queries.add(3);
        m.cache_hits.add(1);
        assert_eq!(
            m.pairs(),
            [("queries", 3), ("cache_hits", 1), ("cache_misses", 0)]
        );
        let reg = crate::obs::Registry::new();
        m.publish(&reg);
        assert_eq!(reg.counter("index.queries").get(), 3);
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(12), "12");
        assert_eq!(human(1_500), "1.50K");
        assert_eq!(human(2_000_000), "2.00M");
        assert_eq!(human(3_300_000_000), "3.30B");
        assert_eq!(human(20_068_000_000_000), "20.07T");
    }

    #[test]
    fn phase_time_sums_duplicates() {
        let m = Meters::new();
        let mut r = Recorder::new(&m);
        r.enter(Phase::Fine);
        r.enter(Phase::Fine);
        let s = r.finish();
        assert_eq!(s.phases.len(), 2);
        let _ = s.phase_time(Phase::Fine);
    }
}
