//! Shared-memory fork-join parallelism built on `std::thread::scope`.
//!
//! The paper's reference implementation uses OpenMP `parallel for`; this
//! module provides the equivalent primitives: a chunked `parallel_for`,
//! a reduce variant, and saturating atomic support cells implementing the
//! paper's `⋈ ← max(θ, ⋈ − x)` update (Alg. 3/4/6).
//!
//! The cargo registry available in this environment does not carry rayon,
//! so the pool is hand-rolled. Threads are spawned per parallel region
//! (scoped), which matches OpenMP's fork-join semantics and keeps the
//! region composable with borrowed data.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub mod atomics;
pub use atomics::SupportCell;

/// Number of worker threads for a parallel region.
///
/// Defaults to the machine's available parallelism; override with
/// `PBNG_THREADS` or per-call sites that take an explicit `threads`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PBNG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `body(thread_id, start, end)` over `0..n` split into contiguous
/// chunks, one chunk stream per thread, work-stealing by grabbing the next
/// chunk index from a shared atomic (guided scheduling, like OpenMP
/// `schedule(dynamic)` with a fixed grain).
pub fn parallel_for_chunked<F>(n: usize, threads: usize, grain: usize, body: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= grain {
        body(0, 0, n);
        return;
    }
    let grain = grain.max(1);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                body(t, start, end);
            });
        }
    });
}

/// Element-wise parallel for: `body(thread_id, i)` for `i in 0..n`.
pub fn parallel_for<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let grain = (n / (threads.max(1) * 8)).max(256);
    parallel_for_chunked(n, threads, grain, |t, lo, hi| {
        for i in lo..hi {
            body(t, i);
        }
    });
}

/// Parallel map-reduce over `0..n`: each thread folds chunks with `fold`,
/// results combined with `combine`.
pub fn parallel_reduce<A, F, C>(n: usize, threads: usize, init: A, fold: F, combine: C) -> A
where
    A: Send + Clone,
    F: Fn(A, usize) -> A + Sync,
    C: Fn(A, A) -> A,
{
    let threads = threads.max(1);
    if threads == 1 || n < 1024 {
        let mut acc = init;
        for i in 0..n {
            acc = fold(acc, i);
        }
        return acc;
    }
    let grain = (n / (threads * 8)).max(256);
    let next = AtomicUsize::new(0);
    let partials: Vec<A> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let fold = &fold;
            let init = init.clone();
            handles.push(s.spawn(move || {
                let mut acc = init;
                loop {
                    let start = next.fetch_add(grain, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + grain).min(n);
                    for i in start..end {
                        acc = fold(acc, i);
                    }
                }
                acc
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    partials.into_iter().fold(init, combine)
}

/// Run one closure per thread id (SPMD region), like `omp parallel`.
pub fn spmd<F>(threads: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        body(0);
        return;
    }
    std::thread::scope(|s| {
        for t in 0..threads {
            let body = &body;
            s.spawn(move || body(t));
        }
    });
}

/// Shared mutable cell for provably disjoint parallel writes.
///
/// Graph peeling mutates per-bloom / per-vertex slices that a parallel
/// loop partitions disjointly (each bloom is owned by exactly one task in
/// a phase). Rust cannot see that disjointness, so this cell provides the
/// escape hatch; every use site documents its disjointness argument.
pub struct RacyCell<T: ?Sized>(std::cell::UnsafeCell<T>);

unsafe impl<T: ?Sized + Send> Sync for RacyCell<T> {}

impl<T> RacyCell<T> {
    pub fn new(v: T) -> Self {
        RacyCell(std::cell::UnsafeCell::new(v))
    }
    /// # Safety
    /// Caller must guarantee no concurrent aliasing access to the parts
    /// of `T` it mutates.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.0.get()
    }
    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}

/// A relaxed global counter for workload metrics (updates, wedges, ...).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn add(&self, x: u64) {
        self.0.fetch_add(x, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..10_000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 4, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 1, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_reduce_sums() {
        let n = 100_000usize;
        let s = parallel_reduce(n, 4, 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn chunked_is_disjoint_and_complete() {
        let n = 5_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(n, 3, 17, |_, lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spmd_runs_each_thread() {
        let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        spmd(4, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        parallel_for(1000, 4, |_, _| c.add(2));
        assert_eq!(c.get(), 2000);
    }
}
