//! Shared-memory fork-join parallelism on a persistent worker pool.
//!
//! The paper's reference implementation uses OpenMP `parallel for`; this
//! module provides the equivalent primitives: a chunked `parallel_for`,
//! a reduce variant, an SPMD region, and saturating atomic support cells
//! implementing the paper's `⋈ ← max(θ, ⋈ − x)` update (Alg. 3/4/6).
//!
//! The cargo registry available in this environment does not carry rayon,
//! so the pool is hand-rolled (see [`pool`]): workers are spawned once,
//! parked between regions, and reused for every parallel region in the
//! process — the thousands of small CD/FD peel iterations no longer pay
//! thread-creation cost per iteration. Scoped borrows still work because
//! a region broadcasts a borrowed closure and barriers on completion
//! before returning. Every primitive degrades to sequential execution
//! below a grain threshold (or when `threads == 1`) without touching the
//! pool at all.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub mod atomics;
pub mod pool;

pub use atomics::SupportCell;
pub use pool::{total_spawns, ScratchSet, ScratchSlot};

/// Number of worker lanes for a parallel region.
///
/// Defaults to the machine's available parallelism; override with
/// `PBNG_THREADS` or per-call sites that take an explicit `threads`.
/// The persistent pool snapshots this value once, when the first
/// multi-lane region creates it; later `PBNG_THREADS` changes only cap
/// requests, they cannot grow the pool.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PBNG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Total lanes of the persistent pool (caller + parked workers).
/// Touching this initializes the pool.
pub fn pool_capacity() -> usize {
    pool::Pool::global().capacity()
}

/// Upper bound on the lane ids a region with this `threads` request can
/// observe — use it to size per-lane scratch ([`ScratchSet::take`]).
/// `threads <= 1` never initializes the pool.
pub fn max_lanes(threads: usize) -> usize {
    if threads <= 1 {
        1
    } else {
        pool::Pool::global().lanes(threads)
    }
}

/// Run `body(thread_id, start, end)` over `0..n` split into contiguous
/// chunks, work-stealing by grabbing the next chunk index from a shared
/// atomic (guided scheduling, like OpenMP `schedule(dynamic)` with a
/// fixed grain). `n <= grain` or `threads == 1` runs inline on the
/// caller without waking the pool.
pub fn parallel_for_chunked<F>(n: usize, threads: usize, grain: usize, body: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= grain {
        body(0, 0, n);
        return;
    }
    let grain = grain.max(1);
    let next = AtomicUsize::new(0);
    pool::Pool::global().run(threads, |t| loop {
        // ORDERING: Relaxed — the fetch_add only needs atomicity (each
        // chunk claimed exactly once); no data is published through it,
        // and region entry/exit barriers order everything else.
        let start = next.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        let end = (start + grain).min(n);
        body(t, start, end);
    });
}

/// Element-wise parallel for: `body(thread_id, i)` for `i in 0..n`.
pub fn parallel_for<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let grain = (n / (threads.max(1) * 8)).max(256);
    parallel_for_chunked(n, threads, grain, |t, lo, hi| {
        for i in lo..hi {
            body(t, i);
        }
    });
}

/// Parallel map-reduce over `0..n`: each lane folds chunks with `fold`,
/// results combined with `combine`.
pub fn parallel_reduce<A, F, C>(n: usize, threads: usize, init: A, fold: F, combine: C) -> A
where
    A: Send + Clone,
    F: Fn(A, usize) -> A + Sync,
    C: Fn(A, A) -> A,
{
    let threads = threads.max(1);
    let lanes = if threads == 1 || n < 1024 {
        1
    } else {
        max_lanes(threads)
    };
    if lanes == 1 {
        let mut acc = init;
        for i in 0..n {
            acc = fold(acc, i);
        }
        return acc;
    }
    let grain = (n / (lanes * 8)).max(256);
    let next = AtomicUsize::new(0);
    // Partials contract: accumulators are pre-cloned on the caller
    // (cloning inside a lane would need `A: Sync`) and handed to lanes
    // through one `RacyCell` per lane. Lane `t` may only ever borrow
    // cell `t`, for the duration of its region body; the pool's
    // completion barrier then orders all lane writes before the caller
    // drains the cells below.
    let partials: Vec<RacyCell<Option<A>>> =
        (0..lanes).map(|_| RacyCell::new(Some(init.clone()))).collect();
    pool::Pool::global().run(lanes, |t| {
        // SAFETY: lane `t` runs exactly once per region and touches only
        // cell `t` — disjoint (the partials contract above).
        let mut slot = unsafe { partials[t].get_mut() };
        let mut acc = slot.take().expect("lane accumulator present");
        loop {
            // ORDERING: Relaxed — chunk claiming only needs the RMW's
            // atomicity; see `parallel_for_chunked`.
            let start = next.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + grain).min(n);
            for i in start..end {
                acc = fold(acc, i);
            }
        }
        *slot = Some(acc);
    });
    partials.into_iter().filter_map(RacyCell::into_inner).fold(init, combine)
}

/// Run one closure per logical thread id (SPMD region), like
/// `omp parallel`: `body(t)` executes exactly once for every
/// `t in 0..threads`, even when the pool has fewer lanes — extra ids are
/// distributed round-robin over the available lanes.
pub fn spmd<F>(threads: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        body(0);
        return;
    }
    let lanes = max_lanes(threads);
    pool::Pool::global().run(lanes, |lane| {
        let mut t = lane;
        while t < threads {
            body(t);
            t += lanes;
        }
    });
}

/// Shared mutable cell for whole-value hand-off to exactly one lane.
///
/// Parallel regions hand per-lane state (scratch slots, reduce
/// accumulators, partition indexes) to worker lanes through a shared
/// borrow. Rust cannot see that each cell is touched by exactly one lane,
/// so this cell provides the escape hatch.
///
/// # Caller obligations (the `get_mut` contract)
///
/// At any instant at most one live [`RacyRef`] may exist per cell, and
/// the access must be region-scoped: the cell is created before the
/// parallel region, each lane borrows *its own* cell (never another
/// lane's) for the duration of its region body, and the region's
/// completion barrier orders all lane writes before the caller collects
/// results with [`RacyCell::as_mut`] / [`RacyCell::into_inner`]. Every
/// use site documents which of these facts makes its access exclusive.
/// For buffers that many lanes scatter into at *element* granularity,
/// use [`RacyBuf`] instead — overlapping `&mut` views of one value are
/// undefined behavior even when the element writes are disjoint.
///
/// Debug builds enforce the single-borrow rule with a per-cell borrow
/// flag: a second `get_mut` while a `RacyRef` is live panics instead of
/// being silent UB. Release builds compile the flag away.
pub struct RacyCell<T: ?Sized> {
    #[cfg(debug_assertions)]
    borrowed: std::sync::atomic::AtomicBool,
    cell: std::cell::UnsafeCell<T>,
}

// SAFETY: the cell hands out `&mut T` across threads only through the
// unsafe `get_mut`, whose callers promise exclusivity (see the contract
// above); with that upheld the cell is just a `T` moved between threads,
// so `T: Send` suffices.
unsafe impl<T: ?Sized + Send> Sync for RacyCell<T> {}

impl<T> RacyCell<T> {
    pub fn new(v: T) -> Self {
        RacyCell {
            #[cfg(debug_assertions)]
            borrowed: std::sync::atomic::AtomicBool::new(false),
            cell: std::cell::UnsafeCell::new(v),
        }
    }
    /// Exclusive access through a shared reference.
    ///
    /// # Safety
    /// Caller must uphold the cell contract above: no other live
    /// [`RacyRef`] to this cell, and no concurrent access of any kind to
    /// the contained value while the returned guard is live.
    #[inline]
    pub unsafe fn get_mut(&self) -> RacyRef<'_, T> {
        #[cfg(debug_assertions)]
        {
            // ORDERING: Acquire on the winning swap pairs with the
            // Release store in `RacyRef::drop`, so the check synchronizes
            // with the previous holder's writes when the flag bounces
            // between threads. The flag is debug-only bookkeeping; real
            // cross-lane publication is the pool's region barrier.
            if self.borrowed.swap(true, Ordering::Acquire) {
                panic!("RacyCell::get_mut: cell already borrowed (aliasing bug)");
            }
        }
        RacyRef {
            #[cfg(debug_assertions)]
            flag: &self.borrowed,
            // SAFETY: exclusivity is the caller's promise (checked by the
            // borrow flag in debug builds), so forming `&mut` is sound.
            val: unsafe { &mut *self.cell.get() },
        }
    }
    /// Safe exclusive access (post-region collection sweeps).
    pub fn as_mut(&mut self) -> &mut T {
        self.cell.get_mut()
    }
    pub fn into_inner(self) -> T {
        self.cell.into_inner()
    }
}

/// Guard returned by [`RacyCell::get_mut`]; derefs to the contained
/// value. In debug builds dropping it clears the cell's borrow flag; in
/// release builds it is a zero-cost wrapper around the `&mut T`.
pub struct RacyRef<'a, T: ?Sized> {
    #[cfg(debug_assertions)]
    flag: &'a std::sync::atomic::AtomicBool,
    val: &'a mut T,
}

impl<T: ?Sized> std::ops::Deref for RacyRef<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        self.val
    }
}

impl<T: ?Sized> std::ops::DerefMut for RacyRef<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        self.val
    }
}

#[cfg(debug_assertions)]
impl<T: ?Sized> Drop for RacyRef<'_, T> {
    fn drop(&mut self) {
        // ORDERING: Release pairs with the Acquire swap in `get_mut` so
        // the next borrower (possibly another thread, across a region
        // boundary) observes this holder's writes before reusing the cell.
        self.flag.store(false, Ordering::Release);
    }
}

/// Shared buffer for provably disjoint parallel writes at *element*
/// granularity.
///
/// Several kernels scatter into disjoint elements or sub-ranges of one
/// shared buffer from many lanes at once (θ write-back in the FD driver,
/// bloom entry compaction, per-node stats). [`RacyCell`] cannot express
/// that: materializing overlapping `&mut Vec<T>` views per lane is
/// undefined behavior even when the element writes never collide. This
/// buffer keeps the aliasing legal by wrapping every element in its own
/// `UnsafeCell` and only forming `&mut` at the granularity the caller
/// claims (one element via [`RacyBuf::set`], one range via
/// [`RacyBuf::slice_mut`]).
///
/// # Caller obligations
/// For every element, at most one lane may access it while the buffer is
/// shared; the parallel region's completion barrier orders all lane
/// writes before [`RacyBuf::into_inner`] collects the result. Every use
/// site documents its disjointness argument (e.g. "CD assigns each
/// entity to exactly one partition").
pub struct RacyBuf<T> {
    data: Vec<std::cell::UnsafeCell<T>>,
}

// SAFETY: lanes only touch disjoint elements (the caller contract
// above), so sharing the buffer is equivalent to partitioning a `Vec<T>`
// into per-lane chunks and sending each to one thread — `T: Send`
// suffices.
unsafe impl<T: Send> Sync for RacyBuf<T> {}

impl<T> RacyBuf<T> {
    pub fn new(v: Vec<T>) -> Self {
        RacyBuf {
            data: v.into_iter().map(std::cell::UnsafeCell::new).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// No other access to element `i` may happen concurrently (the
    /// disjointness contract above).
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        // SAFETY: element `i` is exclusively this lane's by the caller
        // contract, so the raw write cannot race or alias a live `&mut`.
        unsafe { *self.data[i].get() = v }
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// No concurrent *write* to element `i` (concurrent reads are fine
    /// for the owning lane only — the contract gives the element to one
    /// lane, which may freely mix its own reads and writes).
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        // SAFETY: as for `set` — the element belongs to this lane.
        unsafe { *self.data[i].get() }
    }

    /// Exclusive view of the sub-range `lo..hi`.
    ///
    /// # Safety
    /// No other access to any element of `lo..hi` may happen while the
    /// returned slice is live.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        let cells = &self.data[lo..hi];
        // SAFETY: `UnsafeCell<T>` is `repr(transparent)` over `T`, so the
        // cell slice and a `T` slice share layout; exclusivity over the
        // range is the caller's promise, so the `&mut` cannot alias.
        unsafe { std::slice::from_raw_parts_mut(cells.as_ptr() as *mut T, cells.len()) }
    }

    /// Collect the buffer back into a plain `Vec` (after the region's
    /// completion barrier has ordered all lane writes).
    pub fn into_inner(self) -> Vec<T> {
        self.data
            .into_iter()
            .map(std::cell::UnsafeCell::into_inner)
            .collect()
    }
}

/// A relaxed global counter for workload metrics (updates, wedges, ...).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn add(&self, x: u64) {
        // ORDERING: Relaxed — metrics counters are monotonic tallies
        // with no data published alongside them; readers tolerate
        // momentarily stale values, and region barriers make end-of-run
        // reads exact.
        self.0.fetch_add(x, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        // ORDERING: Relaxed — see `add`; a mid-run read is a statistical
        // snapshot, an end-of-run read is ordered by the region barrier.
        self.0.load(Ordering::Relaxed)
    }
    /// Overwrite the value (registry publishing of snapshot views).
    pub fn set(&self, v: u64) {
        // ORDERING: Relaxed — see `add`; publishing a snapshot view is a
        // single-word overwrite with no cross-data dependency.
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn reset(&self) {
        // ORDERING: Relaxed — see `add`; resets happen between runs,
        // outside any parallel region.
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    #[cfg_attr(miri, ignore)] // 10k-element sweep is too slow interpreted
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..10_000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 4, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 1, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 100k folds are too slow interpreted
    fn parallel_reduce_sums() {
        let n = 100_000usize;
        let s = parallel_reduce(n, 4, 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 5k-element sweep is too slow interpreted
    fn chunked_is_disjoint_and_complete() {
        let n = 5_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(n, 3, 17, |_, lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spmd_runs_each_thread() {
        let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        spmd(4, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spmd_covers_ids_beyond_pool_capacity() {
        // More logical ids than the pool can possibly have lanes: the
        // round-robin distribution must still run every id exactly once.
        let n = pool_capacity() + 3;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        spmd(n, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        parallel_for(1000, 4, |_, _| c.add(2));
        assert_eq!(c.get(), 2000);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 64×20k-iteration regions are too slow interpreted
    fn regions_reuse_pool_workers() {
        // Force the pool into existence, then run many regions: no new
        // OS threads may appear (spawns bounded by pool size, not by the
        // number of regions — the PR's acceptance criterion at the unit
        // level).
        let cap = pool_capacity();
        let before = total_spawns();
        for _ in 0..64 {
            parallel_for(20_000, 4, |_, _| {});
            spmd(4, |_| {});
        }
        assert_eq!(total_spawns(), before);
        assert!(before <= cap as u64, "spawns {before} > capacity {cap}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 2k-element nested regions are too slow interpreted
    fn nested_regions_fall_back_sequentially() {
        let hits: Vec<AtomicU64> = (0..2_000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(2, 2, 1, |_, lo, hi| {
            for half in lo..hi {
                // nested region inside a running region: must complete
                // (sequential fallback), not deadlock
                let base = half * 1000;
                parallel_for(1000, 4, |_, i| {
                    hits[base + i].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scratch_set_recycles_slots() {
        let mut s = ScratchSet::take(2);
        // SAFETY: single-threaded test; one lane guard live at a time
        // (each statement's guard is dropped before the next borrow).
        unsafe {
            s.lane(0).a.push(7);
            s.lane(1).b.push(9);
            s.lane(1).pairs.push((3, 12));
            let mut l1 = s.lane(1);
            let (cnt, _, _, _) = l1.split(16);
            cnt[3] += 1;
            cnt[3] = 0; // restore the zero invariant
        }
        let mut seen = Vec::new();
        s.for_each(|sl| seen.push((sl.a.len(), sl.b.len(), sl.pairs.len())));
        assert_eq!(seen, vec![(1, 0, 0), (0, 1, 1)]);
        drop(s);
        // recycled slots come back empty
        let mut s2 = ScratchSet::take(2);
        s2.for_each(|sl| {
            assert!(sl.a.is_empty() && sl.b.is_empty() && sl.pairs.is_empty());
            let (cnt, _, _, _) = sl.split(16);
            assert!(cnt.iter().all(|&c| c == 0));
        });
    }

    /// Miri-sized broadcast check: one small multi-lane region must run
    /// every lane body exactly once (the RegionWait hand-shake under the
    /// interpreter's weak-memory exploration).
    #[test]
    fn pool_broadcast_reaches_every_lane_once() {
        let lanes = max_lanes(2);
        let hits: Vec<AtomicU64> = (0..lanes).map(|_| AtomicU64::new(0)).collect();
        pool::Pool::global().run(2, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        let ran: u64 = hits.iter().map(|h| h.load(Ordering::Relaxed)).sum();
        assert_eq!(ran, 2.min(lanes) as u64);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) <= 1));
    }

    #[test]
    fn racy_buf_disjoint_parallel_writes() {
        let buf = RacyBuf::new(vec![0u64; 1024]);
        assert_eq!(buf.len(), 1024);
        assert!(!buf.is_empty());
        parallel_for(1024, 4, |_, i| {
            // SAFETY: parallel_for visits each index exactly once, so
            // element `i` is exclusively this lane's.
            unsafe { buf.set(i, i as u64 + 1) };
        });
        let v = buf.into_inner();
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u64 + 1));
    }

    #[test]
    fn racy_buf_slice_mut_and_get() {
        let buf = RacyBuf::new(vec![0u32; 8]);
        // SAFETY: single-threaded; the slice is dropped before `get`.
        unsafe {
            let s = buf.slice_mut(2, 5);
            s.copy_from_slice(&[7, 8, 9]);
        }
        // SAFETY: single-threaded — no concurrent writers.
        assert_eq!(unsafe { buf.get(4) }, 9);
        assert_eq!(buf.into_inner(), vec![0, 0, 7, 8, 9, 0, 0, 0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn racy_cell_detects_aliased_get_mut() {
        let c = RacyCell::new(0u32);
        // SAFETY: single-threaded; this is the only live guard.
        let g1 = unsafe { c.get_mut() };
        let second = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: intentionally violates the contract to exercise the
            // debug borrow flag; the call must panic before forming the
            // second `&mut`.
            let _g2 = unsafe { c.get_mut() };
        }));
        assert!(second.is_err(), "aliased get_mut must panic in debug builds");
        drop(g1);
        // the flag is cleared on drop, so borrowing again works
        // SAFETY: single-threaded; the previous guard is dropped.
        let mut g3 = unsafe { c.get_mut() };
        *g3 = 7;
        drop(g3);
        assert_eq!(c.into_inner(), 7);
    }
}
