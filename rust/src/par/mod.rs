//! Shared-memory fork-join parallelism on a persistent worker pool.
//!
//! The paper's reference implementation uses OpenMP `parallel for`; this
//! module provides the equivalent primitives: a chunked `parallel_for`,
//! a reduce variant, an SPMD region, and saturating atomic support cells
//! implementing the paper's `⋈ ← max(θ, ⋈ − x)` update (Alg. 3/4/6).
//!
//! The cargo registry available in this environment does not carry rayon,
//! so the pool is hand-rolled (see [`pool`]): workers are spawned once,
//! parked between regions, and reused for every parallel region in the
//! process — the thousands of small CD/FD peel iterations no longer pay
//! thread-creation cost per iteration. Scoped borrows still work because
//! a region broadcasts a borrowed closure and barriers on completion
//! before returning. Every primitive degrades to sequential execution
//! below a grain threshold (or when `threads == 1`) without touching the
//! pool at all.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub mod atomics;
pub mod pool;

pub use atomics::SupportCell;
pub use pool::{total_spawns, ScratchSet, ScratchSlot};

/// Number of worker lanes for a parallel region.
///
/// Defaults to the machine's available parallelism; override with
/// `PBNG_THREADS` or per-call sites that take an explicit `threads`.
/// The persistent pool snapshots this value once, when the first
/// multi-lane region creates it; later `PBNG_THREADS` changes only cap
/// requests, they cannot grow the pool.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PBNG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Total lanes of the persistent pool (caller + parked workers).
/// Touching this initializes the pool.
pub fn pool_capacity() -> usize {
    pool::Pool::global().capacity()
}

/// Upper bound on the lane ids a region with this `threads` request can
/// observe — use it to size per-lane scratch ([`ScratchSet::take`]).
/// `threads <= 1` never initializes the pool.
pub fn max_lanes(threads: usize) -> usize {
    if threads <= 1 {
        1
    } else {
        pool::Pool::global().lanes(threads)
    }
}

/// Run `body(thread_id, start, end)` over `0..n` split into contiguous
/// chunks, work-stealing by grabbing the next chunk index from a shared
/// atomic (guided scheduling, like OpenMP `schedule(dynamic)` with a
/// fixed grain). `n <= grain` or `threads == 1` runs inline on the
/// caller without waking the pool.
pub fn parallel_for_chunked<F>(n: usize, threads: usize, grain: usize, body: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= grain {
        body(0, 0, n);
        return;
    }
    let grain = grain.max(1);
    let next = AtomicUsize::new(0);
    pool::Pool::global().run(threads, |t| loop {
        let start = next.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        let end = (start + grain).min(n);
        body(t, start, end);
    });
}

/// Element-wise parallel for: `body(thread_id, i)` for `i in 0..n`.
pub fn parallel_for<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let grain = (n / (threads.max(1) * 8)).max(256);
    parallel_for_chunked(n, threads, grain, |t, lo, hi| {
        for i in lo..hi {
            body(t, i);
        }
    });
}

/// Parallel map-reduce over `0..n`: each lane folds chunks with `fold`,
/// results combined with `combine`.
pub fn parallel_reduce<A, F, C>(n: usize, threads: usize, init: A, fold: F, combine: C) -> A
where
    A: Send + Clone,
    F: Fn(A, usize) -> A + Sync,
    C: Fn(A, A) -> A,
{
    let threads = threads.max(1);
    let lanes = if threads == 1 || n < 1024 {
        1
    } else {
        max_lanes(threads)
    };
    if lanes == 1 {
        let mut acc = init;
        for i in 0..n {
            acc = fold(acc, i);
        }
        return acc;
    }
    let grain = (n / (lanes * 8)).max(256);
    let next = AtomicUsize::new(0);
    // Accumulators are pre-cloned on the caller (cloning inside a lane
    // would need `A: Sync`) and handed to lanes through one cell per
    // lane — per-slot cells, so no lane ever forms a reference to
    // another lane's accumulator.
    let partials: Vec<RacyCell<Option<A>>> =
        (0..lanes).map(|_| RacyCell::new(Some(init.clone()))).collect();
    pool::Pool::global().run(lanes, |t| {
        // SAFETY: lane `t` runs exactly once per region and touches only
        // cell `t` — disjoint.
        let slot = unsafe { partials[t].get_mut() };
        let mut acc = slot.take().expect("lane accumulator present");
        loop {
            let start = next.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + grain).min(n);
            for i in start..end {
                acc = fold(acc, i);
            }
        }
        *slot = Some(acc);
    });
    partials.into_iter().filter_map(RacyCell::into_inner).fold(init, combine)
}

/// Run one closure per logical thread id (SPMD region), like
/// `omp parallel`: `body(t)` executes exactly once for every
/// `t in 0..threads`, even when the pool has fewer lanes — extra ids are
/// distributed round-robin over the available lanes.
pub fn spmd<F>(threads: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        body(0);
        return;
    }
    let lanes = max_lanes(threads);
    pool::Pool::global().run(lanes, |lane| {
        let mut t = lane;
        while t < threads {
            body(t);
            t += lanes;
        }
    });
}

/// Shared mutable cell for provably disjoint parallel writes.
///
/// Graph peeling mutates per-bloom / per-vertex slices that a parallel
/// loop partitions disjointly (each bloom is owned by exactly one task in
/// a phase). Rust cannot see that disjointness, so this cell provides the
/// escape hatch; every use site documents its disjointness argument.
pub struct RacyCell<T: ?Sized>(std::cell::UnsafeCell<T>);

unsafe impl<T: ?Sized + Send> Sync for RacyCell<T> {}

impl<T> RacyCell<T> {
    pub fn new(v: T) -> Self {
        RacyCell(std::cell::UnsafeCell::new(v))
    }
    /// # Safety
    /// Caller must guarantee no concurrent aliasing access to the parts
    /// of `T` it mutates.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.0.get()
    }
    /// Safe exclusive access (post-region collection sweeps).
    pub fn as_mut(&mut self) -> &mut T {
        self.0.get_mut()
    }
    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}

/// A relaxed global counter for workload metrics (updates, wedges, ...).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn add(&self, x: u64) {
        self.0.fetch_add(x, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    /// Overwrite the value (registry publishing of snapshot views).
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..10_000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 4, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), 1, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_reduce_sums() {
        let n = 100_000usize;
        let s = parallel_reduce(n, 4, 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn chunked_is_disjoint_and_complete() {
        let n = 5_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(n, 3, 17, |_, lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spmd_runs_each_thread() {
        let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        spmd(4, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spmd_covers_ids_beyond_pool_capacity() {
        // More logical ids than the pool can possibly have lanes: the
        // round-robin distribution must still run every id exactly once.
        let n = pool_capacity() + 3;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        spmd(n, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        parallel_for(1000, 4, |_, _| c.add(2));
        assert_eq!(c.get(), 2000);
    }

    #[test]
    fn regions_reuse_pool_workers() {
        // Force the pool into existence, then run many regions: no new
        // OS threads may appear (spawns bounded by pool size, not by the
        // number of regions — the PR's acceptance criterion at the unit
        // level).
        let cap = pool_capacity();
        let before = total_spawns();
        for _ in 0..64 {
            parallel_for(20_000, 4, |_, _| {});
            spmd(4, |_| {});
        }
        assert_eq!(total_spawns(), before);
        assert!(before <= cap as u64, "spawns {before} > capacity {cap}");
    }

    #[test]
    fn nested_regions_fall_back_sequentially() {
        let hits: Vec<AtomicU64> = (0..2_000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(2, 2, 1, |_, lo, hi| {
            for half in lo..hi {
                // nested region inside a running region: must complete
                // (sequential fallback), not deadlock
                let base = half * 1000;
                parallel_for(1000, 4, |_, i| {
                    hits[base + i].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scratch_set_recycles_slots() {
        let mut s = ScratchSet::take(2);
        // SAFETY: single-threaded test; lanes accessed one at a time.
        unsafe {
            s.lane(0).a.push(7);
            s.lane(1).b.push(9);
            let (cnt, _, _) = s.lane(1).split(16);
            cnt[3] += 1;
            cnt[3] = 0; // restore the zero invariant
        }
        let mut seen = Vec::new();
        s.for_each(|sl| seen.push((sl.a.len(), sl.b.len())));
        assert_eq!(seen, vec![(1, 0), (0, 1)]);
        drop(s);
        // recycled slots come back empty
        let mut s2 = ScratchSet::take(2);
        s2.for_each(|sl| {
            assert!(sl.a.is_empty() && sl.b.is_empty());
            let (cnt, _, _) = sl.split(16);
            assert!(cnt.iter().all(|&c| c == 0));
        });
    }
}
