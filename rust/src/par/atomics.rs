//! Atomic support cells with the paper's floor-clamped decrement.
//!
//! Every peeling algorithm in the paper updates supports as
//! `⋈ ← max(θ, ⋈ − x)` (Alg. 3 line 4, Alg. 4 line 27, Alg. 6 lines 7/12):
//! the support never drops below the level `θ` currently being peeled, so
//! entities already scheduled keep a consistent value. Under concurrent
//! peeling these must be atomic read-modify-write ops.

use std::sync::atomic::{AtomicU64, Ordering};

/// A single entity's support (running butterfly count).
#[derive(Debug)]
pub struct SupportCell(AtomicU64);

impl SupportCell {
    pub fn new(v: u64) -> Self {
        SupportCell(AtomicU64::new(v))
    }

    #[inline]
    pub fn get(&self) -> u64 {
        // ORDERING: Relaxed — a support cell is a self-contained counter:
        // no other data is published through it, the peel loops tolerate
        // momentarily stale reads (an entity re-checks its support under
        // the next level anyway), and phase boundaries are ordered by the
        // pool's region barrier.
        self.0.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn set(&self, v: u64) {
        // ORDERING: Relaxed — see `get`; initialization stores happen
        // before the region that reads them (barrier-ordered).
        self.0.store(v, Ordering::Relaxed);
    }

    /// `⋈ ← max(floor, ⋈ − x)`, atomically. Returns the new value.
    #[inline]
    pub fn sub_clamped(&self, x: u64, floor: u64) -> u64 {
        // ORDERING: Relaxed — the CAS loop below only needs the cell's
        // own modification order (each decrement applied exactly once);
        // see `get` for why no cross-data ordering is required.
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = cur.saturating_sub(x).max(floor);
            // ORDERING: Relaxed success and failure — same argument as
            // the initial load: atomicity of the RMW is all the update
            // needs, and the failure value only re-seeds the loop.
            let res = self.0.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed);
            match res {
                Ok(_) => return new,
                Err(c) => cur = c,
            }
        }
    }

    /// Plain atomic add (used when re-aggregating counts).
    #[inline]
    pub fn add(&self, x: u64) {
        // ORDERING: Relaxed — see `get`; the RMW's atomicity makes
        // concurrent aggregation exact.
        self.0.fetch_add(x, Ordering::Relaxed);
    }
}

/// Allocate a support vector from plain counts.
pub fn support_vec(init: &[u64]) -> Vec<SupportCell> {
    init.iter().map(|&v| SupportCell::new(v)).collect()
}

/// Snapshot a support vector into plain u64s.
pub fn snapshot(cells: &[SupportCell]) -> Vec<u64> {
    cells.iter().map(|c| c.get()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::parallel_for;

    #[test]
    fn sub_clamped_basics() {
        let c = SupportCell::new(10);
        assert_eq!(c.sub_clamped(3, 0), 7);
        assert_eq!(c.sub_clamped(100, 5), 5);
        assert_eq!(c.sub_clamped(1, 5), 5);
    }

    #[test]
    fn sub_clamped_saturates_at_zero() {
        let c = SupportCell::new(2);
        assert_eq!(c.sub_clamped(5, 0), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 50k CAS loops are too slow interpreted
    fn concurrent_decrements_are_exact_above_floor() {
        let c = SupportCell::new(100_000);
        parallel_for(50_000, 4, |_, _| {
            c.sub_clamped(1, 0);
        });
        assert_eq!(c.get(), 50_000);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 50k CAS loops are too slow interpreted
    fn concurrent_decrements_respect_floor() {
        let c = SupportCell::new(1_000);
        parallel_for(50_000, 4, |_, _| {
            c.sub_clamped(1, 900);
        });
        assert_eq!(c.get(), 900);
    }

    #[test]
    fn support_vec_roundtrip() {
        let v = support_vec(&[1, 2, 3]);
        assert_eq!(snapshot(&v), vec![1, 2, 3]);
    }
}
