//! Persistent worker pool: parked OS threads reused across parallel
//! regions.
//!
//! The previous runtime spawned fresh scoped threads for every parallel
//! region. PBNG's coarse decomposition executes thousands of small peel
//! iterations (one region per ρ, often more), so thread-creation cost and
//! scheduler churn dominated the exact overhead regime ParButterfly-style
//! frameworks avoid with persistent pools. This module keeps a single
//! process-wide pool of parked workers and broadcasts each region to them
//! with an epoch ticket:
//!
//! * **Lifecycle** — the pool is created lazily on the first region that
//!   asks for more than one lane. Worker count is `default_threads() - 1`
//!   (the caller itself is lane 0), snapshotted once from `PBNG_THREADS` /
//!   `available_parallelism`. Between regions workers first spin briefly
//!   on a lock-free epoch hint (bridging back-to-back sub-microsecond
//!   regions without park/unpark latency), then park on a condvar, and
//!   live for the rest of the process (like rayon's global pool).
//! * **Region protocol** — the caller publishes a lifetime-erased
//!   `&dyn Fn(usize)` job plus a bumped epoch under the state mutex and
//!   wakes all workers. Each worker runs the job at most once per epoch,
//!   then decrements `remaining`; the caller participates as lane 0 and
//!   blocks until `remaining == 0` before returning, which is what makes
//!   the lifetime erasure sound: the borrowed closure (and everything it
//!   captures from the caller's stack) strictly outlives every use.
//! * **Fallback** — regions are serialized with a `try_lock`. A nested or
//!   concurrent region (or a panicked predecessor) degrades to running
//!   every lane id on the calling thread, so the lane contract below
//!   holds unconditionally and nesting can never deadlock.
//!
//! **Lane contract**: `Pool::run(threads, body)` invokes `body(t)` exactly
//! once for every lane `t in 0..lanes(threads)`, where `lanes(threads) =
//! threads.clamp(1, capacity)`. Per-lane scratch indexed by `t` is
//! therefore race-free within one region.
//!
//! [`ScratchSet`] complements the pool: reusable per-lane buffer slots
//! recycled through a global freelist, so hot peeling kernels neither
//! allocate nor lock per region (two freelist mutex ops per *region*,
//! versus one mutex op per *chunk* with the old `Mutex<Vec<u32>>`
//! collectors).

use super::RacyCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, TryLockError};

/// OS threads ever spawned by the pool (process-wide, monotonic). The
/// peeling pipelines snapshot this around a run to prove worker reuse:
/// the per-run delta is bounded by the pool capacity, not by ρ.
static TOTAL_SPAWNS: AtomicU64 = AtomicU64::new(0);

pub fn total_spawns() -> u64 {
    // ORDERING: Relaxed — a monotonic diagnostic tally; readers compare
    // before/after deltas around fully-barriered runs, so no ordering is
    // carried by the counter itself.
    TOTAL_SPAWNS.load(Ordering::Relaxed)
}

/// A parallel-region job. Lifetime-erased from the caller's borrow; only
/// valid until the caller's region wait completes (see module docs).
type Body = dyn Fn(usize) + Sync;

struct State {
    /// Region ticket; workers run a job at most once per epoch.
    epoch: u64,
    job: Option<&'static Body>,
    /// Worker lanes participating in the current region (lanes `1..=p`).
    participants: usize,
    /// Participants that have not finished the current region yet.
    remaining: usize,
    /// A worker's job panicked; surfaced to the caller after the barrier.
    panicked: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Lock-free mirror of `State.epoch`, bumped (Release) right after a
    /// region is published. Workers spin on it briefly before parking on
    /// the condvar: PBNG's CD phase issues thousands of sub-microsecond
    /// regions back to back, and for those the park/unpark round-trip
    /// (syscall + scheduler latency) dwarfs the region itself. The spin
    /// is bounded ([`SPIN_ITERS`]) so an idle pool still parks.
    epoch_hint: AtomicU64,
    /// Workers park here between regions.
    start: Condvar,
    /// The caller parks here until `remaining == 0`.
    done: Condvar,
}

/// Bounded spin budget before a worker parks (~a few microseconds of
/// `spin_loop` hints on current hardware — enough to bridge back-to-back
/// peel iterations, short enough to not burn an idle core).
const SPIN_ITERS: u32 = 1 << 12;

fn lock_state(sh: &Shared) -> std::sync::MutexGuard<'_, State> {
    // Jobs run outside the lock and decrements are panic-safe, so a
    // poisoned state mutex only ever holds consistent data.
    sh.state.lock().unwrap_or_else(|e| e.into_inner())
}

pub struct Pool {
    shared: Arc<Shared>,
    /// Total lanes including the caller (= worker count + 1).
    capacity: usize,
    /// Serializes regions; `try_lock` losers degrade to sequential.
    region: Mutex<()>,
}

impl Pool {
    /// The process-wide pool, created on first use.
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(Pool::create)
    }

    fn create() -> Pool {
        let capacity = super::default_threads().max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                participants: 0,
                remaining: 0,
                panicked: false,
            }),
            epoch_hint: AtomicU64::new(0),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        for lane in 1..capacity {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("pbng-worker-{lane}"))
                .spawn(move || worker_loop(&sh, lane))
                .expect("spawning pbng pool worker");
            // ORDERING: Relaxed — see `total_spawns`.
            TOTAL_SPAWNS.fetch_add(1, Ordering::Relaxed);
        }
        Pool {
            shared,
            capacity,
            region: Mutex::new(()),
        }
    }

    /// Total lanes (caller + parked workers).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lanes a region with this `threads` request will actually use.
    pub fn lanes(&self, threads: usize) -> usize {
        threads.clamp(1, self.capacity)
    }

    /// Run `body(t)` exactly once for every lane `t in 0..lanes(threads)`
    /// (see the module-level lane contract).
    pub fn run<F>(&self, threads: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let lanes = self.lanes(threads);
        if lanes == 1 {
            body(0);
            return;
        }
        let _guard = match self.region.try_lock() {
            Ok(g) => g,
            // A caller panic mid-region poisons the lock after the
            // region barrier completed; the pool itself is fine.
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            // Nested or concurrent region: keep the lane contract on the
            // calling thread instead of deadlocking on our own lock.
            Err(TryLockError::WouldBlock) => {
                for t in 0..lanes {
                    body(t);
                }
                return;
            }
        };
        let wide: &Body = &body;
        // SAFETY: the erased borrow is only reachable through `State.job`,
        // workers only run it between the publish below and their
        // `remaining` decrement, and `RegionWait` blocks (even during
        // unwinding of `body(0)`) until `remaining == 0` — so every use
        // ends before `body` can be dropped, which is exactly
        // `erase_lifetime`'s contract.
        let job: &'static Body = unsafe { erase_lifetime(wide) };
        {
            let mut st = lock_state(&self.shared);
            st.epoch += 1;
            st.participants = lanes - 1;
            st.remaining = lanes - 1;
            st.job = Some(job);
            // publish the epoch to spinning workers before (and in
            // addition to) the condvar wake-up for parked ones
            // ORDERING: Release — pairs with the Acquire spin in
            // `worker_loop`; a worker that spots the new epoch through the
            // hint must also see the `State` writes above once it takes
            // the mutex (the hint alone never carries the job — it only
            // short-circuits parking — but Release keeps the mirror
            // coherent with the locked state it advertises).
            self.shared.epoch_hint.store(st.epoch, Ordering::Release);
            self.shared.start.notify_all();
        }
        let _wait = RegionWait { shared: &self.shared };
        body(0);
        // `_wait` drops here: barrier, then worker-panic propagation.
    }
}

/// Erase the lifetime of a borrowed region job so it can sit in the
/// pool's `'static` [`State`]. This is the crate's only `transmute`; it
/// is allowlisted by name in `pbng-lint` (`check::rules`), so any new
/// transmute must land in its own reviewed, named wrapper to pass CI.
///
/// # Safety
/// The caller must guarantee that every dereference of the returned
/// borrow happens before `body`'s real lifetime ends. [`Pool::run`]
/// upholds this with its completion barrier: workers only run the job
/// between the epoch publish and their `remaining` decrement, and
/// [`RegionWait`] blocks the caller — even while unwinding — until
/// `remaining == 0`, so every use strictly precedes the drop of the
/// borrowed closure.
unsafe fn erase_lifetime(body: &Body) -> &'static Body {
    // SAFETY: only the lifetime is rewritten (`&Body` and
    // `&'static Body` have identical layout); validity past the true
    // lifetime is the caller's contract above.
    unsafe { std::mem::transmute::<&Body, &'static Body>(body) }
}

/// Blocks until the current region's workers are done — including on the
/// unwind path, which is what keeps the job borrow sound if the caller's
/// own lane panics. Also owns worker-panic handling: the flag is always
/// consumed at the barrier (so it cannot leak into a later region) and
/// re-raised only when the caller is not already unwinding.
struct RegionWait<'a> {
    shared: &'a Shared,
}

impl Drop for RegionWait<'_> {
    fn drop(&mut self) {
        let mut st = lock_state(self.shared);
        while st.remaining > 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let worker_panicked = st.panicked;
        st.panicked = false;
        drop(st);
        if worker_panicked && !std::thread::panicking() {
            panic!("a pbng pool worker panicked during a parallel region");
        }
    }
}

fn worker_loop(sh: &Shared, lane: usize) {
    // Tag this worker's thread with its lane id so obs events it records
    // land in the lane's own lock-free buffer (the `pbng::obs` hook; a
    // one-time thread-local store, nothing on the region hot path).
    crate::obs::set_lane(lane);
    let mut seen = 0u64;
    loop {
        // Bounded spin before parking: catch an imminent next region
        // without paying the condvar round-trip. Correctness does not
        // depend on the hint — a worker that spins out parks on the
        // condvar exactly as before, and one that spots a new epoch just
        // reaches the (unchanged) locked hand-off a bit sooner.
        let mut spins = 0u32;
        // ORDERING: Acquire — pairs with the Release store of
        // `epoch_hint` in `Pool::run`; see that site. The job itself is
        // still handed off under the state mutex below.
        while spins < SPIN_ITERS && sh.epoch_hint.load(Ordering::Acquire) == seen {
            std::hint::spin_loop();
            spins += 1;
        }
        let job = {
            let mut st = lock_state(sh);
            while st.epoch == seen {
                st = sh.start.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            seen = st.epoch;
            // Lanes beyond the region's request sit this epoch out.
            if lane <= st.participants {
                st.job
            } else {
                None
            }
        };
        if let Some(job) = job {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(lane)));
            let mut st = lock_state(sh);
            if outcome.is_err() {
                st.panicked = true;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                sh.done.notify_one();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Per-lane reusable scratch
// ---------------------------------------------------------------------

/// One lane's reusable buffers. The two id collectors keep their
/// capacity across regions; `cnt` is a dense counter array with the
/// invariant that it is all-zero whenever the slot is not inside a
/// region (the peeling kernels re-zero entries as they flush them).
#[derive(Default)]
pub struct ScratchSlot {
    /// First id collector (wing: dirty blooms; tip: wedge-end list).
    pub a: Vec<u32>,
    /// Second id collector (wing/tip: touched entities).
    pub b: Vec<u32>,
    /// `(entity, delta)` update log for the aggregated peel kernels
    /// ([`crate::count::kernel::flush_runs`]); `u64` deltas because tip
    /// deltas are `C(c, 2)` counts that can exceed `u32`.
    pub pairs: Vec<(u32, u64)>,
    cnt: Vec<u32>,
}

impl ScratchSlot {
    /// `(cnt[..n], a, b, pairs)` with `cnt` zero-extended to at least
    /// `n` entries. Callers must restore the zeros they overwrite before
    /// the region ends.
    #[allow(clippy::type_complexity)]
    pub fn split(
        &mut self,
        n: usize,
    ) -> (&mut [u32], &mut Vec<u32>, &mut Vec<u32>, &mut Vec<(u32, u64)>) {
        if self.cnt.len() < n {
            self.cnt.resize(n, 0);
        }
        (&mut self.cnt[..n], &mut self.a, &mut self.b, &mut self.pairs)
    }
}

/// Slots recycled through the freelist so steady-state peel iterations
/// are allocation-free.
static FREELIST: Mutex<Vec<ScratchSlot>> = Mutex::new(Vec::new());

/// A per-lane scratch checkout: one [`ScratchSlot`] per lane id of a
/// parallel region, acquired from (and returned to) the global freelist
/// with a single lock round-trip each way.
pub struct ScratchSet {
    slots: Vec<RacyCell<ScratchSlot>>,
}

impl ScratchSet {
    /// Check out `lanes` slots (size with [`super::max_lanes`]).
    pub fn take(lanes: usize) -> ScratchSet {
        let lanes = lanes.max(1);
        let mut fl = FREELIST.lock().unwrap_or_else(|e| e.into_inner());
        let mut slots = Vec::with_capacity(lanes);
        for _ in 0..lanes {
            slots.push(RacyCell::new(fl.pop().unwrap_or_default()));
        }
        ScratchSet { slots }
    }

    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// The slot for lane `t`.
    ///
    /// # Safety
    /// Caller must currently drive lane `t` of a parallel region that
    /// sized this set with at least `t + 1` lanes — the pool's lane
    /// contract (each lane id runs on exactly one thread per region)
    /// then makes slot `t` exclusively this thread's — and must not hold
    /// two live guards to the same lane's slot at once. Debug builds
    /// enforce the single-guard rule through the slot's borrow flag.
    #[inline]
    pub unsafe fn lane(&self, t: usize) -> super::RacyRef<'_, ScratchSlot> {
        // SAFETY: exclusivity of slot `t` is the caller's contract above.
        unsafe { self.slots[t].get_mut() }
    }

    /// Exclusive post-region sweep over every slot (result collection).
    pub fn for_each(&mut self, mut f: impl FnMut(&mut ScratchSlot)) {
        for s in &mut self.slots {
            f(s.as_mut());
        }
    }
}

impl Drop for ScratchSet {
    fn drop(&mut self) {
        let unwinding = std::thread::panicking();
        let mut fl = FREELIST.lock().unwrap_or_else(|e| e.into_inner());
        for s in self.slots.drain(..) {
            let mut s = s.into_inner();
            s.a.clear();
            s.b.clear();
            s.pairs.clear();
            if unwinding {
                // A panicking kernel may have died between bumping `cnt`
                // and re-zeroing it; sanitize rather than poisoning the
                // freelist (or double-panicking on the assert below).
                s.cnt.fill(0);
            } else {
                debug_assert!(
                    s.cnt.iter().all(|&c| c == 0),
                    "ScratchSlot.cnt returned to the freelist dirty"
                );
            }
            fl.push(s);
        }
    }
}
