//! `pbng` — CLI launcher for the PBNG framework.
//!
//! Subcommands:
//!   gen        generate a synthetic bipartite graph (presets or custom)
//!   count      butterfly counting (per-vertex / per-edge / total)
//!   wing       wing (edge) decomposition — pbng | bup | parb | be-batch | be-pc
//!   tip        tip (vertex) decomposition — pbng | bup | parb
//!   update     incremental decomposition over an edge-delta stream
//!   hierarchy  materialize the k-wing hierarchy levels
//!   index      build + persist the hierarchy forest index
//!   query      one-shot query against a persisted index
//!   serve      serve index queries over stdin or TCP
//!   bench      run a benchmark suite / compare two bench reports
//!   trace      run one decomposition with span tracing, write the trace
//!   verify     run all algorithms and assert they agree
//!   info       runtime / artifact status
//!
//! `wing`, `tip`, `update`, and `bench` also accept `--trace`
//! (`--trace-out FILE`) to capture a Chrome trace of the run they
//! already do.

use anyhow::{bail, Context, Result};
use pbng::cli::Args;
use pbng::graph::{gen, io, BipartiteGraph, Side};
use pbng::metrics::human;
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        usage();
        return;
    }
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "pbng — Parallel Bipartite Network peelinG

USAGE: pbng <command> [args]

  gen --preset <name> --out <file>
  gen --kind zipf|erdos --nu N --nv N --m M [--alpha-u A --alpha-v A] --seed S --out <file>
  count <graph.tsv> [--threads T]
  wing <graph.tsv> [--algo pbng|bup|parb|be-batch|be-pc] [--p P] [--threads T]
                   [--tau F] [--no-batch] [--no-deletes] [--out numbers.txt]
  tip <graph.tsv> [--side u|v] [--algo pbng|bup|parb] [--p P] [--threads T]
                  [--no-batch] [--no-deletes] [--out numbers.txt]
  update <graph.tsv> <deltas.txt> [--kind wing|tip-u|tip-v] [--batch N]
                  [--fallback F] [--p P] [--threads T] [--out numbers.txt]
                  [--verify]
  hierarchy <graph.tsv> [--p P] [--threads T]
  index <graph.tsv> --out <index.idx> [--kind wing|tip-u|tip-v]
                    [--theta numbers.txt] [--p P] [--threads T]
  query <index.idx> <command ...>        (e.g. `query g.idx kwing 3`)
  serve <index.idx> [--port N] [--max-conns N] [--per-ip N]
        [--idle-timeout SECS] [--proto v1|v2] [--watch-interval MS]
        (stdin session without --port; --port 0 picks an ephemeral port;
         the index file is re-served on rewrite or on the `reload` verb)
  serve <graph.tsv> --watch <deltas.txt> [--kind wing|tip-u|tip-v]
        [--batch N] [--fallback F] [--p P] [--threads T] [serve flags]
        (live snapshots: deltas drain through the incremental engine)
  serve <graph.tsv> --wal <log.wal> [--checkpoint <file>] [--delay-ms MS]
        [--kind wing|tip-u|tip-v] [--batch N] [--fallback F] [serve flags]
        (durable ingestion: recover from checkpoint + log replay, accept
         `ingest` over the wire, batch through the coalescing pool)
  wal init <log.wal>
  wal append <log.wal> <deltas.txt> [--batch N]
  wal replay <log.wal> [--quiet]
  wal compact <log.wal> --graph <graph.tsv> [--kind wing|tip-u|tip-v]
              [--checkpoint <file>]        (fold the log into a checkpoint)
  wal compact <log.wal> --keep-after N     (drop records with seq <= N)
  bench [--suite smoke] [--repetitions N] [--warmup N] [--threads T]
        [--out FILE] [--list]
  bench compare <baseline.json> <current.json> [--counter-tolerance F]
        [--time-factor F] [--ignore-time] [--allow-empty-baseline]
  trace <graph.tsv> [--kind wing|tip-u|tip-v] [--p P] [--threads T]
        [--format chrome|jsonl] [--out trace.json] [--verify]
  verify <graph.tsv> [--p P] [--threads T]
  info

wing/tip/update/bench also take --trace [--trace-out FILE] to write a
Chrome trace (trace.json) of the run.

Index line protocol: components/kwing/ktip <k>, membership <id>,
densest <id>, top <n>, summary, stats, metrics, help, quit
(+ reload and `ingest (+|-) u v ...` under protocol v2; ingest needs a
--wal server and acks with the record's durable sequence number).
v2 frames every reply as `OK <verb>` /
`ERR <reason>` … `END`; `--proto v1` keeps the legacy READY/BYE format
for one release.

<graph.tsv> may also be a preset name.
Presets: {}",
        gen::Preset::all_small()
            .iter()
            .chain(gen::Preset::all_medium())
            .map(|p| p.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
}

fn run(argv: Vec<String>) -> Result<()> {
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1))?;
    match cmd.as_str() {
        "gen" => cmd_gen(&args),
        "count" => cmd_count(&args),
        "wing" => cmd_wing(&args),
        "tip" => cmd_tip(&args),
        "update" => cmd_update(&args),
        "hierarchy" => cmd_hierarchy(&args),
        "index" => cmd_index(&args),
        "query" => cmd_query(&args),
        "serve" => cmd_serve(&args),
        "wal" => cmd_wal(&args),
        "bench" => cmd_bench(&args),
        "trace" => cmd_trace(&args),
        "verify" => cmd_verify(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command '{other}' (try --help)"),
    }
}

fn load_graph(args: &Args) -> Result<BipartiteGraph> {
    let path = args
        .positional
        .first()
        .context("expected a graph file (or preset name) argument")?;
    if let Some(p) = gen::Preset::from_name(path) {
        return Ok(p.build());
    }
    io::load(Path::new(path))
}

fn cmd_gen(args: &Args) -> Result<()> {
    let out = args.get("out").context("--out is required")?.to_string();
    let g = if let Some(name) = args.get("preset") {
        gen::Preset::from_name(name)
            .with_context(|| format!("unknown preset '{name}'"))?
            .build()
    } else {
        let nu = args.get_usize("nu", 1000)?;
        let nv = args.get_usize("nv", 1000)?;
        let m = args.get_usize("m", 10_000)?;
        let seed = args.get_u64("seed", 42)?;
        match args.get_or("kind", "zipf") {
            "zipf" => {
                let au = args.get_f64("alpha-u", 1.2)?;
                let av = args.get_f64("alpha-v", 1.2)?;
                gen::zipf(nu, nv, m, au, av, seed)
            }
            "erdos" => gen::erdos(nu, nv, m, seed),
            k => bail!("unknown --kind '{k}'"),
        }
    };
    args.check_unknown()?;
    io::save(&g, Path::new(&out))?;
    println!(
        "wrote {} (|U|={} |V|={} |E|={})",
        out,
        g.nu(),
        g.nv(),
        g.m()
    );
    Ok(())
}

fn cmd_count(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let threads = args.get_usize("threads", pbng::par::default_threads())?;
    args.check_unknown()?;
    let t0 = std::time::Instant::now();
    let (c, _) = pbng::count::pve_bcnt(
        &g,
        pbng::count::CountOptions {
            per_edge: true,
            build_blooms: false,
            threads,
            kernel: pbng::count::KernelConfig::default(),
        },
        None,
    );
    println!("graph: |U|={} |V|={} |E|={}", g.nu(), g.nv(), g.m());
    println!("butterflies: {} ({})", c.total, human(c.total));
    println!(
        "max per-edge: {}   max per-U: {}   max per-V: {}",
        c.per_edge.iter().max().copied().unwrap_or(0),
        c.per_u.iter().max().copied().unwrap_or(0),
        c.per_v.iter().max().copied().unwrap_or(0),
    );
    println!("time: {:?} ({} threads)", t0.elapsed(), threads);
    Ok(())
}

/// One config for both decompositions: all `--p/--threads/--no-batch/
/// --no-deletes` flags route through the shared `engine::EngineConfig`
/// (wing and tip only differ in the default partition count).
fn engine_cfg(args: &Args, default_p: usize) -> Result<pbng::engine::EngineConfig> {
    Ok(pbng::engine::EngineConfig {
        p: args.get_usize("p", default_p)?,
        threads: args.get_usize("threads", pbng::par::default_threads())?,
        batch: !args.flag("no-batch"),
        dynamic_deletes: !args.flag("no-deletes"),
        ..Default::default()
    })
}

fn wing_cfg(args: &Args) -> Result<pbng::engine::EngineConfig> {
    engine_cfg(args, 64)
}

fn report(name: &str, d: &pbng::peel::Decomposition) {
    println!(
        "{name}: time={:?} updates={} wedges={} rho={}",
        d.stats.total,
        human(d.stats.updates),
        human(d.stats.wedges),
        d.stats.rho
    );
    for (ph, t, upd, wdg) in &d.stats.phases {
        println!(
            "  {:<12} {:>10?}  updates={:<10} wedges={}",
            ph.name(),
            t,
            human(*upd),
            human(*wdg)
        );
    }
    let max = d.theta.iter().max().copied().unwrap_or(0);
    println!("  θ_max = {max}");
}

/// Shared `--trace` handling for wing/tip/update/bench: when requested,
/// turns span collection on and returns the trace output path.
fn trace_begin(args: &Args) -> Option<String> {
    let out = args.get("trace-out").map(str::to_string);
    if args.flag("trace") || out.is_some() {
        pbng::obs::enable();
        Some(out.unwrap_or_else(|| "trace.json".to_string()))
    } else {
        None
    }
}

/// Counterpart of [`trace_begin`]: drains the buffered spans and writes
/// a Chrome `trace_event` JSON file.
fn trace_finish(out: Option<String>) -> Result<()> {
    let Some(path) = out else { return Ok(()) };
    let events = pbng::obs::take_events();
    pbng::obs::disable();
    let text = pbng::obs::export::chrome_trace(&events).to_pretty();
    std::fs::write(&path, text).with_context(|| format!("writing trace to {path}"))?;
    let dropped = pbng::obs::dropped();
    let note = if dropped > 0 { format!(" ({dropped} dropped)") } else { String::new() };
    println!("wrote {} trace events to {path}{note}", events.len());
    Ok(())
}

/// `pbng trace`: run one decomposition with span tracing on, validate
/// the span stream, and write the trace in the requested format.
fn cmd_trace(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let kind = args.get_or("kind", "wing").to_string();
    let cfg = engine_cfg(args, if kind == "wing" { 64 } else { 32 })?;
    let out = args.get_or("out", "trace.json").to_string();
    let format = args.get_or("format", "chrome").to_string();
    let verify = args.flag("verify");
    args.check_unknown()?;
    pbng::obs::enable();
    let d = match kind.as_str() {
        "wing" => pbng::wing::wing_pbng(&g, cfg),
        "tip" | "tip-u" => pbng::tip::tip_pbng(&g, Side::U, cfg),
        "tip-v" => pbng::tip::tip_pbng(&g, Side::V, cfg),
        k => bail!("unknown --kind '{k}' (wing | tip-u | tip-v)"),
    };
    let events = pbng::obs::take_events();
    pbng::obs::disable();
    pbng::obs::check_spans(&events)
        .map_err(|e| anyhow::anyhow!("malformed span stream: {e}"))?;
    let text = match format.as_str() {
        "chrome" => pbng::obs::export::chrome_trace(&events).to_pretty(),
        "jsonl" => pbng::obs::export::jsonl(&events),
        f => bail!("unknown --format '{f}' (chrome | jsonl)"),
    };
    std::fs::write(&out, &text).with_context(|| format!("writing trace to {out}"))?;
    if verify {
        match format.as_str() {
            "chrome" => pbng::testkit::check_trace_json(&text)
                .map_err(|e| anyhow::anyhow!("trace validation failed: {e}"))?,
            _ => pbng::testkit::check_trace_jsonl(&text)
                .map_err(|e| anyhow::anyhow!("trace validation failed: {e}"))?,
        }
        println!("OK: trace file validated ({format})");
    }
    report(&format!("{kind}[pbng]"), &d);
    let dropped = pbng::obs::dropped();
    let note = if dropped > 0 { format!(" ({dropped} dropped)") } else { String::new() };
    println!(
        "wrote {} trace events ({} spans) to {out}{note}",
        events.len(),
        events.len() / 2
    );
    Ok(())
}

fn cmd_wing(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let cfg = wing_cfg(args)?;
    let algo = args.get_or("algo", "pbng").to_string();
    let tau = args.get_f64("tau", 0.02)?;
    let out = args.get("out").map(|s| s.to_string());
    let trace = trace_begin(args);
    args.check_unknown()?;
    let d = match algo.as_str() {
        "pbng" => pbng::wing::wing_pbng(&g, cfg),
        "bup" => pbng::peel::bup::wing_bup(&g),
        "parb" => pbng::peel::parb::wing_parb(&g, cfg.threads),
        "be-batch" => pbng::wing::wing_be_batch(&g, cfg.threads),
        "be-pc" => pbng::wing::wing_be_pc(&g, tau),
        a => bail!("unknown wing algo '{a}'"),
    };
    report(&format!("wing[{algo}]"), &d);
    trace_finish(trace)?;
    if let Some(out) = out {
        io::save_numbers(&d.theta, Path::new(&out))?;
        println!("wrote wing numbers to {out}");
    }
    Ok(())
}

fn cmd_tip(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let side = match args.get_or("side", "u") {
        "u" | "U" => Side::U,
        "v" | "V" => Side::V,
        s => bail!("--side must be u or v, got '{s}'"),
    };
    let cfg = engine_cfg(args, 32)?;
    let algo = args.get_or("algo", "pbng").to_string();
    let out = args.get("out").map(|s| s.to_string());
    let trace = trace_begin(args);
    args.check_unknown()?;
    let d = match algo.as_str() {
        "pbng" => pbng::tip::tip_pbng(&g, side, cfg),
        "bup" => pbng::tip::tip_bup(&g, side),
        "parb" => pbng::tip::tip_parb(&g, side, cfg.threads),
        a => bail!("unknown tip algo '{a}'"),
    };
    report(&format!("tip[{algo}]{side:?}"), &d);
    trace_finish(trace)?;
    if let Some(out) = out {
        io::save_numbers(&d.theta, Path::new(&out))?;
        println!("wrote tip numbers to {out}");
    }
    Ok(())
}

/// Incremental decomposition: apply an edge-delta stream in batches on
/// `engine::incremental`, keeping θ consistent without from-scratch
/// recomputation (with `--verify` proving it at the end).
fn cmd_update(args: &Args) -> Result<()> {
    use pbng::engine::incremental::{IncrementalConfig, IncrementalState};
    use pbng::graph::dynamic::{load_deltas, DeltaBatch};
    let g = load_graph(args)?;
    let delta_path = args
        .positional
        .get(1)
        .context("expected a delta file (lines `+ u v` / `- u v`)")?
        .to_string();
    let kind = args.get_or("kind", "wing").to_string();
    let batch_size = args.get_usize("batch", 0)?;
    let fallback = args.get_f64("fallback", 0.25)?;
    let engine = engine_cfg(args, if kind == "wing" { 64 } else { 32 })?;
    let out = args.get("out").map(str::to_string);
    let verify = args.flag("verify");
    let trace = trace_begin(args);
    args.check_unknown()?;
    let ops = load_deltas(Path::new(&delta_path))?;
    for (i, op) in ops.iter().enumerate() {
        let (pbng::graph::dynamic::DeltaOp::Insert(u, v)
        | pbng::graph::dynamic::DeltaOp::Remove(u, v)) = *op;
        anyhow::ensure!(
            (u as usize) < g.nu() && (v as usize) < g.nv(),
            "delta op {} references ({u}, {v}) outside the graph's {}x{} vertex universe \
             (the universe is fixed; regenerate the graph with larger --nu/--nv)",
            i + 1,
            g.nu(),
            g.nv()
        );
    }
    let icfg = IncrementalConfig { engine, fallback_fraction: fallback };
    let fkind = match kind.as_str() {
        "wing" => pbng::index::ForestKind::Wing,
        "tip-u" => pbng::index::ForestKind::TipU,
        "tip-v" => pbng::index::ForestKind::TipV,
        k => bail!("unknown --kind '{k}' (wing | tip-u | tip-v)"),
    };
    let mut st = IncrementalState::new(&g, fkind, icfg);
    let chunk = if batch_size == 0 { ops.len().max(1) } else { batch_size };
    println!("applying {} delta ops in batches of {chunk} ({kind})", ops.len());
    for (i, ops) in ops.chunks(chunk).enumerate() {
        let batch = DeltaBatch::new(ops.to_vec());
        let up = st.apply(&batch);
        println!(
            "batch {i}: +{} -{} edges, butterflies +{}/-{}, affected {}/{}, \
             invalidated {}/{} partitions{} ({:?})",
            up.inserted,
            up.removed,
            up.butterflies_created,
            up.butterflies_destroyed,
            up.affected_entities,
            up.total_entities,
            up.invalidated_partitions,
            up.total_partitions,
            if up.full_rebuild { ", full rebuild" } else { "" },
            up.stats.total,
        );
    }
    // finish before --verify so the trace covers only the delta stream
    trace_finish(trace)?;
    let theta: Vec<u64> = st.theta().to_vec();
    if verify {
        let fresh = match st.kind() {
            pbng::index::ForestKind::Wing => pbng::wing::wing_pbng(st.graph(), engine).theta,
            // the state's graph is already oriented with the peel side as U
            _ => pbng::tip::tip_pbng(st.graph(), Side::U, engine).theta,
        };
        anyhow::ensure!(
            theta == fresh,
            "incremental θ diverged from the from-scratch decomposition"
        );
        println!("OK: incremental θ identical to from-scratch decomposition");
    }
    let max = theta.iter().max().copied().unwrap_or(0);
    println!("final: {} entities, θ_max = {max}", theta.len());
    if let Some(out) = out {
        io::save_numbers(&theta, Path::new(&out))?;
        println!("wrote numbers to {out}");
    }
    Ok(())
}

fn cmd_hierarchy(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let cfg = wing_cfg(args)?;
    args.check_unknown()?;
    let (idx, _) = pbng::beindex::BeIndex::build(&g, cfg.threads);
    let d = pbng::wing::wing_pbng(&g, cfg);
    let summary = pbng::hierarchy::wing_hierarchy_summary(&g, &idx, &d.theta);
    println!("{:>8} {:>10} {:>12} {:>10}", "k", "edges", "components", "largest");
    for l in summary {
        println!(
            "{:>8} {:>10} {:>12} {:>10}",
            l.k, l.entities, l.components, l.largest
        );
    }
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let out = args.get("out").context("--out is required")?.to_string();
    let kind = args.get_or("kind", "wing").to_string();
    let cfg = wing_cfg(args)?;
    let theta_file = args.get("theta").map(|s| s.to_string());
    args.check_unknown()?;
    let t0 = std::time::Instant::now();
    let load_theta = |expected: usize, what: &str| -> Result<Option<Vec<u64>>> {
        match &theta_file {
            None => Ok(None),
            Some(f) => {
                let nums = io::load_numbers(Path::new(f))?;
                anyhow::ensure!(
                    nums.len() == expected,
                    "--theta file has {} values, expected one per {what} ({expected})",
                    nums.len()
                );
                Ok(Some(nums))
            }
        }
    };
    let forest = match kind.as_str() {
        "wing" => {
            let theta = match load_theta(g.m(), "edge")? {
                Some(t) => t,
                None => pbng::wing::wing_pbng(&g, cfg).theta,
            };
            let (idx, _) = pbng::beindex::BeIndex::build(&g, cfg.threads);
            pbng::index::build_wing_forest(&g, &idx, &theta, cfg.threads)
        }
        "tip-u" | "tip-v" => {
            let (side, fkind) = if kind == "tip-u" {
                (Side::U, pbng::index::ForestKind::TipU)
            } else {
                (Side::V, pbng::index::ForestKind::TipV)
            };
            let theta = match load_theta(g.n_side(side), "vertex")? {
                Some(t) => t,
                None => pbng::tip::tip_pbng(&g, side, cfg).theta,
            };
            pbng::index::build_tip_forest(&theta, fkind)
        }
        k => bail!("unknown --kind '{k}' (wing | tip-u | tip-v)"),
    };
    let bytes = pbng::index::codec::save(&forest, Path::new(&out))?;
    println!(
        "wrote {out}: kind={} entities={} nodes={} levels={} members={} ({} on disk) in {:?}",
        forest.kind.name(),
        forest.n_entities(),
        forest.n_nodes(),
        forest.levels.len(),
        forest.n_members(),
        human(bytes),
        t0.elapsed()
    );
    Ok(())
}

fn load_engine(args: &Args) -> Result<pbng::index::query::QueryEngine> {
    let path = args
        .positional
        .first()
        .context("expected an index file argument (built with `pbng index`)")?;
    let forest = pbng::index::codec::load(Path::new(path))?;
    Ok(pbng::index::query::QueryEngine::new(forest))
}

fn cmd_query(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    args.check_unknown()?;
    let cmd = args.positional[1..].join(" ");
    anyhow::ensure!(!cmd.is_empty(), "expected a query command (try `pbng query <idx> help`)");
    match pbng::index::server::handle_command(&engine, &cmd) {
        pbng::index::server::Reply::Body(b) => println!("{b}"),
        pbng::index::server::Reply::Quit => {}
    }
    Ok(())
}

/// Parse `--kind wing|tip-u|tip-v` into a [`pbng::index::ForestKind`].
fn forest_kind(kind: &str) -> Result<pbng::index::ForestKind> {
    match kind {
        "wing" => Ok(pbng::index::ForestKind::Wing),
        "tip-u" => Ok(pbng::index::ForestKind::TipU),
        "tip-v" => Ok(pbng::index::ForestKind::TipV),
        k => bail!("unknown --kind '{k}' (wing | tip-u | tip-v)"),
    }
}

/// `pbng serve`: the poll-based reactor over hot-swappable snapshots.
///
/// Default mode serves a persisted index file; a background updater
/// re-reads it when the file changes on disk or a client sends
/// `reload`. With `--watch <deltas>` the positional is a graph (file or
/// preset) and the updater instead drains the delta log through the
/// incremental engine, republishing a fresh snapshot per batch. With
/// `--wal <log>` the positional is the base graph and the updater tails
/// a durable write-ahead log: startup recovers from the last checkpoint
/// plus log replay, sessions may submit deltas with the `ingest` verb
/// (acked only after fsync), and the staging pool coalesces them into
/// batches by size or latency deadline.
fn cmd_serve(args: &Args) -> Result<()> {
    use pbng::serve::{ProtoVersion, Server, ServerConfig, SnapshotSource, SnapshotStore, Updater};
    let proto = {
        let s = args.get_or("proto", "v2");
        ProtoVersion::parse(s).with_context(|| format!("--proto expects v1 or v2, got '{s}'"))?
    };
    let port = if args.get("port").is_some() {
        Some(args.get_u16("port", 0)?)
    } else {
        None
    };
    let max_conns = args.get_usize("max-conns", 1024)?;
    let per_ip = args.get_usize("per-ip", 32)?;
    let idle_secs = args.get_u64("idle-timeout", 300)?;
    let interval = std::time::Duration::from_millis(args.get_u64("watch-interval", 500)?);
    let watch = args.get("watch").map(str::to_string);
    let wal_path = args.get("wal").map(str::to_string);
    anyhow::ensure!(
        watch.is_none() || wal_path.is_none(),
        "--watch and --wal are mutually exclusive (the wal IS the delta log)"
    );
    let (store, _updater) = match (watch, wal_path) {
        (None, None) => {
            let path = args
                .positional
                .first()
                .context("expected an index file argument (built with `pbng index`)")?
                .clone();
            let engine = load_engine(args)?;
            let store = SnapshotStore::new(engine);
            let upd = Updater::spawn(
                SnapshotSource::IndexFile(path.into()),
                store.clone(),
                interval,
            );
            (store, upd)
        }
        (Some(deltas), None) => {
            use pbng::engine::incremental::{IncrementalConfig, IncrementalState};
            let g = load_graph(args)?;
            let kind = args.get_or("kind", "wing").to_string();
            let fkind = forest_kind(&kind)?;
            let batch = args.get_usize("batch", 256)?;
            let fallback = args.get_f64("fallback", 0.25)?;
            let ecfg = engine_cfg(args, if kind == "wing" { 64 } else { 32 })?;
            let threads = ecfg.threads;
            let icfg = IncrementalConfig { engine: ecfg, fallback_fraction: fallback };
            let state = IncrementalState::new(&g, fkind, icfg);
            let engine = pbng::serve::updater::engine_from_state(&state, threads);
            let store = SnapshotStore::new(engine);
            let upd = Updater::spawn(
                SnapshotSource::DeltaLog {
                    state,
                    path: deltas.into(),
                    batch,
                    threads,
                },
                store.clone(),
                interval,
            );
            (store, upd)
        }
        (None, Some(walp)) => {
            use pbng::engine::incremental::{IncrementalConfig, IncrementalState};
            use pbng::graph::dynamic::DeltaBatch;
            use pbng::ingest::{AdaptiveFallback, Pool, PoolConfig};
            let g = load_graph(args)?;
            let kind = args.get_or("kind", "wing").to_string();
            let fkind = forest_kind(&kind)?;
            let batch = args.get_usize("batch", 256)?.max(1);
            let delay_ms = args.get_u64("delay-ms", 200)?;
            let fallback = args.get_f64("fallback", 0.25)?;
            let ecfg = engine_cfg(args, if kind == "wing" { 64 } else { 32 })?;
            let threads = ecfg.threads;
            let icfg = IncrementalConfig { engine: ecfg, fallback_fraction: fallback };
            let ckpt_path = match args.get("checkpoint") {
                Some(c) => std::path::PathBuf::from(c),
                None => std::path::PathBuf::from(format!("{walp}.ckpt")),
            };
            // recovery anchor: the checkpoint (if any) replaces the
            // positional graph and names the sequence replay starts after
            let (base, start_seq) = if ckpt_path.exists() {
                let ck = pbng::wal::checkpoint::Checkpoint::load(&ckpt_path)?;
                anyhow::ensure!(
                    ck.kind == fkind,
                    "checkpoint {} holds a {} state, --kind asked for {}",
                    ckpt_path.display(),
                    ck.kind.name(),
                    fkind.name()
                );
                anyhow::ensure!(
                    ck.nu == g.nu() && ck.nv == g.nv(),
                    "checkpoint universe {}x{} does not match the graph's {}x{}",
                    ck.nu,
                    ck.nv,
                    g.nu(),
                    g.nv()
                );
                eprintln!(
                    "pbng serve: recovering from checkpoint {} (seq {})",
                    ckpt_path.display(),
                    ck.seq
                );
                (ck.graph(), ck.seq)
            } else {
                (g, 0)
            };
            let (mut writer, tail) =
                pbng::wal::Writer::open_or_create(Path::new(&walp)).map_err(anyhow::Error::new)?;
            if tail.torn_bytes > 0 {
                eprintln!(
                    "pbng serve: truncated {} torn tail byte(s) from {walp} (crash mid-append)",
                    tail.torn_bytes
                );
            }
            let mut state = IncrementalState::new(&base, fkind, icfg);
            let (nu, nv) = state.universe();
            let mut pending = Vec::new();
            let mut next = start_seq + 1;
            let mut skipped = 0usize;
            for rec in &tail.records {
                if rec.seq <= start_seq {
                    continue; // already folded into the checkpoint
                }
                anyhow::ensure!(
                    rec.seq == next,
                    "wal sequence gap during recovery: record {} where {} expected",
                    rec.seq,
                    next
                );
                for &op in &rec.ops {
                    let (u, v) = op.key();
                    if (u as usize) < nu && (v as usize) < nv {
                        pending.push(op);
                    } else {
                        skipped += 1;
                    }
                }
                next += 1;
            }
            if skipped > 0 {
                eprintln!("pbng serve: skipped {skipped} out-of-universe op(s) during replay");
            }
            let replayed = pending.len();
            for ops in pending.chunks(batch) {
                state.apply(&DeltaBatch::new(ops.to_vec()));
            }
            // a fully compacted log must not restart the numbering the
            // checkpoint already burned
            writer.ensure_next_seq(next);
            let start_offset = writer.end_offset();
            let applied_seq = writer.next_seq() - 1;
            eprintln!(
                "pbng serve: wal recovery replayed {replayed} op(s), resuming at seq {}",
                applied_seq + 1
            );
            let engine = pbng::serve::updater::engine_from_state(&state, threads);
            let store = SnapshotStore::new(engine);
            store.attach_ingest(pbng::serve::WalSink::new(writer, nu, nv));
            let upd = Updater::spawn(
                SnapshotSource::Wal {
                    state,
                    path: walp.into(),
                    pool: Pool::new(PoolConfig {
                        max_batch: batch,
                        max_delay: std::time::Duration::from_millis(delay_ms),
                    }),
                    ctl: AdaptiveFallback::new(fallback),
                    threads,
                    start_offset,
                    start_seq: applied_seq,
                },
                store.clone(),
                interval,
            );
            (store, upd)
        }
        (Some(_), Some(_)) => unreachable!("rejected above"),
    };
    args.check_unknown()?;
    let mut cfg = ServerConfig::new()
        .max_conns(max_conns)
        .per_ip(per_ip)
        .idle_timeout(std::time::Duration::from_secs(idle_secs))
        .proto(proto);
    if let Some(p) = port {
        cfg = cfg.addr(format!("127.0.0.1:{p}"));
    }
    Server::new(cfg, store).run()?;
    Ok(())
}

/// `pbng wal`: offline tooling for the durable write-ahead delta log.
///
/// * `init <log>` — create (truncate) an empty log with a valid header.
/// * `append <log> <deltas.txt> [--batch N]` — append a text delta file
///   as durable records (one record per batch; `--batch 0` = one record
///   for the whole file).
/// * `replay <log> [--quiet]` — decode and print every record; exits
///   non-zero on mid-log corruption (a torn tail is only a warning).
/// * `compact <log> --graph <g> [--kind K] [--checkpoint C]` — fold the
///   whole log into a checkpoint of the base graph and drop the folded
///   records; or `compact <log> --keep-after N` to drop records with
///   `seq <= N` without writing a checkpoint.
fn cmd_wal(args: &Args) -> Result<()> {
    use pbng::wal;
    let sub = args
        .positional
        .first()
        .context("expected a wal subcommand: init | append | replay | compact")?
        .clone();
    match sub.as_str() {
        "init" => {
            let log = args.positional.get(1).context("expected a log path")?;
            args.check_unknown()?;
            wal::Writer::create(Path::new(log)).map_err(anyhow::Error::new)?;
            println!("initialized empty wal at {log}");
            Ok(())
        }
        "append" => {
            use pbng::graph::dynamic::load_deltas;
            let log = args.positional.get(1).context("expected a log path")?.clone();
            let deltas = args
                .positional
                .get(2)
                .context("expected a delta file (lines `+ u v` / `- u v`)")?
                .clone();
            let batch = args.get_usize("batch", 0)?;
            args.check_unknown()?;
            let ops = load_deltas(Path::new(&deltas))?;
            let (mut w, tail) = wal::Writer::open(Path::new(&log)).map_err(anyhow::Error::new)?;
            if tail.torn_bytes > 0 {
                eprintln!("warning: truncated {} torn tail byte(s) from {log}", tail.torn_bytes);
            }
            let chunk = if batch == 0 { ops.len().max(1) } else { batch };
            let mut first = None;
            let mut last = 0;
            for part in ops.chunks(chunk) {
                let seq = w.append(part).map_err(anyhow::Error::new)?;
                first.get_or_insert(seq);
                last = seq;
            }
            match first {
                Some(f) => println!(
                    "appended {} op(s) as {} record(s), seq {f}..={last}",
                    ops.len(),
                    last - f + 1
                ),
                None => println!("no ops in {deltas}; log unchanged"),
            }
            println!("log ends at byte {}", w.end_offset());
            Ok(())
        }
        "replay" => {
            let log = args.positional.get(1).context("expected a log path")?.clone();
            let quiet = args.flag("quiet");
            args.check_unknown()?;
            let tail = wal::replay(Path::new(&log)).map_err(anyhow::Error::new)?;
            let mut total_ops = 0usize;
            for rec in &tail.records {
                total_ops += rec.ops.len();
                if !quiet {
                    println!("seq {} ops {}", rec.seq, rec.ops.len());
                }
            }
            println!(
                "{} record(s), {} op(s), log ends at byte {}",
                tail.records.len(),
                total_ops,
                tail.end_offset
            );
            if tail.torn_bytes > 0 {
                eprintln!(
                    "warning: {} torn tail byte(s) after the last valid record \
                     (a writer will truncate them on open)",
                    tail.torn_bytes
                );
            }
            Ok(())
        }
        "compact" => {
            let log = args.positional.get(1).context("expected a log path")?.clone();
            if let Some(keep_after) = args.get("keep-after") {
                let keep_after: u64 = keep_after
                    .parse()
                    .with_context(|| format!("--keep-after expects a sequence number, got '{keep_after}'"))?;
                args.check_unknown()?;
                let st = wal::compact(Path::new(&log), keep_after).map_err(anyhow::Error::new)?;
                println!("kept {} record(s), dropped {}", st.kept, st.dropped);
                return Ok(());
            }
            let graph = args
                .get("graph")
                .context("compact needs --graph <base graph> (or --keep-after N)")?
                .to_string();
            let fkind = forest_kind(args.get_or("kind", "wing"))?;
            let ckpt = match args.get("checkpoint") {
                Some(c) => std::path::PathBuf::from(c),
                None => std::path::PathBuf::from(format!("{log}.ckpt")),
            };
            args.check_unknown()?;
            let base = match gen::Preset::from_name(&graph) {
                Some(p) => p.build(),
                None => io::load(Path::new(&graph))?,
            };
            let tail = wal::replay(Path::new(&log)).map_err(anyhow::Error::new)?;
            // fold every record into a plain dynamic graph (original
            // orientation; IncrementalState re-orients on recovery)
            let mut dg = pbng::graph::dynamic::DynGraph::from_graph(&base);
            let mut skipped = 0usize;
            let mut final_seq = 0u64;
            for rec in &tail.records {
                final_seq = rec.seq;
                for &op in &rec.ops {
                    let (u, v) = op.key();
                    if (u as usize) >= base.nu() || (v as usize) >= base.nv() {
                        skipped += 1;
                        continue;
                    }
                    match op {
                        pbng::graph::dynamic::DeltaOp::Insert(u, v) => {
                            dg.insert(u, v);
                        }
                        pbng::graph::dynamic::DeltaOp::Remove(u, v) => {
                            dg.remove(u, v);
                        }
                    }
                }
            }
            if skipped > 0 {
                eprintln!("warning: skipped {skipped} op(s) outside the graph's vertex universe");
            }
            wal::checkpoint::Checkpoint::from_graph(&dg.snapshot(), fkind, final_seq)
                .save(&ckpt)?;
            let st = wal::compact(Path::new(&log), final_seq).map_err(anyhow::Error::new)?;
            println!(
                "checkpoint {} at seq {final_seq} ({} kind); kept {} record(s), dropped {}",
                ckpt.display(),
                fkind.name(),
                st.kept,
                st.dropped
            );
            Ok(())
        }
        other => bail!("unknown wal subcommand '{other}' (init | append | replay | compact)"),
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    if args.positional.first().map(String::as_str) == Some("compare") {
        return cmd_bench_compare(args);
    }
    let suite_name = args.get_or("suite", "smoke").to_string();
    if args.flag("list") {
        args.check_unknown()?;
        for s in pbng::bench::SUITES {
            let datasets: Vec<&str> = s.datasets.iter().map(|d| d.name).collect();
            let algos: Vec<&str> = s.algos.iter().map(|a| a.name()).collect();
            println!("{:<10} {}", s.name, s.description);
            println!("{:<10}   datasets: {}", "", datasets.join(", "));
            println!("{:<10}   algos:    {}", "", algos.join(", "));
        }
        return Ok(());
    }
    let suite = pbng::bench::find_suite(&suite_name)
        .with_context(|| format!("unknown suite '{suite_name}' (try `pbng bench --list`)"))?;
    let opts = pbng::bench::runner::BenchOptions {
        threads: args.get_usize("threads", 1)?,
        repetitions: args.get_usize("repetitions", 3)?,
        warmup: args.get_usize("warmup", 0)?,
    };
    let out = match args.get("out") {
        Some(s) => s.to_string(),
        None => format!("BENCH_{suite_name}.json"),
    };
    let trace = trace_begin(args);
    args.check_unknown()?;
    // Tracing is always on for bench runs so every entry gets its FD
    // balance summary (the runner only collects, never toggles); the
    // runner clears the span window per repetition, so a `--trace` file
    // holds the recorded (last) repetition of the last cell.
    pbng::obs::enable();
    let report = pbng::bench::runner::run_suite(suite, &opts);
    let widths = [14usize, 14, 10, 10, 10, 8, 10];
    pbng::metrics::print_row(
        &["dataset", "algo", "ms(min)", "updates", "wedges", "rho", "theta_max"]
            .map(String::from),
        &widths,
    );
    for e in &report.entries {
        pbng::metrics::print_row(
            &[
                e.dataset.clone(),
                e.algo.clone(),
                format!("{:.2}", e.wall_ms.min),
                human(e.counters.updates),
                human(e.counters.wedges),
                e.counters.rho.to_string(),
                e.counters.theta_max.to_string(),
            ],
            &widths,
        );
    }
    report.save(Path::new(&out))?;
    if trace.is_some() {
        trace_finish(trace)?;
    } else {
        pbng::obs::disable();
        pbng::obs::clear();
    }
    println!(
        "wrote {out}: {} entries ({} datasets x {} algos), schema v{}, threads={}",
        report.entries.len(),
        suite.datasets.len(),
        suite.algos.len(),
        report.schema_version,
        report.env.threads
    );
    Ok(())
}

fn cmd_bench_compare(args: &Args) -> Result<()> {
    let baseline = args
        .positional
        .get(1)
        .context("expected a baseline report path (bench compare <baseline> <current>)")?;
    let current = args
        .positional
        .get(2)
        .context("expected a current report path (bench compare <baseline> <current>)")?;
    let th = pbng::bench::compare::Thresholds {
        counter_rel_tol: args.get_f64("counter-tolerance", 0.0)?,
        time_factor: args.get_f64("time-factor", 1.5)?,
        ignore_time: args.flag("ignore-time"),
        allow_empty_baseline: args.flag("allow-empty-baseline"),
    };
    args.check_unknown()?;
    let base = pbng::bench::report::Report::load(Path::new(baseline))?;
    let cur = pbng::bench::report::Report::load(Path::new(current))?;
    let cmp = pbng::bench::compare::compare(&base, &cur, &th)?;
    print!("{}", cmp.render());
    if !cmp.passed() {
        bail!(
            "{} regression(s) beyond thresholds (baseline {})",
            cmp.regressions.len(),
            baseline
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let cfg = wing_cfg(args)?;
    args.check_unknown()?;
    println!("verifying on |E|={} ...", g.m());
    let bup = pbng::peel::bup::wing_bup(&g).theta;
    let pbng_d = pbng::wing::wing_pbng(&g, cfg).theta;
    let beb = pbng::wing::wing_be_batch(&g, cfg.threads).theta;
    anyhow::ensure!(pbng_d == bup, "wing: PBNG != BUP");
    anyhow::ensure!(beb == bup, "wing: BE_Batch != BUP");
    for side in [Side::U, Side::V] {
        let b = pbng::tip::tip_bup(&g, side).theta;
        let p = pbng::tip::tip_pbng(
            &g,
            side,
            pbng::engine::EngineConfig {
                threads: cfg.threads,
                ..pbng::engine::EngineConfig::tip()
            },
        )
        .theta;
        anyhow::ensure!(p == b, "tip {side:?}: PBNG != BUP");
    }
    println!("OK: all algorithms agree (wing ×3, tip ×2 sides)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_unknown()?;
    println!("pbng {} — PBNG reproduction", env!("CARGO_PKG_VERSION"));
    println!("threads default: {}", pbng::par::default_threads());
    match pbng::runtime::Runtime::new(pbng::runtime::Runtime::default_dir()) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifact block sizes: {:?}", rt.available_sizes());
        }
        Err(e) => println!("PJRT runtime unavailable: {e}"),
    }
    Ok(())
}
