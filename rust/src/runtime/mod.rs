//! PJRT runtime: load AOT-compiled XLA artifacts and execute them from
//! the rust hot path.
//!
//! `python/compile/aot.py` lowers the L2 `butterfly_block` model (which
//! calls the L1 Pallas kernels) to **HLO text** in `artifacts/`; this
//! module parses the text (`HloModuleProto::from_text_file` — the text
//! parser reassigns instruction ids, avoiding the 64-bit-id proto
//! incompatibility), compiles once per block size on the PJRT CPU
//! client, and exposes a typed `butterfly_block` entry point. Python is
//! never on the request path.
//!
//! The `xla` bindings crate is not on crates.io, so the PJRT-backed
//! [`Runtime`] is gated behind the `xla` cargo feature. Without it a stub
//! with the same API reports itself unavailable from `new()`, and every
//! caller ([`crate::count::dense::DenseCounter`], the CLI `info` command,
//! the HLO integration tests) falls back / skips gracefully.

use anyhow::Result;
use std::path::{Path, PathBuf};

/// Counts returned by one dense-block execution.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockCounts {
    /// Per-row (U) butterfly counts.
    pub per_u: Vec<u64>,
    /// Per-column (V) butterfly counts.
    pub per_v: Vec<u64>,
    /// Per-edge supports, row-major `[m × n]`; 0 on non-edges.
    pub per_edge: Vec<u64>,
    pub total: u64,
}

/// Default artifacts directory: `$PBNG_ARTIFACTS` or `./artifacts`.
fn artifacts_dir() -> PathBuf {
    std::env::var("PBNG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A compiled-artifact cache over the PJRT CPU client.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    execs: std::sync::Mutex<
        std::collections::HashMap<usize, std::sync::Arc<xla::PjRtLoadedExecutable>>,
    >,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Create a runtime rooted at an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        use anyhow::Context;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.as_ref().to_path_buf(),
            execs: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Default artifacts directory: `$PBNG_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        artifacts_dir()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Block sizes with a compiled artifact available on disk.
    pub fn available_sizes(&self) -> Vec<usize> {
        let mut sizes = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if let Some(rest) = name
                    .strip_prefix("butterfly_block_")
                    .and_then(|r| r.strip_suffix(".hlo.txt"))
                {
                    if let Ok(n) = rest.parse::<usize>() {
                        sizes.push(n);
                    }
                }
            }
        }
        sizes.sort_unstable();
        sizes
    }

    /// Smallest available block size that fits `need` rows/cols.
    pub fn pick_size(&self, need: usize) -> Option<usize> {
        self.available_sizes().into_iter().find(|&n| n >= need)
    }

    fn executable(&self, n: usize) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        use anyhow::{anyhow, Context};
        let mut cache = self.execs.lock().unwrap();
        if let Some(e) = cache.get(&n) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("butterfly_block_{n}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", path.display()))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(n, exe.clone());
        Ok(exe)
    }

    /// Execute the butterfly_block artifact of size `n` on a row-major
    /// dense biadjacency block (`block.len() == n*n`, entries 0.0/1.0).
    pub fn butterfly_block(&self, block: &[f32], n: usize) -> Result<BlockCounts> {
        use anyhow::Context;
        anyhow::ensure!(block.len() == n * n, "block must be n*n");
        let exe = self.executable(n)?;
        let a = xla::Literal::vec1(block).reshape(&[n as i64, n as i64])?;
        let result = exe.execute::<xla::Literal>(&[a])?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let (bu, bv, s, total) = result.to_tuple4().context("unpacking 4-tuple")?;
        let to_u64 = |l: &xla::Literal| -> Result<Vec<u64>> {
            Ok(l.to_vec::<f32>()?.into_iter().map(|x| x as u64).collect())
        };
        Ok(BlockCounts {
            per_u: to_u64(&bu)?,
            per_v: to_u64(&bv)?,
            per_edge: to_u64(&s)?,
            total: total.to_vec::<f32>()?[0] as u64,
        })
    }
}

/// Stub runtime for builds without the `xla` feature: `new()` always
/// fails, so callers take their documented fallback paths.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Always fails: the PJRT client is not compiled in.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(anyhow::anyhow!(
            "pbng was built without the `xla` feature; PJRT runtime unavailable \
             (rebuild with `--features xla` and a vendored xla bindings crate)"
        ))
    }

    /// Default artifacts directory: `$PBNG_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        artifacts_dir()
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn available_sizes(&self) -> Vec<usize> {
        Vec::new()
    }

    pub fn pick_size(&self, _need: usize) -> Option<usize> {
        None
    }

    pub fn butterfly_block(&self, _block: &[f32], _n: usize) -> Result<BlockCounts> {
        Err(anyhow::anyhow!("PJRT runtime unavailable (no `xla` feature)"))
    }
}

/// Pure-rust fallback mirroring the artifact's math — used when no
/// artifact covers the block size, and as a cross-check in tests.
pub fn butterfly_block_cpu(block: &[f32], m: usize, n: usize) -> BlockCounts {
    assert_eq!(block.len(), m * n);
    let a = |i: usize, j: usize| block[i * n + j] as u64;
    // Wu = A Aᵀ
    let mut wu = vec![0u64; m * m];
    for i in 0..m {
        for j in 0..m {
            let mut s = 0;
            for p in 0..n {
                s += a(i, p) * a(j, p);
            }
            wu[i * m + j] = s;
        }
    }
    let mut wv = vec![0u64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0;
            for p in 0..m {
                s += a(p, i) * a(p, j);
            }
            wv[i * n + j] = s;
        }
    }
    let c2 = |w: u64| w * w.saturating_sub(1) / 2;
    let per_u: Vec<u64> = (0..m)
        .map(|i| (0..m).filter(|&j| j != i).map(|j| c2(wu[i * m + j])).sum())
        .collect();
    let per_v: Vec<u64> = (0..n)
        .map(|i| (0..n).filter(|&j| j != i).map(|j| c2(wv[i * n + j])).sum())
        .collect();
    let du: Vec<u64> = (0..m).map(|i| (0..n).map(|p| a(i, p)).sum()).collect();
    let dv: Vec<u64> = (0..n).map(|j| (0..m).map(|p| a(p, j)).sum()).collect();
    let mut per_edge = vec![0u64; m * n];
    for i in 0..m {
        for j in 0..n {
            if a(i, j) == 1 {
                let wa: u64 = (0..m).map(|t| wu[i * m + t] * a(t, j)).sum();
                per_edge[i * n + j] = wa - du[i] - dv[j] + 1;
            }
        }
    }
    let total = per_u.iter().sum::<u64>() / 2;
    BlockCounts {
        per_u,
        per_v,
        per_edge,
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fallback_biclique_closed_form() {
        // K_{3,3}: total 9, per-edge 4, per-vertex 6
        let block = vec![1f32; 9];
        let c = butterfly_block_cpu(&block, 3, 3);
        assert_eq!(c.total, 9);
        assert!(c.per_edge.iter().all(|&x| x == 4));
        assert!(c.per_u.iter().all(|&x| x == 6));
        assert!(c.per_v.iter().all(|&x| x == 6));
    }

    #[test]
    fn cpu_fallback_matches_graph_counting() {
        crate::testkit::check_property("dense-cpu-vs-count", 0xD3, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let m = 3 + rng.usize_below(8);
            let n = 3 + rng.usize_below(8);
            let mut block = vec![0f32; m * n];
            let mut edges = Vec::new();
            for i in 0..m {
                for j in 0..n {
                    if rng.chance(0.5) {
                        block[i * n + j] = 1.0;
                        edges.push((i as u32, j as u32));
                    }
                }
            }
            let g = crate::graph::GraphBuilder::new()
                .nu(m)
                .nv(n)
                .edges(&edges)
                .build();
            let (counts, _) = crate::count::pve_bcnt(
                &g,
                crate::count::CountOptions {
                    per_edge: true,
                    build_blooms: false,
                    threads: 1,
                    kernel: crate::count::KernelConfig::default(),
                },
                None,
            );
            let dense = butterfly_block_cpu(&block, m, n);
            if dense.total != counts.total || dense.per_u != counts.per_u || dense.per_v != counts.per_v {
                return Err("dense vs sparse counting mismatch".into());
            }
            // per-edge: map edge ids to matrix slots
            for e in 0..g.m() as u32 {
                let (u, v) = g.edge(e);
                if dense.per_edge[u as usize * n + v as usize] != counts.per_edge[e as usize] {
                    return Err(format!("edge ({u},{v}) support mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_block_has_zero_counts() {
        let c = butterfly_block_cpu(&vec![0f32; 16], 4, 4);
        assert_eq!(c.total, 0);
        assert!(c.per_edge.iter().all(|&x| x == 0));
    }
}
