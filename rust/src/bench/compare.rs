//! Regression gate between two bench reports.
//!
//! Gating policy (the CI contract):
//!
//! * **Counter metrics** (`updates`, `wedges`, `rho`) — gated against a
//!   relative tolerance, default 0 (exact). They are deterministic for a
//!   fixed seed and thread count, so any increase is a real algorithmic
//!   regression, not noise. Decreases are reported as improvements and
//!   never fail the gate (refresh the baseline to lock them in).
//! * **Output shape** (`theta_max`, `peak_entities`, `theta_fnv`) — any
//!   difference fails: the decomposition itself changed, which is a
//!   correctness event, not a performance one.
//! * **Wall time** — gated loosely (`min` ratio vs `--time-factor`,
//!   default 1.5) because shared runners are noisy; `--ignore-time`
//!   disables it entirely, which is what CI uses (counters only).
//! * An entry present in the baseline but missing from the current
//!   report fails; entries new in the current report pass ungated.
//! * An **empty baseline** fails loudly by default: a bootstrap baseline
//!   gates nothing, and a vacuous pass must not masquerade as a green
//!   perf gate. `--allow-empty-baseline` acknowledges the un-armed state
//!   and turns it back into a pass (a local escape hatch — CI instead
//!   substitutes a freshly measured report for an empty baseline and
//!   commits it back, so the flag no longer appears in the workflow).

use super::report::{Entry, Report};
use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    /// Allowed relative increase for counter metrics (0.0 = exact).
    pub counter_rel_tol: f64,
    /// Allowed `current.min / baseline.min` wall-time ratio.
    pub time_factor: f64,
    /// Skip the wall-time gate entirely (CI on shared runners).
    pub ignore_time: bool,
    /// Accept an entry-less bootstrap baseline instead of failing the
    /// gate (an un-armed gate must be a loud, explicit choice).
    pub allow_empty_baseline: bool,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds {
            counter_rel_tol: 0.0,
            time_factor: 1.5,
            ignore_time: false,
            allow_empty_baseline: false,
        }
    }
}

#[derive(Debug, Default)]
pub struct Comparison {
    /// Human-readable regression findings; non-empty fails the gate.
    pub regressions: Vec<String>,
    pub improvements: Vec<String>,
    /// Entries in the current report with no baseline counterpart.
    pub ungated: Vec<String>,
    /// Number of baseline entries that were checked.
    pub checked: usize,
}

impl Comparison {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.regressions {
            out.push_str(&format!("REGRESSION  {r}\n"));
        }
        for i in &self.improvements {
            out.push_str(&format!("improvement {i}\n"));
        }
        for u in &self.ungated {
            out.push_str(&format!("ungated     {u} (not in baseline)\n"));
        }
        out.push_str(&format!(
            "checked {} baseline entr{}: {} regression(s), {} improvement(s), {} ungated\n",
            self.checked,
            if self.checked == 1 { "y" } else { "ies" },
            self.regressions.len(),
            self.improvements.len(),
            self.ungated.len()
        ));
        if self.checked == 0 && !self.ungated.is_empty() {
            out.push_str(
                "baseline has no entries (bootstrap): commit the current report as the new \
                 baseline to arm the gate\n",
            );
        }
        out
    }
}

/// Compare `current` against `baseline`. Errors on malformed pairings
/// (schema/suite mismatch); regressions are reported in the result, not
/// as errors — the caller decides the exit code via [`Comparison::passed`].
pub fn compare(baseline: &Report, current: &Report, th: &Thresholds) -> Result<Comparison> {
    if baseline.schema_version != current.schema_version {
        bail!(
            "schema mismatch: baseline v{} vs current v{}",
            baseline.schema_version,
            current.schema_version
        );
    }
    if baseline.suite != current.suite {
        bail!(
            "suite mismatch: baseline '{}' vs current '{}'",
            baseline.suite,
            current.suite
        );
    }
    if baseline.env.threads != current.env.threads {
        bail!(
            "thread-count mismatch: baseline ran with {} thread(s), current with {} — \
             counter metrics are only schedule-independent at a fixed thread count, so \
             this comparison would gate noise; re-run one side with matching --threads",
            baseline.env.threads,
            current.env.threads
        );
    }
    let mut cmp = Comparison::default();
    if baseline.entries.is_empty() && !th.allow_empty_baseline {
        let msg = "baseline has no entries: the gate is un-armed and would pass vacuously; \
                   refresh and commit the baseline to arm it, or pass --allow-empty-baseline \
                   to accept the bootstrap state explicitly";
        cmp.regressions.push(msg.to_string());
    }
    for be in &baseline.entries {
        let key = format!("{}/{}", be.dataset, be.algo);
        match current.entry(&be.dataset, &be.algo) {
            None => cmp
                .regressions
                .push(format!("{key}: entry missing from current report")),
            Some(ce) => {
                cmp.checked += 1;
                check_entry(&key, be, ce, th, &mut cmp);
            }
        }
    }
    for ce in &current.entries {
        if baseline.entry(&ce.dataset, &ce.algo).is_none() {
            cmp.ungated.push(format!("{}/{}", ce.dataset, ce.algo));
        }
    }
    Ok(cmp)
}

fn check_entry(key: &str, be: &Entry, ce: &Entry, th: &Thresholds, cmp: &mut Comparison) {
    let b = &be.counters;
    let c = &ce.counters;
    for (metric, bv, cv) in [
        ("updates", b.updates, c.updates),
        ("wedges", b.wedges, c.wedges),
        ("rho", b.rho, c.rho),
    ] {
        match cv.cmp(&bv) {
            std::cmp::Ordering::Greater => {
                let rel = (cv - bv) as f64 / bv.max(1) as f64;
                if rel > th.counter_rel_tol {
                    cmp.regressions.push(format!(
                        "{key} {metric}: {bv} -> {cv} (+{:.2}%, tolerance {:.2}%)",
                        rel * 100.0,
                        th.counter_rel_tol * 100.0
                    ));
                }
            }
            std::cmp::Ordering::Less => {
                cmp.improvements
                    .push(format!("{key} {metric}: {bv} -> {cv}"));
            }
            std::cmp::Ordering::Equal => {}
        }
    }
    if b.theta_fnv != c.theta_fnv {
        cmp.regressions.push(format!(
            "{key} theta_fnv: {:#018x} -> {:#018x} (decomposition output changed)",
            b.theta_fnv, c.theta_fnv
        ));
    } else {
        // with an equal θ checksum these can only differ if the checksum
        // collided — gate them anyway, they are nearly free
        if b.theta_max != c.theta_max {
            cmp.regressions.push(format!(
                "{key} theta_max: {} -> {} (peak level changed)",
                b.theta_max, c.theta_max
            ));
        }
        if b.peak_entities != c.peak_entities {
            cmp.regressions.push(format!(
                "{key} peak_entities: {} -> {} (peak set changed)",
                b.peak_entities, c.peak_entities
            ));
        }
    }
    if !th.ignore_time {
        // Median-of-repetitions when both sides recorded per-rep times
        // (additive `rep_ms` field): a single slow rep on a shared
        // runner no longer moves the gated statistic. Older baselines
        // without `rep_ms` fall back to the original `min` gate.
        match (median(&be.rep_ms), median(&ce.rep_ms)) {
            (Some(bm), Some(cm)) => {
                if cm > bm * th.time_factor {
                    cmp.regressions.push(format!(
                        "{key} wall_ms median-of-reps: {bm:.3} -> {cm:.3} (> {:.2}x baseline)",
                        th.time_factor
                    ));
                }
            }
            _ => {
                if ce.wall_ms.min > be.wall_ms.min * th.time_factor {
                    cmp.regressions.push(format!(
                        "{key} wall_ms.min: {:.3} -> {:.3} (> {:.2}x baseline)",
                        be.wall_ms.min, ce.wall_ms.min, th.time_factor
                    ));
                }
            }
        }
    }
}

/// Median of the recorded per-repetition times; `None` when the report
/// predates the `rep_ms` field.
fn median(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("wall times are finite"));
    let n = s.len();
    Some(if n % 2 == 1 {
        s[n / 2]
    } else {
        (s[n / 2 - 1] + s[n / 2]) / 2.0
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::report::tests::{sample_entry, sample_report};

    fn counters_only() -> Thresholds {
        Thresholds { ignore_time: true, ..Thresholds::default() }
    }

    #[test]
    fn identical_reports_pass() {
        let r = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let cmp = compare(&r, &r, &Thresholds::default()).unwrap();
        assert!(cmp.passed());
        assert_eq!(cmp.checked, 1);
        assert!(cmp.ungated.is_empty());
    }

    #[test]
    fn counter_increase_fails_exactly() {
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].counters.updates = 101;
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(!cmp.passed());
        assert!(cmp.regressions[0].contains("updates"), "{:?}", cmp.regressions);
    }

    #[test]
    fn counter_increase_within_tolerance_passes() {
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].counters.updates = 110;
        cur.entries[0].counters.wedges = 220;
        let th = Thresholds { counter_rel_tol: 0.2, ignore_time: true, ..Thresholds::default() };
        assert!(compare(&base, &cur, &th).unwrap().passed());
        let th0 = counters_only();
        assert!(!compare(&base, &cur, &th0).unwrap().passed());
    }

    #[test]
    fn counter_decrease_is_an_improvement() {
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].counters.rho = 1;
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(cmp.passed());
        assert_eq!(cmp.improvements.len(), 1);
    }

    #[test]
    fn theta_checksum_change_fails_despite_tolerance() {
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].counters.theta_fnv ^= 1;
        let th = Thresholds { counter_rel_tol: 1e9, ignore_time: true, ..Thresholds::default() };
        let cmp = compare(&base, &cur, &th).unwrap();
        assert!(!cmp.passed());
        assert!(cmp.regressions[0].contains("theta_fnv"));
    }

    #[test]
    fn time_gate_is_loose_and_skippable() {
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].wall_ms.min = base.entries[0].wall_ms.min * 10.0;
        cur.entries[0].rep_ms = base.entries[0].rep_ms.iter().map(|t| t * 10.0).collect();
        assert!(!compare(&base, &cur, &Thresholds::default()).unwrap().passed());
        assert!(compare(&base, &cur, &counters_only()).unwrap().passed());
        // within the factor: passes
        let mut mild = base.clone();
        mild.entries[0].wall_ms.min = base.entries[0].wall_ms.min * 1.4;
        mild.entries[0].rep_ms = base.entries[0].rep_ms.iter().map(|t| t * 1.4).collect();
        assert!(compare(&base, &mild, &Thresholds::default()).unwrap().passed());
    }

    #[test]
    fn time_gate_uses_median_of_reps() {
        // sample_entry reps are [2.5, 1.5, 2.0] -> median 2.0. One wild
        // outlier rep must not fail the gate (the ROADMAP noise fix)...
        let base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        let mut cur = base.clone();
        cur.entries[0].rep_ms = vec![2.0, 50.0, 1.9]; // median 2.0
        cur.entries[0].wall_ms = crate::bench::report::WallMs {
            min: 1.9,
            mean: 17.966,
            max: 50.0,
        };
        assert!(compare(&base, &cur, &Thresholds::default()).unwrap().passed());
        // ...while a shifted median (all reps slow) still fails.
        let mut slow = base.clone();
        slow.entries[0].rep_ms = vec![8.0, 8.1, 8.2];
        assert!(!compare(&base, &slow, &Thresholds::default()).unwrap().passed());
    }

    #[test]
    fn time_gate_falls_back_to_min_without_rep_times() {
        // baseline written before rep_ms existed: gate on wall_ms.min
        let mut base = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        base.entries[0].rep_ms.clear();
        let mut cur = base.clone();
        cur.entries[0].wall_ms.min = base.entries[0].wall_ms.min * 10.0;
        assert!(!compare(&base, &cur, &Thresholds::default()).unwrap().passed());
        let ok = base.clone();
        assert!(compare(&base, &ok, &Thresholds::default()).unwrap().passed());
    }

    #[test]
    fn median_handles_odd_even_and_empty() {
        assert_eq!(median(&[]), None);
        assert_eq!(median(&[3.0]), Some(3.0));
        assert_eq!(median(&[4.0, 1.0, 3.0]), Some(3.0));
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), Some(2.5));
    }

    #[test]
    fn missing_entry_fails_new_entry_is_ungated() {
        let two = sample_report(vec![
            sample_entry("a", "wing/bup", 100),
            sample_entry("b", "wing/bup", 50),
        ]);
        let one = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        // baseline has more than current: fail
        assert!(!compare(&two, &one, &counters_only()).unwrap().passed());
        // current has more than baseline: pass, ungated noted
        let cmp = compare(&one, &two, &counters_only()).unwrap();
        assert!(cmp.passed());
        assert_eq!(cmp.ungated, vec!["b/wing/bup".to_string()]);
    }

    #[test]
    fn empty_baseline_is_loud_unless_allowed() {
        let base = sample_report(vec![]);
        let cur = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        // default: an un-armed gate fails, with a distinct message
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(!cmp.passed());
        assert_eq!(cmp.checked, 0);
        assert!(cmp.regressions[0].contains("un-armed"), "{:?}", cmp.regressions);
        // explicit opt-in: passes, and still renders the bootstrap hint
        let th = Thresholds { allow_empty_baseline: true, ..counters_only() };
        let cmp = compare(&base, &cur, &th).unwrap();
        assert!(cmp.passed());
        assert_eq!(cmp.checked, 0);
        assert!(cmp.render().contains("bootstrap"));
        // a non-empty baseline is unaffected by the flag
        let armed = sample_report(vec![sample_entry("a", "wing/bup", 100)]);
        assert!(compare(&armed, &cur, &counters_only()).unwrap().passed());
    }

    #[test]
    fn suite_schema_and_threads_mismatch_error() {
        let a = sample_report(vec![]);
        let mut b = sample_report(vec![]);
        b.suite = "other".to_string();
        assert!(compare(&a, &b, &Thresholds::default()).is_err());
        let mut c = sample_report(vec![]);
        c.schema_version += 1;
        assert!(compare(&a, &c, &Thresholds::default()).is_err());
        // a baseline captured at a different thread count would gate
        // scheduling noise, not regressions
        let mut d = sample_report(vec![]);
        d.env.threads = 8;
        let err = compare(&a, &d, &Thresholds::default()).unwrap_err().to_string();
        assert!(err.contains("thread"), "{err}");
    }
}
