//! Versioned, machine-readable bench reports (`BENCH_<suite>.json`).
//!
//! Schema v1 layout:
//!
//! ```text
//! {
//!   "schema_version": 1,
//!   "suite": "smoke",
//!   "env": { threads, repetitions, warmup, git_sha, crate_version },
//!   "entries": [
//!     { dataset, seed, nu, nv, m, algo,
//!       "wall_ms": { min, mean, max },            // loosely gated
//!       "counters": { updates, wedges, rho,       // exactly gated
//!                     theta_max, peak_entities, theta_fnv },
//!       "phases": [ { name, ms, updates, wedges }, ... ] }
//!   ]
//! }
//! ```
//!
//! `counters` carries the deterministic [`crate::metrics::Meters`]
//! members (`spawns` is deliberately excluded: it depends on whether the
//! process already warmed the worker pool) plus the output-shape
//! metrics: `theta_max` / `peak_entities` describe the densest level
//! (peak set), and `theta_fnv` is an FNV-1a 64 checksum of the whole θ
//! vector — any algorithmic output change flips it, so `bench compare`
//! doubles as an equivalence gate. It is serialized as a hex string:
//! 2⁶⁴-range integers do not survive f64 round-trips in common JSON
//! tooling. Unknown members are ignored on load (forward compatible);
//! renaming or removing members requires bumping [`SCHEMA_VERSION`].

use super::runner::BenchOptions;
use crate::index::codec::fnv64;
use crate::jsonio::Value;
use crate::metrics::MetersSnapshot;
use crate::peel::Decomposition;
use anyhow::{bail, Context, Result};
use std::path::Path;

pub const SCHEMA_VERSION: u32 = 1;

#[derive(Clone, Debug)]
pub struct Report {
    pub schema_version: u32,
    pub suite: String,
    pub env: Env,
    pub entries: Vec<Entry>,
}

/// Environment stanza: everything needed to reproduce or explain a run.
#[derive(Clone, Debug)]
pub struct Env {
    pub threads: usize,
    pub repetitions: usize,
    pub warmup: usize,
    pub git_sha: String,
    pub crate_version: String,
}

impl Env {
    pub fn capture(opts: &BenchOptions) -> Env {
        Env {
            threads: opts.threads,
            repetitions: opts.repetitions,
            warmup: opts.warmup,
            git_sha: detect_git_sha(),
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
        }
    }
}

fn detect_git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha.chars().take(12).collect();
        }
    }
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output();
    match out {
        Ok(o) if o.status.success() => String::from_utf8_lossy(&o.stdout).trim().to_string(),
        _ => "unknown".to_string(),
    }
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub dataset: String,
    pub seed: u64,
    pub nu: usize,
    pub nv: usize,
    pub m: usize,
    pub algo: String,
    pub wall_ms: WallMs,
    /// Wall time of each individual repetition, in report order —
    /// `bench compare` gates on the median of these when both sides
    /// carry them (less runner-noise flake than `min`). Empty in
    /// reports written before the field existed.
    pub rep_ms: Vec<f64>,
    pub counters: Counters,
    /// Per-partition FD balance summary of the recorded repetition
    /// (informational, never gated).
    pub fd_balance: FdBalance,
    /// Counting-kernel side-choice / SIMD mix of the recorded repetition
    /// (informational, never gated).
    pub count_side: CountSide,
    pub phases: Vec<PhaseRow>,
}

/// Per-partition workload-balance summary of the FD phase, distilled
/// from the obs `fd_task` spans of the recorded repetition: task-time
/// spread across partitions (max/mean/stddev) plus how many tasks were
/// claimed through the steal path — the RECEIPT-style view that tells
/// whether LPT + stealing actually evened out the lanes. Timing-derived
/// and schedule-dependent, so informational only; `bench compare` never
/// gates on it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FdBalance {
    /// FD partition tasks observed (0 for baselines without an FD phase).
    pub tasks: u64,
    /// Tasks claimed via the global steal path rather than a lane's own
    /// pre-assigned list.
    pub steals: u64,
    /// Distinct pool lanes that executed at least one task.
    pub lanes: u64,
    pub max_ms: f64,
    pub mean_ms: f64,
    pub stddev_ms: f64,
}

impl FdBalance {
    /// Summarize the `fd_task` spans in an obs event drain.
    pub fn from_events(events: &[crate::obs::Event]) -> FdBalance {
        let mut durs_ms: Vec<f64> = Vec::new();
        let mut steals = 0u64;
        let mut lanes = std::collections::BTreeSet::new();
        for (enter, exit) in crate::obs::pair_spans(events) {
            if enter.kind == crate::obs::Kind::FdTask {
                durs_ms.push((exit.ts_ns.saturating_sub(enter.ts_ns)) as f64 / 1e6);
                steals += u64::from(enter.c != 0);
                lanes.insert(enter.lane);
            }
        }
        if durs_ms.is_empty() {
            return FdBalance::default();
        }
        let n = durs_ms.len() as f64;
        let max = durs_ms.iter().copied().fold(0.0f64, f64::max);
        let mean = durs_ms.iter().sum::<f64>() / n;
        let var = durs_ms.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        // microsecond precision: FD tasks are often sub-millisecond
        let r = |x: f64| (x * 1e6).round() / 1e6;
        FdBalance {
            tasks: durs_ms.len() as u64,
            steals,
            lanes: lanes.len() as u64,
            max_ms: r(max),
            mean_ms: r(mean),
            stddev_ms: r(var.sqrt()),
        }
    }

    fn to_json(self) -> Value {
        Value::obj()
            .with("tasks", self.tasks)
            .with("steals", self.steals)
            .with("lanes", self.lanes)
            .with("max_ms", self.max_ms)
            .with("mean_ms", self.mean_ms)
            .with("stddev_ms", self.stddev_ms)
    }

    fn from_json(v: &Value) -> Result<FdBalance> {
        Ok(FdBalance {
            tasks: v.req_u64("tasks")?,
            steals: v.req_u64("steals")?,
            lanes: v.req_u64("lanes")?,
            max_ms: v.req_f64("max_ms")?,
            mean_ms: v.req_f64("mean_ms")?,
            stddev_ms: v.req_f64("stddev_ms")?,
        })
    }
}

/// Wedge-side / SIMD mix of the counting kernel calls in the recorded
/// repetition, distilled from the obs `count_kernel` spans (`b` = the
/// resolved wedge side, `c` = SIMD active). Like [`FdBalance`] it is
/// informational only — `bench compare` never gates on it — but it makes
/// the side-choice cost model auditable from committed reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CountSide {
    /// Counting-kernel invocations observed.
    pub calls: u64,
    /// Calls that resolved to the degree-descending order.
    pub degree: u64,
    /// Calls that resolved to the U-side-major order.
    pub side_u: u64,
    /// Calls that resolved to the V-side-major order.
    pub side_v: u64,
    /// Calls that ran the SIMD intersection path.
    pub simd: u64,
}

impl CountSide {
    /// Summarize the `count_kernel` spans in an obs event drain.
    pub fn from_events(events: &[crate::obs::Event]) -> CountSide {
        let mut cs = CountSide::default();
        for e in events {
            if e.kind != crate::obs::Kind::CountKernel || e.is_exit {
                continue;
            }
            cs.calls += 1;
            match e.b {
                1 => cs.side_u += 1,
                2 => cs.side_v += 1,
                _ => cs.degree += 1,
            }
            cs.simd += u64::from(e.c != 0);
        }
        cs
    }

    fn to_json(self) -> Value {
        Value::obj()
            .with("calls", self.calls)
            .with("degree", self.degree)
            .with("side_u", self.side_u)
            .with("side_v", self.side_v)
            .with("simd", self.simd)
    }

    fn from_json(v: &Value) -> Result<CountSide> {
        Ok(CountSide {
            calls: v.req_u64("calls")?,
            degree: v.req_u64("degree")?,
            side_u: v.req_u64("side_u")?,
            side_v: v.req_u64("side_v")?,
            simd: v.req_u64("simd")?,
        })
    }
}

/// Wall-time statistics over the repetitions, in milliseconds. `min` is
/// the gated member — it is the least noise-inflated on shared runners.
#[derive(Clone, Copy, Debug)]
pub struct WallMs {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

impl WallMs {
    pub fn from_times(ms: &[f64]) -> WallMs {
        assert!(!ms.is_empty());
        let min = ms.iter().copied().fold(f64::INFINITY, f64::min);
        let max = ms.iter().copied().fold(0.0f64, f64::max);
        let mean = ms.iter().sum::<f64>() / ms.len() as f64;
        // millisecond precision keeps report diffs readable
        let r = |x: f64| (x * 1000.0).round() / 1000.0;
        WallMs { min: r(min), mean: r(mean), max: r(max) }
    }
}

/// The exactly-gated section: deterministic for a fixed seed and thread
/// count (the smoke suite runs with `threads = 1` for this reason).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Counters {
    pub updates: u64,
    pub wedges: u64,
    pub rho: u64,
    pub theta_max: u64,
    pub peak_entities: u64,
    pub theta_fnv: u64,
}

impl Counters {
    pub fn from_decomposition(d: &Decomposition) -> Counters {
        let snap = d.stats.meters_snapshot();
        let theta_max = d.theta.iter().max().copied().unwrap_or(0);
        let peak_entities = d.theta.iter().filter(|&&t| t == theta_max).count() as u64;
        Counters {
            updates: snap.updates,
            wedges: snap.wedges,
            rho: snap.rho,
            theta_max,
            peak_entities,
            theta_fnv: theta_fnv(&d.theta),
        }
    }

    fn to_json(self) -> Value {
        // The deterministic core goes through the one shared serializer
        // (`metrics::counters_to_json` over `MetersSnapshot::core_pairs`)
        // — the same prefix `MetersSnapshot::to_json` emits, so the two
        // counter sections cannot silently diverge. `spawns`, a
        // process-lifetime runtime metric (non-zero only for the run
        // that first warms the worker pool), stays excluded here and the
        // v1 key set stays byte-stable; the output-shape metrics follow.
        let core = MetersSnapshot {
            updates: self.updates,
            wedges: self.wedges,
            rho: self.rho,
            spawns: 0,
            invalidated_parts: 0,
        };
        crate::metrics::counters_to_json(&core.core_pairs())
            .with("theta_max", self.theta_max)
            .with("peak_entities", self.peak_entities)
            .with("theta_fnv", format!("{:#018x}", self.theta_fnv))
    }

    fn from_json(v: &Value) -> Result<Counters> {
        let hex = v.req_str("theta_fnv")?;
        let digits = hex
            .strip_prefix("0x")
            .with_context(|| format!("theta_fnv '{hex}' lacks 0x prefix"))?;
        let theta_fnv = u64::from_str_radix(digits, 16)
            .with_context(|| format!("theta_fnv '{hex}' is not a hex u64"))?;
        Ok(Counters {
            updates: v.req_u64("updates")?,
            wedges: v.req_u64("wedges")?,
            rho: v.req_u64("rho")?,
            theta_max: v.req_u64("theta_max")?,
            peak_entities: v.req_u64("peak_entities")?,
            theta_fnv,
        })
    }
}

/// Order-sensitive checksum of a θ vector (little-endian u64 stream).
pub fn theta_fnv(theta: &[u64]) -> u64 {
    let mut bytes = Vec::with_capacity(theta.len() * 8);
    for t in theta {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    fnv64(&bytes)
}

/// Per-phase breakdown (Fig. 7 / Fig. 10 currency) — informational, not
/// gated: phase splits shift with partition spreads across code changes.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub ms: f64,
    pub updates: u64,
    pub wedges: u64,
}

impl Report {
    pub fn to_json(&self) -> Value {
        let env = Value::obj()
            .with("threads", self.env.threads)
            .with("repetitions", self.env.repetitions)
            .with("warmup", self.env.warmup)
            .with("git_sha", self.env.git_sha.as_str())
            .with("crate_version", self.env.crate_version.as_str());
        let entries: Vec<Value> = self.entries.iter().map(Entry::to_json).collect();
        Value::obj()
            .with("schema_version", self.schema_version)
            .with("suite", self.suite.as_str())
            .with("env", env)
            .with("entries", entries)
    }

    pub fn from_json(v: &Value) -> Result<Report> {
        let schema_version = v.req_u64("schema_version")? as u32;
        if schema_version != SCHEMA_VERSION {
            bail!(
                "unsupported schema_version {schema_version} (this binary reads v{SCHEMA_VERSION}); \
                 refresh the report with `pbng bench`"
            );
        }
        let env_v = v.req("env")?;
        let env = Env {
            threads: env_v.req_u64("threads")? as usize,
            repetitions: env_v.req_u64("repetitions")? as usize,
            warmup: env_v.req_u64("warmup")? as usize,
            git_sha: env_v.req_str("git_sha")?.to_string(),
            crate_version: env_v.req_str("crate_version")?.to_string(),
        };
        let mut entries = Vec::new();
        for (i, e) in v.req_arr("entries")?.iter().enumerate() {
            entries.push(Entry::from_json(e).with_context(|| format!("entries[{i}]"))?);
        }
        Ok(Report {
            schema_version,
            suite: v.req_str("suite")?.to_string(),
            env,
            entries,
        })
    }

    pub fn parse(text: &str) -> Result<Report> {
        Report::from_json(&Value::parse(text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing bench report {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Report> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {}", path.display()))?;
        Report::parse(&text).with_context(|| format!("parsing bench report {}", path.display()))
    }

    pub fn entry(&self, dataset: &str, algo: &str) -> Option<&Entry> {
        self.entries
            .iter()
            .find(|e| e.dataset == dataset && e.algo == algo)
    }

    /// The deterministic subset of the report as stable text: one line of
    /// counters per entry, no times, no environment. Two runs with the
    /// same seeds and thread count must produce byte-identical output.
    pub fn counters_fingerprint(&self) -> String {
        let mut lines: Vec<String> = self
            .entries
            .iter()
            .map(|e| {
                let c = &e.counters;
                format!(
                    "{} {} updates={} wedges={} rho={} theta_max={} peak={} fnv={:#018x}",
                    e.dataset,
                    e.algo,
                    c.updates,
                    c.wedges,
                    c.rho,
                    c.theta_max,
                    c.peak_entities,
                    c.theta_fnv
                )
            })
            .collect();
        lines.sort_unstable();
        lines.join("\n")
    }
}

impl Entry {
    fn to_json(&self) -> Value {
        let phases: Vec<Value> = self
            .phases
            .iter()
            .map(|p| {
                Value::obj()
                    .with("name", p.name.as_str())
                    .with("ms", p.ms)
                    .with("updates", p.updates)
                    .with("wedges", p.wedges)
            })
            .collect();
        let rep_ms: Vec<Value> = self.rep_ms.iter().map(|&t| Value::from(t)).collect();
        Value::obj()
            .with("dataset", self.dataset.as_str())
            .with("seed", self.seed)
            .with("nu", self.nu)
            .with("nv", self.nv)
            .with("m", self.m)
            .with("algo", self.algo.as_str())
            .with(
                "wall_ms",
                Value::obj()
                    .with("min", self.wall_ms.min)
                    .with("mean", self.wall_ms.mean)
                    .with("max", self.wall_ms.max),
            )
            .with("rep_ms", rep_ms)
            .with("counters", self.counters.to_json())
            .with("fd_balance", self.fd_balance.to_json())
            .with("count_side", self.count_side.to_json())
            .with("phases", phases)
    }

    fn from_json(v: &Value) -> Result<Entry> {
        let w = v.req("wall_ms")?;
        let mut phases = Vec::new();
        for p in v.req_arr("phases")? {
            phases.push(PhaseRow {
                name: p.req_str("name")?.to_string(),
                ms: p.req_f64("ms")?,
                updates: p.req_u64("updates")?,
                wedges: p.req_u64("wedges")?,
            });
        }
        // Both fields below were added after v1 baselines shipped; absent
        // means "written by an older binary", not an error (additive
        // schema evolution, see the module docs).
        let mut rep_ms = Vec::new();
        if let Some(arr) = v.get("rep_ms").and_then(|x| x.as_arr()) {
            for t in arr {
                rep_ms.push(t.as_f64().context("rep_ms entry")?);
            }
        }
        let fd_balance = match v.get("fd_balance") {
            Some(b) => FdBalance::from_json(b).context("fd_balance")?,
            None => FdBalance::default(),
        };
        let count_side = match v.get("count_side") {
            Some(b) => CountSide::from_json(b).context("count_side")?,
            None => CountSide::default(),
        };
        Ok(Entry {
            dataset: v.req_str("dataset")?.to_string(),
            seed: v.req_u64("seed")?,
            nu: v.req_u64("nu")? as usize,
            nv: v.req_u64("nv")? as usize,
            m: v.req_u64("m")? as usize,
            algo: v.req_str("algo")?.to_string(),
            wall_ms: WallMs {
                min: w.req_f64("min")?,
                mean: w.req_f64("mean")?,
                max: w.req_f64("max")?,
            },
            rep_ms,
            counters: Counters::from_json(v.req("counters")?).context("counters")?,
            fd_balance,
            count_side,
            phases,
        })
    }
}

#[cfg(test)]
pub(super) mod tests {
    use super::*;

    pub(crate) fn sample_entry(dataset: &str, algo: &str, updates: u64) -> Entry {
        Entry {
            dataset: dataset.to_string(),
            seed: 7,
            nu: 10,
            nv: 12,
            m: 40,
            algo: algo.to_string(),
            wall_ms: WallMs { min: 1.5, mean: 2.0, max: 2.5 },
            rep_ms: vec![2.5, 1.5, 2.0],
            fd_balance: FdBalance {
                tasks: 8,
                steals: 2,
                lanes: 2,
                max_ms: 0.5,
                mean_ms: 0.25,
                stddev_ms: 0.125,
            },
            count_side: CountSide { calls: 2, degree: 1, side_u: 1, side_v: 0, simd: 1 },
            counters: Counters {
                updates,
                wedges: 2 * updates,
                rho: 9,
                theta_max: 4,
                peak_entities: 6,
                theta_fnv: 0xDEAD_BEEF_0123_4567,
            },
            phases: vec![PhaseRow {
                name: "fine(FD)".to_string(),
                ms: 1.25,
                updates,
                wedges: 2 * updates,
            }],
        }
    }

    pub(crate) fn sample_report(entries: Vec<Entry>) -> Report {
        Report {
            schema_version: SCHEMA_VERSION,
            suite: "unit".to_string(),
            env: Env {
                threads: 1,
                repetitions: 1,
                warmup: 0,
                git_sha: "unknown".to_string(),
                crate_version: "test".to_string(),
            },
            entries,
        }
    }

    #[test]
    fn roundtrip_preserves_everything_gated() {
        let r = sample_report(vec![
            sample_entry("a", "wing/bup", 100),
            sample_entry("b", "tip/pbng", 50),
        ]);
        let back = Report::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(back.counters_fingerprint(), r.counters_fingerprint());
        assert_eq!(back.suite, r.suite);
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.entries[0].counters, r.entries[0].counters);
        assert_eq!(back.entries[0].phases.len(), 1);
        assert_eq!(back.env.threads, 1);
    }

    #[test]
    fn wrong_schema_version_is_rejected() {
        let r = sample_report(vec![]);
        let mut v = r.to_json();
        if let crate::jsonio::Value::Obj(kv) = &mut v {
            kv[0].1 = crate::jsonio::Value::Int(99);
        }
        let err = Report::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("schema_version"), "{err}");
    }

    #[test]
    fn rep_times_and_balance_round_trip() {
        let r = sample_report(vec![sample_entry("a", "wing/pbng", 10)]);
        let back = Report::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(back.entries[0].rep_ms, vec![2.5, 1.5, 2.0]);
        assert_eq!(back.entries[0].fd_balance, r.entries[0].fd_balance);
    }

    #[test]
    fn entries_without_new_fields_still_load() {
        // Reports written before rep_ms / fd_balance / count_side existed
        // must load with defaults (additive schema evolution, no version
        // bump).
        let r = sample_report(vec![sample_entry("a", "wing/pbng", 10)]);
        let mut v = r.to_json();
        if let Value::Obj(kv) = &mut v {
            let entries = kv.iter_mut().find(|(k, _)| k == "entries").unwrap();
            if let Value::Arr(es) = &mut entries.1 {
                if let Value::Obj(e) = &mut es[0] {
                    e.retain(|(k, _)| k != "rep_ms" && k != "fd_balance" && k != "count_side");
                }
            }
        }
        let back = Report::from_json(&v).unwrap();
        assert!(back.entries[0].rep_ms.is_empty());
        assert_eq!(back.entries[0].fd_balance, FdBalance::default());
        assert_eq!(back.entries[0].count_side, CountSide::default());
    }

    #[test]
    fn count_side_round_trips_and_summarizes_events() {
        let r = sample_report(vec![sample_entry("a", "kern/count-auto", 10)]);
        let back = Report::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(back.entries[0].count_side, r.entries[0].count_side);
        use crate::obs::{Event, Kind};
        let call = |span: u64, side: u64, simd: u64| {
            [
                Event {
                    ts_ns: 0,
                    span,
                    lane: 0,
                    kind: Kind::CountKernel,
                    is_exit: false,
                    a: 100,
                    b: side,
                    c: simd,
                },
                Event {
                    ts_ns: 1,
                    span,
                    lane: 0,
                    kind: Kind::CountKernel,
                    is_exit: true,
                    a: 100,
                    b: side,
                    c: simd,
                },
            ]
        };
        let mut evs = Vec::new();
        evs.extend(call(1, 0, 1)); // degree order, SIMD
        evs.extend(call(2, 1, 0)); // side-U order, scalar
        evs.extend(call(3, 2, 0)); // side-V order, scalar
        let cs = CountSide::from_events(&evs);
        assert_eq!(
            cs,
            CountSide { calls: 3, degree: 1, side_u: 1, side_v: 1, simd: 1 }
        );
        assert_eq!(CountSide::from_events(&[]), CountSide::default());
    }

    #[test]
    fn fd_balance_from_events_summarizes_tasks() {
        use crate::obs::{Event, Kind};
        let task = |span: u64, lane: u32, t0: u64, t1: u64, steal: u64| {
            [
                Event {
                    ts_ns: t0,
                    span,
                    lane,
                    kind: Kind::FdTask,
                    is_exit: false,
                    a: span,
                    b: 10,
                    c: steal,
                },
                Event {
                    ts_ns: t1,
                    span,
                    lane,
                    kind: Kind::FdTask,
                    is_exit: true,
                    a: span,
                    b: 10,
                    c: steal,
                },
            ]
        };
        let mut evs = Vec::new();
        evs.extend(task(1, 0, 0, 2_000_000, 0)); // 2 ms
        evs.extend(task(2, 1, 0, 4_000_000, 1)); // 4 ms, stolen
        // a non-FD span must be ignored
        evs.push(Event {
            ts_ns: 0,
            span: 3,
            lane: 0,
            kind: Kind::CdRound,
            is_exit: false,
            ..Event::default()
        });
        evs.push(Event {
            ts_ns: 1,
            span: 3,
            lane: 0,
            kind: Kind::CdRound,
            is_exit: true,
            ..Event::default()
        });
        let b = FdBalance::from_events(&evs);
        assert_eq!(b.tasks, 2);
        assert_eq!(b.steals, 1);
        assert_eq!(b.lanes, 2);
        assert_eq!(b.max_ms, 4.0);
        assert_eq!(b.mean_ms, 3.0);
        assert_eq!(b.stddev_ms, 1.0);
        assert_eq!(FdBalance::from_events(&[]), FdBalance::default());
    }

    #[test]
    fn theta_fnv_is_order_sensitive() {
        assert_ne!(theta_fnv(&[1, 2, 3]), theta_fnv(&[3, 2, 1]));
        assert_eq!(theta_fnv(&[1, 2, 3]), theta_fnv(&[1, 2, 3]));
        assert_ne!(theta_fnv(&[]), theta_fnv(&[0]));
    }

    #[test]
    fn wall_ms_stats() {
        let w = WallMs::from_times(&[3.0, 1.0, 2.0]);
        assert_eq!(w.min, 1.0);
        assert_eq!(w.max, 3.0);
        assert_eq!(w.mean, 2.0);
    }

    #[test]
    fn entry_lookup_by_key() {
        let r = sample_report(vec![sample_entry("a", "wing/bup", 1)]);
        assert!(r.entry("a", "wing/bup").is_some());
        assert!(r.entry("a", "wing/pbng").is_none());
        assert!(r.entry("b", "wing/bup").is_none());
    }

    #[test]
    fn fingerprint_ignores_times_and_env() {
        let a = sample_report(vec![sample_entry("a", "wing/bup", 1)]);
        let mut b = a.clone();
        b.entries[0].wall_ms = WallMs { min: 99.0, mean: 99.0, max: 99.0 };
        b.env.git_sha = "something".to_string();
        assert_eq!(a.counters_fingerprint(), b.counters_fingerprint());
        let mut c = a.clone();
        c.entries[0].counters.rho += 1;
        assert_ne!(a.counters_fingerprint(), c.counters_fingerprint());
    }
}
