//! Suite execution: warmup + N repetitions per (dataset, algorithm) cell.
//!
//! Counters are taken from the last repetition; with a fixed seed and
//! thread count every repetition produces the same values (asserted by
//! `tests/test_bench.rs`), so which repetition is recorded is moot — but
//! "last" also makes the wall-time and counter sections describe the same
//! run. Wall time is the in-algorithm [`PeelStats::total`], measured
//! around the full pipeline (counting included), matching Tables 3–4.
//!
//! [`PeelStats::total`]: crate::metrics::PeelStats

use super::report::{Counters, CountSide, Entry, Env, FdBalance, PhaseRow, Report, WallMs};
use super::{Algo, DatasetSpec, Suite};
use crate::graph::BipartiteGraph;
use crate::obs;

#[derive(Clone, Copy, Debug)]
pub struct BenchOptions {
    /// Worker threads, honored end to end through every algorithm's
    /// pipeline (counting, CD, FD) on the persistent runtime pool.
    /// Defaults to 1, which never wakes the pool: counter metrics are
    /// only guaranteed schedule-independent single-threaded, and the CI
    /// gate needs determinism more than speed.
    pub threads: usize,
    pub repetitions: usize,
    /// Discarded runs before measuring (cache/allocator warmup).
    pub warmup: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { threads: 1, repetitions: 3, warmup: 0 }
    }
}

/// Execute every (dataset × algorithm) cell of `suite`. `repetitions`
/// is normalized to at least 1 so the env stanza always describes the
/// runs that actually happened.
pub fn run_suite(suite: &Suite, opts: &BenchOptions) -> Report {
    let opts = BenchOptions { repetitions: opts.repetitions.max(1), ..*opts };
    let mut entries = Vec::with_capacity(suite.datasets.len() * suite.algos.len());
    for ds in suite.datasets {
        let g = ds.build();
        for &algo in suite.algos {
            entries.push(run_cell(ds, &g, algo, &opts));
        }
    }
    Report {
        schema_version: super::report::SCHEMA_VERSION,
        suite: suite.name.to_string(),
        env: Env::capture(&opts),
        entries,
    }
}

fn run_cell(ds: &DatasetSpec, g: &BipartiteGraph, algo: Algo, opts: &BenchOptions) -> Entry {
    for _ in 0..opts.warmup {
        let _ = algo.run(g, opts.threads);
    }
    let reps = opts.repetitions; // >= 1, normalized by run_suite
    let mut times_ms = Vec::with_capacity(reps);
    let mut last = None;
    // The FD balance summary is distilled from obs spans, but the runner
    // never toggles the global tracing window itself (a library has no
    // business flipping process state under a concurrent caller): the
    // summary is collected only when the caller — `pbng bench` always
    // does — enabled tracing. Obs overhead is a branch plus one
    // lane-local buffer write per span, far below the wall gate's slack,
    // and does not touch the gated counters at all.
    let collect = obs::enabled();
    let mut balance = FdBalance::default();
    let mut count_side = CountSide::default();
    for _ in 0..reps {
        if collect {
            obs::clear();
        }
        let d = algo.run(g, opts.threads);
        times_ms.push(d.stats.total.as_secs_f64() * 1e3);
        if collect {
            // like the counters: the balance describes the recorded
            // (last) repetition; a snapshot (not a drain) leaves the
            // window in place for `pbng bench --trace` to export
            let events = obs::snapshot_events();
            balance = FdBalance::from_events(&events);
            count_side = CountSide::from_events(&events);
        }
        last = Some(d);
    }
    let d = last.expect("at least one repetition");
    let phases = d
        .stats
        .phases
        .iter()
        .map(|(ph, t, upd, wdg)| PhaseRow {
            name: ph.name().to_string(),
            ms: t.as_secs_f64() * 1e3,
            updates: *upd,
            wedges: *wdg,
        })
        .collect();
    // per-rep times at the same millisecond precision as `wall_ms`
    let rep_ms: Vec<f64> = times_ms.iter().map(|&t| (t * 1000.0).round() / 1000.0).collect();
    Entry {
        dataset: ds.name.to_string(),
        seed: ds.seed,
        nu: g.nu(),
        nv: g.nv(),
        m: g.m(),
        algo: algo.name().to_string(),
        wall_ms: WallMs::from_times(&times_ms),
        rep_ms,
        counters: Counters::from_decomposition(&d),
        fd_balance: balance,
        count_side,
        phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::find_suite;

    fn tiny_opts() -> BenchOptions {
        BenchOptions { threads: 1, repetitions: 1, warmup: 0 }
    }

    #[test]
    fn runner_fills_the_grid() {
        let suite = find_suite("micro").unwrap();
        let r = run_suite(suite, &tiny_opts());
        assert_eq!(r.entries.len(), suite.datasets.len() * suite.algos.len());
        assert_eq!(r.suite, "micro");
        for e in &r.entries {
            assert!(e.m > 0);
            assert!(e.wall_ms.min <= e.wall_ms.mean && e.wall_ms.mean <= e.wall_ms.max);
            assert!(
                e.counters.updates > 0 || e.counters.wedges > 0,
                "{}/{} did no work",
                e.dataset,
                e.algo
            );
            assert!(!e.phases.is_empty());
        }
        // every registered algorithm appears on every dataset
        for ds in suite.datasets {
            for a in suite.algos {
                assert!(r.entry(ds.name, a.name()).is_some(), "{}/{}", ds.name, a.name());
            }
        }
    }

    #[test]
    fn repetitions_and_warmup_are_recorded() {
        // enables the global tracing window to exercise balance capture
        let _g = crate::obs::test_guard();
        crate::obs::enable();
        let micro = find_suite("micro").unwrap();
        let suite = crate::bench::Suite {
            name: "unit",
            description: "one-cell suite",
            datasets: &micro.datasets[2..3], // grid-micro, the smallest
            algos: &[crate::bench::Algo::WingPbng],
        };
        let opts = BenchOptions { threads: 1, repetitions: 2, warmup: 1 };
        let r = run_suite(&suite, &opts);
        assert_eq!(r.env.repetitions, 2);
        assert_eq!(r.env.warmup, 1);
        assert_eq!(r.env.threads, 1);
        assert!(!r.env.crate_version.is_empty());
        // one recorded wall time per repetition, and the FD balance
        // summary of the recorded rep is populated for a PBNG algorithm
        let e = &r.entries[0];
        assert_eq!(e.rep_ms.len(), 2);
        assert!(e.rep_ms.iter().all(|&t| t >= 0.0));
        assert!(e.fd_balance.tasks > 0, "wing/pbng ran FD tasks");
        assert!(e.fd_balance.lanes >= 1);
        // the counting phase emits exactly one count_kernel span per run
        assert_eq!(e.count_side.calls, 1, "wing/pbng counts once");
        assert_eq!(
            e.count_side.degree + e.count_side.side_u + e.count_side.side_v,
            e.count_side.calls
        );
        // repetitions are normalized, and the env stanza reflects that
        let zero = BenchOptions { repetitions: 0, ..opts };
        let r0 = run_suite(&suite, &zero);
        assert_eq!(r0.env.repetitions, 1);
        crate::obs::disable();
        crate::obs::clear();
    }
}
