//! Benchmark & regression subsystem — the repo's measurement backbone.
//!
//! The paper's headline claims are quantitative: up to four orders of
//! magnitude fewer synchronizations ρ than level-synchronous peeling and
//! two orders of magnitude speedup over bottom-up peeling (Tables 3–4).
//! This module turns those currencies into a reproducible, CI-gated
//! harness:
//!
//! * **registry** (this file) — deterministic synthetic dataset suites
//!   (seeded power-law, block-community, and grid bipartite graphs from
//!   [`crate::graph::gen`]) crossed with algorithm configurations (wing:
//!   BUP / ParB / PBNG CD+FD and the PBNG− / PBNG−− ablations / BE_Batch;
//!   tip: peel / ParB / CD+FD);
//! * [`runner`] — warmup + N-repetition execution collecting wall time,
//!   peak-set sizes, and the [`crate::metrics::Meters`] counters;
//! * [`report`] — the versioned `BENCH_<suite>.json` schema;
//! * [`compare`] — the regression gate: counter metrics exactly, wall
//!   time loosely (`pbng bench compare` exits non-zero past thresholds).

pub mod compare;
pub mod report;
pub mod runner;

use crate::graph::{gen, BipartiteGraph, Side};
use crate::peel::Decomposition;

/// A deterministic synthetic dataset: generator function + pinned seed.
/// Building the same spec twice yields byte-identical edge lists.
#[derive(Clone, Copy)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub seed: u64,
    gen_fn: fn(u64) -> BipartiteGraph,
}

impl DatasetSpec {
    pub fn build(&self) -> BipartiteGraph {
        (self.gen_fn)(self.seed)
    }
}

/// One benchmarked algorithm configuration (a Tables 3–4 row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Sequential bottom-up wing peeling.
    WingBup,
    /// Level-synchronous parallel wing peeling (PARBUTTERFLY-style).
    WingParb,
    /// Two-phased PBNG wing decomposition (CD + FD).
    WingPbng,
    /// PBNG without dynamic BE-Index deletes (paper's PBNG−).
    WingPbngMinus,
    /// PBNG without deletes or batching (paper's PBNG−−).
    WingPbngMinusMinus,
    /// BE_Batch baseline: bottom-up level peeling on the BE-Index.
    WingBeBatch,
    /// Sequential bottom-up tip peeling (side U).
    TipPeel,
    /// Level-synchronous tip peeling (side U).
    TipParb,
    /// Two-phased PBNG tip decomposition (side U).
    TipPbng,
    /// Incremental wing maintenance over the standard update stream
    /// (init + per-batch affected-region re-peels).
    WingIncr,
    /// From-scratch wing re-decomposition after every batch of the same
    /// stream (the latency baseline `wing/incr` is measured against).
    WingIncrScratch,
    /// Incremental tip maintenance over the standard update stream.
    TipIncr,
    /// From-scratch tip re-decomposition after every batch.
    TipIncrScratch,
    /// Counting only, forced-scalar intersection kernel.
    KernCountScalar,
    /// Counting only, SIMD intersection when compiled in (`Auto`).
    KernCountSimd,
    /// Counting only, auto wedge-side cost model + `Auto` SIMD.
    KernCountAuto,
    /// Wing peel with scattered (per-hit atomic) support updates.
    KernPeelScatter,
    /// Wing peel with aggregated (sort-then-flush) support updates.
    KernPeelAgg,
    /// Tip peel with scattered support updates.
    KernTipScatter,
    /// Tip peel with aggregated support updates.
    KernTipAgg,
    /// Durable ingestion: the update stream fsynced through the WAL,
    /// then replayed through the staging pool into the incremental
    /// engine (append + replay + coalesce + apply, the `--wal` path).
    IngestWal,
    /// The same stream applied straight to the incremental engine with
    /// no durability — the latency floor `ingest/wal` is measured
    /// against, and its θ twin (the WAL round-trip must not change θ).
    IngestDirect,
}

impl Algo {
    /// Stable identifier used as the report key — renames invalidate
    /// committed baselines, so treat these as part of the schema.
    pub fn name(self) -> &'static str {
        match self {
            Algo::WingBup => "wing/bup",
            Algo::WingParb => "wing/parb",
            Algo::WingPbng => "wing/pbng",
            Algo::WingPbngMinus => "wing/pbng-",
            Algo::WingPbngMinusMinus => "wing/pbng--",
            Algo::WingBeBatch => "wing/be-batch",
            Algo::TipPeel => "tip/peel",
            Algo::TipParb => "tip/parb",
            Algo::TipPbng => "tip/pbng",
            Algo::WingIncr => "wing/incr",
            Algo::WingIncrScratch => "wing/incr-scratch",
            Algo::TipIncr => "tip/incr",
            Algo::TipIncrScratch => "tip/incr-scratch",
            Algo::KernCountScalar => "kern/count-scalar",
            Algo::KernCountSimd => "kern/count-simd",
            Algo::KernCountAuto => "kern/count-auto",
            Algo::KernPeelScatter => "kern/peel-scatter",
            Algo::KernPeelAgg => "kern/peel-agg",
            Algo::KernTipScatter => "kern/tip-scatter",
            Algo::KernTipAgg => "kern/tip-agg",
            Algo::IngestWal => "ingest/wal",
            Algo::IngestDirect => "ingest/direct",
        }
    }

    pub fn is_wing(self) -> bool {
        self.name().starts_with("wing/")
    }

    pub fn run(self, g: &BipartiteGraph, threads: usize) -> Decomposition {
        use crate::count::{KernelConfig, OrderPolicy, SimdPolicy, UpdateKernel};
        let wing_cfg = |batch, dynamic_deletes| crate::engine::EngineConfig {
            p: (g.m() / 500).clamp(4, 64),
            threads,
            batch,
            dynamic_deletes,
            ..Default::default()
        };
        let kern_wing = |updates| crate::engine::EngineConfig {
            kernel: KernelConfig { updates, ..Default::default() },
            ..wing_cfg(true, true)
        };
        let kern_tip = |updates| crate::engine::EngineConfig {
            p: (g.nu() / 100).clamp(4, 32),
            threads,
            kernel: KernelConfig { updates, ..Default::default() },
            ..Default::default()
        };
        match self {
            Algo::WingBup => crate::peel::bup::wing_bup(g),
            Algo::WingParb => crate::peel::parb::wing_parb(g, threads),
            Algo::WingPbng => crate::wing::wing_pbng(g, wing_cfg(true, true)),
            Algo::WingPbngMinus => crate::wing::wing_pbng(g, wing_cfg(true, false)),
            Algo::WingPbngMinusMinus => crate::wing::wing_pbng(g, wing_cfg(false, false)),
            Algo::WingBeBatch => crate::wing::wing_be_batch(g, threads),
            Algo::TipPeel => crate::tip::tip_bup(g, Side::U),
            Algo::TipParb => crate::tip::tip_parb(g, Side::U, threads),
            Algo::TipPbng => crate::tip::tip_pbng(
                g,
                Side::U,
                crate::engine::EngineConfig {
                    p: (g.nu() / 100).clamp(4, 32),
                    threads,
                    ..Default::default()
                },
            ),
            Algo::WingIncr => incr::run_wing_incremental(g, threads),
            Algo::WingIncrScratch => incr::run_wing_scratch(g, threads),
            Algo::TipIncr => incr::run_tip_incremental(g, threads),
            Algo::TipIncrScratch => incr::run_tip_scratch(g, threads),
            Algo::KernCountScalar => run_count_only(
                g,
                threads,
                KernelConfig { simd: SimdPolicy::Scalar, ..Default::default() },
            ),
            Algo::KernCountSimd => run_count_only(
                g,
                threads,
                KernelConfig { simd: SimdPolicy::Auto, ..Default::default() },
            ),
            Algo::KernCountAuto => run_count_only(
                g,
                threads,
                KernelConfig { order: OrderPolicy::Auto, ..Default::default() },
            ),
            Algo::KernPeelScatter => {
                crate::wing::wing_pbng(g, kern_wing(UpdateKernel::Scattered))
            }
            Algo::KernPeelAgg => crate::wing::wing_pbng(g, kern_wing(UpdateKernel::Aggregated)),
            Algo::KernTipScatter => {
                crate::tip::tip_pbng(g, Side::U, kern_tip(UpdateKernel::Scattered))
            }
            Algo::KernTipAgg => {
                crate::tip::tip_pbng(g, Side::U, kern_tip(UpdateKernel::Aggregated))
            }
            Algo::IngestWal => ingest_cell::run_wal(g, threads),
            Algo::IngestDirect => ingest_cell::run_direct(g, threads),
        }
    }
}

/// Counting-only cell for the `kernels` suite: one `pve_bcnt` pass with
/// the given kernel config, reported as a Decomposition whose "θ" is the
/// per-U butterfly count vector — so the θ checksum in the committed
/// report doubles as the scalar-vs-SIMD byte-equality gate.
fn run_count_only(
    g: &BipartiteGraph,
    threads: usize,
    kernel: crate::count::KernelConfig,
) -> Decomposition {
    let meters = crate::metrics::Meters::new();
    let mut rec = crate::metrics::Recorder::new(&meters);
    rec.enter(crate::metrics::Phase::Count);
    let (c, _) = crate::count::pve_bcnt(
        g,
        crate::count::CountOptions {
            per_edge: false,
            build_blooms: false,
            threads,
            kernel,
        },
        Some(&meters),
    );
    Decomposition { theta: c.per_u, stats: rec.finish() }
}

/// Incremental-suite drivers: a pinned mixed update stream applied either
/// through [`crate::engine::incremental`] or via from-scratch
/// re-decomposition, so the `incremental` suite's wall-time columns are a
/// direct update-latency comparison and the θ checksums of the `incr` /
/// `incr-scratch` pairs must match entry for entry.
mod incr {
    use super::BipartiteGraph;
    use crate::engine::incremental::{IncrementalConfig, TipIncremental, WingIncremental};
    use crate::engine::EngineConfig;
    use crate::graph::dynamic::{DeltaBatch, DeltaOp, DynGraph};
    use crate::graph::Side;
    use crate::metrics::PeelStats;
    use crate::peel::Decomposition;

    const STREAM_SEED: u64 = 0x1C4B;
    const ROUNDS: usize = 4;
    const OPS_PER_ROUND: usize = 24;

    /// Deterministic mixed stream: alternating random-pair inserts and
    /// removals of original edges (no-ops allowed — set semantics).
    pub(super) fn update_stream(g: &BipartiteGraph) -> Vec<DeltaBatch> {
        let mut rng = crate::testkit::Rng::new(STREAM_SEED);
        let es = g.edges();
        (0..ROUNDS)
            .map(|_| {
                let ops = (0..OPS_PER_ROUND)
                    .map(|k| {
                        if k % 2 == 0 || es.is_empty() {
                            DeltaOp::Insert(
                                rng.usize_below(g.nu()) as u32,
                                rng.usize_below(g.nv()) as u32,
                            )
                        } else {
                            let (u, v) = es[rng.usize_below(es.len())];
                            DeltaOp::Remove(u, v)
                        }
                    })
                    .collect();
                DeltaBatch::new(ops)
            })
            .collect()
    }

    pub(super) fn wing_cfg(g: &BipartiteGraph, threads: usize) -> EngineConfig {
        EngineConfig {
            p: (g.m() / 500).clamp(4, 64),
            threads,
            ..Default::default()
        }
    }

    fn tip_cfg(g: &BipartiteGraph, threads: usize) -> EngineConfig {
        EngineConfig {
            p: (g.nu() / 100).clamp(4, 32),
            threads,
            ..Default::default()
        }
    }

    pub(super) fn merge_stats(acc: &mut PeelStats, s: PeelStats) {
        acc.updates += s.updates;
        acc.wedges += s.wedges;
        acc.rho += s.rho;
        acc.spawns += s.spawns;
        acc.invalidated_parts += s.invalidated_parts;
        acc.total += s.total;
        acc.phases.extend(s.phases);
    }

    pub fn run_wing_incremental(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let cfg = IncrementalConfig {
            engine: wing_cfg(g, threads),
            ..Default::default()
        };
        let mut st = WingIncremental::new(g, cfg);
        let mut stats = st.init_stats().clone();
        for batch in update_stream(g) {
            merge_stats(&mut stats, st.apply(&batch).stats);
        }
        Decomposition { theta: st.theta().to_vec(), stats }
    }

    pub fn run_wing_scratch(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let cfg = wing_cfg(g, threads);
        let mut dg = DynGraph::from_graph(g);
        let mut last = crate::wing::wing_pbng(g, cfg);
        let mut stats = std::mem::take(&mut last.stats);
        for batch in update_stream(g) {
            dg.apply_batch(&batch);
            last = crate::wing::wing_pbng(&dg.snapshot(), cfg);
            merge_stats(&mut stats, std::mem::take(&mut last.stats));
        }
        Decomposition { theta: last.theta, stats }
    }

    pub fn run_tip_incremental(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let cfg = IncrementalConfig {
            engine: tip_cfg(g, threads),
            ..Default::default()
        };
        let mut st = TipIncremental::new(g, Side::U, cfg);
        let mut stats = st.init_stats().clone();
        for batch in update_stream(g) {
            merge_stats(&mut stats, st.apply(&batch).stats);
        }
        Decomposition { theta: st.theta().to_vec(), stats }
    }

    pub fn run_tip_scratch(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let cfg = tip_cfg(g, threads);
        let mut dg = DynGraph::from_graph(g);
        let mut last = crate::tip::tip_pbng(g, Side::U, cfg);
        let mut stats = std::mem::take(&mut last.stats);
        for batch in update_stream(g) {
            dg.apply_batch(&batch);
            last = crate::tip::tip_pbng(&dg.snapshot(), Side::U, cfg);
            merge_stats(&mut stats, std::mem::take(&mut last.stats));
        }
        Decomposition { theta: last.theta, stats }
    }
}

/// Ingest-suite drivers: the same pinned update stream as the
/// `incremental` suite, but routed through the durability stack — each
/// round fsynced into a WAL record, then tailed back through the
/// staging pool (coalescing + cancellation) into the incremental
/// engine. The `ingest/direct` cell skips the log and pool entirely, so
/// the pair's wall-time delta is the price of durability and its θ
/// checksums must match entry for entry (the WAL round-trip and pool
/// reordering are invisible under set semantics).
mod ingest_cell {
    use super::{incr, BipartiteGraph};
    use crate::engine::incremental::{IncrementalConfig, WingIncremental};
    use crate::ingest::{AdaptiveFallback, Pool, PoolConfig};
    use crate::peel::Decomposition;
    use crate::wal;
    use std::time::Instant;

    fn state_for(g: &BipartiteGraph, threads: usize) -> WingIncremental {
        let cfg = IncrementalConfig {
            engine: incr::wing_cfg(g, threads),
            ..Default::default()
        };
        WingIncremental::new(g, cfg)
    }

    /// Durable path: append every stream round as one fsynced record,
    /// replay the log, and drain each record through the pool with a
    /// forced flush (the serve path's per-poll behavior).
    pub fn run_wal(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let dir = crate::testkit::TempDir::new("bench-ingest").expect("tempdir");
        let log = dir.file("stream.wal");
        let mut w = wal::Writer::create(&log).expect("wal create");
        for batch in incr::update_stream(g) {
            w.append(&batch.ops).expect("wal append");
        }
        drop(w);
        let tail = wal::replay(&log).expect("wal replay");
        let mut st = state_for(g, threads);
        let mut ctl = AdaptiveFallback::new(st.fallback_fraction());
        let mut stats = st.init_stats().clone();
        let mut pool = Pool::new(PoolConfig {
            max_batch: 24,
            max_delay: std::time::Duration::ZERO,
        });
        let t0 = Instant::now();
        for rec in &tail.records {
            for &op in &rec.ops {
                pool.push(op, t0);
            }
            if let Some((batches, _lag)) = pool.take_ready(t0, true) {
                for b in batches {
                    let up = st.apply(&b);
                    st.set_fallback_fraction(ctl.observe(&up));
                    incr::merge_stats(&mut stats, up.stats);
                }
            }
        }
        Decomposition { theta: st.theta().to_vec(), stats }
    }

    /// Durability-free twin: the same stream applied straight to the
    /// incremental engine (no log, no pool, fixed fallback threshold).
    pub fn run_direct(g: &BipartiteGraph, threads: usize) -> Decomposition {
        let mut st = state_for(g, threads);
        let mut stats = st.init_stats().clone();
        for batch in incr::update_stream(g) {
            incr::merge_stats(&mut stats, st.apply(&batch).stats);
        }
        Decomposition { theta: st.theta().to_vec(), stats }
    }
}

/// A named dataset × algorithm grid. Tiers keep CI fast: `smoke` must
/// finish well under two minutes on a shared runner.
pub struct Suite {
    pub name: &'static str,
    pub description: &'static str,
    pub datasets: &'static [DatasetSpec],
    pub algos: &'static [Algo],
}

// --- dataset generator thunks (seed-parametric, sizes pinned) ---------

fn pl_micro(seed: u64) -> BipartiteGraph {
    gen::zipf(120, 100, 700, 1.2, 1.2, seed)
}
fn blocks_micro(seed: u64) -> BipartiteGraph {
    let blocks = [
        gen::Block { rows: 8, cols: 8, density: 1.0 },
        gen::Block { rows: 6, cols: 6, density: 0.9 },
    ];
    gen::planted_blocks(80, 80, 250, &blocks, seed)
}
fn grid_micro(seed: u64) -> BipartiteGraph {
    gen::grid(60, 60, 4, 0.9, seed)
}

fn pl_smoke(seed: u64) -> BipartiteGraph {
    gen::zipf(700, 500, 4000, 1.25, 1.25, seed)
}
fn blocks_smoke(seed: u64) -> BipartiteGraph {
    let blocks = [
        gen::Block { rows: 16, cols: 16, density: 0.9 },
        gen::Block { rows: 12, cols: 12, density: 0.95 },
        gen::Block { rows: 24, cols: 8, density: 0.85 },
    ];
    gen::planted_blocks(400, 400, 1500, &blocks, seed)
}
fn grid_smoke(seed: u64) -> BipartiteGraph {
    gen::grid(300, 300, 5, 0.9, seed)
}

fn preset_di_af_s(_seed: u64) -> BipartiteGraph {
    gen::Preset::DiAfS.build()
}
fn preset_tr_s(_seed: u64) -> BipartiteGraph {
    gen::Preset::TrS.build()
}
fn preset_planted_s(_seed: u64) -> BipartiteGraph {
    gen::Preset::PlantedS.build()
}
fn preset_nested_s(_seed: u64) -> BipartiteGraph {
    gen::Preset::NestedS.build()
}
fn preset_grid_s(_seed: u64) -> BipartiteGraph {
    gen::Preset::GridS.build()
}
fn preset_tr_m(_seed: u64) -> BipartiteGraph {
    gen::Preset::TrM.build()
}
fn preset_or_m(_seed: u64) -> BipartiteGraph {
    gen::Preset::OrM.build()
}

// Recorded seeds for presets are the generator seeds pinned in
// `gen::Preset::build` — the spec seed is documentation there, not input.

const MICRO_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "pl-micro", seed: 31, gen_fn: pl_micro },
    DatasetSpec { name: "blocks-micro", seed: 32, gen_fn: blocks_micro },
    DatasetSpec { name: "grid-micro", seed: 33, gen_fn: grid_micro },
];

const SMOKE_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "pl-s", seed: 21, gen_fn: pl_smoke },
    DatasetSpec { name: "blocks-s", seed: 22, gen_fn: blocks_smoke },
    DatasetSpec { name: "grid-s", seed: 23, gen_fn: grid_smoke },
];

/// Kernel-suite datasets: one skewed (power-law — lopsided adjacency
/// lists, galloping-heavy intersections) and one flat (grid — uniform
/// short lists), the two shapes that stress the kernels differently.
/// Same specs as the smoke entries of the same names.
const KERNEL_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "pl-s", seed: 21, gen_fn: pl_smoke },
    DatasetSpec { name: "grid-s", seed: 23, gen_fn: grid_smoke },
];

const STANDARD_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "di-af-s", seed: 101, gen_fn: preset_di_af_s },
    DatasetSpec { name: "tr-s", seed: 106, gen_fn: preset_tr_s },
    DatasetSpec { name: "planted-s", seed: 108, gen_fn: preset_planted_s },
    DatasetSpec { name: "nested-s", seed: 109, gen_fn: preset_nested_s },
    DatasetSpec { name: "grid-s", seed: 112, gen_fn: preset_grid_s },
];

const MEDIUM_DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "tr-m", seed: 110, gen_fn: preset_tr_m },
    DatasetSpec { name: "or-m", seed: 111, gen_fn: preset_or_m },
];

const FULL_ALGOS: &[Algo] = &[
    Algo::WingBup,
    Algo::WingParb,
    Algo::WingPbng,
    Algo::WingPbngMinus,
    Algo::WingPbngMinusMinus,
    Algo::WingBeBatch,
    Algo::TipPeel,
    Algo::TipParb,
    Algo::TipPbng,
];

/// Index-free sequential baselines are too slow for the medium tier (the
/// paper's own Table 3 has "-" entries for the same reason).
const MEDIUM_ALGOS: &[Algo] = &[Algo::WingParb, Algo::WingPbng, Algo::TipPbng];

/// Update-latency pairs: each `incr` entry's θ checksum must equal its
/// `incr-scratch` sibling (same stream, same final graph).
const INCR_ALGOS: &[Algo] = &[
    Algo::WingIncr,
    Algo::WingIncrScratch,
    Algo::TipIncr,
    Algo::TipIncrScratch,
];

/// Kernel-engineering cells: counting-only (scalar vs SIMD vs auto
/// side-choice — θ checksums of the `count-*` triple must match exactly)
/// and peel-only (scattered vs aggregated support updates — each pair
/// must match its sibling's checksum, with the aggregated wall time
/// expected at or below the scattered one).
const KERNEL_ALGOS: &[Algo] = &[
    Algo::KernCountScalar,
    Algo::KernCountSimd,
    Algo::KernCountAuto,
    Algo::KernPeelScatter,
    Algo::KernPeelAgg,
    Algo::KernTipScatter,
    Algo::KernTipAgg,
];

/// Durability cells: each `ingest/wal` entry's θ checksum must equal its
/// `ingest/direct` sibling (same stream, same final graph — the WAL and
/// pool must be semantically invisible).
const INGEST_ALGOS: &[Algo] = &[Algo::IngestWal, Algo::IngestDirect];

pub const SUITES: &[Suite] = &[
    Suite {
        name: "micro",
        description: "seconds-fast tier for unit/integration tests",
        datasets: MICRO_DATASETS,
        algos: FULL_ALGOS,
    },
    Suite {
        name: "smoke",
        description: "CI regression gate (<2 min on a shared runner)",
        datasets: SMOKE_DATASETS,
        algos: FULL_ALGOS,
    },
    Suite {
        name: "standard",
        description: "paper-analog small presets (Tables 3-4 shape)",
        datasets: STANDARD_DATASETS,
        algos: FULL_ALGOS,
    },
    Suite {
        name: "medium",
        description: "larger tier, parallel algorithms only",
        datasets: MEDIUM_DATASETS,
        algos: MEDIUM_ALGOS,
    },
    Suite {
        name: "incremental",
        description: "dynamic-graph update streams: incremental vs from-scratch re-peeling",
        datasets: MICRO_DATASETS,
        algos: INCR_ALGOS,
    },
    Suite {
        name: "kernels",
        description: "counting/peel kernel configs: scalar vs SIMD vs auto side-choice, scattered vs aggregated updates",
        datasets: KERNEL_DATASETS,
        algos: KERNEL_ALGOS,
    },
    Suite {
        name: "ingest",
        description: "durable ingestion: WAL append + replay + pool coalescing vs direct incremental application",
        datasets: MICRO_DATASETS,
        algos: INGEST_ALGOS,
    },
];

pub fn find_suite(name: &str) -> Option<&'static Suite> {
    SUITES.iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_lookup() {
        assert!(find_suite("smoke").is_some());
        assert!(find_suite("micro").is_some());
        assert!(find_suite("nope").is_none());
    }

    #[test]
    fn smoke_meets_acceptance_floor() {
        // ISSUE acceptance: ≥ 5 algorithm configs on ≥ 3 datasets.
        let s = find_suite("smoke").unwrap();
        assert!(s.datasets.len() >= 3);
        assert!(s.algos.len() >= 5);
    }

    #[test]
    fn algo_names_are_unique_and_prefixed() {
        let mut names: Vec<&str> = FULL_ALGOS
            .iter()
            .chain(INCR_ALGOS.iter())
            .chain(KERNEL_ALGOS.iter())
            .chain(INGEST_ALGOS.iter())
            .map(|a| a.name())
            .collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n);
        for a in FULL_ALGOS.iter().chain(INCR_ALGOS.iter()) {
            assert!(a.name().starts_with(if a.is_wing() { "wing/" } else { "tip/" }));
        }
        for a in KERNEL_ALGOS {
            assert!(a.name().starts_with("kern/"), "{}", a.name());
        }
        for a in INGEST_ALGOS {
            assert!(a.name().starts_with("ingest/"), "{}", a.name());
        }
    }

    #[test]
    fn ingest_wal_and_direct_agree_on_final_theta() {
        // the WAL round-trip + pool coalescing must be semantically
        // invisible: both cells end on the same graph, so same θ
        let s = find_suite("ingest").unwrap();
        assert_eq!(s.algos.len(), 2);
        let g = MICRO_DATASETS[2].build(); // grid-micro, the smallest
        let wal = Algo::IngestWal.run(&g, 1);
        let direct = Algo::IngestDirect.run(&g, 1);
        assert_eq!(wal.theta, direct.theta, "wal ingest != direct");
        // and the reference: direct matches the incremental cell exactly
        assert_eq!(direct.theta, Algo::WingIncr.run(&g, 1).theta);
    }

    #[test]
    fn kernel_count_variants_are_byte_identical() {
        // ISSUE acceptance: θ checksums byte-identical scalar vs SIMD vs
        // auto side-choice. The count-only cells report per-U counts as θ.
        let g = MICRO_DATASETS[0].build(); // pl-micro: skewed lists
        let scalar = Algo::KernCountScalar.run(&g, 2).theta;
        let simd = Algo::KernCountSimd.run(&g, 2).theta;
        let auto = Algo::KernCountAuto.run(&g, 2).theta;
        assert_eq!(scalar, simd, "scalar vs simd counts diverged");
        assert_eq!(scalar, auto, "degree vs auto side-choice counts diverged");
        assert_eq!(scalar.len(), g.nu());
    }

    #[test]
    fn kernel_peel_variants_match_reference_theta() {
        let g = MICRO_DATASETS[2].build(); // grid-micro, the smallest
        let wing_ref = Algo::WingPbng.run(&g, 1).theta;
        assert_eq!(Algo::KernPeelScatter.run(&g, 1).theta, wing_ref);
        assert_eq!(Algo::KernPeelAgg.run(&g, 1).theta, wing_ref);
        let tip_ref = Algo::TipPbng.run(&g, 1).theta;
        assert_eq!(Algo::KernTipScatter.run(&g, 1).theta, tip_ref);
        assert_eq!(Algo::KernTipAgg.run(&g, 1).theta, tip_ref);
    }

    #[test]
    fn incremental_suite_pairs_agree_on_final_theta() {
        // the incr / incr-scratch pairs follow the same pinned stream, so
        // their final θ vectors (and lengths) must match exactly
        let s = find_suite("incremental").unwrap();
        assert!(s.algos.len() >= 4);
        let g = MICRO_DATASETS[2].build(); // grid-micro, the smallest
        let wi = Algo::WingIncr.run(&g, 1);
        let ws = Algo::WingIncrScratch.run(&g, 1);
        assert_eq!(wi.theta, ws.theta, "wing incr != scratch");
        let ti = Algo::TipIncr.run(&g, 1);
        let ts = Algo::TipIncrScratch.run(&g, 1);
        assert_eq!(ti.theta, ts.theta, "tip incr != scratch");
        // counters are deterministic run to run (the CI gate relies on it)
        let wi2 = Algo::WingIncr.run(&g, 1);
        assert_eq!(wi.stats.updates, wi2.stats.updates);
        assert_eq!(wi.stats.invalidated_parts, wi2.stats.invalidated_parts);
    }

    #[test]
    fn dataset_specs_are_deterministic() {
        for s in SUITES.iter().filter(|s| s.name == "micro") {
            for ds in s.datasets {
                let a = ds.build();
                let b = ds.build();
                assert_eq!(a.edges(), b.edges(), "{} not deterministic", ds.name);
                assert!(a.m() > 0, "{} is empty", ds.name);
            }
        }
    }

    #[test]
    fn micro_algos_produce_full_theta() {
        let ds = &MICRO_DATASETS[2]; // grid: smallest
        let g = ds.build();
        for &algo in FULL_ALGOS {
            let d = algo.run(&g, 1);
            let want = if algo.is_wing() { g.m() } else { g.nu() };
            assert_eq!(d.theta.len(), want, "{}", algo.name());
        }
    }
}
