//! Hierarchy extraction: materialize k-wings / k-tips from wing / tip
//! numbers (the space-efficient index the decomposition outputs, §2.2).
//!
//! A k-wing is a maximal *butterfly-connected* subgraph of the edges with
//! `θ_e ≥ k`. Butterfly connectivity is computed through blooms: inside
//! one bloom, a wedge is "active at level k" iff both its (twin) edges
//! have `θ ≥ k`, and all edges of ≥ 2 active wedges of a bloom are
//! pairwise butterfly-connected (Property 1).

use crate::beindex::BeIndex;
use crate::graph::{BipartiteGraph, Side};

/// Union-find with path halving and union by size (near-inverse-Ackermann
/// amortized finds even on adversarial union orders). Shared by the level
/// materialization here and the incremental forest builder in
/// [`crate::index`].
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
        }
    }
    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }
    /// Merge the sets of `a` and `b`; returns whether a merge happened.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        self.union_roots(a, b).is_some()
    }
    /// Merge by size, returning `(winner_root, loser_root)` when the two
    /// were in different sets. The winner remains a valid root; the loser
    /// root's satellite data can be folded into the winner's (the forest
    /// builder relies on this).
    pub fn union_roots(&mut self, a: u32, b: u32) -> Option<(u32, u32)> {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return None;
        }
        let (w, l) = if self.size[ra as usize] >= self.size[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[l as usize] = w;
        self.size[w as usize] += self.size[l as usize];
        Some((w, l))
    }
    /// Size of the set containing `x`.
    pub fn size_of(&mut self, x: u32) -> u32 {
        let r = self.find(x);
        self.size[r as usize]
    }
}

/// Edges of the k-wing level: `θ_e ≥ k`.
pub fn kwing_edges(theta: &[u64], k: u64) -> Vec<u32> {
    (0..theta.len() as u32)
        .filter(|&e| theta[e as usize] >= k)
        .collect()
}

/// Butterfly-connected components of the k-wing level. Returns edge-id
/// groups (components with ≥ 1 butterfly; isolated qualifying edges that
/// share no butterfly at level k are omitted — they belong to no k-wing
/// for k ≥ 1).
pub fn kwing_components(idx: &BeIndex, theta: &[u64], k: u64) -> Vec<Vec<u32>> {
    let m = theta.len();
    let mut uf = UnionFind::new(m);
    let mut in_wing = vec![false; m];
    for b in 0..idx.n_blooms() as u32 {
        // active wedges: both twins at level >= k
        let ents = idx.entries(b);
        let mut first: Option<u32> = None;
        let mut actives = 0usize;
        for &(e, t) in ents {
            if e < t {
                continue; // count each wedge once
            }
            if theta[e as usize] >= k && theta[t as usize] >= k {
                actives += 1;
                if first.is_none() {
                    first = Some(e);
                }
            }
        }
        if actives >= 2 {
            let f = first.unwrap();
            for &(e, t) in ents {
                if theta[e as usize] >= k && theta[t as usize] >= k {
                    uf.union(e, f);
                    in_wing[e as usize] = true;
                    in_wing[t as usize] = true;
                }
            }
        }
    }
    let mut groups: std::collections::HashMap<u32, Vec<u32>> = std::collections::HashMap::new();
    for e in 0..m as u32 {
        if in_wing[e as usize] {
            groups.entry(uf.find(e)).or_default().push(e);
        }
    }
    let mut out: Vec<Vec<u32>> = groups.into_values().collect();
    out.sort_by_key(|g| g.first().copied());
    out
}

/// Vertices of the k-tip level of `side`: `θ_u ≥ k`.
pub fn ktip_vertices(theta: &[u64], k: u64) -> Vec<u32> {
    (0..theta.len() as u32)
        .filter(|&u| theta[u as usize] >= k)
        .collect()
}

/// Summary of one hierarchy level for reporting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelSummary {
    pub k: u64,
    pub entities: usize,
    pub components: usize,
    pub largest: usize,
}

/// Summaries for every distinct wing-number level (Fig. 1b style).
///
/// Builds the nested-component forest once (`O(m α)` sweep over all
/// levels, [`crate::index::build_wing_forest`]) and reads every level off
/// it, instead of re-running union-find over all blooms per level.
pub fn wing_hierarchy_summary(
    g: &BipartiteGraph,
    idx: &BeIndex,
    theta: &[u64],
) -> Vec<LevelSummary> {
    // summaries never read the per-node density stats — skip that pass
    let forest = crate::index::build_wing_forest_opts(
        g,
        idx,
        theta,
        crate::par::default_threads(),
        false,
    );
    crate::index::forest_level_summaries(&forest)
}

/// Check the nesting property: the (k+1)-level is contained in the
/// k-level (both edge sets and component containment). Used by tests and
/// the verify CLI.
///
/// Containment is verified through an edge → component-id map of the
/// lower level, so one level pair costs `O(m)` instead of the old
/// `O(|hc| · |lc|)` scan per component pair.
pub fn check_wing_nesting(g: &BipartiteGraph, idx: &BeIndex, theta: &[u64]) -> Result<(), String> {
    let _ = g;
    let m = theta.len();
    let mut levels: Vec<u64> = theta.iter().copied().filter(|&t| t > 0).collect();
    levels.sort_unstable();
    levels.dedup();
    let mut comp_of = vec![u32::MAX; m];
    for w in levels.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let lo_comps = kwing_components(idx, theta, lo);
        let hi_comps = kwing_components(idx, theta, hi);
        for e in comp_of.iter_mut() {
            *e = u32::MAX;
        }
        for (ci, lc) in lo_comps.iter().enumerate() {
            for &e in lc {
                comp_of[e as usize] = ci as u32;
            }
        }
        // every hi component must be fully inside one lo component
        for hc in &hi_comps {
            let c0 = hc.first().map(|&e| comp_of[e as usize]).unwrap_or(u32::MAX);
            if c0 == u32::MAX || hc.iter().any(|&e| comp_of[e as usize] != c0) {
                return Err(format!(
                    "level {hi} component not nested in any level {lo} component"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(5);
        uf.union(0, 1);
        uf.union(3, 4);
        assert_eq!(uf.find(0), uf.find(1));
        assert_ne!(uf.find(0), uf.find(3));
        uf.union(1, 3);
        assert_eq!(uf.find(0), uf.find(4));
    }

    #[test]
    fn union_by_size_reports_winner_and_loser() {
        let mut uf = UnionFind::new(6);
        assert!(uf.union(0, 1));
        assert!(!uf.union(0, 1), "second union of same pair is a no-op");
        // {0,1} has size 2; merging in singleton 2 must keep the big root
        let (w, l) = uf.union_roots(2, 0).unwrap();
        assert_eq!(w, uf.find(0));
        assert_eq!(uf.find(l), w);
        assert_eq!(uf.size_of(2), 3);
        assert_eq!(uf.size_of(5), 1);
    }

    #[test]
    fn biclique_is_single_component() {
        let g = gen::biclique(3, 3);
        let (idx, _) = crate::beindex::BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        let comps = kwing_components(&idx, &theta, 1);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].len(), 9);
    }

    #[test]
    fn disjoint_blocks_are_separate_components() {
        // two disjoint K_{2,2}s
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 0), (1, 1), (2, 2), (2, 3), (3, 2), (3, 3)])
            .build();
        let (idx, _) = crate::beindex::BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        let comps = kwing_components(&idx, &theta, 1);
        assert_eq!(comps.len(), 2);
        assert!(comps.iter().all(|c| c.len() == 4));
    }

    #[test]
    fn fig1_hierarchy_nests() {
        let g = gen::paper_fig1();
        let (idx, _) = crate::beindex::BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        check_wing_nesting(&g, &idx, &theta).unwrap();
        let summary = wing_hierarchy_summary(&g, &idx, &theta);
        // levels 1..4 present
        let ks: Vec<u64> = summary.iter().map(|l| l.k).collect();
        assert_eq!(ks, vec![1, 2, 3, 4]);
        // entity counts strictly shrink up the hierarchy
        for w in summary.windows(2) {
            assert!(w[1].entities < w[0].entities);
        }
    }

    #[test]
    fn nesting_holds_on_random_graphs() {
        crate::testkit::check_property("wing-nesting", 0x4E57, 6, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(10),
                6 + rng.usize_below(10),
                20 + rng.usize_below(50),
                seed,
            );
            let (idx, _) = crate::beindex::BeIndex::build(&g, 1);
            let theta = wing_bup(&g).theta;
            check_wing_nesting(&g, &idx, &theta)
        });
    }

    #[test]
    fn ktip_levels_shrink() {
        let g = gen::paper_fig1();
        let theta = crate::count::brute::brute_tip_numbers(&g, crate::graph::Side::U);
        let max = *theta.iter().max().unwrap();
        let mut last = usize::MAX;
        for k in 1..=max {
            let n = ktip_vertices(&theta, k).len();
            assert!(n <= last);
            last = n;
        }
    }

    #[test]
    fn side_enum_is_used() {
        // silence Side import: hierarchy functions are side-agnostic
        let _ = Side::U;
    }
}
