//! Trace exporters: Chrome `trace_event` JSON and self-describing JSONL.
//!
//! Both formats are built on [`crate::jsonio`] so output is deterministic
//! for a given event list (fixed key order, stable number formatting):
//! two traces of the same single-threaded run differ only in the
//! timestamp fields.

use super::{Event, Kind};
use crate::jsonio::Value;

/// Schema tag emitted by both exporters (first JSONL line, Chrome-trace
/// `otherData.schema`). Bump on any field change.
pub const SCHEMA: &str = "pbng-obs-v1";

fn args_json(e: &Event) -> Value {
    let names = e.kind.attr_names();
    Value::obj()
        .with("span", e.span)
        .with(names[0], e.a)
        .with(names[1], e.b)
        .with(names[2], e.c)
}

/// Chrome `trace_event` format (the JSON-object flavour): duration
/// events (`ph: "B"`/`"E"`) with `tid` = pool lane and `ts` in
/// microseconds, loadable in `chrome://tracing` or Perfetto.
pub fn chrome_trace(events: &[Event]) -> Value {
    let mut out = Vec::with_capacity(events.len());
    for e in events {
        out.push(
            Value::obj()
                .with("name", e.kind.name())
                .with("cat", e.kind.cat())
                .with("ph", if e.is_exit { "E" } else { "B" })
                .with("ts", e.ts_ns as f64 / 1_000.0)
                .with("pid", 1u64)
                .with("tid", u64::from(e.lane))
                .with("args", args_json(e)),
        );
    }
    Value::obj()
        .with("traceEvents", out)
        .with("displayTimeUnit", "ms")
        .with("otherData", Value::obj().with("schema", SCHEMA))
}

/// Self-describing JSONL: line 1 is a schema header naming every field,
/// then one compact JSON object per event.
pub fn jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    let header = Value::obj()
        .with("schema", SCHEMA)
        .with(
            "fields",
            vec![
                Value::from("ts_ns"),
                Value::from("span"),
                Value::from("lane"),
                Value::from("kind"),
                Value::from("phase"),
                Value::from("a"),
                Value::from("b"),
                Value::from("c"),
            ],
        )
        .with(
            "kinds",
            Kind::ALL
                .iter()
                .map(|k| {
                    let names = k.attr_names();
                    Value::obj()
                        .with("kind", k.name())
                        .with("a", names[0])
                        .with("b", names[1])
                        .with("c", names[2])
                })
                .collect::<Vec<_>>(),
        );
    push_line(&mut out, &header);
    for e in events {
        let line = Value::obj()
            .with("ts_ns", e.ts_ns)
            .with("span", e.span)
            .with("lane", u64::from(e.lane))
            .with("kind", e.kind.name())
            .with("phase", if e.is_exit { "exit" } else { "enter" })
            .with("a", e.a)
            .with("b", e.b)
            .with("c", e.c);
        push_line(&mut out, &line);
    }
    out
}

fn push_line(out: &mut String, v: &Value) {
    // `to_pretty` is the only writer jsonio exposes; collapse it to one
    // line so the log stays one-event-per-line greppable.
    let pretty = v.to_pretty();
    let mut first = true;
    for part in pretty.lines() {
        if !first {
            out.push(' ');
        }
        out.push_str(part.trim());
        first = false;
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn sample_events() -> Vec<Event> {
        vec![
            Event {
                ts_ns: 10,
                span: 1,
                lane: 0,
                kind: Kind::CountKernel,
                is_exit: false,
                a: 64,
                b: 0,
                c: 0,
            },
            Event {
                ts_ns: 40,
                span: 1,
                lane: 0,
                kind: Kind::CountKernel,
                is_exit: true,
                a: 64,
                b: 0,
                c: 0,
            },
            Event {
                ts_ns: 50,
                span: 2,
                lane: 1,
                kind: Kind::FdTask,
                is_exit: false,
                a: 3,
                b: 120,
                c: 1,
            },
            Event {
                ts_ns: 90,
                span: 2,
                lane: 1,
                kind: Kind::FdTask,
                is_exit: true,
                a: 3,
                b: 120,
                c: 1,
            },
        ]
    }

    #[test]
    fn chrome_trace_round_trips_through_jsonio() {
        let v = chrome_trace(&sample_events());
        let text = v.to_pretty();
        let back = jsonio::Value::parse(&text).expect("chrome trace parses");
        let evs = back.req_arr("traceEvents").unwrap();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].req_str("ph").unwrap(), "B");
        assert_eq!(evs[1].req_str("ph").unwrap(), "E");
        let args = evs[2].get("args").unwrap();
        assert_eq!(args.req_u64("partition").unwrap(), 3);
        assert_eq!(args.req_u64("steal").unwrap(), 1);
    }

    #[test]
    fn jsonl_every_line_parses() {
        let text = jsonl(&sample_events());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        let header = jsonio::Value::parse(lines[0]).unwrap();
        assert_eq!(header.req_str("schema").unwrap(), SCHEMA);
        for line in &lines[1..] {
            let v = jsonio::Value::parse(line).unwrap();
            assert!(v.req_u64("span").unwrap() >= 1);
        }
    }

    #[test]
    fn export_is_deterministic_for_same_events() {
        let a = chrome_trace(&sample_events()).to_pretty();
        let b = chrome_trace(&sample_events()).to_pretty();
        assert_eq!(a, b);
        assert_eq!(jsonl(&sample_events()), jsonl(&sample_events()));
    }
}
