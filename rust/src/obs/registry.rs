//! Named counter/histogram registry — hand-rolled, no deps.
//!
//! A process-wide [`Registry`] owns named [`crate::par::Counter`]s and
//! log-scale [`Histogram`]s behind `Arc`s, so call sites cache a handle
//! once and then update it with a single relaxed atomic op. `metrics::
//! Meters` / `PeelStats` publish into it as thin views (see
//! `metrics::publish_*`), and `index::server` reads it live for the
//! `METRICS` line-protocol command.

use crate::jsonio::Value;
use crate::par::Counter;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Power-of-two latency histogram: bucket `i` counts samples `v` with
/// `⌊log2 v⌋ = i` (bucket 0 additionally holds `v == 0`). 64 buckets
/// cover the full `u64` nanosecond range with 16 words of state and a
/// branch-free record path — no float math, no allocation.
pub struct Histogram {
    buckets: [AtomicU64; 64],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; 64],
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index for a sample value.
    #[inline]
    fn bucket(v: u64) -> usize {
        (63 - (v | 1).leading_zeros()) as usize
    }

    #[inline]
    pub fn record(&self, v: u64) {
        // ORDERING: Relaxed — monotonic stats; readers tolerate a
        // momentarily torn (count, sum) pair, see `count`/`sum`.
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        // ORDERING: Relaxed — approximate live read; exact once all
        // recorders have quiesced (e.g. after a region barrier).
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> u64 {
        // ORDERING: Relaxed — approximate live read, see `count`.
        self.sum.load(Ordering::Relaxed)
    }

    /// Upper bound (exclusive, power of two) of the highest non-empty
    /// bucket; 0 when empty.
    pub fn max_bound(&self) -> u64 {
        for i in (0..64).rev() {
            // ORDERING: Relaxed — approximate live read, see `count`.
            if self.buckets[i].load(Ordering::Relaxed) > 0 {
                return if i >= 63 { u64::MAX } else { 1u64 << (i + 1) };
            }
        }
        0
    }

    /// `{"count":…,"sum":…,"buckets":[{"pow2":i,"n":…},…]}` with only
    /// non-empty buckets, in ascending order — deterministic for a given
    /// set of samples.
    pub fn to_json(&self) -> Value {
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            // ORDERING: Relaxed — approximate live read, see `count`.
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push(Value::obj().with("pow2", i as u64).with("n", n));
            }
        }
        Value::obj()
            .with("count", self.count())
            .with("sum", self.sum())
            .with("buckets", buckets)
    }
}

#[derive(Default)]
struct Inner {
    counters: Vec<(String, Arc<Counter>)>,
    histograms: Vec<(String, Arc<Histogram>)>,
}

/// Named metric store. Lookup is a short linear scan under a mutex
/// (done once per call site, the handle is then lock-free); snapshots
/// are emitted in sorted-name order for deterministic output.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry (server counters, phase histograms).
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Get-or-create the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.lock();
        if let Some((_, c)) = g.counters.iter().find(|(n, _)| n == name) {
            return Arc::clone(c);
        }
        let c = Arc::new(Counter::new());
        g.counters.push((name.to_string(), Arc::clone(&c)));
        c
    }

    /// Get-or-create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.lock();
        if let Some((_, h)) = g.histograms.iter().find(|(n, _)| n == name) {
            return Arc::clone(h);
        }
        let h = Arc::new(Histogram::new());
        g.histograms.push((name.to_string(), Arc::clone(&h)));
        h
    }

    /// `(name, value)` for every counter, sorted by name.
    pub fn counter_snapshot(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = self
            .lock()
            .counters
            .iter()
            .map(|(n, c)| (n.clone(), c.get()))
            .collect();
        out.sort();
        out
    }

    /// `(name, count, sum, max_bound)` for every histogram, sorted by
    /// name — the per-listener summary the serving layer's v2 `metrics`
    /// verb dumps as `hist <name> count <c> sum <s> max <b>` lines
    /// (reload latencies land here as `server.reload_ns`).
    pub fn histogram_snapshot(&self) -> Vec<(String, u64, u64, u64)> {
        let mut out: Vec<(String, u64, u64, u64)> = self
            .lock()
            .histograms
            .iter()
            .map(|(n, h)| (n.clone(), h.count(), h.sum(), h.max_bound()))
            .collect();
        out.sort();
        out
    }

    /// `{"counters":{…},"histograms":{…}}`, names sorted.
    pub fn to_json(&self) -> Value {
        let mut counters = Value::obj();
        for (n, v) in self.counter_snapshot() {
            counters = counters.with(n.as_str(), v);
        }
        let mut hists: Vec<(String, Value)> = self
            .lock()
            .histograms
            .iter()
            .map(|(n, h)| (n.clone(), h.to_json()))
            .collect();
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        let mut histograms = Value::obj();
        for (n, v) in hists {
            histograms = histograms.with(n.as_str(), v);
        }
        Value::obj().with("counters", counters).with("histograms", histograms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_log2() {
        assert_eq!(Histogram::bucket(0), 0);
        assert_eq!(Histogram::bucket(1), 0);
        assert_eq!(Histogram::bucket(2), 1);
        assert_eq!(Histogram::bucket(3), 1);
        assert_eq!(Histogram::bucket(4), 2);
        assert_eq!(Histogram::bucket(1023), 9);
        assert_eq!(Histogram::bucket(1024), 10);
        assert_eq!(Histogram::bucket(u64::MAX), 63);
    }

    #[test]
    fn histogram_counts_and_sums() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 700, 700, 5000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 6403);
        assert_eq!(h.max_bound(), 8192);
        let j = h.to_json();
        assert_eq!(j.req_u64("count").unwrap(), 6);
        // buckets: pow2 0 holds {0,1}, pow2 1 holds {2}, pow2 9 holds
        // {700,700}, pow2 12 holds {5000}
        let b = j.req_arr("buckets").unwrap();
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn registry_reuses_named_handles() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.add(3);
        b.add(4);
        assert_eq!(r.counter("x").get(), 7);
        let snap = r.counter_snapshot();
        assert_eq!(snap, vec![("x".to_string(), 7)]);
    }

    #[test]
    fn histogram_snapshot_is_sorted_with_summaries() {
        let r = Registry::new();
        r.histogram("z.lat").record(100);
        r.histogram("a.lat").record(3);
        r.histogram("a.lat").record(5);
        let snap = r.histogram_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a.lat");
        assert_eq!((snap[0].1, snap[0].2), (2, 8));
        assert_eq!(snap[1], ("z.lat".to_string(), 1, 100, 128));
    }

    #[test]
    fn registry_json_is_sorted() {
        let r = Registry::new();
        r.counter("zeta").add(1);
        r.counter("alpha").add(2);
        r.histogram("lat").record(100);
        let j = r.to_json();
        let text = j.to_pretty();
        let za = text.find("zeta").unwrap();
        let al = text.find("alpha").unwrap();
        assert!(al < za);
        assert!(text.contains("histograms"));
    }
}
