//! Span tracing + metrics registry (`pbng::obs`).
//!
//! PBNG's thesis is *where the time goes* — synchronization rounds in CD,
//! workload redistribution in FD — yet `metrics::Meters` only reports
//! per-phase totals. This module attributes wall time to individual CD
//! rounds, FD partition tasks (with lane id, steal provenance, and
//! workload), incremental re-peels, and counting kernels, RECEIPT-style
//! (Lakhotia et al.), without perturbing the measured code:
//!
//! * **Disabled path is a branch + nothing.** Every recording call first
//!   loads one relaxed global flag; when tracing is off there is no clock
//!   read, no allocation, and no buffer write, so θ output is byte-
//!   identical with tracing on or off (determinism is engine-guaranteed;
//!   the overhead contract is obs's).
//! * **Per-lane buffers, no cross-lane contention.** Each pool lane owns
//!   a fixed-capacity event buffer written by the thread driving that
//!   lane (workers tag themselves via [`set_lane`] — the `par::pool`
//!   hook; the region caller is lane 0). A one-word per-lane spin lock
//!   guards the slot write; with a single producer per lane — the
//!   production shape — it never spins, so the enabled hot path is one
//!   uncontended swap, a slot write, and a plain length bump (the
//!   lock's `Release` unlock is what publishes both to the next
//!   holder). Full buffers drop new events (counted, never blocking).
//! * **Typed spans.** [`Kind`] enumerates the instrumented operations;
//!   every span carries three kind-specific `u64` attributes (see the
//!   variant docs) plus a process-unique span id that pairs its enter and
//!   exit events even when lanes interleave.
//!
//! Exporters live in [`export`] (Chrome `trace_event` JSON for
//! `chrome://tracing` / Perfetto, and a self-describing JSONL log); the
//! named counter/histogram [`registry`] backs the server `METRICS`
//! command and `Recorder`'s phase-latency histograms.
//!
//! Drain discipline: [`take_events`] and [`clear`] are memory-safe at
//! any time (the per-lane lock), but call them only after the
//! decomposition returns — the pool's region barrier guarantees every
//! worker's spans are complete and visible, so the window holds whole
//! span trees rather than a mid-region cut.

pub mod export;
pub mod registry;

pub use registry::{Histogram, Registry};

use crate::par::RacyCell;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Events a single lane can hold per drain window. At two events per
/// span this covers ~4k spans per lane — far above any decomposition on
/// the bench suites (partitions are capped at 64); overflow increments
/// [`dropped`] instead of blocking or reallocating.
pub const RING_CAP: usize = 1 << 13;

/// What a span measures. The `a`/`b`/`c` attribute meanings are fixed
/// per kind so exports are self-describing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Kind {
    /// One CD synchronization round (Alg. 4 inner iteration).
    /// `a` = partition index, `b` = ρ epoch, `c` = active-set size.
    #[default]
    CdRound,
    /// One FD per-partition peel task (Alg. 5).
    /// `a` = partition id, `b` = workload proxy, `c` = 1 if claimed via
    /// the steal path, 0 if from the lane's own queue.
    FdTask,
    /// One incremental re-peel ([`crate::engine::incremental`]).
    /// `a` = affected entities (component union size), `b` = invalidated
    /// partitions, `c` = 1 if the batch fell back to a full rebuild.
    Repeel,
    /// One counting kernel pass ([`crate::count::pve_bcnt`]).
    /// `a` = entities indexed, `b` = resolved wedge side
    /// ([`crate::count::OrderPolicy::side_code`]: 0 degree / 1 side-U /
    /// 2 side-V), `c` = 1 if the SIMD intersection path is active.
    CountKernel,
}

impl Kind {
    /// Stable export name (also the Chrome-trace event name).
    pub fn name(self) -> &'static str {
        match self {
            Kind::CdRound => "cd_round",
            Kind::FdTask => "fd_task",
            Kind::Repeel => "repeel",
            Kind::CountKernel => "count_kernel",
        }
    }

    /// Chrome-trace category.
    pub fn cat(self) -> &'static str {
        match self {
            Kind::CdRound => "cd",
            Kind::FdTask => "fd",
            Kind::Repeel => "incremental",
            Kind::CountKernel => "count",
        }
    }

    /// Attribute names for `a`/`b`/`c`, in order (export key names).
    pub fn attr_names(self) -> [&'static str; 3] {
        match self {
            Kind::CdRound => ["partition", "rho", "active"],
            Kind::FdTask => ["partition", "workload", "steal"],
            Kind::Repeel => ["affected", "invalidated", "fallback"],
            Kind::CountKernel => ["entities", "side", "simd"],
        }
    }

    pub const ALL: [Kind; 4] = [Kind::CdRound, Kind::FdTask, Kind::Repeel, Kind::CountKernel];
}

/// One enter or exit record. Exit events repeat the span's attributes
/// (possibly updated mid-span via [`Span::set_b`]/[`Span::set_c`]) so a
/// lone half still carries context.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Event {
    /// Nanoseconds since the process-wide trace epoch.
    pub ts_ns: u64,
    /// Process-unique span id pairing enter with exit.
    pub span: u64,
    /// Pool lane that recorded the event (`0` = region caller).
    pub lane: u32,
    pub kind: Kind,
    pub is_exit: bool,
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

struct LaneBuf {
    events: RacyCell<Vec<Event>>,
    /// Number of initialized events. Written and read only under
    /// [`LaneBuf::busy`]; the lock's Acquire/Release pair is what makes
    /// a drain see fully-written slots, so this counter needs no
    /// ordering of its own (it is atomic only so cross-thread access is
    /// defined at all).
    len: AtomicUsize,
    dropped: AtomicU64,
    /// One-word spin lock around buffer access. In production each lane
    /// has exactly one producer (pool workers are pinned to their lane,
    /// the region caller is lane 0), so the swap never spins — but
    /// threads outside the pool all map to lane 0 (e.g. the
    /// multi-threaded `cargo test` harness), and the lock makes their
    /// interleaved writes safe instead of undefined.
    busy: AtomicBool,
}

impl LaneBuf {
    fn new() -> LaneBuf {
        LaneBuf {
            events: RacyCell::new(vec![Event::default(); RING_CAP]),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            busy: AtomicBool::new(false),
        }
    }

    fn lock(&self) {
        // ORDERING: Acquire on the winning swap pairs with the Release
        // store in `unlock`, so every buffer/len write of the previous
        // lock holder happens-before our access.
        while self.busy.swap(true, Ordering::Acquire) {
            std::hint::spin_loop();
        }
    }

    fn unlock(&self) {
        // ORDERING: Release publishes all buffer/len writes made under
        // the lock to the next Acquire winner in `lock`.
        self.busy.store(false, Ordering::Release);
    }

    fn push(&self, ev: Event) {
        self.lock();
        // ORDERING: Relaxed — `len` is only accessed under the lock;
        // the lock's Acquire/Release pair is the synchronization.
        let n = self.len.load(Ordering::Relaxed);
        if n >= RING_CAP {
            // ORDERING: Relaxed — monotonic stat, read approximately.
            self.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            // SAFETY: the per-lane lock gives this thread exclusive
            // access to the buffer for the duration of the write (the
            // guard is a temporary, dropped before `unlock`).
            unsafe { self.events.get_mut() }[n] = ev;
            // ORDERING: Relaxed — lock-protected; `unlock` publishes it.
            self.len.store(n + 1, Ordering::Relaxed);
        }
        self.unlock();
    }

    fn drain_into(&self, out: &mut Vec<Event>) {
        self.lock();
        // ORDERING: Relaxed — lock-protected, see `push`.
        let n = self.len.load(Ordering::Relaxed).min(RING_CAP);
        {
            // SAFETY: the per-lane lock excludes concurrent producers;
            // the guard is dropped before `unlock` releases the lock.
            let evs = unsafe { self.events.get_mut() };
            out.extend_from_slice(&evs[..n]);
        }
        // ORDERING: Relaxed — lock-protected; `unlock` publishes it.
        self.len.store(0, Ordering::Relaxed);
        self.unlock();
    }

    fn copy_into(&self, out: &mut Vec<Event>) {
        self.lock();
        // ORDERING: Relaxed — lock-protected, see `push`.
        let n = self.len.load(Ordering::Relaxed).min(RING_CAP);
        {
            // SAFETY: the per-lane lock excludes concurrent producers;
            // the guard is dropped before `unlock` releases the lock.
            let evs = unsafe { self.events.get_mut() };
            out.extend_from_slice(&evs[..n]);
        }
        self.unlock();
    }
}

struct Buffers {
    lanes: Vec<LaneBuf>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static BUFFERS: OnceLock<Buffers> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Pool lane driven by this thread; set once per worker by the
    /// `par::pool` spawn hook, 0 for every other thread.
    static LANE: Cell<usize> = const { Cell::new(0) };
}

/// Is tracing on? One relaxed load — the entirety of the disabled path.
#[inline]
pub fn enabled() -> bool {
    // ORDERING: Relaxed — a standalone on/off flag; callers that then
    // record go through the per-lane lock, which orders buffer access.
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on: allocates the per-lane buffers (sized to the pool
/// capacity) on first use, clears any previous window, and resets the
/// span-id counter so single-threaded traces are bit-reproducible
/// modulo timestamps.
pub fn enable() {
    let cap = crate::par::pool_capacity();
    BUFFERS.get_or_init(|| Buffers {
        lanes: (0..cap.max(1)).map(|_| LaneBuf::new()).collect(),
    });
    let _ = EPOCH.get_or_init(Instant::now);
    clear();
    // ORDERING: Relaxed on both — enable() is called before the traced
    // region starts; the pool's region barrier (not these stores)
    // publishes the reset to workers.
    NEXT_SPAN.store(1, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn tracing off. Already-buffered events stay until [`take_events`]
/// or [`clear`].
pub fn disable() {
    // ORDERING: Relaxed — see `enabled`; callers drain only after the
    // region barrier, which is the real synchronization point.
    ENABLED.store(false, Ordering::Relaxed);
}

/// `par::pool` lane hook: workers call this once at spawn so their
/// events land in their own lane buffer.
pub fn set_lane(lane: usize) {
    LANE.with(|l| l.set(lane));
}

fn current_lane() -> usize {
    LANE.with(|l| l.get())
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn push(ev: Event) {
    if let Some(bufs) = BUFFERS.get() {
        let lane = (ev.lane as usize).min(bufs.lanes.len() - 1);
        bufs.lanes[lane].push(ev);
    }
}

/// RAII span: records an enter event now and the matching exit event on
/// drop. When tracing is disabled this is an inert zero-field struct —
/// constructing and dropping it costs one relaxed load and a branch.
pub struct Span {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    kind: Kind,
    span: u64,
    a: u64,
    b: u64,
    c: u64,
}

/// Open a span of `kind` with attributes `(a, b, c)` (meanings fixed per
/// [`Kind`]).
#[inline]
pub fn span(kind: Kind, a: u64, b: u64, c: u64) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    // ORDERING: Relaxed — the RMW is atomic, so ids are unique; nothing
    // else is ordered against the id allocation.
    let id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
    push(Event {
        ts_ns: now_ns(),
        span: id,
        lane: current_lane() as u32,
        kind,
        is_exit: false,
        a,
        b,
        c,
    });
    Span {
        live: Some(LiveSpan { kind, span: id, a, b, c }),
    }
}

impl Span {
    /// Update attribute `b` before the exit event is recorded.
    pub fn set_b(&mut self, v: u64) {
        if let Some(l) = &mut self.live {
            l.b = v;
        }
    }

    /// Update attribute `c` before the exit event is recorded.
    pub fn set_c(&mut self, v: u64) {
        if let Some(l) = &mut self.live {
            l.c = v;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(l) = self.live.take() {
            push(Event {
                ts_ns: now_ns(),
                span: l.span,
                lane: current_lane() as u32,
                kind: l.kind,
                is_exit: true,
                a: l.a,
                b: l.b,
                c: l.c,
            });
        }
    }
}

/// Drain every lane buffer into one list ordered by `(ts_ns, span,
/// is_exit)`. Must not race an in-flight region (see module docs).
pub fn take_events() -> Vec<Event> {
    let mut out = Vec::new();
    if let Some(bufs) = BUFFERS.get() {
        for lane in &bufs.lanes {
            lane.drain_into(&mut out);
        }
    }
    out.sort_by_key(|e| (e.ts_ns, e.span, e.is_exit));
    out
}

/// Copy every buffered event without draining, same order as
/// [`take_events`]. Must not race an in-flight region (see module docs).
/// Used where a reader wants a mid-stream view that leaves the window
/// intact for a later exporter (e.g. the bench runner's balance summary
/// under an outer `--trace`).
pub fn snapshot_events() -> Vec<Event> {
    let mut out = Vec::new();
    if let Some(bufs) = BUFFERS.get() {
        for lane in &bufs.lanes {
            lane.copy_into(&mut out);
        }
    }
    out.sort_by_key(|e| (e.ts_ns, e.span, e.is_exit));
    out
}

/// Discard buffered events and reset the overflow counter.
pub fn clear() {
    if let Some(bufs) = BUFFERS.get() {
        for lane in &bufs.lanes {
            let mut sink = Vec::new();
            lane.drain_into(&mut sink);
            // ORDERING: Relaxed — monotonic stat reset, approximate.
            lane.dropped.store(0, Ordering::Relaxed);
        }
    }
}

/// Events discarded because a lane buffer filled up since the last
/// [`clear`]/[`enable`].
pub fn dropped() -> u64 {
    let Some(bufs) = BUFFERS.get() else { return 0 };
    // ORDERING: Relaxed — approximate stat; exact only after the region
    // barrier, which already orders the producers' writes.
    bufs.lanes.iter().map(|l| l.dropped.load(Ordering::Relaxed)).sum()
}

/// Number of per-lane buffers (0 until tracing is first enabled). Every
/// recorded `Event.lane` is strictly below this.
pub fn lane_count() -> usize {
    BUFFERS.get().map(|b| b.lanes.len()).unwrap_or(0)
}

/// Serialize unit tests (across this crate's modules) that enable or
/// assert on the global tracing window — `cargo test` runs tests
/// concurrently in one process, and two overlapping windows would
/// cross-contaminate.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<std::sync::Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Validate span-tree well-formedness: every span id has exactly one
/// enter and one exit, kinds match, exit does not precede enter, and
/// every lane id is within the buffer range.
pub fn check_spans(events: &[Event]) -> Result<(), String> {
    let lanes = lane_count();
    let mut open: std::collections::HashMap<u64, Event> = std::collections::HashMap::new();
    for e in events {
        if lanes > 0 && e.lane as usize >= lanes {
            return Err(format!("event lane {} out of range (< {lanes})", e.lane));
        }
        if !e.is_exit {
            if open.insert(e.span, *e).is_some() {
                return Err(format!("span {} entered twice", e.span));
            }
        } else {
            let enter = open
                .remove(&e.span)
                .ok_or_else(|| format!("span {} exited without an enter", e.span))?;
            if enter.kind != e.kind {
                return Err(format!(
                    "span {} kind mismatch: enter {:?} vs exit {:?}",
                    e.span, enter.kind, e.kind
                ));
            }
            if e.ts_ns < enter.ts_ns {
                return Err(format!("span {} exits before it enters", e.span));
            }
        }
    }
    if let Some(id) = open.keys().min() {
        return Err(format!("span {id} never exited"));
    }
    Ok(())
}

/// Paired (enter, exit) events per completed span, in enter order.
/// Unpaired halves (dropped under overflow) are skipped.
pub fn pair_spans(events: &[Event]) -> Vec<(Event, Event)> {
    let mut open: std::collections::HashMap<u64, Event> = std::collections::HashMap::new();
    let mut pairs = Vec::new();
    for e in events {
        if !e.is_exit {
            open.insert(e.span, *e);
        } else if let Some(enter) = open.remove(&e.span) {
            pairs.push((enter, *e));
        }
    }
    pairs.sort_by_key(|(en, _)| (en.ts_ns, en.span));
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(span: u64, kind: Kind, is_exit: bool, ts: u64) -> Event {
        Event {
            ts_ns: ts,
            span,
            lane: 0,
            kind,
            is_exit,
            a: 0,
            b: 0,
            c: 0,
        }
    }

    #[test]
    fn check_spans_accepts_matched_pairs() {
        let evs = vec![
            ev(1, Kind::CdRound, false, 0),
            ev(2, Kind::FdTask, false, 1),
            ev(2, Kind::FdTask, true, 5),
            ev(1, Kind::CdRound, true, 9),
        ];
        assert!(check_spans(&evs).is_ok());
        assert_eq!(pair_spans(&evs).len(), 2);
    }

    #[test]
    fn check_spans_rejects_unbalanced() {
        let evs = vec![ev(1, Kind::CdRound, false, 0)];
        assert!(check_spans(&evs).is_err());
        let evs = vec![ev(1, Kind::CdRound, true, 0)];
        assert!(check_spans(&evs).is_err());
    }

    #[test]
    fn check_spans_rejects_kind_mismatch() {
        let evs = vec![ev(3, Kind::CdRound, false, 0), ev(3, Kind::FdTask, true, 1)];
        assert!(check_spans(&evs).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // fills an 8k-event ring — too slow under Miri
    fn lane_buf_drops_on_overflow() {
        let b = LaneBuf::new();
        for i in 0..(RING_CAP as u64 + 10) {
            b.push(ev(i, Kind::FdTask, false, i));
        }
        assert_eq!(b.len.load(Ordering::Relaxed), RING_CAP);
        assert_eq!(b.dropped.load(Ordering::Relaxed), 10);
        let mut out = Vec::new();
        b.drain_into(&mut out);
        assert_eq!(out.len(), RING_CAP);
        assert_eq!(b.len.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn disabled_span_is_inert() {
        let _g = test_guard();
        // Construct/drop a span with tracing off: must not touch buffers.
        disable();
        assert!(!enabled());
        let before = BUFFERS.get().map(|b| {
            b.lanes
                .iter()
                .map(|l| l.len.load(Ordering::Relaxed))
                .sum::<usize>()
        });
        {
            let mut s = span(Kind::FdTask, 1, 2, 3);
            s.set_c(9);
        }
        let after = BUFFERS.get().map(|b| {
            b.lanes
                .iter()
                .map(|l| l.len.load(Ordering::Relaxed))
                .sum::<usize>()
        });
        assert_eq!(before, after);
    }

    #[test]
    fn kind_names_are_stable() {
        for k in Kind::ALL {
            assert!(!k.name().is_empty());
            assert_eq!(k.attr_names().len(), 3);
        }
    }
}
