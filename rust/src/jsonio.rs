//! Dependency-free JSON tree: stable-order writer + strict parser.
//!
//! The offline registry carries no `serde`; the bench subsystem
//! ([`crate::bench`]) needs machine-readable reports that CI can diff
//! against a committed baseline, so this module supplies the minimal JSON
//! kernel: a [`Value`] tree, a pretty-printer with deterministic member
//! order (insertion order — writers control the byte layout), and a
//! recursive-descent parser for `pbng bench compare`.
//!
//! Numbers: non-negative integer literals parse to [`Value::Int`] (exact
//! `u64` — counter metrics must not round-trip through `f64`); signed or
//! fractional literals parse to [`Value::Num`]. Writers emit counters as
//! `Int` and wall times as `Num`.

use anyhow::{bail, Context, Result};

/// Parse recursion cap: reports nest ~4 deep, so 128 is generous while
/// keeping a malformed file from overflowing the stack.
const MAX_DEPTH: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Exact non-negative integer (counters, checksums).
    Int(u64),
    /// Any other number.
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered members, preserved by the writer.
    Obj(Vec<(String, Value)>),
}

impl From<bool> for Value {
    fn from(x: bool) -> Value {
        Value::Bool(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Value {
        Value::Int(x)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Value {
        Value::Int(x as u64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Int(x as u64)
    }
}
impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Value {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Value {
        Value::Str(x)
    }
}
impl From<Vec<Value>> for Value {
    fn from(x: Vec<Value>) -> Value {
        Value::Arr(x)
    }
}

impl Value {
    /// Empty object, for use with [`Value::with`].
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Builder-style member append (panics on non-objects — writer-side
    /// misuse, not data-dependent).
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(kv) => kv.push((key.to_string(), v.into())),
            _ => panic!("Value::with on a non-object"),
        }
        self
    }

    /// Object member lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(x) => Some(*x as f64),
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Typed member getters with path context for error messages.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .with_context(|| format!("missing member '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .with_context(|| format!("member '{key}' is not an unsigned integer"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .with_context(|| format!("member '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .with_context(|| format!("member '{key}' is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .with_context(|| format!("member '{key}' is not an array"))
    }

    /// Serialize with 2-space indentation and a trailing newline.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(x) => out.push_str(&x.to_string()),
            Value::Num(x) => {
                if x.is_finite() {
                    out.push_str(&x.to_string());
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_string(out, s),
            Value::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    x.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Value::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_string(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Strict parse of a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value(0)?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn eat_lit(&mut self, lit: &str) -> bool {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting exceeds {MAX_DEPTH} levels");
        }
        self.ws();
        let Some(c) = self.peek() else {
            bail!("unexpected end of input")
        };
        match c {
            b'n' | b't' | b'f' => {
                for (lit, v) in [
                    ("null", Value::Null),
                    ("true", Value::Bool(true)),
                    ("false", Value::Bool(false)),
                ] {
                    if self.eat_lit(lit) {
                        return Ok(v);
                    }
                }
                bail!("bad literal at byte {}", self.i)
            }
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            c if c == b'-' || c.is_ascii_digit() => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            xs.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(xs));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(kv));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.i;
            while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                self.i += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.b[start..self.i]).context("invalid UTF-8 in string")?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().context("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape at byte {}", self.i);
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .with_context(|| format!("bad \\u escape at byte {}", self.i))?;
                            self.i += 4;
                            // Surrogate pairs are not produced by our
                            // writer; map lone surrogates to U+FFFD.
                            s.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("unknown escape '\\{}' at byte {}", c as char, self.i),
                    }
                }
                _ => bail!("unterminated string at byte {}", self.i),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        let mut integral = true;
        if self.peek() == Some(b'-') {
            integral = false;
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    integral = false;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if integral {
            if let Ok(x) = text.parse::<u64>() {
                return Ok(Value::Int(x));
            }
        }
        let x: f64 = text
            .parse()
            .with_context(|| format!("bad number '{text}' at byte {start}"))?;
        Ok(Value::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_structure_and_order() {
        let v = Value::obj()
            .with("b", 1u64)
            .with("a", "x")
            .with("list", vec![Value::Int(1), Value::Num(2.5), Value::Null])
            .with("nested", Value::obj().with("flag", true));
        let text = v.to_pretty();
        let back = Value::parse(&text).unwrap();
        assert_eq!(v, back);
        // insertion order is the byte order: "b" precedes "a"
        assert!(text.find("\"b\"").unwrap() < text.find("\"a\"").unwrap());
    }

    #[test]
    fn u64_counters_are_exact() {
        let v = Value::obj().with("fnv", u64::MAX);
        let back = Value::parse(&v.to_pretty()).unwrap();
        assert_eq!(back.req_u64("fnv").unwrap(), u64::MAX);
    }

    #[test]
    fn serialization_is_deterministic() {
        let v = Value::obj().with("x", 3u64).with("y", 1.25f64);
        assert_eq!(v.to_pretty(), v.to_pretty());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote \" slash \\ newline \n tab \t end";
        let v = Value::obj().with("s", s);
        let back = Value::parse(&v.to_pretty()).unwrap();
        assert_eq!(back.req_str("s").unwrap(), s);
    }

    #[test]
    fn parse_errors_are_rejected() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "tru",
            "{\"a\":1} extra",
            "\"unterminated",
        ] {
            assert!(Value::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn negative_and_fractional_are_num() {
        assert_eq!(Value::parse("-3").unwrap(), Value::Num(-3.0));
        assert_eq!(Value::parse("2.5").unwrap(), Value::Num(2.5));
        assert_eq!(Value::parse("7").unwrap(), Value::Int(7));
    }

    #[test]
    fn deep_nesting_is_capped() {
        let text = format!("{}1{}", "[".repeat(300), "]".repeat(300));
        assert!(Value::parse(&text).is_err());
    }

    #[test]
    fn typed_getters_report_the_key() {
        let v = Value::obj().with("n", 1u64);
        let err = v.req_u64("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        let err = v.req_str("n").unwrap_err().to_string();
        assert!(err.contains("n"), "{err}");
    }
}
