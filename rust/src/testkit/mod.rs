//! Deterministic RNG + randomized-property harness.
//!
//! The offline registry has neither `rand` nor `proptest`, so this module
//! supplies (a) a splitmix64 PRNG (Steele et al., public domain algorithm)
//! and (b) a tiny property-test runner that sweeps seeds and reports the
//! failing seed so any counterexample is reproducible with
//! `Rng::new(seed)`, plus (c) a self-cleaning [`TempDir`] (no `tempfile`
//! crate) for codec and bench-report I/O tests.

/// SplitMix64: tiny, fast, statistically solid for test-data generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free approximation is fine for
        // tests, but keep it exact with rejection sampling.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Inclusive range.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Zipf-ish rank sampling: returns rank in [0, n) with P(r) ∝ (r+1)^-s
    /// via inverse-CDF over a precomputed table — callers should prefer
    /// `ZipfSampler` for repeated draws.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed Zipf(α) sampler over ranks [0, n).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        // binary search for first cdf >= x
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Unique self-cleaning temp directory for tests that exercise file I/O
/// (index codec round trips, bench report save/load). Directories are
/// disambiguated by pid + a process-wide sequence number so parallel test
/// threads and concurrent `cargo test` invocations never collide.
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> std::io::Result<TempDir> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "pbng-{label}-{}-{seq}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    pub fn file(&self, name: &str) -> std::path::PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Run `prop(seed)` for `cases` seeds derived from `base_seed`; panic with
/// the reproducing seed on the first failure (returned as Err(msg)).
pub fn check_property<F>(name: &str, base_seed: u64, cases: u64, prop: F)
where
    F: Fn(u64) -> Result<(), String>,
{
    let mut meta = Rng::new(base_seed);
    for case in 0..cases {
        let seed = meta.next_u64();
        if let Err(msg) = prop(seed) {
            panic!(
                "property '{name}' failed on case {case} (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Validate a Chrome `trace_event` JSON document (the object flavour
/// [`crate::obs::export::chrome_trace`] emits): `traceEvents` must be an
/// array of events each carrying `name`/`cat`/`ph`/`ts`/`pid`/`tid`, and
/// every `ph: "B"` must have a matching `"E"` (paired through
/// `args.span`). Backs `pbng trace --verify` and the CI trace-smoke step.
pub fn check_trace_json(text: &str) -> Result<(), String> {
    let v = crate::jsonio::Value::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = v
        .req_arr("traceEvents")
        .map_err(|e| format!("missing traceEvents array: {e}"))?;
    let mut open: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for (i, e) in events.iter().enumerate() {
        let ctx = |err: anyhow::Error| format!("traceEvents[{i}]: {err}");
        e.req_str("name").map_err(ctx)?;
        e.req_str("cat").map_err(ctx)?;
        e.req_f64("ts").map_err(ctx)?;
        e.req_u64("pid").map_err(ctx)?;
        e.req_u64("tid").map_err(ctx)?;
        let ph = e.req_str("ph").map_err(ctx)?;
        let span = e
            .get("args")
            .and_then(|a| a.req_u64("span").ok())
            .ok_or_else(|| format!("traceEvents[{i}] missing args.span"))?;
        match ph {
            "B" => {
                if !open.insert(span) {
                    return Err(format!("span {span} opened twice"));
                }
            }
            "E" => {
                if !open.remove(&span) {
                    return Err(format!("span {span} closed without opening"));
                }
            }
            other => return Err(format!("traceEvents[{i}] has ph '{other}' (want B or E)")),
        }
    }
    if let Some(span) = open.iter().min() {
        return Err(format!("span {span} never closed"));
    }
    Ok(())
}

/// Validate a JSONL trace ([`crate::obs::export::jsonl`]): a schema
/// header line followed by one parseable JSON object per event.
pub fn check_trace_jsonl(text: &str) -> Result<(), String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines.next().ok_or("empty trace")?;
    let h = crate::jsonio::Value::parse(header).map_err(|e| format!("bad header: {e}"))?;
    h.req_str("schema").map_err(|e| format!("header missing schema: {e}"))?;
    for (i, line) in lines {
        let v = crate::jsonio::Value::parse(line)
            .map_err(|e| format!("line {}: not valid JSON: {e}", i + 1))?;
        for key in ["ts_ns", "span", "lane", "a", "b", "c"] {
            v.req_u64(key).map_err(|e| format!("line {}: {e}", i + 1))?;
        }
        v.req_str("kind").map_err(|e| format!("line {}: {e}", i + 1))?;
        let phase = v.req_str("phase").map_err(|e| format!("line {}: {e}", i + 1))?;
        if phase != "enter" && phase != "exit" {
            return Err(format!("line {}: phase '{phase}' (want enter|exit)", i + 1));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let z = ZipfSampler::new(1000, 1.5);
        let mut r = Rng::new(11);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-10 ranks should absorb a large fraction of mass at alpha=1.5
        assert!(head > n / 4, "zipf head mass too small: {head}/{n}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut xs: Vec<u32> = (0..100).collect();
        let mut r = Rng::new(5);
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn temp_dir_is_unique_and_cleaned() {
        let a = TempDir::new("unit").unwrap();
        let b = TempDir::new("unit").unwrap();
        assert_ne!(a.path(), b.path());
        let f = a.file("x.txt");
        std::fs::write(&f, b"hi").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists());
        assert!(b.path().exists());
    }

    #[test]
    fn check_property_passes() {
        check_property("trivial", 1, 16, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_property_reports_seed() {
        check_property("always-fails", 1, 4, |_| Err("boom".into()));
    }

    fn sample_trace_events() -> Vec<crate::obs::Event> {
        use crate::obs::{Event, Kind};
        let ev = |span, is_exit, ts| Event {
            ts_ns: ts,
            span,
            lane: 0,
            kind: Kind::FdTask,
            is_exit,
            a: 1,
            b: 2,
            c: 0,
        };
        vec![ev(1, false, 10), ev(2, false, 20), ev(2, true, 30), ev(1, true, 40)]
    }

    #[test]
    fn trace_checker_accepts_exporter_output() {
        let evs = sample_trace_events();
        let chrome = crate::obs::export::chrome_trace(&evs).to_pretty();
        check_trace_json(&chrome).unwrap();
        let jsonl = crate::obs::export::jsonl(&evs);
        check_trace_jsonl(&jsonl).unwrap();
    }

    #[test]
    fn trace_checker_rejects_malformed() {
        assert!(check_trace_json("not json").is_err());
        assert!(check_trace_json("{\"other\": 1}").is_err());
        // drop the closing E of span 1: unbalanced
        let mut evs = sample_trace_events();
        evs.pop();
        let chrome = crate::obs::export::chrome_trace(&evs).to_pretty();
        assert!(check_trace_json(&chrome).unwrap_err().contains("never closed"));
        assert!(check_trace_jsonl("").is_err());
        assert!(check_trace_jsonl("{\"schema\":\"x\"}\nnot json\n").is_err());
    }
}
