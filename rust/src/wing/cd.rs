//! PBNG Coarse-grained Decomposition for wing decomposition (Alg. 4).
//!
//! Divides `E(G)` into `P` partitions by iteratively peeling, in
//! parallel, *every* edge whose support falls in the current range
//! `[θ(i), θ(i+1))`. Each parallel iteration peels a large set (little
//! synchronization — the ρ reduction that is the paper's core claim) and
//! uses the Alg. 6 batch engine with twin conflict resolution.
//!
//! Outputs per-edge partition assignments, the support-initialization
//! vector ⋈init (supports snapshotted when each partition starts — i.e.
//! the cumulative effect of peeling all lower partitions), and the range
//! bounds.

use super::range::{find_range, AdaptiveTarget};
use super::state::{peel_set_batch, peel_set_single, WingState};
use crate::beindex::BeIndex;
use crate::metrics::Meters;

#[derive(Clone, Copy, Debug)]
pub struct CdConfig {
    /// Number of partitions P.
    pub p: usize,
    pub threads: usize,
    /// Batch optimization (§5.1); off = PBNG−− ablation.
    pub batch: bool,
    /// Dynamic BE-Index updates (§5.2); off = PBNG− ablation.
    pub dynamic_deletes: bool,
}

impl Default for CdConfig {
    fn default() -> Self {
        CdConfig {
            p: 64,
            threads: crate::par::default_threads(),
            batch: true,
            dynamic_deletes: true,
        }
    }
}

#[derive(Debug)]
pub struct CdOutput {
    /// Partition index per edge.
    pub part_of: Vec<u32>,
    /// ⋈init per edge: support after all lower partitions were peeled.
    pub sup_init: Vec<u64>,
    /// Lower bound θ(i) per partition (`lowers[i] ≤ θ_e < lowers[i+1]`
    /// for e ∈ E_i; the last upper bound is implicit/unbounded).
    pub lowers: Vec<u64>,
    /// Number of partitions actually created.
    pub n_parts: usize,
}

pub fn coarse_decompose(
    idx: &BeIndex,
    per_edge: &[u64],
    cfg: CdConfig,
    meters: &Meters,
) -> CdOutput {
    let m = per_edge.len();
    let st = WingState::new(idx, per_edge, cfg.dynamic_deletes);
    let mut part_of = vec![u32::MAX; m];
    let mut sup_init = vec![0u64; m];
    let mut lowers = Vec::new();
    let mut remaining = m;
    let mut epoch = 0u32;
    let mut lower = 0u64;
    let mut adaptive = AdaptiveTarget::new(cfg.p);
    let mut i = 0usize;

    while remaining > 0 {
        // Snapshot ⋈init for alive edges (Alg. 4 lines 6–7).
        // (Also used for FD workload estimation.)
        let mut remaining_work = 0u64;
        for e in 0..m {
            if st.is_alive(e as u32) {
                let s = st.sup[e].get();
                sup_init[e] = s;
                remaining_work += s;
            }
        }
        // Range upper bound.
        let is_last = i + 1 >= cfg.p;
        let (upper, initial_estimate) = if is_last {
            (u64::MAX, remaining_work)
        } else {
            let tgt = adaptive.target(remaining_work);
            let r = find_range(
                (0..m as u32)
                    .filter(|&e| st.is_alive(e))
                    .map(|e| {
                        let s = st.sup[e as usize].get();
                        (s, s.max(1))
                    }),
                tgt.max(1),
            );
            (r.upper.max(lower + 1), r.initial_estimate)
        };
        lowers.push(lower);

        // Initial active set: all alive edges with support < upper.
        let mut active: Vec<u32> = (0..m as u32)
            .filter(|&e| st.is_alive(e) && st.sup[e as usize].get() < upper)
            .collect();
        let mut partition_work = 0u64;

        while !active.is_empty() {
            meters.rho.add(1);
            epoch += 1;
            for &e in &active {
                part_of[e as usize] = i as u32;
                partition_work += sup_init[e as usize];
            }
            remaining -= active.len();
            let touched = if cfg.batch {
                st.mark_peeled(&active, epoch, cfg.threads);
                peel_set_batch(&st, &active, lower, epoch, cfg.threads, meters)
            } else {
                peel_set_single(&st, &active, lower, epoch, meters)
            };
            // next frontier: live edges that dropped under the bound
            let mut next = touched;
            next.sort_unstable();
            next.dedup();
            next.retain(|&e| st.is_alive(e) && st.sup[e as usize].get() < upper);
            active = next;
        }

        adaptive.record(initial_estimate, partition_work.max(1));
        lower = upper;
        i += 1;
        if is_last {
            break;
        }
    }
    debug_assert_eq!(remaining, 0, "all edges must be assigned");
    CdOutput {
        part_of,
        sup_init,
        lowers,
        n_parts: i,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;

    fn run_cd(g: &crate::graph::BipartiteGraph, p: usize) -> (CdOutput, Vec<u64>) {
        let (idx, per_edge) = BeIndex::build(g, 1);
        let meters = Meters::new();
        let out = coarse_decompose(
            &idx,
            &per_edge,
            CdConfig {
                p,
                threads: 2,
                batch: true,
                dynamic_deletes: true,
            },
            &meters,
        );
        (out, per_edge)
    }

    /// Theorem 1: partitions bracket the true wing numbers.
    #[test]
    fn partitions_bracket_wing_numbers() {
        crate::testkit::check_property("cd-brackets-theta", 0xCD1, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(12),
                6 + rng.usize_below(12),
                20 + rng.usize_below(60),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let theta = wing_bup(&g).theta;
            let p = 1 + rng.usize_below(5);
            let (out, _) = run_cd(&g, p);
            for e in 0..g.m() {
                let i = out.part_of[e] as usize;
                let lo = out.lowers[i];
                let hi = out
                    .lowers
                    .get(i + 1)
                    .copied()
                    .unwrap_or(u64::MAX);
                if theta[e] < lo || theta[e] >= hi {
                    return Err(format!(
                        "edge {e}: θ={} outside partition {i} range [{lo},{hi})",
                        theta[e]
                    ));
                }
            }
            Ok(())
        });
    }

    /// ⋈init must equal the butterfly count of e restricted to its own and
    /// higher partitions (§3.1.1).
    #[test]
    fn sup_init_counts_higher_universe() {
        crate::testkit::check_property("cd-supinit", 0xCD2, 6, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(10),
                6 + rng.usize_below(10),
                20 + rng.usize_below(50),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let (out, _) = run_cd(&g, 3);
            for i in 0..out.n_parts as u32 {
                // alive = edges in partitions >= i
                let alive: Vec<bool> = (0..g.m())
                    .map(|e| out.part_of[e] >= i)
                    .collect();
                let oracle = crate::count::brute::edge_support_restricted(&g, &alive);
                for e in 0..g.m() {
                    if out.part_of[e] == i && out.sup_init[e] != oracle[e] {
                        return Err(format!(
                            "edge {e} (part {i}): sup_init={} oracle={}",
                            out.sup_init[e], oracle[e]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_partition_assigns_everything_to_zero() {
        let g = gen::biclique(3, 3);
        let (out, _) = run_cd(&g, 1);
        assert!(out.part_of.iter().all(|&p| p == 0));
        assert_eq!(out.n_parts, 1);
    }

    #[test]
    fn respects_partition_budget() {
        let g = gen::zipf(60, 60, 400, 1.2, 1.2, 5);
        let (out, _) = run_cd(&g, 8);
        assert!(out.n_parts <= 8);
        assert!(out.part_of.iter().all(|&p| (p as usize) < out.n_parts));
    }

    #[test]
    fn batch_and_single_produce_same_partitions() {
        let g = gen::zipf(40, 40, 250, 1.2, 1.2, 9);
        let (idx, per_edge) = BeIndex::build(&g, 1);
        let meters = Meters::new();
        let a = coarse_decompose(
            &idx,
            &per_edge,
            CdConfig { p: 4, threads: 2, batch: true, dynamic_deletes: true },
            &meters,
        );
        let b = coarse_decompose(
            &idx,
            &per_edge,
            CdConfig { p: 4, threads: 1, batch: false, dynamic_deletes: false },
            &meters,
        );
        assert_eq!(a.part_of, b.part_of);
        assert_eq!(a.sup_init, b.sup_init);
    }

    #[test]
    fn rho_is_much_less_than_m_with_wide_ranges() {
        let g = gen::zipf(80, 80, 600, 1.2, 1.2, 11);
        let (idx, per_edge) = BeIndex::build(&g, 1);
        let meters = Meters::new();
        coarse_decompose(&idx, &per_edge, CdConfig { p: 4, ..Default::default() }, &meters);
        assert!(
            meters.rho.get() < g.m() as u64 / 4,
            "rho {} not << m {}",
            meters.rho.get(),
            g.m()
        );
    }
}
