//! PBNG Fine-grained Decomposition for wing decomposition (Alg. 5).
//!
//! Each partition `E_i`, together with its partitioned BE-Index `I_i`
//! (bloom numbers adjusted to the `≥ i` universe), is peeled by
//! sequential bottom-up peeling *independently* of all other partitions —
//! supports are initialized from ⋈init, so no cross-partition updates are
//! needed and **no global synchronization** happens: partitions are
//! dynamically pulled off a workload-sorted task queue (LPT, §3.1.4) by
//! the persistent runtime pool's lanes ([`crate::par::spmd`] — no thread
//! spawning here either).

use crate::beindex::partition::{PartIndex, Partitioned};
use crate::metrics::Meters;
use crate::par::{spmd, RacyCell};
use crate::peel::BucketQueue;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug)]
pub struct FdConfig {
    pub threads: usize,
    /// Dynamic link deletion (§5.2); off = PBNG− ablation.
    pub dynamic_deletes: bool,
}

/// Peel all partitions; returns θ per (global) edge.
pub fn fine_decompose(
    pt: &mut Partitioned,
    part_of: &[u32],
    sup_init: &[u64],
    lowers: &[u64],
    cfg: FdConfig,
    meters: &Meters,
) -> Vec<u64> {
    let m = part_of.len();
    let p = pt.parts.len();

    // LPT order: workload indicator = Σ ⋈init over the partition's edges
    // (Alg. 5 line 4).
    let mut order: Vec<usize> = (0..p).collect();
    let work: Vec<u64> = (0..p)
        .map(|i| pt.edges_of[i].iter().map(|&e| sup_init[e as usize]).sum())
        .collect();
    order.sort_unstable_by(|&a, &b| work[b].cmp(&work[a]));

    // Wrap each partition for exclusive hand-off to one worker.
    let parts: Vec<Mutex<&mut PartIndex>> = pt.parts.iter_mut().map(Mutex::new).collect();
    let theta_cell = RacyCell::new(vec![0u64; m]);
    let next_task = AtomicUsize::new(0);

    spmd(cfg.threads.max(1), |_| loop {
        let t = next_task.fetch_add(1, Ordering::Relaxed);
        if t >= p {
            break;
        }
        let i = order[t];
        let mut part = parts[i].lock().unwrap();
        // SAFETY: partitions are disjoint edge sets; each θ slot is
        // written only by this partition's owner.
        let theta = unsafe { theta_cell.get_mut() };
        let lo = lowers.get(i).copied().unwrap_or(0);
        let hi = lowers.get(i + 1).copied().unwrap_or(u64::MAX);
        peel_partition(
            i as u32,
            &mut part,
            &pt.edges_of[i],
            &pt.local_of,
            part_of,
            sup_init,
            (lo, hi),
            theta,
            cfg.dynamic_deletes,
            meters,
        );
    });
    theta_cell.into_inner()
}

/// Sequential bottom-up peel of one partition over its own BE-Index.
#[allow(clippy::too_many_arguments)]
fn peel_partition(
    part_id: u32,
    idx: &mut PartIndex,
    edges: &[u32],
    local_of: &[u32],
    part_of: &[u32],
    sup_init: &[u64],
    (range_lo, range_hi): (u64, u64),
    theta: &mut [u64],
    dynamic_deletes: bool,
    meters: &Meters,
) {
    let n = edges.len();
    if n == 0 {
        return;
    }
    let mut sup: Vec<u64> = edges.iter().map(|&e| sup_init[e as usize]).collect();
    let mut peeled = vec![false; n];
    let mut bloom_len: Vec<u32> = (0..idx.n_blooms())
        .map(|b| (idx.bloom_offs[b + 1] - idx.bloom_offs[b]) as u32)
        .collect();
    // Clamped bucket queue over the partition's range (Theorem 1): θs
    // assigned here fall in [range_lo, range_hi), so exact ordering is
    // only needed below range_hi. For the last (unbounded) partition the
    // width is capped by the max initial support.
    let hi = if range_hi == u64::MAX {
        sup.iter().copied().max().unwrap_or(range_lo) + 1
    } else {
        range_hi
    };
    let mut heap = BucketQueue::new(range_lo, hi);
    for (le, &s) in sup.iter().enumerate() {
        heap.push(s, le as u32);
    }
    let mut level = 0u64;
    let mut remaining = n;
    let mut wedges = 0u64;
    let mut updates = 0u64;
    while remaining > 0 {
        let (s, le) = heap
            .pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]))
            .expect("partition heap exhausted early");
        let le = le as usize;
        level = level.max(s);
        let e_glob = edges[le];
        theta[e_glob as usize] = level;
        peeled[le] = true;
        remaining -= 1;
        // Alg. 3 over the partitioned index.
        let links_start = idx.edge_offs[le];
        let links_end = idx.edge_offs[le + 1];
        for li in links_start..links_end {
            let (lb, tw) = idx.edge_links[li];
            wedges += 1;
            // twin peeled already (same partition only — higher-partition
            // twins are never peeled during this run)?
            let tw_same_part = part_of[tw as usize] == part_id;
            if tw_same_part && peeled[local_of[tw as usize] as usize] {
                continue; // wedge already removed
            }
            let lbu = lb as usize;
            let k = idx.bloom_k[lbu];
            debug_assert!(k >= 1, "live wedge implies k >= 1 (bloom {lb})");
            if tw_same_part {
                let lt = local_of[tw as usize] as usize;
                let ns = sup[lt].saturating_sub(k as u64 - 1).max(level);
                if ns != sup[lt] {
                    sup[lt] = ns;
                    heap.push(ns, lt as u32);
                }
                updates += 1;
            }
            idx.bloom_k[lbu] = k - 1;
            // neighborhood sweep: −1 to live edges with live wedges
            let bs = idx.bloom_offs[lbu];
            let blen = bloom_len[lbu] as usize;
            let mut w = 0usize;
            for r in 0..blen {
                wedges += 1;
                let (e2, t2) = idx.bloom_entries[bs + r];
                // e2 ∈ E_i by link preservation
                let l2 = local_of[e2 as usize] as usize;
                let e2_dead = peeled[l2] || e2 == e_glob;
                let t2_dead = t2 == e_glob
                    || (part_of[t2 as usize] == part_id
                        && peeled[local_of[t2 as usize] as usize]);
                if e2_dead || t2_dead {
                    if !dynamic_deletes {
                        idx.bloom_entries[bs + w] = idx.bloom_entries[bs + r];
                        w += 1;
                    }
                    continue;
                }
                let ns = sup[l2].saturating_sub(1).max(level);
                if ns != sup[l2] {
                    sup[l2] = ns;
                    heap.push(ns, l2 as u32);
                }
                updates += 1;
                idx.bloom_entries[bs + w] = idx.bloom_entries[bs + r];
                w += 1;
            }
            if dynamic_deletes {
                bloom_len[lbu] = w as u32;
            }
        }
    }
    meters.wedges.add(wedges);
    meters.updates.add(updates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::partition::partition_be_index;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::wing::cd::{coarse_decompose, CdConfig};

    fn pbng_theta(g: &crate::graph::BipartiteGraph, p: usize, threads: usize) -> Vec<u64> {
        let (idx, per_edge) = BeIndex::build(g, 1);
        let meters = Meters::new();
        let cd = coarse_decompose(
            &idx,
            &per_edge,
            CdConfig {
                p,
                threads,
                batch: true,
                dynamic_deletes: true,
            },
            &meters,
        );
        let mut pt = partition_be_index(&idx, &cd.part_of, cd.n_parts);
        fine_decompose(
            &mut pt,
            &cd.part_of,
            &cd.sup_init,
            &cd.lowers,
            FdConfig {
                threads,
                dynamic_deletes: true,
            },
            &meters,
        )
    }

    #[test]
    fn matches_bup_single_partition() {
        let g = gen::biclique(3, 4);
        assert_eq!(pbng_theta(&g, 1, 1), wing_bup(&g).theta);
    }

    #[test]
    fn matches_bup_multi_partition() {
        let g = gen::paper_fig1();
        assert_eq!(pbng_theta(&g, 3, 2), wing_bup(&g).theta);
    }

    #[test]
    fn matches_bup_on_random_graphs_theorem2() {
        crate::testkit::check_property("pbng-fd-vs-bup", 0xFD1, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(14),
                6 + rng.usize_below(14),
                20 + rng.usize_below(80),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let p = 1 + rng.usize_below(6);
            let threads = 1 + rng.usize_below(4);
            let a = pbng_theta(&g, p, threads);
            let b = wing_bup(&g).theta;
            if a != b {
                return Err(format!("P={p} T={threads}: pbng={a:?} bup={b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_bup_on_skewed_graph() {
        let g = gen::zipf(50, 50, 350, 1.3, 1.3, 42);
        assert_eq!(pbng_theta(&g, 8, 3), wing_bup(&g).theta);
    }

    #[test]
    fn deletes_off_gives_same_output() {
        let g = gen::zipf(30, 30, 180, 1.2, 1.2, 43);
        let (idx, per_edge) = BeIndex::build(&g, 1);
        let meters = Meters::new();
        let cd = coarse_decompose(
            &idx,
            &per_edge,
            CdConfig { p: 4, threads: 1, batch: true, dynamic_deletes: false },
            &meters,
        );
        let mut pt = partition_be_index(&idx, &cd.part_of, cd.n_parts);
        let theta = fine_decompose(
            &mut pt,
            &cd.part_of,
            &cd.sup_init,
            &cd.lowers,
            FdConfig { threads: 1, dynamic_deletes: false },
            &meters,
        );
        assert_eq!(theta, wing_bup(&g).theta);
    }
}
