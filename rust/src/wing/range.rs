//! Range determination for PBNG CD (§3.1.3, Alg. 4 lines 15–20).
//!
//! The spectrum of entity numbers is split into `P` non-overlapping
//! ranges so that each partition poses roughly `tgt` peeling workload.
//! Workload of peeling entity `l` is proxied by its current support
//! (wing: `O(⋈_e)` BE-Index traversal per peeled edge). Bins keyed by
//! support value are prefix-scanned to find the smallest upper bound
//! whose cumulative workload reaches the target.
//!
//! The *two-way adaptive* scheme: (1) `tgt` is recomputed per partition
//! from the remaining workload and remaining partition count; (2) the
//! target is scaled down by the previous partition's overshoot ratio
//! (initial estimate ÷ final workload), assuming locally predictive
//! behaviour.

/// Result of one range computation.
#[derive(Clone, Copy, Debug)]
pub struct Range {
    /// Exclusive upper bound θ(i+1) on supports peeled into this
    /// partition.
    pub upper: u64,
    /// Estimated workload of the initial active set (Σ support of
    /// entities currently under `upper`).
    pub initial_estimate: u64,
}

/// Find the smallest `upper` such that entities with support `< upper`
/// carry cumulative workload ≥ `tgt`. `supports` enumerates the supports
/// of *alive* entities only. `workload(s)` maps a support value to that
/// entity's workload proxy (identity for wing, wedge count for tip).
pub fn find_range<I>(supports: I, tgt: u64) -> Range
where
    I: Iterator<Item = (u64, u64)>, // (support, workload)
{
    // bin by support value
    let mut bins: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
    for (s, w) in supports {
        *bins.entry(s).or_insert(0) += w;
    }
    let mut keys: Vec<u64> = bins.keys().copied().collect();
    keys.sort_unstable();
    let mut acc = 0u64;
    for &k in &keys {
        acc += bins[&k];
        if acc >= tgt {
            return Range {
                upper: k + 1,
                initial_estimate: acc,
            };
        }
    }
    // everything fits under the target: take it all
    Range {
        upper: keys.last().map(|&k| k + 1).unwrap_or(1),
        initial_estimate: acc,
    }
}

/// Adaptive target state across partitions.
#[derive(Debug)]
pub struct AdaptiveTarget {
    /// Partitions still to create (including the current one).
    remaining_parts: usize,
    /// Overshoot scale from the previous partition (≤ 1.0).
    scale: f64,
}

impl AdaptiveTarget {
    pub fn new(p: usize) -> Self {
        AdaptiveTarget {
            remaining_parts: p.max(1),
            scale: 1.0,
        }
    }

    /// Target workload for the next partition given the total remaining
    /// workload.
    pub fn target(&self, remaining_workload: u64) -> u64 {
        let base = remaining_workload as f64 / self.remaining_parts as f64;
        ((base * self.scale).max(1.0)) as u64
    }

    /// Record a finished partition: its initial estimate (at range time)
    /// and the final workload it actually absorbed.
    pub fn record(&mut self, initial_estimate: u64, final_workload: u64) {
        if self.remaining_parts > 1 {
            self.remaining_parts -= 1;
        }
        if final_workload > 0 && initial_estimate > 0 {
            // assume the next partition overshoots similarly
            self.scale = (initial_estimate as f64 / final_workload as f64).clamp(0.02, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_range_basic() {
        // supports 1,1,2,3 with identity workload; tgt 3 → bins: 1→2, 2→2
        // cumulative at support 1 = 2 < 3; at 2 = 4 ≥ 3 → upper 3
        let sup = vec![1u64, 1, 2, 3];
        let r = find_range(sup.iter().map(|&s| (s, s)), 3);
        assert_eq!(r.upper, 3);
        assert_eq!(r.initial_estimate, 4);
    }

    #[test]
    fn find_range_takes_all_when_target_large() {
        let sup = vec![5u64, 7];
        let r = find_range(sup.iter().map(|&s| (s, s)), 1_000);
        assert_eq!(r.upper, 8);
        assert_eq!(r.initial_estimate, 12);
    }

    #[test]
    fn find_range_single_bin() {
        let sup = vec![4u64; 10];
        let r = find_range(sup.iter().map(|&s| (s, s)), 1);
        assert_eq!(r.upper, 5);
    }

    #[test]
    fn find_range_empty() {
        let r = find_range(std::iter::empty(), 10);
        assert_eq!(r.upper, 1);
        assert_eq!(r.initial_estimate, 0);
    }

    #[test]
    fn adaptive_target_divides_evenly() {
        let t = AdaptiveTarget::new(4);
        assert_eq!(t.target(100), 25);
    }

    #[test]
    fn adaptive_target_scales_down_after_overshoot() {
        let mut t = AdaptiveTarget::new(4);
        // estimated 25 but absorbed 100 → scale 0.25
        t.record(25, 100);
        // remaining workload 300 over 3 parts = 100, scaled to 25
        assert_eq!(t.target(300), 25);
    }

    #[test]
    fn adaptive_scale_clamped() {
        let mut t = AdaptiveTarget::new(2);
        t.record(1, 1_000_000);
        assert!(t.target(1_000_000) >= 1);
    }
}
