//! Wing (edge) peel domain: plugs BE-Index edge peeling into the
//! generic two-phase engine ([`crate::engine`]).
//!
//! * CD hook — [`WingState`] with the Alg. 6 batch engine
//!   ([`peel_set_batch`], twin conflict resolution) or the Alg. 3
//!   per-edge ablation ([`peel_set_single`]); the workload proxy is the
//!   edge support itself (peeling `e` is `O(⋈_e)` index traversal).
//! * FD substrate — the partitioned BE-Index (Alg. 5 lines 12–25,
//!   [`partition_be_index`]); each partition is peeled sequentially over
//!   its own `I_i` with a range-clamped [`BucketQueue`].

use crate::beindex::partition::{partition_be_index, PartIndex};
use crate::beindex::BeIndex;
use crate::engine::{CdOutput, EngineConfig, PeelDomain, PeelOutcome};
use crate::metrics::Meters;
use crate::par::{RacyBuf, RacyCell};
use crate::peel::BucketQueue;
use crate::wing::state::{peel_set_batch, peel_set_single, WingState};

pub struct WingDomain<'a> {
    st: WingState<'a>,
    /// FD substrate (set by `build_substrate`). Each partition's index is
    /// handed off exclusively to the one FD task that claims the
    /// partition (the queue's `taken` flags in [`crate::engine::fd`]
    /// claim each exactly once), so a lock would only re-prove what the
    /// claim already guarantees; the cell keeps the hot path lock-free
    /// and its debug borrow flag asserts the hand-off.
    parts: Vec<RacyCell<PartIndex>>,
    edges_of: Vec<Vec<u32>>,
    local_of: Vec<u32>,
}

impl<'a> WingDomain<'a> {
    pub fn new(idx: &'a BeIndex, per_edge: &[u64], cfg: &EngineConfig) -> Self {
        WingDomain {
            st: WingState::new(idx, per_edge, cfg.dynamic_deletes),
            parts: Vec::new(),
            edges_of: Vec::new(),
            local_of: Vec::new(),
        }
    }
}

impl PeelDomain for WingDomain<'_> {
    fn n_entities(&self) -> usize {
        self.st.sup.len()
    }

    fn is_alive(&self, e: u32) -> bool {
        self.st.is_alive(e)
    }

    fn support(&self, e: u32) -> u64 {
        self.st.sup[e as usize].get()
    }

    fn workload_proxy(&self, _e: u32, sup_init: u64) -> u64 {
        sup_init
    }

    fn peel_set(
        &mut self,
        active: &[u32],
        lower: u64,
        epoch: u32,
        _remaining: usize,
        cfg: &EngineConfig,
        meters: &Meters,
    ) -> PeelOutcome {
        let touched = if cfg.batch {
            self.st.mark_peeled(active, epoch, cfg.threads);
            peel_set_batch(
                &self.st,
                active,
                lower,
                epoch,
                cfg.threads,
                cfg.kernel.updates,
                meters,
            )
        } else {
            // Alg. 3 semantics: peel_set_single marks one edge at a time
            peel_set_single(&self.st, active, lower, epoch, meters)
        };
        PeelOutcome::Touched(touched)
    }

    fn build_substrate(&mut self, cd: &CdOutput, _cfg: &EngineConfig) {
        let pt = partition_be_index(self.st.idx, &cd.part_of, cd.n_parts);
        self.parts = pt.parts.into_iter().map(RacyCell::new).collect();
        self.edges_of = pt.edges_of;
        self.local_of = pt.local_of;
    }

    fn partition_workload(&self, part: usize, cd: &CdOutput) -> u64 {
        // Σ ⋈init over the partition's edges (Alg. 5 line 4)
        self.edges_of[part]
            .iter()
            .map(|&e| cd.sup_init[e as usize])
            .sum()
    }

    fn peel_partition(
        &self,
        part: usize,
        bounds: (u64, u64),
        theta: &RacyBuf<u64>,
        cd: &CdOutput,
        cfg: &EngineConfig,
        meters: &Meters,
    ) {
        // SAFETY: the FD queue's claim flags hand partition `part` to
        // exactly one logical lane per run (`engine::fd::LaneQueue`), and
        // the pool's region protocol orders `build_substrate`'s writes
        // before any lane body — so this is the only live access to
        // `parts[part]`.
        let mut idx = unsafe { self.parts[part].get_mut() };
        peel_one_partition(
            part as u32,
            &mut idx,
            &self.edges_of[part],
            &self.local_of,
            &cd.part_of,
            &cd.sup_init,
            bounds,
            theta,
            cfg.dynamic_deletes,
            meters,
        );
    }
}

/// Sequential bottom-up peel of one partition over its own BE-Index.
#[allow(clippy::too_many_arguments)]
fn peel_one_partition(
    part_id: u32,
    idx: &mut PartIndex,
    edges: &[u32],
    local_of: &[u32],
    part_of: &[u32],
    sup_init: &[u64],
    (range_lo, range_hi): (u64, u64),
    theta: &RacyBuf<u64>,
    dynamic_deletes: bool,
    meters: &Meters,
) {
    let n = edges.len();
    if n == 0 {
        return;
    }
    let mut sup: Vec<u64> = edges.iter().map(|&e| sup_init[e as usize]).collect();
    let mut peeled = vec![false; n];
    let mut bloom_len: Vec<u32> = (0..idx.n_blooms())
        .map(|b| (idx.bloom_offs[b + 1] - idx.bloom_offs[b]) as u32)
        .collect();
    // Clamped bucket queue over the partition's range (Theorem 1): θs
    // assigned here fall in [range_lo, range_hi), so exact ordering is
    // only needed below range_hi. For the last (unbounded) partition the
    // width is capped by the max initial support.
    let hi = if range_hi == u64::MAX {
        sup.iter().copied().max().unwrap_or(range_lo) + 1
    } else {
        range_hi
    };
    let mut heap = BucketQueue::new(range_lo, hi);
    for (le, &s) in sup.iter().enumerate() {
        heap.push(s, le as u32);
    }
    let mut level = 0u64;
    let mut remaining = n;
    let mut wedges = 0u64;
    let mut updates = 0u64;
    while remaining > 0 {
        let (s, le) = heap
            .pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]))
            .expect("partition heap exhausted early");
        let le = le as usize;
        level = level.max(s);
        let e_glob = edges[le];
        // SAFETY: CD assigns every edge to exactly one partition and this
        // task owns partition `part_id` exclusively, so no other lane
        // touches θ[e_glob] (the FD driver's disjointness contract,
        // `engine::fd::fine_decompose`).
        unsafe { theta.set(e_glob as usize, level) };
        peeled[le] = true;
        remaining -= 1;
        // Alg. 3 over the partitioned index.
        let links_start = idx.edge_offs[le];
        let links_end = idx.edge_offs[le + 1];
        for li in links_start..links_end {
            let (lb, tw) = idx.edge_links[li];
            wedges += 1;
            // twin peeled already (same partition only — higher-partition
            // twins are never peeled during this run)?
            let tw_same_part = part_of[tw as usize] == part_id;
            if tw_same_part && peeled[local_of[tw as usize] as usize] {
                continue; // wedge already removed
            }
            let lbu = lb as usize;
            let k = idx.bloom_k[lbu];
            debug_assert!(k >= 1, "live wedge implies k >= 1 (bloom {lb})");
            if tw_same_part {
                let lt = local_of[tw as usize] as usize;
                let ns = sup[lt].saturating_sub(k as u64 - 1).max(level);
                if ns != sup[lt] {
                    sup[lt] = ns;
                    heap.push(ns, lt as u32);
                }
                updates += 1;
            }
            idx.bloom_k[lbu] = k - 1;
            // neighborhood sweep: −1 to live edges with live wedges
            let bs = idx.bloom_offs[lbu];
            let blen = bloom_len[lbu] as usize;
            let mut w = 0usize;
            for r in 0..blen {
                wedges += 1;
                let (e2, t2) = idx.bloom_entries[bs + r];
                // e2 ∈ E_i by link preservation
                let l2 = local_of[e2 as usize] as usize;
                let e2_dead = peeled[l2] || e2 == e_glob;
                let t2_dead = t2 == e_glob
                    || (part_of[t2 as usize] == part_id
                        && peeled[local_of[t2 as usize] as usize]);
                if e2_dead || t2_dead {
                    if !dynamic_deletes {
                        idx.bloom_entries[bs + w] = idx.bloom_entries[bs + r];
                        w += 1;
                    }
                    continue;
                }
                let ns = sup[l2].saturating_sub(1).max(level);
                if ns != sup[l2] {
                    sup[l2] = ns;
                    heap.push(ns, l2 as u32);
                }
                updates += 1;
                idx.bloom_entries[bs + w] = idx.bloom_entries[bs + r];
                w += 1;
            }
            if dynamic_deletes {
                bloom_len[lbu] = w as u32;
            }
        }
    }
    meters.wedges.add(wedges);
    meters.updates.add(updates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{coarse_decompose, EngineConfig};
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::wing::wing_pbng;

    fn cfg(p: usize, threads: usize, batch: bool, dynamic_deletes: bool) -> EngineConfig {
        EngineConfig {
            p,
            threads,
            batch,
            dynamic_deletes,
            ..Default::default()
        }
    }

    fn run_cd(g: &crate::graph::BipartiteGraph, p: usize) -> CdOutput {
        let (idx, per_edge) = BeIndex::build(g, 1);
        let meters = Meters::new();
        let c = cfg(p, 2, true, true);
        let mut dom = WingDomain::new(&idx, &per_edge, &c);
        coarse_decompose(&mut dom, &c, &meters)
    }

    /// Theorem 1: partitions bracket the true wing numbers.
    #[test]
    fn partitions_bracket_wing_numbers() {
        crate::testkit::check_property("cd-brackets-theta", 0xCD1, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(12),
                6 + rng.usize_below(12),
                20 + rng.usize_below(60),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let theta = wing_bup(&g).theta;
            let p = 1 + rng.usize_below(5);
            let out = run_cd(&g, p);
            for e in 0..g.m() {
                let i = out.part_of[e] as usize;
                let lo = out.lowers[i];
                let hi = out.lowers.get(i + 1).copied().unwrap_or(u64::MAX);
                if theta[e] < lo || theta[e] >= hi {
                    return Err(format!(
                        "edge {e}: θ={} outside partition {i} range [{lo},{hi})",
                        theta[e]
                    ));
                }
            }
            Ok(())
        });
    }

    /// ⋈init must equal the butterfly count of e restricted to its own and
    /// higher partitions (§3.1.1).
    #[test]
    fn sup_init_counts_higher_universe() {
        crate::testkit::check_property("cd-supinit", 0xCD2, 6, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(10),
                6 + rng.usize_below(10),
                20 + rng.usize_below(50),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let out = run_cd(&g, 3);
            for i in 0..out.n_parts as u32 {
                // alive = edges in partitions >= i
                let alive: Vec<bool> = (0..g.m()).map(|e| out.part_of[e] >= i).collect();
                let oracle = crate::count::brute::edge_support_restricted(&g, &alive);
                for e in 0..g.m() {
                    if out.part_of[e] == i && out.sup_init[e] != oracle[e] {
                        return Err(format!(
                            "edge {e} (part {i}): sup_init={} oracle={}",
                            out.sup_init[e], oracle[e]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_partition_assigns_everything_to_zero() {
        let g = gen::biclique(3, 3);
        let out = run_cd(&g, 1);
        assert!(out.part_of.iter().all(|&p| p == 0));
        assert_eq!(out.n_parts, 1);
    }

    #[test]
    fn respects_partition_budget() {
        let g = gen::zipf(60, 60, 400, 1.2, 1.2, 5);
        let out = run_cd(&g, 8);
        assert!(out.n_parts <= 8);
        assert!(out.part_of.iter().all(|&p| (p as usize) < out.n_parts));
    }

    #[test]
    fn batch_and_single_produce_same_partitions() {
        let g = gen::zipf(40, 40, 250, 1.2, 1.2, 9);
        let (idx, per_edge) = BeIndex::build(&g, 1);
        let meters = Meters::new();
        let ca = cfg(4, 2, true, true);
        let mut da = WingDomain::new(&idx, &per_edge, &ca);
        let a = coarse_decompose(&mut da, &ca, &meters);
        let cb = cfg(4, 1, false, false);
        let mut db = WingDomain::new(&idx, &per_edge, &cb);
        let b = coarse_decompose(&mut db, &cb, &meters);
        assert_eq!(a.part_of, b.part_of);
        assert_eq!(a.sup_init, b.sup_init);
    }

    #[test]
    fn rho_is_much_less_than_m_with_wide_ranges() {
        let g = gen::zipf(80, 80, 600, 1.2, 1.2, 11);
        let (idx, per_edge) = BeIndex::build(&g, 1);
        let meters = Meters::new();
        let c = cfg(4, crate::par::default_threads(), true, true);
        let mut dom = WingDomain::new(&idx, &per_edge, &c);
        coarse_decompose(&mut dom, &c, &meters);
        assert!(
            meters.rho.get() < g.m() as u64 / 4,
            "rho {} not << m {}",
            meters.rho.get(),
            g.m()
        );
    }

    /// Theorem 2 end to end: the engine pipeline equals sequential BUP.
    #[test]
    fn matches_bup_on_random_graphs_theorem2() {
        crate::testkit::check_property("pbng-fd-vs-bup", 0xFD1, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(14),
                6 + rng.usize_below(14),
                20 + rng.usize_below(80),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let p = 1 + rng.usize_below(6);
            let threads = 1 + rng.usize_below(4);
            let a = wing_pbng(&g, cfg(p, threads, true, true)).theta;
            let b = wing_bup(&g).theta;
            if a != b {
                return Err(format!("P={p} T={threads}: pbng={a:?} bup={b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn deletes_off_gives_same_output() {
        let g = gen::zipf(30, 30, 180, 1.2, 1.2, 43);
        let theta = wing_pbng(&g, cfg(4, 1, true, false)).theta;
        assert_eq!(theta, wing_bup(&g).theta);
    }
}
