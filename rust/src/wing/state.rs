//! Mutable wing-peeling state over the BE-Index, with the two update
//! engines:
//!
//! * [`peel_set_batch`] — Alg. 6: support updates from a whole peeled set
//!   are aggregated per bloom (`count[B]`) and applied in one traversal
//!   of each dirty bloom's neighborhood, with the twin-edge conflict
//!   resolution of Alg. 4 (lines 26–31).
//! * [`peel_set_single`] — Alg. 3 repeated per edge: the PBNG−− ablation
//!   (batch optimization disabled).
//!
//! Twin semantics (Property 1): when edge `e` is peeled from bloom `B`
//! with current bloom number `k`, its twin loses all its `k − 1`
//! butterflies in `B`; every other live edge of `B` loses exactly one
//! butterfly per wedge removed. A link `(e, B)` is *dead* once `e`'s twin
//! is peeled; dead links are detected through the peel-epoch array and —
//! with the §5.2 dynamic-deletes optimization — compacted out of the
//! bloom's entry list.
//!
//! The original [`BeIndex`] stays immutable (FD re-partitions it); this
//! state owns working copies of the bloom numbers and entry lists.

use crate::beindex::BeIndex;
use crate::count::UpdateKernel;
use crate::metrics::Meters;
use crate::par::{parallel_for_chunked, RacyBuf, SupportCell};
use std::sync::atomic::{AtomicU32, Ordering};

/// Epoch value meaning "not peeled".
pub const ALIVE: u32 = u32::MAX;

pub struct WingState<'a> {
    pub idx: &'a BeIndex,
    /// Current edge supports.
    pub sup: Vec<SupportCell>,
    /// Peel epoch per edge (`ALIVE` = not peeled). Epochs strictly
    /// increase across peeling iterations.
    pub epoch: Vec<AtomicU32>,
    /// Working copy of bloom numbers.
    bloom_k: Vec<AtomicU32>,
    /// Working copy of bloom entry lists (compacted under dynamic
    /// deletes). Element-granular shared mutation: phase 2 of
    /// [`peel_set_batch`] rewrites each dirty bloom's sub-range from
    /// exactly one lane, so a [`RacyBuf`] (per-element `UnsafeCell`)
    /// keeps the concurrent disjoint writes legal.
    entries: RacyBuf<(u32, u32)>,
    /// Active length per bloom (same per-bloom ownership as `entries`).
    bloom_len: RacyBuf<u32>,
    /// Per-bloom batch counters (zeroed between iterations).
    count: Vec<AtomicU32>,
    /// §5.2 optimization toggle.
    pub dynamic_deletes: bool,
}

impl<'a> WingState<'a> {
    pub fn new(idx: &'a BeIndex, per_edge: &[u64], dynamic_deletes: bool) -> Self {
        WingState {
            idx,
            sup: per_edge.iter().map(|&s| SupportCell::new(s)).collect(),
            epoch: (0..per_edge.len()).map(|_| AtomicU32::new(ALIVE)).collect(),
            bloom_k: idx.bloom_k.iter().map(|&k| AtomicU32::new(k)).collect(),
            entries: RacyBuf::new(idx.bloom_entries.clone()),
            bloom_len: RacyBuf::new(idx.bloom_len.clone()),
            count: (0..idx.n_blooms()).map(|_| AtomicU32::new(0)).collect(),
            dynamic_deletes,
        }
    }

    #[inline]
    pub fn is_alive(&self, e: u32) -> bool {
        self.epoch[e as usize].load(Ordering::Relaxed) == ALIVE
    }

    /// Mark a set as peeled at `epoch` (must be called before the peel).
    pub fn mark_peeled(&self, active: &[u32], epoch: u32, threads: usize) {
        crate::par::parallel_for(active.len(), threads, |_, i| {
            self.epoch[active[i] as usize].store(epoch, Ordering::Relaxed);
        });
    }

    pub fn support_snapshot(&self) -> Vec<u64> {
        self.sup.iter().map(|c| c.get()).collect()
    }
}

/// Batch peel (Alg. 6). `active` must already be marked at `epoch`
/// via [`WingState::mark_peeled`]. Returns live edges whose support
/// changed (with duplicates; callers dedup).
///
/// `upd` selects the support-update kernel: `Scattered` issues one
/// atomic `sub_clamped` per hit (the measurable baseline), `Aggregated`
/// logs `(edge, delta)` per lane and flushes once per batch via
/// [`crate::count::kernel::flush_runs`]. The two are value-equivalent:
/// supports are write-only for the duration of the batch and clamped
/// subtraction to the common `floor` is associative and commutative
/// (`max(max(x-a, f)-b, f) = max(x-a-b, f)`), so per-entity aggregation
/// and arbitrary flush order cannot change the result. The `updates`
/// and `touched` bookkeeping is recorded at hit time in both modes, so
/// gated meters are identical too.
pub fn peel_set_batch(
    st: &WingState,
    active: &[u32],
    floor: u64,
    epoch: u32,
    threads: usize,
    upd: UpdateKernel,
    meters: &Meters,
) -> Vec<u32> {
    let threads = threads.max(1);
    // Per-lane collectors checked out from the runtime pool's freelist:
    // CD calls this once per peel iteration (ρ times), so per-call
    // `Mutex<Vec<u32>>` allocation and locking was pure overhead.
    let mut scratch = crate::par::ScratchSet::take(crate::par::max_lanes(threads));

    // Phase 1: per peeled edge, resolve twins and aggregate wedge-removal
    // counts at blooms. bloom_k reads are stable (only phase 2 writes).
    parallel_for_chunked(active.len(), threads, 64, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so slot `t` is exclusively ours inside this chunk.
        let mut sc = unsafe { scratch.lane(t) };
        let sc = &mut *sc;
        let (dirty, touched, pairs) = (&mut sc.a, &mut sc.b, &mut sc.pairs);
        let mut wedges = 0u64;
        let mut updates = 0u64;
        for &e in &active[lo..hi] {
            for &(b, tw) in st.idx.links_of(e) {
                wedges += 1;
                let te = st.epoch[tw as usize].load(Ordering::Relaxed);
                if te < epoch {
                    continue; // wedge already removed in an earlier iteration
                }
                if te == epoch {
                    // both twins peeled this iteration: the higher-id edge
                    // is the representative that counts the wedge removal
                    if e < tw {
                        continue;
                    }
                } else {
                    // twin is live: it loses all its k−1 butterflies in B
                    let k = st.bloom_k[b as usize].load(Ordering::Relaxed) as u64;
                    if k >= 1 {
                        match upd {
                            UpdateKernel::Scattered => {
                                st.sup[tw as usize].sub_clamped(k - 1, floor);
                            }
                            // delta 0 still logged: sub_clamped(0, floor)
                            // lifts to the floor exactly like Scattered
                            UpdateKernel::Aggregated => pairs.push((tw, k - 1)),
                        }
                        updates += 1;
                        touched.push(tw);
                    }
                }
                if st.count[b as usize].fetch_add(1, Ordering::Relaxed) == 0 {
                    dirty.push(b);
                }
            }
        }
        meters.wedges.add(wedges);
        meters.updates.add(updates);
    });

    let mut dirty: Vec<u32> = Vec::new();
    let mut touched: Vec<u32> = Vec::new();
    scratch.for_each(|sc| {
        dirty.extend_from_slice(&sc.a);
        sc.a.clear();
        touched.extend_from_slice(&sc.b);
        sc.b.clear();
    });

    // Phase 2: per dirty bloom, decrement the bloom number and apply the
    // aggregated −count[B] to live edges with live twins. Disjoint blooms
    // → element-disjoint `RacyBuf` writes are race-free. Lane slots (`b`)
    // are reused as the phase-local touched collectors.
    parallel_for_chunked(dirty.len(), threads, 16, |t, lo, hi| {
        // SAFETY: lane-exclusive slot (see phase 1).
        let mut sc = unsafe { scratch.lane(t) };
        let sc = &mut *sc;
        let (touched, pairs) = (&mut sc.b, &mut sc.pairs);
        let mut wedges = 0u64;
        let mut updates = 0u64;
        for &b in &dirty[lo..hi] {
            let c = st.count[b as usize].swap(0, Ordering::Relaxed);
            debug_assert!(c > 0);
            let k = st.bloom_k[b as usize].load(Ordering::Relaxed);
            debug_assert!(k >= c, "bloom {b}: k={k} < c={c}");
            st.bloom_k[b as usize].store(k - c, Ordering::Relaxed);
            let s = st.idx.bloom_offs[b as usize];
            // SAFETY: each dirty bloom appears exactly once in `dirty`
            // (guarded by the fetch_add(0→1) push), so this lane owns
            // bloom `b`'s length slot and entry range exclusively; ranges
            // of distinct blooms are disjoint by construction.
            let len = unsafe { st.bloom_len.get(b as usize) } as usize;
            // SAFETY: as above — bloom `b`'s range is exclusively ours.
            let slice = unsafe { st.entries.slice_mut(s, s + len) };
            let mut w = 0usize; // compaction write cursor
            for r in 0..len {
                wedges += 1;
                let (e2, t2) = slice[r];
                let e2_dead = st.epoch[e2 as usize].load(Ordering::Relaxed) <= epoch;
                let t2_dead = st.epoch[t2 as usize].load(Ordering::Relaxed) <= epoch;
                if e2_dead || t2_dead {
                    // dead link: compact out under the §5.2 optimization
                    if !st.dynamic_deletes {
                        slice[w] = slice[r];
                        w += 1;
                    }
                    continue;
                }
                match upd {
                    UpdateKernel::Scattered => {
                        st.sup[e2 as usize].sub_clamped(c as u64, floor);
                    }
                    UpdateKernel::Aggregated => pairs.push((e2, c as u64)),
                }
                updates += 1;
                touched.push(e2);
                slice[w] = slice[r];
                w += 1;
            }
            if st.dynamic_deletes {
                // SAFETY: as above — bloom `b` is exclusively ours.
                unsafe { st.bloom_len.set(b as usize, w as u32) };
            }
        }
        meters.wedges.add(wedges);
        meters.updates.add(updates);
    });
    scratch.for_each(|sc| {
        touched.extend_from_slice(&sc.b);
        sc.b.clear();
    });
    if upd == UpdateKernel::Aggregated {
        // One flush for both phases: per-lane sort + run-sum, one atomic
        // op per distinct edge per lane (commutes — doc on `upd` above).
        crate::count::kernel::flush_runs(&scratch, |e, d| {
            st.sup[e as usize].sub_clamped(d, floor);
        });
    }
    touched
}

/// Per-edge peel (Alg. 3 in a loop) — the PBNG−− ablation: no batch
/// aggregation, every peeled edge traverses its blooms' neighborhoods
/// itself. Sequential over the set.
///
/// Unlike [`peel_set_batch`], the set must **not** be pre-marked: this
/// engine marks each edge right before processing it, so that Alg. 3's
/// one-at-a-time twin semantics hold exactly (a twin later in the set is
/// still "in the graph" when an earlier edge is peeled).
pub fn peel_set_single(
    st: &WingState,
    active: &[u32],
    floor: u64,
    epoch: u32,
    meters: &Meters,
) -> Vec<u32> {
    let mut touched = Vec::new();
    let mut wedges = 0u64;
    let mut updates = 0u64;
    for &e in active {
        st.epoch[e as usize].store(epoch, Ordering::Relaxed);
        for &(b, tw) in st.idx.links_of(e) {
            wedges += 1;
            if st.epoch[tw as usize].load(Ordering::Relaxed) != ALIVE {
                continue; // wedge already removed when the twin was peeled
            }
            let kb = &st.bloom_k[b as usize];
            let k = kb.load(Ordering::Relaxed);
            debug_assert!(k >= 1, "live wedge implies k >= 1");
            // twin loses all its k−1 butterflies in B (Alg. 3 line 4)
            st.sup[tw as usize].sub_clamped(k as u64 - 1, floor);
            updates += 1;
            touched.push(tw);
            kb.store(k - 1, Ordering::Relaxed);
            // one traversal of the bloom per peeled edge (no aggregation)
            let s = st.idx.bloom_offs[b as usize];
            // SAFETY: this engine is sequential — no other thread touches
            // the state during the loop, so every element is ours.
            let len = unsafe { st.bloom_len.get(b as usize) } as usize;
            // SAFETY: as above — sequential, exclusive access.
            let slice = unsafe { st.entries.slice_mut(s, s + len) };
            let mut w = 0usize;
            for r in 0..len {
                wedges += 1;
                let (e2, t2) = slice[r];
                let e2_dead = st.epoch[e2 as usize].load(Ordering::Relaxed) != ALIVE;
                let t2_dead = st.epoch[t2 as usize].load(Ordering::Relaxed) != ALIVE;
                if e2_dead || t2_dead {
                    if !st.dynamic_deletes {
                        slice[w] = slice[r];
                        w += 1;
                    }
                    continue;
                }
                st.sup[e2 as usize].sub_clamped(1, floor);
                updates += 1;
                touched.push(e2);
                slice[w] = slice[r];
                w += 1;
            }
            if st.dynamic_deletes {
                // SAFETY: as above — sequential, exclusive access.
                unsafe { st.bloom_len.set(b as usize, w as u32) };
            }
        }
    }
    meters.wedges.add(wedges);
    meters.updates.add(updates);
    touched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn setup(g: &crate::graph::BipartiteGraph) -> (BeIndex, Vec<u64>) {
        BeIndex::build(g, 1)
    }

    #[test]
    fn batch_peel_single_butterfly() {
        let g = gen::biclique(2, 2);
        let (idx, per_edge) = setup(&g);
        let st = WingState::new(&idx, &per_edge, true);
        let m = Meters::new();
        // peel edge 0: the other three edges' support must drop to 0
        st.mark_peeled(&[0], 1, 1);
        peel_set_batch(&st, &[0], 0, 1, 1, UpdateKernel::Aggregated, &m);
        let sup = st.support_snapshot();
        assert_eq!(sup[0], 1); // peeled edge keeps its value
        assert_eq!(&sup[1..], &[0, 0, 0]);
    }

    #[test]
    fn batch_peel_twin_pair_together() {
        let g = gen::biclique(2, 2);
        let (idx, per_edge) = setup(&g);
        let st = WingState::new(&idx, &per_edge, true);
        let m = Meters::new();
        // the bloom's entries tell us the twin pairing
        let (e, t) = idx.entries(0)[0];
        st.mark_peeled(&[e, t], 1, 1);
        peel_set_batch(&st, &[e, t], 0, 1, 1, UpdateKernel::Scattered, &m);
        let sup = st.support_snapshot();
        for x in 0..4u32 {
            if x != e && x != t {
                assert_eq!(sup[x as usize], 0, "edge {x} should have lost its butterfly");
            }
        }
    }

    /// Supports of *live* edges must agree between engines (peeled edges'
    /// values are dead state and may differ).
    fn live_supports(st: &WingState, m: usize) -> Vec<Option<u64>> {
        (0..m as u32)
            .map(|e| st.is_alive(e).then(|| st.sup[e as usize].get()))
            .collect()
    }

    #[test]
    fn batch_matches_single_on_k35() {
        let g = gen::biclique(3, 5);
        let (idx, per_edge) = setup(&g);
        let stb = WingState::new(&idx, &per_edge, true);
        let sts = WingState::new(&idx, &per_edge, true);
        let m = Meters::new();
        let active = vec![0u32, 3, 7];
        stb.mark_peeled(&active, 1, 1);
        peel_set_batch(&stb, &active, 0, 1, 2, UpdateKernel::Aggregated, &m);
        peel_set_single(&sts, &active, 0, 1, &m);
        assert_eq!(live_supports(&stb, g.m()), live_supports(&sts, g.m()));
    }

    #[test]
    fn batch_engines_agree_on_random_sets() {
        crate::testkit::check_property("batch-vs-single", 0xBA7C4, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(10 + rng.usize_below(15), 10 + rng.usize_below(15), 40 + rng.usize_below(80), seed);
            if g.m() == 0 {
                return Ok(());
            }
            let (idx, per_edge) = setup(&g);
            // random subset of edges
            let active: Vec<u32> = (0..g.m() as u32).filter(|_| rng.chance(0.3)).collect();
            if active.is_empty() {
                return Ok(());
            }
            let m = Meters::new();
            let stb = WingState::new(&idx, &per_edge, true);
            let sts = WingState::new(&idx, &per_edge, false);
            let upd = if rng.chance(0.5) {
                UpdateKernel::Aggregated
            } else {
                UpdateKernel::Scattered
            };
            stb.mark_peeled(&active, 1, 1);
            peel_set_batch(&stb, &active, 0, 1, 3, upd, &m);
            peel_set_single(&sts, &active, 0, 1, &m);
            if live_supports(&stb, g.m()) != live_supports(&sts, g.m()) {
                return Err("batch vs single support divergence".into());
            }
            Ok(())
        });
    }

    #[test]
    fn batch_result_matches_brute_force_removal() {
        crate::testkit::check_property("batch-vs-brute-removal", 0xBB, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(8 + rng.usize_below(10), 8 + rng.usize_below(10), 25 + rng.usize_below(60), seed);
            if g.m() == 0 {
                return Ok(());
            }
            let (idx, per_edge) = setup(&g);
            let active: Vec<u32> = (0..g.m() as u32).filter(|_| rng.chance(0.25)).collect();
            if active.is_empty() {
                return Ok(());
            }
            let m = Meters::new();
            let st = WingState::new(&idx, &per_edge, true);
            st.mark_peeled(&active, 1, 1);
            peel_set_batch(&st, &active, 0, 1, 2, UpdateKernel::Aggregated, &m);
            // oracle: recount supports on the graph minus active edges
            let mut alive = vec![true; g.m()];
            for &e in &active {
                alive[e as usize] = false;
            }
            let oracle = crate::count::brute::edge_support_restricted(&g, &alive);
            let got = st.support_snapshot();
            for e in 0..g.m() {
                if alive[e] && got[e] != oracle[e] {
                    return Err(format!(
                        "edge {e}: batch={} oracle={} (m={}, active={:?})",
                        got[e],
                        oracle[e],
                        g.m(),
                        active
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn aggregated_matches_scattered_updates_and_meters() {
        crate::testkit::check_property("agg-vs-scatter", 0xA66, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                8 + rng.usize_below(12),
                8 + rng.usize_below(12),
                30 + rng.usize_below(70),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let (idx, per_edge) = setup(&g);
            let active: Vec<u32> = (0..g.m() as u32).filter(|_| rng.chance(0.3)).collect();
            if active.is_empty() {
                return Ok(());
            }
            let (ma, ms) = (Meters::new(), Meters::new());
            let sta = WingState::new(&idx, &per_edge, true);
            let sts = WingState::new(&idx, &per_edge, true);
            sta.mark_peeled(&active, 1, 2);
            sts.mark_peeled(&active, 1, 2);
            let mut ta = peel_set_batch(&sta, &active, 1, 1, 2, UpdateKernel::Aggregated, &ma);
            let mut ts = peel_set_batch(&sts, &active, 1, 1, 2, UpdateKernel::Scattered, &ms);
            if sta.support_snapshot() != sts.support_snapshot() {
                return Err("support divergence".into());
            }
            ta.sort_unstable();
            ts.sort_unstable();
            if ta != ts {
                return Err("touched-set divergence".into());
            }
            let (sa, ss) = (ma.snapshot(), ms.snapshot());
            if sa.updates != ss.updates || sa.wedges != ss.wedges {
                return Err(format!("meter divergence: {sa:?} vs {ss:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn dynamic_deletes_compact_entries() {
        let g = gen::biclique(2, 4);
        let (idx, per_edge) = setup(&g);
        let st = WingState::new(&idx, &per_edge, true);
        let m = Meters::new();
        st.mark_peeled(&[0], 1, 1);
        peel_set_batch(&st, &[0], 0, 1, 1, UpdateKernel::Aggregated, &m);
        // bloom 0 lost edge 0's wedge: entries shrink by 2 (both orientations)
        // SAFETY: single-threaded test — no concurrent writers.
        let len = unsafe { st.bloom_len.get(0) };
        assert_eq!(len as usize, idx.entries(0).len() - 2);
    }
}
