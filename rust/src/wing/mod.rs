//! Wing decomposition (bitruss decomposition): the PBNG pipeline on the
//! generic two-phase engine, plus the BE-Index based baselines.
//!
//! Since the engine refactor, this module holds **no CD/FD driver of its
//! own**: [`wing_pbng`] builds the BE-Index (the counting phase), wraps
//! it in [`domain::WingDomain`] — the [`crate::engine::PeelDomain`] impl
//! for edges — and hands off to [`crate::engine::decompose`], which owns
//! range finding, active-set management, ⋈init snapshotting, LPT
//! scheduling, and θ write-back for *both* decompositions. What remains
//! here is strictly edge-specific: the Alg. 6 batch kernels
//! ([`state`]), the per-partition sequential peel over the partitioned
//! BE-Index ([`domain`]), and the baselines.
//!
//! * [`wing_pbng`] — counting + BE-Index → engine CD (Alg. 4) → index
//!   partitioning (Alg. 5) → engine FD: the paper's contribution.
//! * [`wing_be_batch`] — BE_Batch baseline [67]: bottom-up level peeling
//!   with batched BE-Index updates and dynamic deletes.
//! * [`wing_be_pc`] — BE_PC-style baseline [67]: sequential
//!   progressive-compression peeling; here realized as a sequential
//!   range-partitioned two-phase peel with geometric candidate ranges
//!   controlled by τ (see DESIGN.md §Substitutions).
//! * Index-free baselines BUP and ParB live in [`crate::peel`].
//!
//! Configuration: the former `PbngConfig`/`CdConfig`/`FdConfig` trio is
//! replaced by [`crate::engine::EngineConfig`]; `PbngConfig` remains as
//! an alias for downstream code.

pub mod domain;
pub mod state;

use crate::beindex::BeIndex;
use crate::engine::{self, EngineConfig};
use crate::graph::BipartiteGraph;
use crate::metrics::{Meters, Phase, Recorder};
use crate::peel::{Decomposition, LazyHeap};
use domain::WingDomain;
use state::{peel_set_batch, WingState};

/// Back-compat alias: the wing pipeline is configured by the shared
/// engine config since the `pbng::engine` refactor.
pub type PbngConfig = EngineConfig;

/// PBNG wing decomposition (two-phased peeling on the generic engine).
pub fn wing_pbng(g: &BipartiteGraph, cfg: PbngConfig) -> Decomposition {
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    // the counting kernel emits its own CountKernel span (with the
    // resolved wedge side and SIMD flag) from inside pve_bcnt
    let (idx, per_edge) = BeIndex::build_with(g, cfg.threads, cfg.kernel);
    let mut dom = WingDomain::new(&idx, &per_edge, &cfg);
    engine::decompose(&mut dom, &cfg, rec).into_decomposition()
}

/// BE_Batch baseline: bottom-up peeling of minimum-support levels with
/// the Alg. 6 batch engine and dynamic deletes [67].
pub fn wing_be_batch(g: &BipartiteGraph, threads: usize) -> Decomposition {
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    let (idx, per_edge) = BeIndex::build(g, threads);
    rec.enter(Phase::Fine);
    let m = g.m();
    let st = WingState::new(&idx, &per_edge, true);
    let mut theta = vec![0u64; m];
    let mut heap = LazyHeap::new();
    for (e, &s) in per_edge.iter().enumerate() {
        heap.push(s, e as u32);
    }
    let mut remaining = m;
    let mut epoch = 0u32;
    while remaining > 0 {
        let (k, first) = heap
            .pop_live(|e| st.is_alive(e).then(|| st.sup[e as usize].get()))
            .expect("heap exhausted");
        let mut active = vec![first];
        while let Some((s, e)) = heap.pop_live(|e| st.is_alive(e).then(|| st.sup[e as usize].get()))
        {
            if s > k {
                heap.push(s, e);
                break;
            }
            active.push(e);
        }
        active.sort_unstable();
        active.dedup();
        while !active.is_empty() {
            meters.rho.add(1);
            epoch += 1;
            for &e in &active {
                theta[e as usize] = k;
            }
            remaining -= active.len();
            st.mark_peeled(&active, epoch, threads);
            let mut touched = peel_set_batch(
                &st,
                &active,
                k,
                epoch,
                threads,
                crate::count::UpdateKernel::Scattered,
                &meters,
            );
            touched.sort_unstable();
            touched.dedup();
            let mut next = Vec::new();
            for &e in &touched {
                if st.is_alive(e) {
                    let s = st.sup[e as usize].get();
                    if s <= k {
                        next.push(e);
                    } else {
                        heap.push(s, e);
                    }
                }
            }
            active = next;
        }
    }
    Decomposition {
        theta,
        stats: rec.finish(),
    }
}

/// BE_PC-style baseline: sequential two-phase peel with τ-spaced
/// candidate ranges (P = ⌈1/τ⌉), avoiding support updates from lower to
/// higher candidate subgraphs via the partitioned index — the
/// progressive-compression idea of [67] realized with this crate's
/// machinery. τ = 0.02 as in the paper's experiments.
pub fn wing_be_pc(g: &BipartiteGraph, tau: f64) -> Decomposition {
    let p = (1.0 / tau).ceil() as usize;
    wing_pbng(
        g,
        PbngConfig {
            p,
            threads: 1,
            batch: true,
            dynamic_deletes: true,
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::peel::parb::wing_parb;

    #[test]
    fn all_algorithms_agree() {
        crate::testkit::check_property("wing-all-agree", 0xA11, 6, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                8 + rng.usize_below(12),
                8 + rng.usize_below(12),
                25 + rng.usize_below(70),
                seed,
            );
            if g.m() == 0 {
                return Ok(());
            }
            let bup = wing_bup(&g).theta;
            let pbng = wing_pbng(&g, PbngConfig { p: 4, threads: 2, ..Default::default() }).theta;
            let beb = wing_be_batch(&g, 2).theta;
            let pc = wing_be_pc(&g, 0.25).theta;
            let parb = wing_parb(&g, 2).theta;
            if pbng != bup {
                return Err(format!("pbng != bup: {pbng:?} vs {bup:?}"));
            }
            if beb != bup {
                return Err(format!("be_batch != bup: {beb:?} vs {bup:?}"));
            }
            if pc != bup {
                return Err(format!("be_pc != bup: {pc:?} vs {bup:?}"));
            }
            if parb != bup {
                return Err(format!("parb != bup: {parb:?} vs {bup:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pbng_rho_beats_be_batch_rho() {
        let g = gen::zipf(70, 70, 500, 1.2, 1.2, 61);
        let pbng = wing_pbng(&g, PbngConfig { p: 4, threads: 2, ..Default::default() });
        let beb = wing_be_batch(&g, 2);
        assert!(
            pbng.stats.rho <= beb.stats.rho,
            "pbng rho {} > be_batch rho {}",
            pbng.stats.rho,
            beb.stats.rho
        );
    }

    #[test]
    fn ablations_preserve_output() {
        let g = gen::zipf(40, 40, 250, 1.2, 1.2, 62);
        let base = wing_pbng(&g, PbngConfig { p: 4, threads: 2, ..Default::default() }).theta;
        let minus = wing_pbng(
            &g,
            PbngConfig { p: 4, threads: 2, dynamic_deletes: false, ..Default::default() },
        )
        .theta;
        let minus2 = wing_pbng(
            &g,
            PbngConfig { p: 4, threads: 2, batch: false, dynamic_deletes: false, ..Default::default() },
        )
        .theta;
        assert_eq!(base, minus);
        assert_eq!(base, minus2);
    }

    #[test]
    fn phases_are_recorded() {
        let g = gen::biclique(4, 4);
        let d = wing_pbng(&g, PbngConfig { p: 2, threads: 1, ..Default::default() });
        assert_eq!(d.stats.phases.len(), 4);
        assert!(d.stats.updates > 0);
    }

    #[test]
    fn thread_count_does_not_change_output() {
        let g = gen::zipf(60, 60, 400, 1.3, 1.3, 63);
        let t1 = wing_pbng(&g, PbngConfig { p: 6, threads: 1, ..Default::default() }).theta;
        let t4 = wing_pbng(&g, PbngConfig { p: 6, threads: 4, ..Default::default() }).theta;
        assert_eq!(t1, t4);
    }

    #[test]
    fn partition_count_does_not_change_output() {
        let g = gen::zipf(50, 50, 300, 1.2, 1.2, 64);
        let base = wing_bup(&g).theta;
        for p in [1, 2, 5, 9, 33] {
            let th = wing_pbng(&g, PbngConfig { p, threads: 2, ..Default::default() }).theta;
            assert_eq!(th, base, "P={p} diverged");
        }
    }
}
