//! Durable write-ahead delta log: the storage half of the streaming
//! ingestion pipeline (the staging half is [`crate::ingest`]).
//!
//! The text delta files consumed by `pbng update` / `serve --watch` are
//! fine as one-shot inputs but unusable as a durability substrate: they
//! must be re-parsed whole on every poll, a torn write is
//! indistinguishable from a garbled line, and nothing ties "what was
//! applied" to "what is on disk". This module replaces them with an
//! append-only binary record log following the framing idioms of
//! [`crate::index::codec`] (little-endian integers, length-prefixed
//! payloads, FNV-1a 64 checksums):
//!
//! ```text
//! header   24 bytes: magic "PBNGWAL1", version u32, reserved u32,
//!          fnv64(first 16 bytes)
//! record   len: u32 | payload: len bytes | fnv64(payload): u64
//! payload  seq: u64 | count: u32 | count × 9-byte DeltaOp wire forms
//! ```
//!
//! Sequence numbers are strictly contiguous (`seq + 1` per record), so
//! a reader can tell replayed history, fresh records, and lost records
//! apart. The error taxonomy is the contract the serving layer builds
//! on:
//!
//! * **Torn tail** — the final frame extends past end-of-file (a crash
//!   mid-append, or a concurrent writer caught mid-frame). Tolerated:
//!   [`read_from`] stops at the last complete record and reports the
//!   dangling bytes; [`Writer::open`] truncates them (truncate-on-
//!   replay), which is safe because [`Writer::append`] only
//!   acknowledges a record after `fsync`.
//! * **Mid-log corruption** — a complete frame whose checksum fails, an
//!   implausible length prefix, a bad op tag, or a sequence gap.
//!   Rejected loudly ([`WalError::Corrupt`]): replaying past damage
//!   would silently diverge the maintained θ.
//! * **Rotation** — the file shrank below the reader's resume offset
//!   (an external `wal compact` or replacement).
//!   [`WalError::Rotated`] tells tailing readers to restart from the
//!   head and skip already-applied sequence numbers.
//!
//! One deliberate trade-off: a frame claiming to extend past EOF is
//! classified as *torn*, not corrupt. A bit-flipped length prefix could
//! therefore masquerade as a torn tail and truncate valid later
//! records — but only if the flipped length still lands under
//! [`MAX_RECORD_BYTES`] *and* inside the remaining file; flips past the
//! bound are caught as corruption. Sequence contiguity at the next open
//! catches the remaining cases.

pub mod checkpoint;

use crate::graph::dynamic::DeltaOp;
use crate::index::codec::fnv64;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write as _};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"PBNGWAL1";
pub const VERSION: u32 = 1;
/// File offset of the first record (magic + version + reserved + hdrsum).
pub const HEADER_LEN: u64 = 24;
/// Upper bound on one record's payload. Lengths beyond it are rejected
/// as corruption rather than interpreted as a (file-sized) torn tail.
pub const MAX_RECORD_BYTES: usize = 64 << 20;

/// Fixed per-record overhead: length prefix + seq + count + checksum.
const FRAME_OVERHEAD: usize = 4 + 8;
const PAYLOAD_MIN: usize = 12;

/// One decoded log record: a monotonic sequence number and its op batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub seq: u64,
    pub ops: Vec<DeltaOp>,
}

/// Result of reading the log from an offset: every complete record, the
/// offset just past the last one (the next tail position), and how many
/// dangling torn-tail bytes were ignored after it.
#[derive(Clone, Debug, Default)]
pub struct Tail {
    pub records: Vec<Record>,
    pub end_offset: u64,
    pub torn_bytes: u64,
}

/// Why a log read failed — the serving layer reacts differently to each
/// variant (see module docs).
#[derive(Debug)]
pub enum WalError {
    /// File shorter than the reader's resume offset: rotated/compacted.
    Rotated { offset: u64, len: u64 },
    /// Structural damage before the tail record; never auto-repaired.
    Corrupt { at: u64, what: String },
    Io(std::io::Error),
}

impl std::fmt::Display for WalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WalError::Rotated { offset, len } => write!(
                f,
                "wal rotated: resume offset {offset} past file length {len}"
            ),
            WalError::Corrupt { at, what } => {
                write!(f, "wal corrupt at offset {at}: {what}")
            }
            WalError::Io(e) => write!(f, "wal io error: {e}"),
        }
    }
}

impl std::error::Error for WalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WalError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WalError {
    fn from(e: std::io::Error) -> WalError {
        WalError::Io(e)
    }
}

fn header_bytes() -> [u8; HEADER_LEN as usize] {
    let mut h = [0u8; HEADER_LEN as usize];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    // bytes 12..16 reserved (zero)
    let sum = fnv64(&h[..16]);
    h[16..24].copy_from_slice(&sum.to_le_bytes());
    h
}

fn check_header(h: &[u8]) -> Result<(), WalError> {
    let bad = |what: &str| WalError::Corrupt { at: 0, what: what.to_string() };
    if h.len() < HEADER_LEN as usize {
        return Err(bad("short header"));
    }
    if &h[..8] != MAGIC {
        return Err(bad("bad magic (not a pbng wal)"));
    }
    let ver = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    if ver != VERSION {
        return Err(bad(&format!("unsupported wal version {ver}")));
    }
    let sum = u64::from_le_bytes(h[16..24].try_into().expect("sized slice"));
    if fnv64(&h[..16]) != sum {
        return Err(bad("header checksum mismatch"));
    }
    Ok(())
}

/// Encode one complete record frame (length prefix through checksum).
fn encode_frame(seq: u64, ops: &[DeltaOp]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(PAYLOAD_MIN + ops.len() * DeltaOp::WIRE_LEN);
    payload.extend_from_slice(&seq.to_le_bytes());
    payload.extend_from_slice(&(ops.len() as u32).to_le_bytes());
    for &op in ops {
        op.encode_into(&mut payload);
    }
    let mut frame = Vec::with_capacity(payload.len() + FRAME_OVERHEAD);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame.extend_from_slice(&fnv64(&payload).to_le_bytes());
    frame
}

fn decode_payload(payload: &[u8]) -> Result<Record, String> {
    if payload.len() < PAYLOAD_MIN {
        return Err(format!("payload too short ({} bytes)", payload.len()));
    }
    let seq = u64::from_le_bytes(payload[..8].try_into().expect("sized slice"));
    let count =
        u32::from_le_bytes(payload[8..12].try_into().expect("sized slice")) as usize;
    if payload.len() != PAYLOAD_MIN + count * DeltaOp::WIRE_LEN {
        return Err(format!(
            "op count {count} disagrees with payload length {}",
            payload.len()
        ));
    }
    let mut ops = Vec::with_capacity(count);
    for chunk in payload[PAYLOAD_MIN..].chunks_exact(DeltaOp::WIRE_LEN) {
        ops.push(DeltaOp::decode(chunk).map_err(|e| e.to_string())?);
    }
    Ok(Record { seq, ops })
}

/// Parse complete record frames out of `buf` (whose first byte sits at
/// file offset `base`), enforcing checksums and intra-read sequence
/// contiguity. An incomplete final frame becomes `torn_bytes`.
fn parse_records(buf: &[u8], base: u64) -> Result<Tail, WalError> {
    let mut records: Vec<Record> = Vec::new();
    let mut pos = 0usize;
    loop {
        let rem = buf.len() - pos;
        if rem == 0 {
            break;
        }
        if rem < 4 {
            // not even a full length prefix: torn
            break;
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().expect("sized slice"))
            as usize;
        if !(PAYLOAD_MIN..=MAX_RECORD_BYTES).contains(&len) {
            return Err(WalError::Corrupt {
                at: base + pos as u64,
                what: format!("implausible record length {len}"),
            });
        }
        if rem < 4 + len + 8 {
            // the frame claims bytes past EOF: torn tail
            break;
        }
        let payload = &buf[pos + 4..pos + 4 + len];
        let sum = u64::from_le_bytes(
            buf[pos + 4 + len..pos + 4 + len + 8]
                .try_into()
                .expect("sized slice"),
        );
        if fnv64(payload) != sum {
            return Err(WalError::Corrupt {
                at: base + pos as u64,
                what: "record checksum mismatch".to_string(),
            });
        }
        let rec = decode_payload(payload).map_err(|what| WalError::Corrupt {
            at: base + pos as u64,
            what,
        })?;
        if let Some(last) = records.last() {
            if rec.seq != last.seq + 1 {
                return Err(WalError::Corrupt {
                    at: base + pos as u64,
                    what: format!("sequence gap: {} after {}", rec.seq, last.seq),
                });
            }
        }
        records.push(rec);
        pos += 4 + len + 8;
    }
    Ok(Tail {
        records,
        end_offset: base + pos as u64,
        torn_bytes: (buf.len() - pos) as u64,
    })
}

/// Read every complete record at or after byte `offset` (which must be
/// a record boundary from a previous [`Tail::end_offset`], or `0` /
/// [`HEADER_LEN`] for the whole log). Tolerates a torn tail; rejects
/// mid-log corruption; reports [`WalError::Rotated`] when the file is
/// shorter than `offset`.
pub fn read_from(path: &Path, offset: u64) -> Result<Tail, WalError> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let start = if offset <= HEADER_LEN {
        let mut hdr = [0u8; HEADER_LEN as usize];
        if file_len < HEADER_LEN {
            return Err(WalError::Corrupt {
                at: 0,
                what: format!("short header ({file_len} bytes)"),
            });
        }
        f.read_exact(&mut hdr)?;
        check_header(&hdr)?;
        HEADER_LEN
    } else {
        if offset > file_len {
            return Err(WalError::Rotated {
                offset,
                len: file_len,
            });
        }
        f.seek(SeekFrom::Start(offset))?;
        offset
    };
    let mut buf = Vec::with_capacity((file_len.saturating_sub(start)) as usize);
    f.read_to_end(&mut buf)?;
    parse_records(&buf, start)
}

/// Replay the whole log (header validation + every record).
pub fn replay(path: &Path) -> Result<Tail, WalError> {
    read_from(path, 0)
}

/// What [`compact`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactStats {
    pub kept: usize,
    pub dropped: usize,
}

/// Rewrite the log keeping only records with `seq > keep_after`
/// (everything at or below is covered by a checkpoint). Atomic:
/// records are written to a sibling temp file which then replaces the
/// log, so readers see either the old or the new file, never a partial
/// rewrite — tailing readers observe the shrink as [`WalError::Rotated`].
pub fn compact(path: &Path, keep_after: u64) -> Result<CompactStats, WalError> {
    let tail = replay(path)?;
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| "wal".into());
    name.push(".compact-tmp");
    let tmp = path.with_file_name(name);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&header_bytes())?;
        for rec in tail.records.iter().filter(|r| r.seq > keep_after) {
            f.write_all(&encode_frame(rec.seq, &rec.ops))?;
        }
        f.sync_data()?;
    }
    std::fs::rename(&tmp, path)?;
    let kept = tail.records.iter().filter(|r| r.seq > keep_after).count();
    Ok(CompactStats {
        kept,
        dropped: tail.records.len() - kept,
    })
}

/// Append handle. Every [`Writer::append`] is flushed and `fsync`ed
/// before the sequence number is returned, so an acknowledged record is
/// durable — the invariant that makes truncate-on-replay safe.
pub struct Writer {
    file: File,
    end: u64,
    next_seq: u64,
}

impl Writer {
    /// Create (or truncate) a fresh log at `path`.
    pub fn create(path: &Path) -> Result<Writer, WalError> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.write_all(&header_bytes())?;
        file.sync_data()?;
        Ok(Writer {
            file,
            end: HEADER_LEN,
            next_seq: 1,
        })
    }

    /// Open an existing log: validate it end to end, truncate a torn
    /// tail, and position for appending. Returns the writer plus the
    /// full replay [`Tail`] (so recovery does not scan twice).
    pub fn open(path: &Path) -> Result<(Writer, Tail), WalError> {
        let tail = replay(path)?;
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        if tail.torn_bytes > 0 {
            file.set_len(tail.end_offset)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::Start(tail.end_offset))?;
        let next_seq = tail.records.last().map_or(1, |r| r.seq + 1);
        Ok((
            Writer {
                file,
                end: tail.end_offset,
                next_seq,
            },
            tail,
        ))
    }

    /// [`Writer::open`] when the file exists, else [`Writer::create`].
    pub fn open_or_create(path: &Path) -> Result<(Writer, Tail), WalError> {
        if path.exists() {
            Writer::open(path)
        } else {
            Ok((Writer::create(path)?, Tail::default()))
        }
    }

    /// Durably append one record; returns its sequence number only
    /// after the bytes are synced to disk.
    pub fn append(&mut self, ops: &[DeltaOp]) -> Result<u64, WalError> {
        if ops.len() * DeltaOp::WIRE_LEN + PAYLOAD_MIN > MAX_RECORD_BYTES {
            return Err(WalError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("record of {} ops exceeds the 64 MiB bound", ops.len()),
            )));
        }
        let seq = self.next_seq;
        let frame = encode_frame(seq, ops);
        self.file.write_all(&frame)?;
        self.file.sync_data()?;
        self.end += frame.len() as u64;
        self.next_seq += 1;
        Ok(seq)
    }

    /// Byte offset just past the last durable record.
    pub fn end_offset(&self) -> u64 {
        self.end
    }

    /// Sequence number the next [`Writer::append`] will use.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Raise the next sequence number to at least `n` — recovery calls
    /// this after loading a checkpoint whose records were compacted
    /// away, so fresh appends continue the global numbering instead of
    /// reusing burned sequence numbers.
    pub fn ensure_next_seq(&mut self, n: u64) {
        self.next_seq = self.next_seq.max(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn ops(tag: u32) -> Vec<DeltaOp> {
        vec![
            DeltaOp::Insert(tag, 0),
            DeltaOp::Remove(tag, 1),
            DeltaOp::Insert(tag + 1, 2),
        ]
    }

    #[test]
    fn append_replay_roundtrip_with_offset_tailing() {
        let dir = TempDir::new("wal-roundtrip").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        assert_eq!(w.append(&ops(0)).unwrap(), 1);
        let mid = w.end_offset();
        assert_eq!(w.append(&ops(10)).unwrap(), 2);
        assert_eq!(w.append(&[]).unwrap(), 3); // empty batches are legal
        let tail = replay(&p).unwrap();
        assert_eq!(tail.torn_bytes, 0);
        assert_eq!(tail.end_offset, w.end_offset());
        assert_eq!(
            tail.records,
            vec![
                Record { seq: 1, ops: ops(0) },
                Record { seq: 2, ops: ops(10) },
                Record { seq: 3, ops: vec![] },
            ]
        );
        // tailing from a recorded boundary skips the decoded prefix
        let rest = read_from(&p, mid).unwrap();
        assert_eq!(rest.records.len(), 2);
        assert_eq!(rest.records[0].seq, 2);
        assert_eq!(rest.end_offset, tail.end_offset);
        // tailing from the very end yields nothing
        let none = read_from(&p, tail.end_offset).unwrap();
        assert!(none.records.is_empty());
    }

    #[test]
    fn torn_tail_is_tolerated_and_truncated_on_open() {
        let dir = TempDir::new("wal-torn").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        w.append(&ops(0)).unwrap();
        w.append(&ops(5)).unwrap();
        let good_end = w.end_offset();
        drop(w);
        // simulate a crash mid-append: a full length prefix + partial payload
        let mut frame = encode_frame(3, &ops(9));
        frame.truncate(frame.len() / 2);
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(&frame).unwrap();
        drop(f);
        // readers stop at the last complete record
        let tail = replay(&p).unwrap();
        assert_eq!(tail.records.len(), 2);
        assert_eq!(tail.end_offset, good_end);
        assert!(tail.torn_bytes > 0);
        // open truncates the torn bytes and appends continue the numbering
        let (mut w, tail) = Writer::open(&p).unwrap();
        assert!(tail.torn_bytes > 0);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), good_end);
        assert_eq!(w.next_seq(), 3);
        w.append(&ops(9)).unwrap();
        let again = replay(&p).unwrap();
        assert_eq!(again.records.len(), 3);
        assert_eq!(again.torn_bytes, 0);
        assert_eq!(again.records[2], Record { seq: 3, ops: ops(9) });
    }

    #[test]
    fn midlog_corruption_is_rejected_loudly() {
        let dir = TempDir::new("wal-corrupt").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        w.append(&ops(0)).unwrap();
        w.append(&ops(5)).unwrap();
        drop(w);
        // flip one payload byte of the *first* record
        let mut bytes = std::fs::read(&p).unwrap();
        let at = HEADER_LEN as usize + 4 + 13;
        bytes[at] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = replay(&p).unwrap_err();
        assert!(
            matches!(&err, WalError::Corrupt { what, .. } if what.contains("checksum")),
            "{err}"
        );
        // open refuses too — corruption is never auto-truncated
        assert!(Writer::open(&p).is_err());
    }

    #[test]
    fn implausible_length_prefix_is_corruption_not_torn_tail() {
        let dir = TempDir::new("wal-badlen").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        w.append(&ops(0)).unwrap();
        drop(w);
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap();
        f.write_all(&[0u8; 32]).unwrap();
        drop(f);
        let err = replay(&p).unwrap_err();
        assert!(
            matches!(&err, WalError::Corrupt { what, .. } if what.contains("length")),
            "{err}"
        );
    }

    #[test]
    fn sequence_gap_is_corruption() {
        let dir = TempDir::new("wal-gap").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        w.append(&ops(0)).unwrap();
        drop(w);
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(&encode_frame(5, &ops(1))).unwrap();
        drop(f);
        let err = replay(&p).unwrap_err();
        assert!(
            matches!(&err, WalError::Corrupt { what, .. } if what.contains("sequence gap")),
            "{err}"
        );
    }

    #[test]
    fn rotation_is_detected_from_a_stale_offset() {
        let dir = TempDir::new("wal-rotate").unwrap();
        let p = dir.file("a.wal");
        let mut w = Writer::create(&p).unwrap();
        for _ in 0..3 {
            w.append(&ops(2)).unwrap();
        }
        let end = w.end_offset();
        drop(w);
        let st = compact(&p, 2).unwrap();
        assert_eq!(st, CompactStats { kept: 1, dropped: 2 });
        // a tailing reader holding the old end offset sees the shrink
        let err = read_from(&p, end).unwrap_err();
        assert!(matches!(err, WalError::Rotated { .. }), "{err}");
        // the surviving record keeps its original sequence number
        let tail = replay(&p).unwrap();
        assert_eq!(tail.records.len(), 1);
        assert_eq!(tail.records[0].seq, 3);
        // and appends resume the numbering after open
        let (mut w, _) = Writer::open(&p).unwrap();
        assert_eq!(w.append(&ops(7)).unwrap(), 4);
    }

    #[test]
    fn open_or_create_and_ensure_next_seq() {
        let dir = TempDir::new("wal-ckseq").unwrap();
        let p = dir.file("a.wal");
        let (mut w, tail) = Writer::open_or_create(&p).unwrap();
        assert!(tail.records.is_empty());
        // a checkpoint at seq 9 with a fully compacted log: appends must
        // continue at 10, not restart at 1
        w.ensure_next_seq(10);
        assert_eq!(w.append(&ops(0)).unwrap(), 10);
        let (w2, tail2) = Writer::open_or_create(&p).unwrap();
        assert_eq!(tail2.records.len(), 1);
        assert_eq!(w2.next_seq(), 11);
    }

    #[test]
    fn non_wal_files_are_rejected() {
        let dir = TempDir::new("wal-notawal").unwrap();
        let p = dir.file("a.wal");
        std::fs::write(&p, b"definitely not a wal header....").unwrap();
        assert!(matches!(
            replay(&p).unwrap_err(),
            WalError::Corrupt { .. }
        ));
        std::fs::write(&p, b"short").unwrap();
        assert!(matches!(
            replay(&p).unwrap_err(),
            WalError::Corrupt { .. }
        ));
    }
}
