//! Graph checkpoints anchoring WAL replay.
//!
//! A checkpoint is the durable companion of [`super::compact`]: it
//! captures the full edge set of the maintained graph *in its original
//! orientation* together with the sequence number of the last WAL
//! record folded into it. Recovery is then
//! `checkpoint + replay(records with seq > checkpoint.seq)`, which the
//! differential tests pin to be byte-identical in θ to a from-scratch
//! decompose. The orientation matters: `IncrementalState::new` performs
//! its own peel-side transposition for tip-V, so the checkpoint always
//! stores what the *caller* sees — the same (nu, nv, edges) the input
//! TSV had.
//!
//! Layout (little-endian throughout, mirroring `index::codec`):
//!
//! ```text
//! header  40 bytes: magic "PBNGCKP1", version u32, kind u8, pad ×3,
//!         seq u64, nu u64, nv u64
//! hdrsum  fnv64(header) u64
//! edges   one codec-style section: len u64 | len bytes of (u,v) u32
//!         pairs | fnv64(bytes) u64
//! ```

use crate::graph::{BipartiteGraph, GraphBuilder};
use crate::index::codec::fnv64;
use crate::index::ForestKind;
use anyhow::{bail, ensure, Context, Result};
use std::fs::File;
use std::io::Write as _;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"PBNGCKP1";
pub const VERSION: u32 = 1;
const HEADER_LEN: usize = 40;

/// A recovery anchor: the graph state after applying every WAL record
/// with sequence number ≤ `seq`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Checkpoint {
    pub kind: ForestKind,
    pub seq: u64,
    pub nu: usize,
    pub nv: usize,
    /// Original-orientation edge list, sorted for determinism.
    pub edges: Vec<(u32, u32)>,
}

impl Checkpoint {
    /// Capture `g` (already in original orientation) at WAL position `seq`.
    pub fn from_graph(g: &BipartiteGraph, kind: ForestKind, seq: u64) -> Checkpoint {
        let mut edges = g.edges().to_vec();
        edges.sort_unstable();
        Checkpoint {
            kind,
            seq,
            nu: g.nu(),
            nv: g.nv(),
            edges,
        }
    }

    /// Rebuild the checkpointed graph.
    pub fn graph(&self) -> BipartiteGraph {
        GraphBuilder::new()
            .nu(self.nu)
            .nv(self.nv)
            .edges(&self.edges)
            .build()
    }

    /// Atomically persist to `path` (temp-file + rename, like the
    /// index codec): a crash mid-save leaves the previous checkpoint
    /// intact.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut hdr = [0u8; HEADER_LEN];
        hdr[..8].copy_from_slice(MAGIC);
        hdr[8..12].copy_from_slice(&VERSION.to_le_bytes());
        hdr[12] = self.kind.tag();
        // bytes 13..16 pad (zero)
        hdr[16..24].copy_from_slice(&self.seq.to_le_bytes());
        hdr[24..32].copy_from_slice(&(self.nu as u64).to_le_bytes());
        hdr[32..40].copy_from_slice(&(self.nv as u64).to_le_bytes());

        let mut body = Vec::with_capacity(self.edges.len() * 8);
        for &(u, v) in &self.edges {
            body.extend_from_slice(&u.to_le_bytes());
            body.extend_from_slice(&v.to_le_bytes());
        }

        let mut name = path
            .file_name()
            .map(|n| n.to_os_string())
            .unwrap_or_else(|| "ckpt".into());
        name.push(".tmp");
        let tmp = path.with_file_name(name);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(&hdr)?;
            f.write_all(&fnv64(&hdr).to_le_bytes())?;
            f.write_all(&(body.len() as u64).to_le_bytes())?;
            f.write_all(&body)?;
            f.write_all(&fnv64(&body).to_le_bytes())?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename checkpoint into {}", path.display()))?;
        Ok(())
    }

    /// Load and fully validate a checkpoint written by [`Checkpoint::save`].
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        ensure!(
            bytes.len() >= HEADER_LEN + 8 + 8 + 8,
            "checkpoint too short ({} bytes)",
            bytes.len()
        );
        let hdr = &bytes[..HEADER_LEN];
        ensure!(&hdr[..8] == MAGIC, "bad checkpoint magic (not a pbng checkpoint)");
        let ver = u32::from_le_bytes(hdr[8..12].try_into().expect("sized slice"));
        ensure!(ver == VERSION, "unsupported checkpoint version {ver}");
        let Some(kind) = ForestKind::from_tag(hdr[12]) else {
            bail!("unknown forest kind tag {}", hdr[12]);
        };
        let seq = u64::from_le_bytes(hdr[16..24].try_into().expect("sized slice"));
        let nu = u64::from_le_bytes(hdr[24..32].try_into().expect("sized slice")) as usize;
        let nv = u64::from_le_bytes(hdr[32..40].try_into().expect("sized slice")) as usize;
        let hdrsum = u64::from_le_bytes(
            bytes[HEADER_LEN..HEADER_LEN + 8]
                .try_into()
                .expect("sized slice"),
        );
        ensure!(fnv64(hdr) == hdrsum, "checkpoint header checksum mismatch");

        let mut pos = HEADER_LEN + 8;
        let body_len = u64::from_le_bytes(
            bytes[pos..pos + 8].try_into().expect("sized slice"),
        ) as usize;
        pos += 8;
        ensure!(
            body_len % 8 == 0 && bytes.len() == pos + body_len + 8,
            "checkpoint edge section length {body_len} disagrees with file size {}",
            bytes.len()
        );
        let body = &bytes[pos..pos + body_len];
        let bodysum = u64::from_le_bytes(
            bytes[pos + body_len..pos + body_len + 8]
                .try_into()
                .expect("sized slice"),
        );
        ensure!(fnv64(body) == bodysum, "checkpoint edge checksum mismatch");

        let mut edges = Vec::with_capacity(body_len / 8);
        for pair in body.chunks_exact(8) {
            let u = u32::from_le_bytes(pair[..4].try_into().expect("sized slice"));
            let v = u32::from_le_bytes(pair[4..].try_into().expect("sized slice"));
            ensure!(
                (u as usize) < nu && (v as usize) < nv,
                "checkpoint edge ({u}, {v}) outside universe {nu}x{nv}"
            );
            edges.push((u, v));
        }
        Ok(Checkpoint {
            kind,
            seq,
            nu,
            nv,
            edges,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testkit::TempDir;

    #[test]
    fn checkpoint_roundtrips_for_all_kinds() {
        let dir = TempDir::new("ckpt-roundtrip").unwrap();
        let g = gen::erdos(40, 44, 180, 7);
        for kind in [ForestKind::Wing, ForestKind::TipU, ForestKind::TipV] {
            let p = dir.file(&format!("ck-{}.bin", kind.tag()));
            let ck = Checkpoint::from_graph(&g, kind, 17);
            ck.save(&p).unwrap();
            let back = Checkpoint::load(&p).unwrap();
            assert_eq!(back, ck);
            let rg = back.graph();
            assert_eq!((rg.nu(), rg.nv(), rg.m()), (g.nu(), g.nv(), g.m()));
            let mut want = g.edges().to_vec();
            want.sort_unstable();
            assert_eq!(rg.edges(), &want[..]);
        }
    }

    #[test]
    fn corruption_and_foreign_files_are_rejected() {
        let dir = TempDir::new("ckpt-corrupt").unwrap();
        let g = gen::erdos(10, 10, 25, 3);
        let p = dir.file("ck.bin");
        Checkpoint::from_graph(&g, ForestKind::Wing, 5).save(&p).unwrap();

        let mut bytes = std::fs::read(&p).unwrap();
        let flip = bytes.len() - 12; // inside the edge body
        bytes[flip] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");

        std::fs::write(&p, b"not a checkpoint at all, sorry........").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
