//! Mempool-style ingestion staging: the in-memory half of the streaming
//! pipeline (the durable half is [`crate::wal`]).
//!
//! Ops arriving over the wire (or tailed from the WAL) are not applied
//! one by one — the incremental engine amortizes much better over
//! batches, and live streams are full of redundancy: the same edge
//! re-inserted, an insert immediately followed by its remove. The
//! [`Pool`] stages pending ops keyed by edge, so at most one op per
//! edge survives (*last-op-wins*, which is exact under set semantics:
//! the final presence of an edge depends only on the last op that
//! touched it, and θ depends only on the final graph). Batches are
//! formed when either a size target or a latency deadline is hit —
//! the same two triggers muta's `core/mempool` uses for block package
//! formation.
//!
//! [`AdaptiveFallback`] closes the control loop on the incremental
//! engine's rebuild heuristic: it tracks an EWMA of the observed
//! invalidated-partition fraction and lowers the fallback threshold
//! while the stream is churning wide swaths of the hierarchy (full
//! rebuilds are then cheaper than many near-total incremental passes),
//! drifting back toward the configured base as the stream quiets.

use crate::engine::incremental::UpdateStats;
use crate::graph::dynamic::{DeltaBatch, DeltaOp};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Form a batch as soon as this many distinct edges are staged;
    /// also the chunk size when draining.
    pub max_batch: usize,
    /// Form a batch once the oldest staged op has waited this long.
    /// `Duration::ZERO` means "drain whenever non-empty".
    pub max_delay: Duration,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            max_batch: 256,
            max_delay: Duration::from_millis(200),
        }
    }
}

/// What [`Pool::push`] did with an op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Staged {
    /// First pending op for this edge.
    New,
    /// Replaced an identical pending op (duplicate submission).
    Coalesced,
    /// Replaced the opposing op for this edge (insert↔remove).
    Cancelled,
}

/// Cumulative pool activity, kept local (not in the global registry) so
/// tests stay deterministic; the serving layer mirrors these into
/// `pbng::obs` counters after each drain.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Ops accepted by [`Pool::push`].
    pub staged: u64,
    /// Duplicate submissions absorbed.
    pub coalesced: u64,
    /// Opposing insert/remove pairs collapsed.
    pub cancelled: u64,
    /// Batches emitted by [`Pool::take_ready`].
    pub batches: u64,
}

/// Staging pool: at most one pending op per edge, drained in
/// deterministic (sorted edge key) order.
pub struct Pool {
    cfg: PoolConfig,
    staged: BTreeMap<(u32, u32), DeltaOp>,
    /// When the pool last became non-empty (the latency-deadline anchor).
    since: Option<Instant>,
    stats: PoolStats,
}

impl Pool {
    pub fn new(cfg: PoolConfig) -> Pool {
        Pool {
            cfg,
            staged: BTreeMap::new(),
            since: None,
            stats: PoolStats::default(),
        }
    }

    /// Number of distinct edges currently staged.
    pub fn len(&self) -> usize {
        self.staged.len()
    }

    pub fn is_empty(&self) -> bool {
        self.staged.is_empty()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Stage one op, coalescing against any pending op on the same edge.
    pub fn push(&mut self, op: DeltaOp, now: Instant) -> Staged {
        if self.staged.is_empty() {
            self.since = Some(now);
        }
        self.stats.staged += 1;
        match self.staged.insert(op.key(), op) {
            None => Staged::New,
            Some(prev) if prev == op => {
                self.stats.coalesced += 1;
                Staged::Coalesced
            }
            Some(_) => {
                self.stats.cancelled += 1;
                Staged::Cancelled
            }
        }
    }

    /// Would [`Pool::take_ready`] emit batches right now?
    pub fn ready(&self, now: Instant, forced: bool) -> bool {
        if self.staged.is_empty() {
            return false;
        }
        if forced || self.staged.len() >= self.cfg.max_batch {
            return true;
        }
        self.since
            .is_some_and(|s| now.saturating_duration_since(s) >= self.cfg.max_delay)
    }

    /// Drain every staged op into `max_batch`-sized [`DeltaBatch`]es if
    /// a formation trigger (size, deadline, or `forced`) has fired.
    /// Returns the batches plus the staging lag — how long the oldest
    /// op waited in the pool.
    pub fn take_ready(
        &mut self,
        now: Instant,
        forced: bool,
    ) -> Option<(Vec<DeltaBatch>, Duration)> {
        if !self.ready(now, forced) {
            return None;
        }
        let lag = self
            .since
            .take()
            .map_or(Duration::ZERO, |s| now.saturating_duration_since(s));
        let ops: Vec<DeltaOp> = std::mem::take(&mut self.staged).into_values().collect();
        let batches: Vec<DeltaBatch> = ops
            .chunks(self.cfg.max_batch.max(1))
            .map(|c| DeltaBatch::new(c.to_vec()))
            .collect();
        self.stats.batches += batches.len() as u64;
        Some((batches, lag))
    }
}

/// EWMA controller for the incremental engine's full-rebuild threshold.
///
/// `observe` folds one apply's invalidated-partition fraction into the
/// running average and returns the threshold to install before the next
/// apply: `base · (1 − 0.8·ewma)`, clamped to `[min(0.05, base), base]`.
/// A quiet stream (ewma → 0) keeps the configured base; a stream that
/// keeps invalidating most partitions drives the threshold down so the
/// engine flips to (cheaper) full rebuilds sooner.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveFallback {
    base: f64,
    ewma: f64,
    alpha: f64,
}

impl AdaptiveFallback {
    pub fn new(base: f64) -> AdaptiveFallback {
        AdaptiveFallback {
            base: base.clamp(0.0, 1.0),
            ewma: 0.0,
            alpha: 0.3,
        }
    }

    /// Current threshold without new evidence.
    pub fn threshold(&self) -> f64 {
        let t = self.base * (1.0 - 0.8 * self.ewma);
        t.clamp(0.05_f64.min(self.base), self.base)
    }

    /// Fold in one apply's stats; returns the updated threshold.
    pub fn observe(&mut self, up: &UpdateStats) -> f64 {
        let frac = if up.total_partitions == 0 {
            0.0
        } else {
            up.invalidated_partitions as f64 / up.total_partitions as f64
        };
        self.ewma = self.alpha * frac + (1.0 - self.alpha) * self.ewma;
        self.threshold()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn coalesces_duplicates_and_cancels_opposing_ops() {
        let mut p = Pool::new(PoolConfig {
            max_batch: 16,
            max_delay: Duration::ZERO,
        });
        let now = t0();
        assert_eq!(p.push(DeltaOp::Insert(1, 2), now), Staged::New);
        assert_eq!(p.push(DeltaOp::Insert(1, 2), now), Staged::Coalesced);
        assert_eq!(p.push(DeltaOp::Remove(1, 2), now), Staged::Cancelled);
        assert_eq!(p.push(DeltaOp::Remove(3, 0), now), Staged::New);
        assert_eq!(p.len(), 2);
        let (batches, _) = p.take_ready(now, false).unwrap();
        assert_eq!(batches.len(), 1);
        // last-op-wins, drained in sorted edge order
        assert_eq!(
            batches[0].ops,
            vec![DeltaOp::Remove(1, 2), DeltaOp::Remove(3, 0)]
        );
        let st = p.stats();
        assert_eq!(
            st,
            PoolStats { staged: 4, coalesced: 1, cancelled: 1, batches: 1 }
        );
        assert!(p.is_empty());
    }

    #[test]
    fn size_trigger_fires_without_deadline() {
        let mut p = Pool::new(PoolConfig {
            max_batch: 3,
            max_delay: Duration::from_secs(3600),
        });
        let now = t0();
        p.push(DeltaOp::Insert(0, 0), now);
        p.push(DeltaOp::Insert(0, 1), now);
        assert!(!p.ready(now, false));
        assert!(p.take_ready(now, false).is_none());
        p.push(DeltaOp::Insert(0, 2), now);
        assert!(p.ready(now, false));
        let (batches, _) = p.take_ready(now, false).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].ops.len(), 3);
    }

    #[test]
    fn deadline_trigger_uses_oldest_staged_age() {
        let mut p = Pool::new(PoolConfig {
            max_batch: 100,
            max_delay: Duration::from_millis(50),
        });
        let start = t0();
        p.push(DeltaOp::Insert(0, 0), start);
        assert!(!p.ready(start, false));
        // later pushes do not reset the anchor
        p.push(DeltaOp::Insert(1, 1), start + Duration::from_millis(40));
        let late = start + Duration::from_millis(55);
        assert!(p.ready(late, false));
        let (batches, lag) = p.take_ready(late, false).unwrap();
        assert_eq!(batches[0].ops.len(), 2);
        assert_eq!(lag, Duration::from_millis(55));
        // after a drain the anchor resets
        let now2 = late + Duration::from_millis(1);
        p.push(DeltaOp::Insert(2, 2), now2);
        assert!(!p.ready(now2, false));
    }

    #[test]
    fn forced_drains_any_nonempty_pool_and_chunks_batches() {
        let mut p = Pool::new(PoolConfig {
            max_batch: 4,
            max_delay: Duration::from_secs(3600),
        });
        let now = t0();
        assert!(p.take_ready(now, true).is_none()); // forced + empty = nothing
        for i in 0..10u32 {
            p.push(DeltaOp::Insert(i, i), now);
        }
        let (batches, _) = p.take_ready(now, true).unwrap();
        assert_eq!(
            batches.iter().map(|b| b.ops.len()).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
        assert_eq!(p.stats().batches, 3);
    }

    #[test]
    fn adaptive_fallback_tracks_invalidation_and_clamps() {
        let mut ctl = AdaptiveFallback::new(0.25);
        assert!((ctl.threshold() - 0.25).abs() < 1e-12);
        let mut quiet = UpdateStats::default();
        quiet.total_partitions = 10;
        quiet.invalidated_partitions = 0;
        assert!((ctl.observe(&quiet) - 0.25).abs() < 1e-12);

        let mut noisy = UpdateStats::default();
        noisy.total_partitions = 10;
        noisy.invalidated_partitions = 10;
        let mut last = ctl.threshold();
        for _ in 0..20 {
            let t = ctl.observe(&noisy);
            assert!(t <= last + 1e-12);
            last = t;
        }
        // converges toward base·0.2 but never below the floor
        assert!(last >= 0.05 - 1e-12);
        assert!(last < 0.25);

        // zero denominators are treated as "no evidence"
        let empty = UpdateStats::default();
        let before = ctl.threshold();
        let after = ctl.observe(&empty);
        assert!(after >= before); // ewma decays toward zero → threshold rises

        // a tiny base clamps to itself, not to 0.05
        let ctl2 = AdaptiveFallback::new(0.01);
        assert!((ctl2.threshold() - 0.01).abs() < 1e-12);
    }
}
