//! Decomposition index: the nested-component forest (merge tree) of the
//! k-wing / k-tip hierarchy, built once and queried many times.
//!
//! The paper frames peeling output as a *space-efficient index* (§2.2):
//! once θ numbers are known, every k-wing / k-tip is reconstructible on
//! demand. [`crate::hierarchy::kwing_components`] does that per level with
//! a fresh union-find over all blooms — `O(levels × (blooms + m))` to walk
//! the whole hierarchy. This module instead builds the **nested-component
//! forest** in a *single* sweep over θ levels, descending from the densest
//! level: entities and bloom wedges activate at their level, an
//! incremental union-find (union by size + path halving, `O(m α)` total)
//! merges components, and every time a component's composition changes a
//! forest node is sealed. Each node records its level `k`, the entities
//! that first appear in it, its parent (the containing component at the
//! next lower level), and density stats over its subtree.
//!
//! Nodes are laid out in DFS preorder with members grouped per node, so a
//! node's *subtree* — i.e. the full entity set of the component it roots —
//! is one contiguous span of the flat `members` array. That makes the
//! on-disk format ([`codec`]) a handful of flat, mmap-friendly arrays and
//! makes `kwing(k)` a cut through the forest: the maximal nodes with
//! `level ≥ k`, each answering with one contiguous span.
//!
//! Query serving lives in [`query`] (LRU-cached level materialization) and
//! [`server`] (line protocol over stdin/TCP); persistence in [`codec`].

pub mod codec;
pub mod query;
pub mod server;

use crate::beindex::BeIndex;
use crate::graph::BipartiteGraph;
use crate::hierarchy::{LevelSummary, UnionFind};
use crate::par::{parallel_for_chunked, RacyBuf, RacyCell};

/// Sentinel for "no node / no parent".
pub const NONE: u32 = u32::MAX;

/// What the forest's entities and levels mean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForestKind {
    /// Entities are edge ids; levels are wing numbers θ_e.
    Wing,
    /// Entities are U-side vertex ids; levels are tip numbers θ_u.
    TipU,
    /// Entities are V-side vertex ids; levels are tip numbers θ_v.
    TipV,
}

impl ForestKind {
    pub fn tag(self) -> u8 {
        match self {
            ForestKind::Wing => 0,
            ForestKind::TipU => 1,
            ForestKind::TipV => 2,
        }
    }
    pub fn from_tag(t: u8) -> Option<ForestKind> {
        match t {
            0 => Some(ForestKind::Wing),
            1 => Some(ForestKind::TipU),
            2 => Some(ForestKind::TipV),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            ForestKind::Wing => "wing",
            ForestKind::TipU => "tip-u",
            ForestKind::TipV => "tip-v",
        }
    }
    pub fn entity_name(self) -> &'static str {
        match self {
            ForestKind::Wing => "edge",
            ForestKind::TipU | ForestKind::TipV => "vertex",
        }
    }
}

/// The nested-component forest. Immutable after build; all arrays are
/// flat and indexed by DFS-preorder node id, so `save`/`load` are
/// straight section dumps ([`codec`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Forest {
    pub kind: ForestKind,
    /// θ per entity (`m` values for wing, side vertex count for tip).
    pub theta: Vec<u64>,
    /// Distinct levels at which components form or merge, ascending.
    pub levels: Vec<u64>,
    /// Level k of each node (the highest level where this exact
    /// component exists).
    pub node_level: Vec<u64>,
    /// Parent node (containing component at the next lower level where
    /// composition changes); [`NONE`] for roots.
    pub parent: Vec<u32>,
    /// DFS preorder: subtree of `n` is nodes `n..subtree_end[n]`.
    pub subtree_end: Vec<u32>,
    /// CSR offsets (`n_nodes + 1`) into `members`: entities *introduced*
    /// at node `n` (first level at which they join any component).
    /// Because nodes are in DFS preorder, the full entity set of the
    /// component rooted at `n` is the contiguous span
    /// `members[member_off[n] .. member_off[subtree_end[n]]]`.
    pub member_off: Vec<u32>,
    pub members: Vec<u32>,
    /// Distinct U vertices in the subtree (wing) / subtree entity count
    /// (tip).
    pub sub_nu: Vec<u32>,
    /// Distinct V vertices in the subtree (wing) / 0 (tip).
    pub sub_nv: Vec<u32>,
}

impl Forest {
    pub fn n_nodes(&self) -> usize {
        self.node_level.len()
    }
    pub fn n_entities(&self) -> usize {
        self.theta.len()
    }
    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Entities introduced at node `n` (not the whole component).
    pub fn own_members(&self, n: u32) -> &[u32] {
        &self.members[self.member_off[n as usize] as usize..self.member_off[n as usize + 1] as usize]
    }

    /// Full entity set of the component rooted at `n`: contiguous span
    /// covering the subtree (DFS layout invariant).
    pub fn subtree_members(&self, n: u32) -> &[u32] {
        let s = self.member_off[n as usize] as usize;
        let e = self.member_off[self.subtree_end[n as usize] as usize] as usize;
        &self.members[s..e]
    }

    /// Component size (entity count) of the component rooted at `n`.
    pub fn sub_size(&self, n: u32) -> usize {
        self.subtree_members(n).len()
    }

    /// Root nodes in DFS order.
    pub fn roots(&self) -> Vec<u32> {
        let mut out = Vec::new();
        let mut r = 0u32;
        while (r as usize) < self.n_nodes() {
            out.push(r);
            r = self.subtree_end[r as usize];
        }
        out
    }

    /// Direct children of `n` in DFS order.
    pub fn children(&self, n: u32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut c = n + 1;
        while c < self.subtree_end[n as usize] {
            out.push(c);
            c = self.subtree_end[c as usize];
        }
        out
    }

    /// Path from `n` up to its root (inclusive both ends).
    pub fn path_to_root(&self, n: u32) -> Vec<u32> {
        let mut out = vec![n];
        let mut cur = n;
        while self.parent[cur as usize] != NONE {
            cur = self.parent[cur as usize];
            out.push(cur);
        }
        out
    }

    /// Density statistic used for ranking: edges / (|U|·|V|) of the
    /// component subgraph for wing forests (the biclique fill ratio);
    /// the level itself for tip forests (deeper ⇒ denser).
    pub fn density(&self, n: u32) -> f64 {
        match self.kind {
            ForestKind::Wing => {
                let cells = self.sub_nu[n as usize] as f64 * self.sub_nv[n as usize] as f64;
                if cells == 0.0 {
                    0.0
                } else {
                    self.sub_size(n) as f64 / cells
                }
            }
            ForestKind::TipU | ForestKind::TipV => self.node_level[n as usize] as f64,
        }
    }

    /// The forest cut at level `k`: maximal nodes with `level ≥ k`. Each
    /// is the root of exactly one k-level component.
    pub fn cut(&self, k: u64) -> Vec<u32> {
        let mut out = Vec::new();
        for n in 0..self.n_nodes() as u32 {
            if self.node_level[n as usize] >= k {
                let p = self.parent[n as usize];
                if p == NONE || self.node_level[p as usize] < k {
                    out.push(n);
                }
            }
        }
        out
    }

    /// Materialize the k-level components, in the exact shape
    /// [`crate::hierarchy::kwing_components`] produces: each component
    /// sorted ascending, components ordered by first entity.
    pub fn components(&self, k: u64) -> Vec<Vec<u32>> {
        let mut out: Vec<Vec<u32>> = self
            .cut(k)
            .into_iter()
            .map(|n| {
                let mut c = self.subtree_members(n).to_vec();
                c.sort_unstable();
                c
            })
            .collect();
        out.sort_by_key(|c| c.first().copied());
        out
    }

    /// Inverse member map: entity → node that introduced it ([`NONE`] for
    /// entities never part of any component, e.g. butterfly-free edges).
    pub fn entity_nodes(&self) -> Vec<u32> {
        let mut out = vec![NONE; self.n_entities()];
        for n in 0..self.n_nodes() as u32 {
            for &e in self.own_members(n) {
                out[e as usize] = n;
            }
        }
        out
    }

    /// Structural invariants; used by tests and by [`codec::load`] to
    /// reject files that pass checksums but encode nonsense.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_nodes();
        if self.parent.len() != n
            || self.subtree_end.len() != n
            || self.sub_nu.len() != n
            || self.sub_nv.len() != n
        {
            return Err("node array lengths disagree".into());
        }
        if self.member_off.len() != n + 1 {
            return Err("member_off length must be n_nodes + 1".into());
        }
        if self.member_off.first() != Some(&0)
            || self.member_off.last().copied() != Some(self.members.len() as u32)
        {
            return Err("member_off must span the members array".into());
        }
        if self.member_off.windows(2).any(|w| w[0] > w[1]) {
            return Err("member_off not monotone".into());
        }
        if self.levels.windows(2).any(|w| w[0] >= w[1]) {
            return Err("levels not strictly ascending".into());
        }
        for i in 0..n {
            let end = self.subtree_end[i] as usize;
            if end <= i || end > n {
                return Err(format!("node {i}: bad subtree_end {end}"));
            }
            let p = self.parent[i];
            if p != NONE {
                let p = p as usize;
                if p >= n {
                    return Err(format!("node {i}: parent out of range"));
                }
                // DFS preorder: parent precedes and contains the child
                if p >= i || self.subtree_end[p] as usize <= i {
                    return Err(format!("node {i}: not inside parent {p} span"));
                }
                if self.node_level[p] >= self.node_level[i] {
                    return Err(format!("node {i}: level not above parent level"));
                }
            }
        }
        let ne = self.n_entities() as u32;
        if self.members.iter().any(|&e| e >= ne) {
            return Err("member entity id out of range".into());
        }
        let mut seen = vec![false; ne as usize];
        for &e in &self.members {
            if seen[e as usize] {
                return Err(format!("entity {e} introduced twice"));
            }
            seen[e as usize] = true;
        }
        Ok(())
    }
}

/// Incremental forest construction. Feed levels strictly descending;
/// within a level, `activate` entities and `union` connected pairs; the
/// builder seals changed components into nodes at each level boundary.
pub struct ForestBuilder {
    uf: UnionFind,
    present: Vec<bool>,
    /// Node currently representing the component; indexed by entity id,
    /// meaningful only at union-find roots.
    node_at: Vec<u32>,
    /// Entities that joined since the component's last node; per root.
    pending: Vec<Vec<u32>>,
    /// Nodes of components absorbed since the last seal; per root.
    children_acc: Vec<Vec<u32>>,
    touched: Vec<u32>,
    mark: Vec<bool>,
    cur_level: Option<u64>,
    levels_desc: Vec<u64>,
    tmp_level: Vec<u64>,
    tmp_children: Vec<Vec<u32>>,
    tmp_members: Vec<Vec<u32>>,
}

impl ForestBuilder {
    pub fn new(n_entities: usize) -> Self {
        ForestBuilder {
            uf: UnionFind::new(n_entities),
            present: vec![false; n_entities],
            node_at: vec![NONE; n_entities],
            pending: vec![Vec::new(); n_entities],
            children_acc: vec![Vec::new(); n_entities],
            touched: Vec::new(),
            mark: vec![false; n_entities],
            cur_level: None,
            levels_desc: Vec::new(),
            tmp_level: Vec::new(),
            tmp_children: Vec::new(),
            tmp_members: Vec::new(),
        }
    }

    /// Start processing level `k`; must be strictly below the previous
    /// level. Seals the components changed at the previous level.
    pub fn begin_level(&mut self, k: u64) {
        if let Some(prev) = self.cur_level {
            assert!(k < prev, "levels must be fed strictly descending");
        }
        self.seal();
        self.levels_desc.push(k);
        self.cur_level = Some(k);
    }

    fn touch(&mut self, e: u32) {
        if !self.mark[e as usize] {
            self.mark[e as usize] = true;
            self.touched.push(e);
        }
    }

    /// Entity becomes part of some component at the current level.
    pub fn activate(&mut self, e: u32) {
        if !self.present[e as usize] {
            self.present[e as usize] = true;
            // a never-present entity is its own union-find root
            self.pending[e as usize].push(e);
            self.touch(e);
        }
    }

    /// Entities `a` and `b` are connected at the current level
    /// (activating both if needed).
    pub fn union(&mut self, a: u32, b: u32) {
        self.activate(a);
        self.activate(b);
        if let Some((w, l)) = self.uf.union_roots(a, b) {
            if self.node_at[l as usize] != NONE {
                self.children_acc[w as usize].push(self.node_at[l as usize]);
                self.node_at[l as usize] = NONE;
            }
            let mut p = std::mem::take(&mut self.pending[l as usize]);
            self.pending[w as usize].append(&mut p);
            let mut c = std::mem::take(&mut self.children_acc[l as usize]);
            self.children_acc[w as usize].append(&mut c);
            self.touch(w);
        }
    }

    /// Seal every component changed at the current level into a node.
    fn seal(&mut self) {
        let Some(k) = self.cur_level else {
            return;
        };
        let touched = std::mem::take(&mut self.touched);
        for &t in &touched {
            self.mark[t as usize] = false;
        }
        // distinct roots of the touched entities (post-union)
        let mut roots = Vec::new();
        for &t in &touched {
            let r = self.uf.find(t);
            if !self.mark[r as usize] {
                self.mark[r as usize] = true;
                roots.push(r);
            }
        }
        for &r in &roots {
            self.mark[r as usize] = false;
            let mut ch = std::mem::take(&mut self.children_acc[r as usize]);
            let mut mem = std::mem::take(&mut self.pending[r as usize]);
            if self.node_at[r as usize] != NONE {
                ch.push(self.node_at[r as usize]);
            }
            if ch.len() == 1 && mem.is_empty() {
                // composition unchanged — keep the existing node
                self.node_at[r as usize] = ch[0];
                continue;
            }
            if ch.is_empty() && mem.is_empty() {
                continue;
            }
            mem.sort_unstable();
            let id = self.tmp_level.len() as u32;
            self.tmp_level.push(k);
            self.tmp_children.push(ch);
            self.tmp_members.push(mem);
            self.node_at[r as usize] = id;
        }
    }

    /// Finish the sweep: seal the last level and lay the forest out in
    /// DFS preorder with per-node member grouping. `theta` is retained
    /// for membership queries; density stats start zeroed (see
    /// [`build_wing_forest`] / [`build_tip_forest`]).
    pub fn finish(mut self, kind: ForestKind, theta: Vec<u64>) -> Forest {
        self.seal();
        let nt = self.tmp_level.len();
        // parent links from children lists
        let mut tmp_parent = vec![NONE; nt];
        for (n, ch) in self.tmp_children.iter().enumerate() {
            for &c in ch {
                tmp_parent[c as usize] = n as u32;
            }
        }
        // smallest entity of each subtree: children always have smaller
        // tmp ids than their parent (created at a higher level), so one
        // ascending pass suffices; used for deterministic ordering.
        let mut min_entity = vec![u32::MAX; nt];
        for n in 0..nt {
            let own = self.tmp_members[n].first().copied().unwrap_or(u32::MAX);
            let chmin = self.tmp_children[n]
                .iter()
                .map(|&c| min_entity[c as usize])
                .min()
                .unwrap_or(u32::MAX);
            min_entity[n] = own.min(chmin);
        }
        for ch in self.tmp_children.iter_mut() {
            ch.sort_unstable_by_key(|&c| min_entity[c as usize]);
        }
        let mut tmp_roots: Vec<u32> = (0..nt as u32)
            .filter(|&n| tmp_parent[n as usize] == NONE)
            .collect();
        tmp_roots.sort_unstable_by_key(|&n| min_entity[n as usize]);
        // subtree sizes bottom-up (children before parents in tmp order)
        let mut size = vec![1u32; nt];
        for n in 0..nt {
            for &c in &self.tmp_children[n] {
                let s = size[c as usize];
                size[n] += s;
            }
        }
        // DFS preorder
        let mut order = Vec::with_capacity(nt); // preorder list of tmp ids
        let mut stack: Vec<u32> = tmp_roots.iter().rev().copied().collect();
        while let Some(n) = stack.pop() {
            order.push(n);
            for &c in self.tmp_children[n as usize].iter().rev() {
                stack.push(c);
            }
        }
        debug_assert_eq!(order.len(), nt);
        let mut new_id = vec![NONE; nt];
        for (i, &old) in order.iter().enumerate() {
            new_id[old as usize] = i as u32;
        }
        let mut node_level = Vec::with_capacity(nt);
        let mut parent = Vec::with_capacity(nt);
        let mut subtree_end = Vec::with_capacity(nt);
        let mut member_off = Vec::with_capacity(nt + 1);
        let mut members = Vec::new();
        member_off.push(0u32);
        for (i, &old) in order.iter().enumerate() {
            node_level.push(self.tmp_level[old as usize]);
            let p = tmp_parent[old as usize];
            parent.push(if p == NONE { NONE } else { new_id[p as usize] });
            subtree_end.push(i as u32 + size[old as usize]);
            members.extend_from_slice(&self.tmp_members[old as usize]);
            member_off.push(members.len() as u32);
        }
        let mut levels = self.levels_desc;
        levels.reverse();
        // drop fed levels at which nothing ever happened
        let used: std::collections::HashSet<u64> = node_level.iter().copied().collect();
        levels.retain(|k| used.contains(k));
        let nt_f = node_level.len();
        Forest {
            kind,
            theta,
            levels,
            node_level,
            parent,
            subtree_end,
            member_off,
            members,
            sub_nu: vec![0; nt_f],
            sub_nv: vec![0; nt_f],
        }
    }
}

/// Build the wing forest: one descending sweep over the bloom wedges of
/// the BE-Index. A wedge (twin-edge pair) of bloom `B` activates at
/// `min(θ_e, θ_t)`; once `B` has ≥ 2 active wedges, all their edges are
/// pairwise butterfly-connected (Property 1) and merge. Harvesting the
/// wedge events is parallel over blooms; the union-find sweep itself is
/// sequential and `O(W α)` in the number of wedges `W`.
pub fn build_wing_forest(
    g: &BipartiteGraph,
    idx: &BeIndex,
    theta: &[u64],
    threads: usize,
) -> Forest {
    build_wing_forest_opts(g, idx, theta, threads, true)
}

/// [`build_wing_forest`] with the subtree density-stats pass optional:
/// summaries and pure component queries never read `sub_nu`/`sub_nv`, and
/// the stats pass is the only super-linear step (`O(Σ subtree sizes)`).
pub fn build_wing_forest_opts(
    g: &BipartiteGraph,
    idx: &BeIndex,
    theta: &[u64],
    threads: usize,
    with_stats: bool,
) -> Forest {
    assert_eq!(theta.len(), g.m(), "theta must be per-edge wing numbers");
    let nb = idx.n_blooms();
    let threads = threads.max(1);
    let lanes = crate::par::max_lanes(threads);
    // (level, bloom, e, t) wedge-activation events, harvested in parallel
    let mut buffers: Vec<RacyCell<Vec<(u64, u32, u32, u32)>>> =
        (0..lanes).map(|_| RacyCell::new(Vec::new())).collect();
    parallel_for_chunked(nb, threads, 64, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so buffer `t` is exclusively ours in this chunk.
        let mut buf = unsafe { buffers[t].get_mut() };
        for b in lo..hi {
            for &(e, tw) in idx.entries(b as u32) {
                if e < tw {
                    continue; // count each wedge once
                }
                let mw = theta[e as usize].min(theta[tw as usize]);
                if mw >= 1 {
                    buf.push((mw, b as u32, e, tw));
                }
            }
        }
    });
    let mut events: Vec<(u64, u32, u32, u32)> = Vec::new();
    for b in buffers.iter_mut() {
        events.append(b.as_mut()); // region over: exclusive access
    }
    // full deterministic order: by level descending, then bloom/edge ids
    events.sort_unstable_by(|a, b| b.0.cmp(&a.0).then_with(|| (a.1, a.2, a.3).cmp(&(b.1, b.2, b.3))));

    let mut fb = ForestBuilder::new(g.m());
    let mut bloom_active = vec![0u32; nb];
    let mut bloom_first = vec![(0u32, 0u32); nb];
    let mut cur: Option<u64> = None;
    for &(level, b, e, t) in &events {
        if cur != Some(level) {
            fb.begin_level(level);
            cur = Some(level);
        }
        let bi = b as usize;
        bloom_active[bi] += 1;
        match bloom_active[bi] {
            1 => bloom_first[bi] = (e, t), // one wedge = no butterfly yet
            2 => {
                let (e0, t0) = bloom_first[bi];
                fb.union(e0, t0);
                fb.union(e, t);
                fb.union(e0, e);
            }
            _ => {
                fb.union(e, t);
                fb.union(bloom_first[bi].0, e);
            }
        }
    }
    let mut forest = fb.finish(ForestKind::Wing, theta.to_vec());
    if with_stats {
        compute_wing_stats(&mut forest, g, threads);
    }
    forest
}

/// Build a tip forest for one side. The repo's tip hierarchy is the
/// nested vertex-set chain (`ktip_vertices` per level): every vertex with
/// θ ≥ k belongs to the single k-level set, so the forest is one chain of
/// nodes, each introducing the vertices of its level.
pub fn build_tip_forest(theta: &[u64], kind: ForestKind) -> Forest {
    assert!(matches!(kind, ForestKind::TipU | ForestKind::TipV));
    let mut order: Vec<u32> = (0..theta.len() as u32)
        .filter(|&v| theta[v as usize] > 0)
        .collect();
    order.sort_unstable_by(|&a, &b| {
        theta[b as usize]
            .cmp(&theta[a as usize])
            .then(a.cmp(&b))
    });
    let mut fb = ForestBuilder::new(theta.len());
    let mut cur: Option<u64> = None;
    let mut anchor: Option<u32> = None;
    for &v in &order {
        let k = theta[v as usize];
        if cur != Some(k) {
            fb.begin_level(k);
            cur = Some(k);
        }
        match anchor {
            None => {
                fb.activate(v);
                anchor = Some(v);
            }
            Some(a) => fb.union(a, v),
        }
    }
    let mut forest = fb.finish(kind, theta.to_vec());
    for n in 0..forest.n_nodes() as u32 {
        forest.sub_nu[n as usize] = forest.sub_size(n) as u32;
        forest.sub_nv[n as usize] = 0;
    }
    forest
}

/// Fill `sub_nu` / `sub_nv`: distinct U / V endpoints of each node's
/// subtree edge span. Parallel over nodes with per-thread stamp scratch;
/// each node index is written by exactly one chunk iteration. Costs
/// `O(Σ subtree sizes)` ≤ `O(m · depth)` — a one-off build step.
fn compute_wing_stats(forest: &mut Forest, g: &BipartiteGraph, threads: usize) {
    let n = forest.n_nodes();
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    // Many lanes scatter into disjoint node indices of these shared
    // buffers, so they are `RacyBuf`s (element-granular cells), not
    // whole-value `RacyCell`s.
    let sub_nu = RacyBuf::new(vec![0u32; n]);
    let sub_nv = RacyBuf::new(vec![0u32; n]);
    let scratch: Vec<RacyCell<(Vec<u32>, Vec<u32>)>> = (0..crate::par::max_lanes(threads))
        .map(|_| RacyCell::new((vec![NONE; g.nu()], vec![NONE; g.nv()])))
        .collect();
    let f: &Forest = forest;
    parallel_for_chunked(n, threads, 8, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so stamp pair `t` is exclusively ours in this chunk.
        let mut sc = unsafe { scratch[t].get_mut() };
        let (stamp_u, stamp_v) = &mut *sc;
        for node in lo..hi {
            let mut cu = 0u32;
            let mut cv = 0u32;
            for &e in f.subtree_members(node as u32) {
                let (u, v) = g.edge(e);
                if stamp_u[u as usize] != node as u32 {
                    stamp_u[u as usize] = node as u32;
                    cu += 1;
                }
                if stamp_v[v as usize] != node as u32 {
                    stamp_v[v as usize] = node as u32;
                    cv += 1;
                }
            }
            // SAFETY: each `node` index is visited by exactly one chunk,
            // so writes to sub_nu[node] / sub_nv[node] are disjoint.
            unsafe {
                sub_nu.set(node, cu);
                sub_nv.set(node, cv);
            }
        }
    });
    forest.sub_nu = sub_nu.into_inner();
    forest.sub_nv = sub_nv.into_inner();
}

/// Per-level summaries read off the forest: one `O(nodes)` cut per level
/// instead of a fresh union-find over all blooms.
pub fn forest_level_summaries(forest: &Forest) -> Vec<LevelSummary> {
    let mut levels: Vec<u64> = forest.theta.iter().copied().filter(|&t| t > 0).collect();
    levels.sort_unstable();
    levels.dedup();
    let mut sorted_theta: Vec<u64> = forest.theta.clone();
    sorted_theta.sort_unstable();
    levels
        .into_iter()
        .map(|k| {
            let cut = forest.cut(k);
            let entities = sorted_theta.len() - sorted_theta.partition_point(|&t| t < k);
            LevelSummary {
                k,
                entities,
                components: cut.len(),
                largest: cut.iter().map(|&n| forest.sub_size(n)).max().unwrap_or(0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::hierarchy::kwing_components;
    use crate::peel::bup::wing_bup;

    fn wing_forest(g: &BipartiteGraph, threads: usize) -> (Forest, BeIndex, Vec<u64>) {
        let (idx, _) = BeIndex::build(g, 1);
        let theta = wing_bup(g).theta;
        let f = build_wing_forest(g, &idx, &theta, threads);
        (f, idx, theta)
    }

    #[test]
    fn fig1_forest_matches_direct_components_at_every_level() {
        let g = gen::paper_fig1();
        let (f, idx, theta) = wing_forest(&g, 2);
        f.validate().unwrap();
        let max = *theta.iter().max().unwrap();
        for k in 0..=max + 1 {
            assert_eq!(
                f.components(k),
                kwing_components(&idx, &theta, k),
                "level {k} diverged"
            );
        }
    }

    #[test]
    fn fig1_forest_shape() {
        let g = gen::paper_fig1();
        let (f, _, _) = wing_forest(&g, 1);
        // four disconnected dense blocks → four leaves; the hierarchy
        // never merges them (bridges are butterfly-free), so every node
        // chain is disjoint and there are exactly 4 roots.
        assert_eq!(f.roots().len(), 4);
        assert_eq!(f.levels, vec![1, 2, 3, 4]);
        // each root's component is one block; the θ=4 block has 9 edges
        let top = f
            .cut(4)
            .into_iter()
            .map(|n| f.sub_size(n))
            .collect::<Vec<_>>();
        assert_eq!(top, vec![9]);
    }

    #[test]
    fn forest_is_deterministic_across_thread_counts() {
        let g = gen::zipf(60, 60, 400, 1.2, 1.2, 91);
        let (f1, _, _) = wing_forest(&g, 1);
        let (f4, _, _) = wing_forest(&g, 4);
        assert_eq!(f1, f4);
    }

    #[test]
    fn butterfly_free_graph_has_empty_forest() {
        // a tree: no butterflies, no wings
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 1), (2, 1)])
            .build();
        let (f, idx, theta) = wing_forest(&g, 1);
        assert_eq!(f.n_nodes(), 0);
        assert!(f.components(1).is_empty());
        assert!(kwing_components(&idx, &theta, 1).is_empty());
    }

    #[test]
    fn random_graphs_forest_equals_direct_per_level() {
        crate::testkit::check_property("forest-vs-direct", 0x1D8, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(14),
                6 + rng.usize_below(14),
                20 + rng.usize_below(90),
                seed,
            );
            let (idx, _) = BeIndex::build(&g, 1);
            let theta = wing_bup(&g).theta;
            let f = build_wing_forest(&g, &idx, &theta, 2);
            if let Err(e) = f.validate() {
                return Err(e);
            }
            let max = theta.iter().max().copied().unwrap_or(0);
            for k in 0..=max + 1 {
                if f.components(k) != kwing_components(&idx, &theta, k) {
                    return Err(format!("level {k} components diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tip_forest_is_a_chain_matching_ktip_vertices() {
        let g = gen::paper_fig1();
        let theta = crate::count::brute::brute_tip_numbers(&g, crate::graph::Side::U);
        let f = build_tip_forest(&theta, ForestKind::TipU);
        f.validate().unwrap();
        assert!(f.roots().len() <= 1);
        let max = *theta.iter().max().unwrap();
        for k in 1..=max + 1 {
            let comps = f.components(k);
            let want = crate::hierarchy::ktip_vertices(&theta, k);
            if want.is_empty() {
                assert!(comps.is_empty(), "level {k}");
            } else {
                assert_eq!(comps.len(), 1, "level {k}");
                assert_eq!(comps[0], want, "level {k}");
            }
        }
    }

    #[test]
    fn subtree_spans_are_contiguous_and_nested() {
        let g = gen::zipf(40, 40, 260, 1.3, 1.3, 17);
        let (f, _, _) = wing_forest(&g, 2);
        for n in 0..f.n_nodes() as u32 {
            for c in f.children(n) {
                assert_eq!(f.parent[c as usize], n);
                // child span inside parent span
                let ps = f.member_off[n as usize];
                let pe = f.member_off[f.subtree_end[n as usize] as usize];
                let cs = f.member_off[c as usize];
                let ce = f.member_off[f.subtree_end[c as usize] as usize];
                assert!(ps <= cs && ce <= pe);
                assert!(f.node_level[c as usize] > f.node_level[n as usize]);
            }
        }
    }

    #[test]
    fn wing_stats_count_distinct_endpoints() {
        let g = gen::biclique(3, 4);
        let (f, _, _) = wing_forest(&g, 1);
        // single component: the full K_{3,4}
        assert_eq!(f.roots().len(), 1);
        let r = f.roots()[0];
        assert_eq!(f.sub_size(r), 12);
        assert_eq!(f.sub_nu[r as usize], 3);
        assert_eq!(f.sub_nv[r as usize], 4);
        assert!((f.density(r) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn summaries_match_legacy_shape() {
        let g = gen::paper_fig1();
        let (f, idx, theta) = wing_forest(&g, 1);
        let s = forest_level_summaries(&f);
        let ks: Vec<u64> = s.iter().map(|l| l.k).collect();
        assert_eq!(ks, vec![1, 2, 3, 4]);
        for l in &s {
            let direct = kwing_components(&idx, &theta, l.k);
            assert_eq!(l.components, direct.len());
            assert_eq!(
                l.largest,
                direct.iter().map(|c| c.len()).max().unwrap_or(0)
            );
            assert_eq!(
                l.entities,
                crate::hierarchy::kwing_edges(&theta, l.k).len()
            );
        }
    }
}
