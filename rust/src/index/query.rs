//! Query API over a built [`Forest`]: membership, level materialization
//! (k-wing / k-tip via forest cuts), density ranking, and traversal —
//! with an LRU cache of materialized levels so repeated queries for hot
//! levels (the common serving pattern) cost one clone of an `Arc`.

use super::{Forest, ForestKind, NONE};
use crate::hierarchy::LevelSummary;
use crate::metrics::IndexMeters;
use std::sync::{Arc, Mutex};

/// Denormalized per-node facts for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeInfo {
    pub id: u32,
    pub level: u64,
    /// Entities in the component rooted here (subtree span).
    pub size: usize,
    pub nu: u32,
    pub nv: u32,
    pub density: f64,
    pub parent: Option<u32>,
}

/// Where an entity lives in the hierarchy.
#[derive(Clone, Debug, PartialEq)]
pub struct Membership {
    pub entity: u32,
    pub theta: u64,
    /// Root-ward path of components containing the entity, deepest
    /// first; empty when the entity belongs to no component (θ = 0 or a
    /// butterfly-free edge).
    pub path: Vec<u32>,
}

/// Move-to-front LRU over materialized levels. Level counts are small
/// (distinct θ values), so a vector scan beats hash overhead.
struct LevelCache {
    cap: usize,
    entries: Vec<(u64, Arc<Vec<Vec<u32>>>)>,
}

impl LevelCache {
    fn get(&mut self, k: u64) -> Option<Arc<Vec<Vec<u32>>>> {
        let pos = self.entries.iter().position(|(key, _)| *key == k)?;
        let hit = self.entries.remove(pos);
        let out = hit.1.clone();
        self.entries.insert(0, hit);
        Some(out)
    }
    fn put(&mut self, k: u64, v: Arc<Vec<Vec<u32>>>) {
        self.entries.insert(0, (k, v));
        self.entries.truncate(self.cap.max(1));
    }
}

/// Thread-safe serving facade over an immutable forest.
pub struct QueryEngine {
    forest: Forest,
    /// entity → node that introduced it ([`NONE`] if never a member).
    entity_node: Vec<u32>,
    cache: Mutex<LevelCache>,
    pub meters: IndexMeters,
}

impl QueryEngine {
    pub fn new(forest: Forest) -> Self {
        Self::with_cache_capacity(forest, 8)
    }

    pub fn with_cache_capacity(forest: Forest, cap: usize) -> Self {
        let entity_node = forest.entity_nodes();
        QueryEngine {
            forest,
            entity_node,
            cache: Mutex::new(LevelCache {
                cap,
                entries: Vec::new(),
            }),
            meters: IndexMeters::new(),
        }
    }

    pub fn forest(&self) -> &Forest {
        &self.forest
    }

    pub fn kind(&self) -> ForestKind {
        self.forest.kind
    }

    /// The stored level actually answering a query for `k`: the smallest
    /// level ≥ k (cuts are identical for every k in the gap between two
    /// stored levels). `None` when k exceeds the deepest level — the
    /// k-level is empty.
    pub fn effective_level(&self, k: u64) -> Option<u64> {
        let i = self.forest.levels.partition_point(|&l| l < k);
        self.forest.levels.get(i).copied()
    }

    /// Materialize the k-level components (k-wings for a wing forest,
    /// the k-tip vertex set for a tip forest), LRU-cached per effective
    /// level. Matches `hierarchy::kwing_components` byte for byte.
    pub fn components(&self, k: u64) -> Arc<Vec<Vec<u32>>> {
        self.meters.queries.add(1);
        let Some(eff) = self.effective_level(k) else {
            return Arc::new(Vec::new());
        };
        if let Some(hit) = self.cache.lock().unwrap().get(eff) {
            self.meters.cache_hits.add(1);
            return hit;
        }
        self.meters.cache_misses.add(1);
        // materialize outside the lock so concurrent hits on other levels
        // are not serialized behind a slow miss
        let comps = Arc::new(self.forest.components(eff));
        let mut cache = self.cache.lock().unwrap();
        if let Some(raced) = cache.get(eff) {
            return raced; // another thread materialized it meanwhile
        }
        cache.put(eff, comps.clone());
        comps
    }

    /// Hierarchy position of one entity.
    pub fn membership(&self, entity: u32) -> Option<Membership> {
        if entity as usize >= self.forest.n_entities() {
            return None;
        }
        self.meters.queries.add(1);
        let node = self.entity_node[entity as usize];
        let path = if node == NONE {
            Vec::new()
        } else {
            self.forest.path_to_root(node)
        };
        Some(Membership {
            entity,
            theta: self.forest.theta[entity as usize],
            path,
        })
    }

    /// The densest component containing `entity` (max density along its
    /// root-ward path; the deepest wins ties).
    pub fn densest_containing(&self, entity: u32) -> Option<NodeInfo> {
        let m = self.membership(entity)?;
        let best = m.path.iter().copied().max_by(|&a, &b| {
            self.forest
                .density(a)
                .total_cmp(&self.forest.density(b))
                .then(self.forest.node_level[a as usize].cmp(&self.forest.node_level[b as usize]))
        })?;
        Some(self.node_info(best))
    }

    /// The `n` densest components anywhere in the hierarchy.
    pub fn top_k_densest(&self, n: usize) -> Vec<NodeInfo> {
        self.meters.queries.add(1);
        let mut ids: Vec<u32> = (0..self.forest.n_nodes() as u32).collect();
        ids.sort_by(|&a, &b| {
            self.forest
                .density(b)
                .total_cmp(&self.forest.density(a))
                .then(a.cmp(&b))
        });
        ids.truncate(n);
        ids.into_iter().map(|i| self.node_info(i)).collect()
    }

    pub fn node_info(&self, n: u32) -> NodeInfo {
        let p = self.forest.parent[n as usize];
        NodeInfo {
            id: n,
            level: self.forest.node_level[n as usize],
            size: self.forest.sub_size(n),
            nu: self.forest.sub_nu[n as usize],
            nv: self.forest.sub_nv[n as usize],
            density: self.forest.density(n),
            parent: if p == NONE { None } else { Some(p) },
        }
    }

    /// Per-level summaries (`hierarchy::wing_hierarchy_summary` shape).
    pub fn summaries(&self) -> Vec<LevelSummary> {
        self.meters.queries.add(1);
        super::forest_level_summaries(&self.forest)
    }

    /// Pre-materialize the `n` deepest stored levels into the LRU cache.
    ///
    /// The serving layer ([`crate::serve`]) calls this on a freshly built
    /// engine *before* publishing it as a snapshot, so the hot levels of
    /// a new epoch don't all cold-miss at swap time. Bypasses the query/
    /// cache meters: warming is build work, not traffic.
    pub fn warm_deepest(&self, n: usize) {
        for &k in self.forest.levels.iter().rev().take(n) {
            let mut cache = self.cache.lock().unwrap();
            if cache.get(k).is_none() {
                let comps = Arc::new(self.forest.components(k));
                cache.put(k, comps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::peel::bup::wing_bup;

    fn engine() -> QueryEngine {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1))
    }

    #[test]
    fn effective_level_rounds_up() {
        let e = engine();
        assert_eq!(e.effective_level(0), Some(1));
        assert_eq!(e.effective_level(1), Some(1));
        assert_eq!(e.effective_level(3), Some(3));
        assert_eq!(e.effective_level(4), Some(4));
        assert_eq!(e.effective_level(5), None);
    }

    #[test]
    fn cache_hits_on_repeat_and_on_gap_levels() {
        let e = engine();
        let a = e.components(2);
        let b = e.components(2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(e.meters.cache_hits.get(), 1);
        assert_eq!(e.meters.cache_misses.get(), 1);
        // k=0 resolves to effective level 1 — a different entry...
        let _ = e.components(0);
        assert_eq!(e.meters.cache_misses.get(), 2);
        // ...and k=1 hits it
        let _ = e.components(1);
        assert_eq!(e.meters.cache_hits.get(), 2);
        // above the max level: served without touching the cache
        assert!(e.components(99).is_empty());
        assert_eq!(e.meters.cache_misses.get(), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        let e = QueryEngine::with_cache_capacity(build_wing_forest(&g, &idx, &theta, 1), 2);
        let _ = e.components(1); // miss {1}
        let _ = e.components(2); // miss {2,1}
        let _ = e.components(1); // hit  {1,2}
        let _ = e.components(3); // miss {3,1} — evicts 2
        let _ = e.components(2); // miss again
        assert_eq!(e.meters.cache_hits.get(), 1);
        assert_eq!(e.meters.cache_misses.get(), 4);
    }

    #[test]
    fn warm_deepest_primes_cache_without_touching_meters() {
        let e = engine();
        e.warm_deepest(2);
        assert_eq!(e.meters.queries.get(), 0);
        assert_eq!(e.meters.cache_misses.get(), 0);
        // the two deepest levels now hit; warming again is idempotent
        e.warm_deepest(2);
        let deepest = *e.forest().levels.last().unwrap();
        let _ = e.components(deepest);
        let _ = e.components(deepest - 1);
        assert_eq!(e.meters.cache_hits.get(), 2);
        assert_eq!(e.meters.cache_misses.get(), 0);
    }

    #[test]
    fn membership_walks_to_root() {
        let e = engine();
        // edge 0 is in the K_{2,2} block: θ = 1, single-node path
        let m = e.membership(0).unwrap();
        assert_eq!(m.theta, 1);
        assert_eq!(m.path.len(), 1);
        // the K_{3,3} block (θ=4): its edges sit on a leaf of a chain
        let top_edge = e
            .forest()
            .theta
            .iter()
            .position(|&t| t == 4)
            .unwrap() as u32;
        let m = e.membership(top_edge).unwrap();
        assert_eq!(m.theta, 4);
        assert!(!m.path.is_empty());
        let levels: Vec<u64> = m
            .path
            .iter()
            .map(|&n| e.forest().node_level[n as usize])
            .collect();
        // deepest-first, strictly decreasing levels
        assert!(levels.windows(2).all(|w| w[0] > w[1]));
        assert_eq!(levels[0], 4);
        // out-of-range entity
        assert!(e.membership(10_000).is_none());
    }

    #[test]
    fn densest_and_top_k() {
        let e = engine();
        // fig1's densest block is the K_{3,3} (fill ratio 1.0, 9 edges)
        let top = e.top_k_densest(1);
        assert_eq!(top.len(), 1);
        assert!((top[0].density - 1.0).abs() < 1e-9);
        let top_edge = e
            .forest()
            .theta
            .iter()
            .position(|&t| t == 4)
            .unwrap() as u32;
        let d = e.densest_containing(top_edge).unwrap();
        assert_eq!(d.level, 4);
        assert_eq!(d.size, 9);
        // an isolated θ=0 bridge edge belongs nowhere
        let bridge = e.forest().theta.iter().position(|&t| t == 0).unwrap() as u32;
        assert!(e.densest_containing(bridge).is_none());
    }
}
