//! Versioned binary on-disk format for [`Forest`].
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header    48 bytes: magic "PBNGIDX1", version u32, kind u8 (+3 pad),
//!           4 × u64 counts (n_entities, n_levels, n_nodes, n_members)
//! hdrsum    u64 fnv64(header) — a kind/count flip cannot decode quietly
//! sections  9 × { len: u64, payload: len bytes, fnv64(payload): u64 }
//!           in fixed order: theta, levels, node_level, parent,
//!           subtree_end, member_off, members, sub_nu, sub_nv
//! ```
//!
//! Every section is a flat array dump (mmap-friendly: fixed offsets are
//! computable from the header counts alone), guarded by an FNV-1a 64
//! checksum so bit rot or truncation is rejected at load instead of
//! surfacing as wrong query answers. [`load`] additionally runs
//! [`Forest::validate`], so a file that checksums correctly but encodes
//! an inconsistent forest (hand-crafted or version-skewed) is rejected
//! too.

use super::{Forest, ForestKind};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"PBNGIDX1";
pub const VERSION: u32 = 1;

/// FNV-1a 64-bit — dependency-free integrity hash for sections.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn u32s_to_bytes(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn write_section<W: Write>(w: &mut W, payload: &[u8]) -> Result<u64> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&fnv64(payload).to_le_bytes())?;
    Ok(16 + payload.len() as u64)
}

/// Serialize `forest` to `path`. Returns the total bytes written.
pub fn save(forest: &Forest, path: &Path) -> Result<u64> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating index file {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    let mut header = Vec::with_capacity(48);
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&VERSION.to_le_bytes());
    header.extend_from_slice(&[forest.kind.tag(), 0, 0, 0]);
    for count in [
        forest.n_entities() as u64,
        forest.levels.len() as u64,
        forest.n_nodes() as u64,
        forest.n_members() as u64,
    ] {
        header.extend_from_slice(&count.to_le_bytes());
    }
    w.write_all(&header)?;
    w.write_all(&fnv64(&header).to_le_bytes())?;
    let mut bytes = header.len() as u64 + 8;
    bytes += write_section(&mut w, &u64s_to_bytes(&forest.theta))?;
    bytes += write_section(&mut w, &u64s_to_bytes(&forest.levels))?;
    bytes += write_section(&mut w, &u64s_to_bytes(&forest.node_level))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.parent))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.subtree_end))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.member_off))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.members))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.sub_nu))?;
    bytes += write_section(&mut w, &u32s_to_bytes(&forest.sub_nv))?;
    w.flush()?;
    Ok(bytes)
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            bail!(
                "truncated index file: wanted {} bytes at offset {}, have {}",
                n,
                self.off,
                self.buf.len()
            );
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Read one checksummed section, expecting exactly `expect` bytes.
    fn section(&mut self, name: &str, expect: usize) -> Result<&'a [u8]> {
        let len = self.u64()? as usize;
        if len != expect {
            bail!("section {name}: length {len} != expected {expect}");
        }
        let payload = self.take(len)?;
        let sum = self.u64()?;
        if sum != fnv64(payload) {
            bail!("section {name}: checksum mismatch (corrupt index file)");
        }
        Ok(payload)
    }
    fn section_u32s(&mut self, name: &str, count: usize) -> Result<Vec<u32>> {
        let b = self.section(name, count * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn section_u64s(&mut self, name: &str, count: usize) -> Result<Vec<u64>> {
        let b = self.section(name, count * 8)?;
        Ok(b.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Deserialize a [`Forest`] from `path`, verifying magic, version,
/// per-section checksums, and structural invariants.
pub fn load(path: &Path) -> Result<Forest> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading index file {}", path.display()))?;
    let mut c = Cursor { buf: &buf, off: 0 };
    let header = c.take(48)?;
    if &header[0..8] != MAGIC {
        bail!("not a pbng index file (bad magic)");
    }
    let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported index version {version} (this build reads {VERSION})");
    }
    let hdrsum = c.u64()?;
    if hdrsum != fnv64(header) {
        bail!("header checksum mismatch (corrupt index file)");
    }
    let kind_tag = header[12];
    let kind = ForestKind::from_tag(kind_tag)
        .with_context(|| format!("unknown forest kind tag {kind_tag}"))?;
    let n_entities = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
    let n_levels = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
    let n_nodes = u64::from_le_bytes(header[32..40].try_into().unwrap()) as usize;
    let n_members = u64::from_le_bytes(header[40..48].try_into().unwrap()) as usize;
    if n_members > n_entities {
        bail!("header: more members ({n_members}) than entities ({n_entities})");
    }
    let forest = Forest {
        kind,
        theta: c.section_u64s("theta", n_entities)?,
        levels: c.section_u64s("levels", n_levels)?,
        node_level: c.section_u64s("node_level", n_nodes)?,
        parent: c.section_u32s("parent", n_nodes)?,
        subtree_end: c.section_u32s("subtree_end", n_nodes)?,
        member_off: c.section_u32s("member_off", n_nodes + 1)?,
        members: c.section_u32s("members", n_members)?,
        sub_nu: c.section_u32s("sub_nu", n_nodes)?,
        sub_nv: c.section_u32s("sub_nv", n_nodes)?,
    };
    if c.off != buf.len() {
        bail!("trailing garbage after last section");
    }
    forest
        .validate()
        .map_err(|e| anyhow::anyhow!("index file fails structural validation: {e}"))?;
    Ok(forest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::build_wing_forest;
    use crate::peel::bup::wing_bup;

    fn tmp(name: &str) -> (crate::testkit::TempDir, std::path::PathBuf) {
        let dir = crate::testkit::TempDir::new("codec").unwrap();
        let path = dir.file(name);
        (dir, path) // keep the TempDir alive alongside the path
    }

    fn sample_forest() -> Forest {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        build_wing_forest(&g, &idx, &theta, 1)
    }

    #[test]
    fn fnv64_is_stable() {
        // reference values of FNV-1a 64
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv64(b"ab"), fnv64(b"ba"));
    }

    #[test]
    fn roundtrip_preserves_forest_exactly() {
        let f = sample_forest();
        let (_dir, p) = tmp("roundtrip.idx");
        let bytes = save(&f, &p).unwrap();
        assert_eq!(bytes, std::fs::metadata(&p).unwrap().len());
        let g = load(&p).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let f = sample_forest();
        let (_dir, p) = tmp("magic.idx");
        save(&f, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("bad magic"));
        bytes[0] ^= 0xFF;
        bytes[8] = 0xEE; // version
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn rejects_payload_corruption_and_truncation() {
        let f = sample_forest();
        let (_dir, p) = tmp("corrupt.idx");
        save(&f, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // flip one byte in the middle of some section payload
        let mut flipped = bytes.clone();
        let mid = bytes.len() / 2;
        flipped[mid] ^= 0x01;
        std::fs::write(&p, &flipped).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("length") || err.contains("validation"),
            "unexpected error: {err}"
        );
        // truncate
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_unknown_kind_tag_and_checksummed_header() {
        let f = sample_forest();
        let (_dir, p) = tmp("kind.idx");
        save(&f, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // a header flip without fixing the header checksum is caught...
        bytes[12] = 9; // kind byte
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("checksum"));
        // ...and even a "consistent" forgery with an unknown tag is rejected
        let sum = fnv64(&bytes[0..48]);
        bytes[48..56].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("kind"));
    }

    #[test]
    fn empty_forest_roundtrips() {
        let f = Forest {
            kind: ForestKind::Wing,
            theta: vec![0, 0, 0],
            levels: vec![],
            node_level: vec![],
            parent: vec![],
            subtree_end: vec![],
            member_off: vec![0],
            members: vec![],
            sub_nu: vec![],
            sub_nv: vec![],
        };
        f.validate().unwrap();
        let (_dir, p) = tmp("empty.idx");
        save(&f, &p).unwrap();
        assert_eq!(load(&p).unwrap(), f);
    }
}
