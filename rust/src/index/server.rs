//! Line-protocol command handling for hierarchy queries (protocol v1).
//!
//! One command per line, one multi-line response terminated by `END`.
//! [`dispatch`] is the transport-agnostic core: it parses one line,
//! executes the verb against a [`QueryEngine`], and reports the verb,
//! the body (or error reason), and whether the session should close.
//! Three transports reuse it: the `pbng query` one-shot CLI, the v1
//! stdin/TCP loops below, and the poll-based reactor in
//! [`crate::serve`] (which adds the v2 `OK <verb>`/`ERR <reason>`
//! framing, admission control, and hot-swappable snapshots).
//!
//! ```text
//! components <k>      k-level components (kwing/ktip aliases check kind)
//! membership <id>     θ + root-ward component path of one entity
//! densest <id>        densest component containing the entity
//! top <n>             n densest components overall
//! summary             per-level table (k, entities, components, largest)
//! stats               index shape + query/cache counters
//! metrics             live registry dump (index.* + server.* counters)
//! help                command list
//! quit                close the session
//! ```
//!
//! `metrics` reads the process-wide [`crate::obs::Registry`]: the
//! engine's [`crate::metrics::IndexMeters`] are published into it on
//! every call (so they are readable, not write-only), alongside the
//! always-on `server.connections` / `server.commands` counters.
//! `server.commands` counts real commands only — empty lines and
//! `quit`/`exit` are session plumbing, not queries, and are excluded
//! (see [`Dispatch::counted`]).
//!
//! The thread-per-connection entry points ([`serve_stdin`],
//! [`serve_tcp`], [`serve_listener`]) are deprecated in favor of the
//! reactor behind [`crate::serve::ServerConfig`] / [`crate::serve::Server`];
//! they remain as thin wrappers for one release.

use super::query::{NodeInfo, QueryEngine};
use super::ForestKind;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Outcome of one command.
pub enum Reply {
    Body(String),
    Quit,
}

/// Result of [`dispatch`]ing one protocol line, before any wire framing.
pub struct Dispatch {
    /// Lower-cased verb token (empty for a blank line).
    pub verb: String,
    /// `Ok(body)` or `Err(reason)`; the v1 wire format renders errors as
    /// `ERR <reason>`, v2 ([`crate::serve::proto`]) adds `OK <verb>`.
    pub body: Result<String, String>,
    /// The session should close after replying (`quit` / `exit`).
    pub quit: bool,
    /// Whether this line was counted in `server.commands` (real commands
    /// only; empty lines and `quit` are excluded).
    pub counted: bool,
}

fn node_line(info: &NodeInfo) -> String {
    format!(
        "node {} level {} size {} nu {} nv {} density {:.6} parent {}",
        info.id,
        info.level,
        info.size,
        info.nu,
        info.nv,
        info.density,
        info.parent
            .map(|p| p.to_string())
            .unwrap_or_else(|| "-".to_string()),
    )
}

fn parse_num<T: std::str::FromStr>(tok: Option<&str>, what: &str) -> Result<T, String> {
    tok.ok_or_else(|| format!("missing {what}"))?
        .parse()
        .map_err(|_| format!("bad {what}: expected a number"))
}

fn components_reply(engine: &QueryEngine, k: u64) -> String {
    let comps = engine.components(k);
    let mut out = match engine.effective_level(k) {
        Some(eff) => format!("components {} level {} query-k {}", comps.len(), eff, k),
        None => format!("components 0 query-k {} (above deepest level)", k),
    };
    for (i, c) in comps.iter().enumerate() {
        out.push_str(&format!("\n{} size {}:", i, c.len()));
        for e in c.iter() {
            out.push(' ');
            out.push_str(&e.to_string());
        }
    }
    out
}

/// Execute one protocol line against the engine. Never panics on
/// malformed input; errors come back as `Err(reason)` bodies. This is
/// the transport-agnostic core shared by the v1 wrappers here and the
/// v2 framing in [`crate::serve::proto`].
pub fn dispatch(engine: &QueryEngine, line: &str) -> Dispatch {
    let mut toks = line.split_whitespace();
    let verb = match toks.next() {
        Some(v) => v.to_ascii_lowercase(),
        None => {
            return Dispatch {
                verb: String::new(),
                body: Err("empty command (try: help)".to_string()),
                quit: false,
                counted: false,
            }
        }
    };
    if verb == "quit" || verb == "exit" {
        return Dispatch {
            verb: "quit".to_string(),
            body: Ok(String::new()),
            quit: true,
            counted: false,
        };
    }
    crate::obs::Registry::global().counter("server.commands").add(1);
    let body = match verb.as_str() {
        "help" => Ok(concat!(
            "commands:\n",
            "  components <k>   k-level components (aliases: kwing, ktip)\n",
            "  membership <id>  theta + component path of one entity\n",
            "  densest <id>     densest component containing the entity\n",
            "  top <n>          n densest components\n",
            "  summary          per-level hierarchy table\n",
            "  stats            index shape + query counters\n",
            "  metrics          live registry dump (index.* + server.*)\n",
            "  quit             close the session"
        )
        .to_string()),
        "components" | "kwing" | "ktip" => {
            let kind_ok = match verb.as_str() {
                "kwing" => engine.kind() == ForestKind::Wing,
                "ktip" => matches!(engine.kind(), ForestKind::TipU | ForestKind::TipV),
                _ => true,
            };
            if !kind_ok {
                Err(format!(
                    "this is a {} index; use `components` or the matching verb",
                    engine.kind().name()
                ))
            } else {
                parse_num::<u64>(toks.next(), "level k").map(|k| components_reply(engine, k))
            }
        }
        "membership" => parse_num::<u32>(toks.next(), "entity id").and_then(|e| {
            let m = engine
                .membership(e)
                .ok_or_else(|| format!("entity {e} out of range"))?;
            let mut out = format!(
                "{} {} theta {}",
                engine.kind().entity_name(),
                m.entity,
                m.theta
            );
            if m.path.is_empty() {
                out.push_str("\nno component (not part of any level)");
            } else {
                for &n in &m.path {
                    out.push('\n');
                    out.push_str(&node_line(&engine.node_info(n)));
                }
            }
            Ok(out)
        }),
        "densest" => parse_num::<u32>(toks.next(), "entity id").and_then(|e| {
            if e as usize >= engine.forest().n_entities() {
                return Err(format!("entity {e} out of range"));
            }
            Ok(match engine.densest_containing(e) {
                Some(info) => node_line(&info),
                None => "none".to_string(),
            })
        }),
        "top" => parse_num::<usize>(toks.next(), "count").map(|n| {
            let infos = engine.top_k_densest(n);
            if infos.is_empty() {
                "none".to_string()
            } else {
                infos
                    .iter()
                    .map(node_line)
                    .collect::<Vec<_>>()
                    .join("\n")
            }
        }),
        "summary" => Ok(engine
            .summaries()
            .iter()
            .map(|l| {
                format!(
                    "level {} entities {} components {} largest {}",
                    l.k, l.entities, l.components, l.largest
                )
            })
            .collect::<Vec<_>>()
            .join("\n")),
        "stats" => {
            let f = engine.forest();
            Ok(format!(
                "kind {} entities {} nodes {} levels {} members {}\nqueries {} cache-hits {} cache-misses {}",
                f.kind.name(),
                f.n_entities(),
                f.n_nodes(),
                f.levels.len(),
                f.n_members(),
                engine.meters.queries.get(),
                engine.meters.cache_hits.get(),
                engine.meters.cache_misses.get(),
            ))
        }
        "metrics" => {
            let reg = crate::obs::Registry::global();
            engine.meters.publish(reg);
            Ok(reg
                .counter_snapshot()
                .iter()
                .map(|(n, v)| format!("{n} {v}"))
                .collect::<Vec<_>>()
                .join("\n"))
        }
        other => Err(format!("unknown command '{other}' (try: help)")),
    };
    Dispatch {
        verb,
        body,
        quit: false,
        counted: true,
    }
}

/// [`dispatch`] rendered in the v1 wire shape: errors prefixed with
/// `ERR `, `quit` collapsed to [`Reply::Quit`].
pub fn handle_command(engine: &QueryEngine, line: &str) -> Reply {
    let d = dispatch(engine, line);
    if d.quit {
        return Reply::Quit;
    }
    Reply::Body(match d.body {
        Ok(b) => b,
        Err(e) => format!("ERR {e}"),
    })
}

fn session<R: BufRead, W: Write>(engine: &QueryEngine, reader: R, mut writer: W) -> std::io::Result<()> {
    crate::obs::Registry::global().counter("server.connections").add(1);
    writeln!(
        writer,
        "READY kind={} entities={} nodes={} levels={}",
        engine.kind().name(),
        engine.forest().n_entities(),
        engine.forest().n_nodes(),
        engine.forest().levels.len()
    )?;
    writer.flush()?;
    for line in reader.lines() {
        let line = line?;
        match handle_command(engine, &line) {
            Reply::Quit => {
                writeln!(writer, "BYE")?;
                writer.flush()?;
                break;
            }
            Reply::Body(b) => {
                writeln!(writer, "{b}")?;
                writeln!(writer, "END")?;
                writer.flush()?;
            }
        }
    }
    Ok(())
}

/// Serve queries over stdin/stdout until EOF or `quit` (protocol v1).
#[deprecated(
    note = "use pbng::serve::ServerConfig / Server::run (protocol v2, admission \
            control, hot-swappable snapshots); this v1 wrapper serves one release"
)]
pub fn serve_stdin(engine: &QueryEngine) -> std::io::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    session(engine, stdin.lock(), stdout.lock())
}

/// Serve one accepted TCP connection to completion (protocol v1).
pub fn handle_connection(engine: &QueryEngine, stream: TcpStream) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    session(engine, reader, stream)
}

/// Bind `addr` (e.g. `127.0.0.1:7878`) and serve forever, one thread per
/// connection (protocol v1).
#[deprecated(
    note = "use pbng::serve::ServerConfig / Server::run (protocol v2, admission \
            control, hot-swappable snapshots); this v1 wrapper serves one release"
)]
pub fn serve_tcp(engine: Arc<QueryEngine>, addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("pbng index server listening on {}", listener.local_addr()?);
    #[allow(deprecated)]
    serve_listener(engine, listener)
}

/// Accept-loop over an already-bound listener, one thread per connection
/// (protocol v1; lets callers pick ephemeral ports).
///
/// Session failures — IO errors *and* handler panics, which a detached
/// thread would otherwise swallow silently — are logged and counted in
/// the `server.session_errors` registry counter, matching the reactor's
/// accounting.
#[deprecated(
    note = "use pbng::serve::ServerConfig / Server::run (protocol v2, admission \
            control, hot-swappable snapshots); this v1 wrapper serves one release"
)]
pub fn serve_listener(engine: Arc<QueryEngine>, listener: TcpListener) -> std::io::Result<()> {
    let errors = crate::obs::Registry::global().counter("server.session_errors");
    for stream in listener.incoming() {
        let stream = stream?;
        let engine = engine.clone();
        let errors = errors.clone();
        std::thread::spawn(move || {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".to_string());
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_connection(&engine, stream)
            })) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    errors.add(1);
                    eprintln!("pbng serve: session error from {peer}: {e}");
                }
                Err(_) => {
                    errors.add(1);
                    eprintln!("pbng serve: session thread panicked for {peer}");
                }
            }
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beindex::BeIndex;
    use crate::graph::gen;
    use crate::index::{build_tip_forest, build_wing_forest};
    use crate::peel::bup::wing_bup;

    fn engine() -> QueryEngine {
        let g = gen::paper_fig1();
        let (idx, _) = BeIndex::build(&g, 1);
        let theta = wing_bup(&g).theta;
        QueryEngine::new(build_wing_forest(&g, &idx, &theta, 1))
    }

    fn body(engine: &QueryEngine, line: &str) -> String {
        match handle_command(engine, line) {
            Reply::Body(b) => b,
            Reply::Quit => panic!("unexpected quit"),
        }
    }

    #[test]
    fn kwing_reply_lists_components() {
        let e = engine();
        let b = body(&e, "kwing 4");
        assert!(b.starts_with("components 1 level 4"), "{b}");
        assert!(b.contains("size 9:"), "{b}");
        let b0 = body(&e, "components 99");
        assert!(b0.starts_with("components 0"), "{b0}");
    }

    #[test]
    fn kind_mismatch_and_errors() {
        let e = engine();
        assert!(body(&e, "ktip 1").starts_with("ERR"));
        assert!(body(&e, "kwing").starts_with("ERR"));
        assert!(body(&e, "kwing x").starts_with("ERR"));
        assert!(body(&e, "frobnicate").starts_with("ERR"));
        assert!(body(&e, "").starts_with("ERR"));
        assert!(body(&e, "membership 99999").starts_with("ERR"));
        assert!(matches!(handle_command(&e, "quit"), Reply::Quit));
    }

    #[test]
    fn ktip_verb_works_on_tip_index() {
        let g = gen::paper_fig1();
        let theta = crate::count::brute::brute_tip_numbers(&g, crate::graph::Side::U);
        let e = QueryEngine::new(build_tip_forest(&theta, crate::index::ForestKind::TipU));
        let b = body(&e, "ktip 1");
        assert!(b.starts_with("components 1"), "{b}");
        assert!(body(&e, "kwing 1").starts_with("ERR"));
    }

    #[test]
    fn stats_and_summary_render() {
        let e = engine();
        let s = body(&e, "stats");
        assert!(s.contains("kind wing"), "{s}");
        assert!(s.contains("queries"), "{s}");
        let sm = body(&e, "summary");
        assert_eq!(sm.lines().count(), 4, "{sm}");
        assert!(sm.contains("level 4 entities 9 components 1 largest 9"), "{sm}");
    }

    #[test]
    fn metrics_verb_reads_registry() {
        let e = engine();
        // drive a query so the cache counters move, then dump
        let _ = body(&e, "kwing 2");
        let b = body(&e, "metrics");
        let mut seen_queries = false;
        for line in b.lines() {
            let mut toks = line.split_whitespace();
            let name = toks.next().unwrap();
            let val: u64 = toks.next().unwrap().parse().unwrap();
            assert!(toks.next().is_none(), "bad metrics line: {line}");
            if name == "index.queries" {
                assert!(val >= 1, "{line}");
                seen_queries = true;
            }
        }
        assert!(seen_queries, "index.queries missing from:\n{b}");
        assert!(b.contains("server.commands"), "{b}");
        // names come out sorted (registry snapshot contract)
        let names: Vec<&str> =
            b.lines().map(|l| l.split_whitespace().next().unwrap()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }

    #[test]
    fn dispatch_classifies_quit_and_empty_as_uncounted() {
        let e = engine();
        // real commands are counted in server.commands, session plumbing
        // (quit/exit aliases, blank lines) is not — `counted` carries the
        // classification so tests stay independent of the global registry
        for (line, counted, quit) in [
            ("stats", true, false),
            ("help", true, false),
            ("frobnicate", true, false), // unknown but still a command
            ("", false, false),
            ("   ", false, false),
            ("quit", false, true),
            ("exit", false, true),
        ] {
            let d = dispatch(&e, line);
            assert_eq!(d.counted, counted, "line {line:?}");
            assert_eq!(d.quit, quit, "line {line:?}");
        }
        assert_eq!(dispatch(&e, "exit").verb, "quit");
        assert!(dispatch(&e, "").body.is_err());
    }

    #[test]
    fn session_over_in_memory_pipe() {
        let e = engine();
        let input = b"stats\nkwing 2\nquit\nnever-reached\n".to_vec();
        let mut out = Vec::new();
        session(&e, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("READY kind=wing"), "{text}");
        assert_eq!(text.matches("\nEND\n").count(), 2, "{text}");
        assert!(text.trim_end().ends_with("BYE"), "{text}");
        assert!(!text.contains("never-reached"));
    }

    #[test]
    fn tcp_round_trip_on_ephemeral_port() {
        use std::io::Read;
        let e = Arc::new(engine());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = {
            let e = e.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_connection(&e, stream).unwrap();
            })
        };
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"membership 0\nquit\n").unwrap();
        let mut text = String::new();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        reader.read_to_string(&mut text).unwrap();
        assert!(text.contains("theta 1"), "{text}");
        assert!(text.trim_end().ends_with("BYE"), "{text}");
        srv.join().unwrap();
    }
}
