//! Range determination for PBNG CD (§3.1.3, Alg. 4 lines 15–20).
//!
//! The spectrum of entity numbers is split into `P` non-overlapping
//! ranges so that each partition poses roughly `tgt` peeling workload.
//! Workload of peeling entity `l` is proxied by the domain
//! ([`crate::engine::PeelDomain::workload_proxy`]): current support for
//! wing (`O(⋈_e)` BE-Index traversal per peeled edge), wedge count for
//! tip. Bins keyed by support value are prefix-scanned to find the
//! smallest upper bound whose cumulative workload reaches the target.
//!
//! Binning uses a caller-provided `Vec<(support, workload)>` that is
//! cleared and sorted in place: the CD driver reuses one buffer across
//! all `P` partitions, so the hot path neither allocates nor rehashes
//! (the previous implementation built a fresh `HashMap` per partition)
//! and iterates bins in deterministic ascending-support order by
//! construction.
//!
//! The *two-way adaptive* scheme: (1) `tgt` is recomputed per partition
//! from the remaining workload and remaining partition count; (2) the
//! target is scaled down by the previous partition's overshoot ratio
//! (initial estimate ÷ final workload), assuming locally predictive
//! behaviour. The clamp on that scale is configurable via
//! [`AdaptiveConfig`].

/// Result of one range computation.
#[derive(Clone, Copy, Debug)]
pub struct Range {
    /// Exclusive upper bound θ(i+1) on supports peeled into this
    /// partition.
    pub upper: u64,
    /// Estimated workload of the initial active set (Σ workload of
    /// entities currently under `upper`).
    pub initial_estimate: u64,
}

/// Find the smallest `upper` such that entities with support `< upper`
/// carry cumulative workload ≥ `tgt`. `supports` enumerates
/// `(support, workload)` of *alive* entities only. `bins` is reusable
/// scratch: cleared, filled, and sorted by support in place.
pub fn find_range<I>(supports: I, tgt: u64, bins: &mut Vec<(u64, u64)>) -> Range
where
    I: Iterator<Item = (u64, u64)>, // (support, workload)
{
    bins.clear();
    bins.extend(supports);
    bins.sort_unstable_by_key(|&(s, _)| s);
    let mut acc = 0u64;
    let mut i = 0usize;
    let n = bins.len();
    while i < n {
        // aggregate the run of equal supports into one bin
        let k = bins[i].0;
        while i < n && bins[i].0 == k {
            acc += bins[i].1;
            i += 1;
        }
        if acc >= tgt {
            return Range {
                upper: k + 1,
                initial_estimate: acc,
            };
        }
    }
    // everything fits under the target: take it all
    Range {
        upper: bins.last().map(|&(k, _)| k + 1).unwrap_or(1),
        initial_estimate: acc,
    }
}

/// Knobs of the two-way adaptive target scheme.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// Lower clamp on the overshoot-correction scale: prevents one
    /// wildly-overshooting partition from collapsing all later targets.
    pub scale_floor: f64,
    /// Upper clamp on the scale (1.0 = targets are never scaled *up*).
    pub scale_cap: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            scale_floor: 0.02,
            scale_cap: 1.0,
        }
    }
}

/// Adaptive target state across partitions.
#[derive(Debug)]
pub struct AdaptiveTarget {
    /// Partitions still to create (including the current one).
    remaining_parts: usize,
    /// Overshoot scale from the previous partition (≤ scale_cap).
    scale: f64,
    knobs: AdaptiveConfig,
}

impl AdaptiveTarget {
    pub fn new(p: usize, knobs: AdaptiveConfig) -> Self {
        AdaptiveTarget {
            remaining_parts: p.max(1),
            scale: 1.0,
            knobs,
        }
    }

    /// Target workload for the next partition given the total remaining
    /// workload.
    pub fn target(&self, remaining_workload: u64) -> u64 {
        let base = remaining_workload as f64 / self.remaining_parts as f64;
        ((base * self.scale).max(1.0)) as u64
    }

    /// Record a finished partition: its initial estimate (at range time)
    /// and the final workload it actually absorbed.
    pub fn record(&mut self, initial_estimate: u64, final_workload: u64) {
        if self.remaining_parts > 1 {
            self.remaining_parts -= 1;
        }
        if final_workload > 0 && initial_estimate > 0 {
            // assume the next partition overshoots similarly; min/max
            // instead of clamp so a misordered knob pair cannot panic
            self.scale = (initial_estimate as f64 / final_workload as f64)
                .max(self.knobs.scale_floor)
                .min(self.knobs.scale_cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn range(sup: &[u64], tgt: u64) -> Range {
        let mut bins = Vec::new();
        find_range(sup.iter().map(|&s| (s, s)), tgt, &mut bins)
    }

    #[test]
    fn find_range_basic() {
        // supports 1,1,2,3 with identity workload; tgt 3 → bins: 1→2, 2→2
        // cumulative at support 1 = 2 < 3; at 2 = 4 ≥ 3 → upper 3
        let r = range(&[1, 1, 2, 3], 3);
        assert_eq!(r.upper, 3);
        assert_eq!(r.initial_estimate, 4);
    }

    #[test]
    fn find_range_takes_all_when_target_large() {
        let r = range(&[5, 7], 1_000);
        assert_eq!(r.upper, 8);
        assert_eq!(r.initial_estimate, 12);
    }

    #[test]
    fn find_range_single_bin() {
        let r = range(&[4; 10], 1);
        assert_eq!(r.upper, 5);
    }

    #[test]
    fn find_range_empty() {
        let mut bins = Vec::new();
        let r = find_range(std::iter::empty(), 10, &mut bins);
        assert_eq!(r.upper, 1);
        assert_eq!(r.initial_estimate, 0);
    }

    #[test]
    fn bins_are_reused_and_sorted() {
        let mut bins = vec![(99, 99); 8]; // stale contents must not leak
        let r = find_range([(3u64, 1u64), (1, 1), (2, 1)].into_iter(), 2, &mut bins);
        assert_eq!(r.upper, 3); // bins 1→1, 2→1: cumulative 2 ≥ 2 at support 2
        assert_eq!(bins, vec![(1, 1), (2, 1), (3, 1)]);
        // second use of the same buffer
        let r2 = find_range([(7u64, 5u64)].into_iter(), 1, &mut bins);
        assert_eq!(r2.upper, 8);
        assert_eq!(bins, vec![(7, 5)]);
    }

    /// Empty-partition case incremental invalidation leans on: an empty
    /// universe (everything already assigned) must yield the degenerate
    /// range even when the scratch buffer holds stale bins.
    #[test]
    fn find_range_empty_universe_with_dirty_scratch() {
        let mut bins = vec![(42, 42); 6];
        let r = find_range(std::iter::empty(), 1_000, &mut bins);
        assert_eq!(r.upper, 1);
        assert_eq!(r.initial_estimate, 0);
        assert!(bins.is_empty(), "stale bins must be cleared");
    }

    /// All-equal supports collapse into a single bin: the range must
    /// close just above that support and absorb the whole workload,
    /// regardless of how small the target is.
    #[test]
    fn find_range_all_equal_supports() {
        let mut bins = Vec::new();
        for tgt in [1u64, 5, 500] {
            let r = find_range((0..10).map(|_| (7u64, 3u64)), tgt, &mut bins);
            assert_eq!(r.upper, 8, "tgt={tgt}");
            assert_eq!(r.initial_estimate, 30, "tgt={tgt}");
            assert_eq!(bins, vec![(7, 3); 10]);
        }
    }

    /// A single bucket that alone overshoots the target must still be
    /// taken whole (ranges cannot split a support value), reporting the
    /// true (over-target) initial estimate.
    #[test]
    fn find_range_single_over_target_bucket() {
        let mut bins = Vec::new();
        let r = find_range([(4u64, 1_000u64)].into_iter(), 10, &mut bins);
        assert_eq!(r.upper, 5);
        assert_eq!(r.initial_estimate, 1_000);
        // and ahead of later bins: the first bucket already closes it
        let r2 = find_range([(9u64, 1u64), (2, 500)].into_iter(), 100, &mut bins);
        assert_eq!(r2.upper, 3);
        assert_eq!(r2.initial_estimate, 500);
    }

    /// The reusable-scratch path is deterministic: identical inputs give
    /// identical ranges *and* identical bin contents, no matter what the
    /// buffer held before (pinned for incremental re-runs, which reuse
    /// one buffer across differently-sized sub-universes).
    #[test]
    fn reused_scratch_is_deterministic() {
        let input = [(3u64, 2u64), (1, 4), (8, 1), (3, 5)];
        let mut fresh = Vec::new();
        let a = find_range(input.into_iter(), 6, &mut fresh);
        let mut dirty = vec![(u64::MAX, u64::MAX); 32];
        // interleave an unrelated query, then repeat the original
        let _ = find_range([(5u64, 5u64)].into_iter(), 1, &mut dirty);
        let b = find_range(input.into_iter(), 6, &mut dirty);
        assert_eq!(a.upper, b.upper);
        assert_eq!(a.initial_estimate, b.initial_estimate);
        assert_eq!(fresh, dirty);
        // bins hold exactly the input, ascending by support (the order of
        // equal supports is whatever the unstable sort picks — but it is
        // a pure function of the input, as the equality above pins)
        assert!(dirty.windows(2).all(|w| w[0].0 <= w[1].0));
        let mut got = dirty.clone();
        got.sort_unstable();
        assert_eq!(got, vec![(1, 4), (3, 2), (3, 5), (8, 1)]);
    }

    #[test]
    fn adaptive_target_divides_evenly() {
        let t = AdaptiveTarget::new(4, AdaptiveConfig::default());
        assert_eq!(t.target(100), 25);
    }

    #[test]
    fn adaptive_target_scales_down_after_overshoot() {
        let mut t = AdaptiveTarget::new(4, AdaptiveConfig::default());
        // estimated 25 but absorbed 100 → scale 0.25
        t.record(25, 100);
        // remaining workload 300 over 3 parts = 100, scaled to 25
        assert_eq!(t.target(300), 25);
    }

    #[test]
    fn adaptive_scale_clamped() {
        let mut t = AdaptiveTarget::new(2, AdaptiveConfig::default());
        t.record(1, 1_000_000);
        assert!(t.target(1_000_000) >= 1);
        // default floor 0.02, one partition left: 1000 × 0.02 = 20
        assert_eq!(t.target(1_000), 20);
    }

    #[test]
    fn adaptive_knobs_are_honored() {
        let mut t = AdaptiveTarget::new(2, AdaptiveConfig { scale_floor: 0.5, scale_cap: 1.0 });
        t.record(1, 1_000_000); // raw ratio ~1e-6, floored to 0.5
        assert_eq!(t.target(1_000), 500);
    }
}
