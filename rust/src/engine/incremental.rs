//! Engine-level incremental peeling for dynamic graphs.
//!
//! The two-phase engine ([`super::decompose`]) assumes a static graph;
//! this module maintains θ (wing and tip, through the same
//! [`PeelDomain`](super::PeelDomain) impls) under batched edge
//! insertions and deletions without recomputing from scratch — and with
//! a hard guarantee: after every batch the maintained θ is
//! **byte-identical** to a fresh [`super::decompose`] of the updated
//! graph (gated by `tests/test_incremental.rs`).
//!
//! # How it works
//!
//! 1. **Delta counting** — [`DynGraph::apply_batch`] applies the batch
//!    and reports per-entity butterfly-count deltas by enumerating only
//!    the wedges incident to changed edges (no global recount), plus the
//!    adjacency links of every butterfly it created.
//! 2. **Invalidation** — θ is a *per-butterfly-component* quantity:
//!    supports only ever flow along butterfly adjacency, so a component
//!    of the butterfly-adjacency graph (old components ∪ created links —
//!    a sound coarsening of the union graph's components) that contains
//!    no touched entity has an unchanged level structure and keeps its θ
//!    verbatim. The *affected* set is therefore the union of components
//!    containing a touched entity. Components are cached from the last
//!    full run (derived from the counting blooms: every k ≥ 2 bloom's
//!    entities are pairwise butterfly-adjacent, Property 1) and only
//!    merged — never re-split — between full runs, which is conservative
//!    and cheap to maintain. Each non-empty batch still pays an `O(m)`
//!    remap/relabel floor (wing edge ids shift with the sorted edge
//!    list, and labels are re-rooted) — it is the *butterfly-heavy* work
//!    (counting and peeling) that is confined to the affected region.
//!    At partition granularity, a CD partition of the last full run is
//!    *invalidated* when its support interval `[θ(i), θ(i+1))` contains
//!    the pre-update θ of an affected entity
//!    ([`Meters::invalidated_parts`]).
//! 3. **Re-peel** — the affected entities form a self-contained
//!    sub-universe (every butterfly of an affected entity stays inside
//!    its component), so the generic CD + FD drivers re-run on the
//!    compacted induced subgraph — the same `engine::cd`/`engine::fd`
//!    code path as a full run, just restricted — and the resulting θ
//!    values are scattered back. CD must re-run on that sub-universe
//!    (not just FD): deltas move θ across the cached range boundaries,
//!    so the old partition assignment cannot be trusted inside the
//!    affected region.
//! 4. **Fallback** — when the affected fraction exceeds
//!    [`IncrementalConfig::fallback_fraction`], locality buys nothing:
//!    the state falls back to a full [`super::decompose`] (which also
//!    re-canonicalizes the cached component labels and range bounds).
//!
//! Determinism: delta reports are sorted, the sub-universe relabeling is
//! order-preserving, and the engine drivers are θ-deterministic across
//! thread counts — so incremental θ equals from-scratch θ for *any*
//! interleaving of batch sizes and thread counts.

use super::{decompose, EngineConfig};
use crate::beindex::BeIndex;
use crate::graph::dynamic::{DeltaBatch, DynGraph};
use crate::graph::{BipartiteGraph, GraphBuilder, Side};
use crate::hierarchy::UnionFind;
use crate::metrics::{Meters, PeelStats, Phase, Recorder};
use crate::tip::domain::TipDomain;
use crate::wing::domain::WingDomain;

/// Configuration of an incremental peeling state.
#[derive(Clone, Copy, Debug)]
pub struct IncrementalConfig {
    /// Engine knobs used for full runs and affected-region re-peels.
    pub engine: EngineConfig,
    /// Full-rebuild threshold: when `affected / total` exceeds this
    /// fraction, [`WingIncremental::apply`] / [`TipIncremental::apply`]
    /// fall back to a full decomposition.
    pub fallback_fraction: f64,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        IncrementalConfig {
            engine: EngineConfig::default(),
            fallback_fraction: 0.25,
        }
    }
}

/// What one applied batch did, for observability and tests.
#[derive(Clone, Debug, Default)]
pub struct UpdateStats {
    /// Net edges inserted / removed by the batch.
    pub inserted: usize,
    pub removed: usize,
    pub butterflies_created: u64,
    pub butterflies_destroyed: u64,
    /// Entities whose θ had to be recomputed (0 when the batch only
    /// touched butterfly-free structure).
    pub affected_entities: usize,
    pub total_entities: usize,
    /// Partitions of the last full run whose support interval contained
    /// a pre-update θ of an affected entity.
    pub invalidated_partitions: usize,
    pub total_partitions: usize,
    /// Whether the fallback-to-full path ran.
    pub full_rebuild: bool,
    /// Phase-attributed stats of this apply (the `incremental` phase
    /// covers delta application and invalidation analysis; the re-peel
    /// records the usual engine phases after it).
    pub stats: PeelStats,
}

/// Partitions (given the last full run's lower bounds) whose support
/// interval contains at least one of `values`.
fn invalidated_partitions(lowers: &[u64], values: impl Iterator<Item = u64>) -> usize {
    if lowers.is_empty() {
        return 0;
    }
    let mut hit = vec![false; lowers.len()];
    for v in values {
        // lowers is strictly ascending and starts at 0
        let i = lowers.partition_point(|&lo| lo <= v).saturating_sub(1);
        hit[i] = true;
    }
    hit.iter().filter(|&&h| h).count()
}

const NONE: u32 = u32::MAX;

// ---------------------------------------------------------------- wing

/// Incrementally maintained wing (edge) decomposition.
///
/// Edge ids follow the usual convention (position in the sorted edge
/// list), so they shift under updates; [`WingIncremental::theta`] is
/// always indexed by the *current* graph's edge ids — byte-comparable to
/// `wing_pbng(self.graph(), ..)`.
pub struct WingIncremental {
    dg: DynGraph,
    graph: BipartiteGraph,
    theta: Vec<u64>,
    /// Full-graph per-edge butterfly counts, delta-maintained.
    counts: Vec<u64>,
    /// Cached butterfly-component root per edge (a coarsening between
    /// full runs — see module docs).
    comp: Vec<u32>,
    /// Partition lower bounds of the last full run.
    lowers: Vec<u64>,
    cfg: IncrementalConfig,
    init_stats: PeelStats,
}

impl WingIncremental {
    /// Build the state with one full decomposition of `g`.
    pub fn new(g: &BipartiteGraph, cfg: IncrementalConfig) -> WingIncremental {
        debug_assert!(
            g.edges().windows(2).all(|w| w[0] < w[1]),
            "edge list must be sorted (GraphBuilder invariant)"
        );
        let mut s = WingIncremental {
            dg: DynGraph::from_graph(g),
            graph: g.clone(),
            theta: Vec::new(),
            counts: Vec::new(),
            comp: Vec::new(),
            lowers: Vec::new(),
            cfg,
            init_stats: PeelStats::default(),
        };
        let meters = Meters::new();
        let rec = Recorder::new(&meters);
        s.init_stats = s.rebuild_full(rec);
        s
    }

    /// Current graph (updated by [`WingIncremental::apply`]).
    pub fn graph(&self) -> &BipartiteGraph {
        &self.graph
    }

    /// θ per current edge id.
    pub fn theta(&self) -> &[u64] {
        &self.theta
    }

    /// Delta-maintained per-edge butterfly counts (tests compare these
    /// against fresh recounts).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Stats of the initial full decomposition.
    pub fn init_stats(&self) -> &PeelStats {
        &self.init_stats
    }

    /// Current full-rebuild threshold.
    pub fn fallback_fraction(&self) -> f64 {
        self.cfg.fallback_fraction
    }

    /// Retune the full-rebuild threshold (the ingestion pipeline's
    /// adaptive controller calls this after every applied batch).
    pub fn set_fallback_fraction(&mut self, f: f64) {
        self.cfg.fallback_fraction = f.clamp(0.0, 1.0);
    }

    /// Full decomposition of `self.graph`, refreshing θ, counts,
    /// component labels, and partition bounds.
    fn rebuild_full(&mut self, mut rec: Recorder<'_>) -> PeelStats {
        let threads = self.cfg.engine.threads;
        rec.enter(Phase::Count);
        let (idx, per_edge) =
            BeIndex::build_with(&self.graph, threads, self.cfg.engine.kernel);
        let m = self.graph.m();
        // butterfly components: all edges of a k >= 2 bloom are pairwise
        // butterfly-adjacent (Property 1)
        let mut uf = UnionFind::new(m);
        for b in 0..idx.n_blooms() as u32 {
            if idx.bloom_k[b as usize] >= 2 {
                let ents = idx.entries(b);
                let anchor = ents[0].0;
                for &(e, _) in ents {
                    uf.union(anchor, e);
                }
            }
        }
        let (theta, lowers, stats) = {
            let mut dom = WingDomain::new(&idx, &per_edge, &self.cfg.engine);
            let rep = decompose(&mut dom, &self.cfg.engine, rec);
            (rep.theta, rep.cd.lowers, rep.stats)
        };
        self.theta = theta;
        self.lowers = lowers;
        self.counts = per_edge;
        self.comp = (0..m as u32).map(|e| uf.find(e)).collect();
        stats
    }

    /// Apply one batch; afterwards [`WingIncremental::theta`] equals a
    /// from-scratch decomposition of the updated graph.
    pub fn apply(&mut self, batch: &DeltaBatch) -> UpdateStats {
        let meters = Meters::new();
        let mut rec = Recorder::new(&meters);
        rec.enter(Phase::Incremental);
        let rep = self.dg.apply_batch(batch);
        if rep.inserted.is_empty() && rep.removed.is_empty() && rep.edge_delta.is_empty() {
            // pure no-op batch: nothing changed, skip even the remap
            return UpdateStats {
                total_entities: self.graph.m(),
                total_partitions: self.lowers.len(),
                stats: rec.finish(),
                ..UpdateStats::default()
            };
        }
        let new_graph = self.dg.snapshot();
        let m_new = new_graph.m();
        let m_old = self.graph.m();

        // Remap θ / counts / components old edge ids → new edge ids
        // (inserts and removals shift the sorted-list positions).
        let mut theta = vec![0u64; m_new];
        let mut counts = vec![0u64; m_new];
        let mut from_old = vec![false; m_new];
        let mut uf = UnionFind::new(m_new);
        let mut root_rep = vec![NONE; m_old];
        {
            let old_edges = self.graph.edges();
            let new_edges = new_graph.edges();
            let (mut i, mut j) = (0usize, 0usize);
            while i < m_old && j < m_new {
                match old_edges[i].cmp(&new_edges[j]) {
                    std::cmp::Ordering::Less => i += 1, // removed
                    std::cmp::Ordering::Greater => j += 1, // inserted
                    std::cmp::Ordering::Equal => {
                        theta[j] = self.theta[i];
                        counts[j] = self.counts[i];
                        from_old[j] = true;
                        let r = self.comp[i] as usize;
                        if root_rep[r] == NONE {
                            root_rep[r] = j as u32;
                        } else {
                            uf.union(root_rep[r], j as u32);
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
        // Fold in the butterfly-count deltas; every touched surviving
        // edge is dirty (touch entries exist even at net delta 0).
        let mut dirty: Vec<u32> = Vec::new();
        for &((u, v), d) in &rep.edge_delta {
            if let Some(e) = new_graph.edge_id(u, v) {
                counts[e as usize] = (counts[e as usize] as i64 + d) as u64;
                dirty.push(e);
            }
        }
        // Merge components along created butterflies (links whose edges
        // were removed again later in the batch are gone — skipping them
        // is exact, not just sound).
        for &((au, av), (bu, bv)) in &rep.links {
            if let (Some(a), Some(b)) = (new_graph.edge_id(au, av), new_graph.edge_id(bu, bv)) {
                uf.union(a, b);
            }
        }
        // Affected = components containing a dirty edge.
        let mut aff_root = vec![false; m_new];
        for &d in &dirty {
            aff_root[uf.find(d) as usize] = true;
        }
        let affected: Vec<u32> =
            (0..m_new as u32).filter(|&e| aff_root[uf.find(e) as usize]).collect();

        let inval = invalidated_partitions(
            &self.lowers,
            affected
                .iter()
                .filter(|&&e| from_old[e as usize])
                .map(|&e| theta[e as usize]),
        );
        meters.invalidated_parts.add(inval as u64);

        let frac = if m_new == 0 {
            0.0
        } else {
            affected.len() as f64 / m_new as f64
        };
        let mut out = UpdateStats {
            inserted: rep.inserted.len(),
            removed: rep.removed.len(),
            butterflies_created: rep.butterflies_created,
            butterflies_destroyed: rep.butterflies_destroyed,
            affected_entities: affected.len(),
            total_entities: m_new,
            invalidated_partitions: inval,
            total_partitions: self.lowers.len(),
            full_rebuild: frac > self.cfg.fallback_fraction,
            stats: PeelStats::default(),
        };
        let _sp = crate::obs::span(
            crate::obs::Kind::Repeel,
            affected.len() as u64,
            inval as u64,
            u64::from(out.full_rebuild),
        );
        self.graph = new_graph;
        if out.full_rebuild {
            out.stats = self.rebuild_full(rec);
            return out;
        }
        self.counts = counts;
        if affected.is_empty() {
            // only butterfly-free structure changed: θ survives verbatim
            self.theta = theta;
            self.comp = (0..m_new as u32).map(|e| uf.find(e)).collect();
            out.stats = rec.finish();
            return out;
        }
        // Compact the affected components into a sub-universe. The
        // endpoint relabeling is monotone, so sub edge id i corresponds
        // exactly to affected[i].
        let g = &self.graph;
        let mut us: Vec<u32> = Vec::with_capacity(affected.len());
        let mut vs: Vec<u32> = Vec::with_capacity(affected.len());
        for &e in &affected {
            let (u, v) = g.edge(e);
            us.push(u);
            vs.push(v);
        }
        us.sort_unstable();
        us.dedup();
        vs.sort_unstable();
        vs.dedup();
        let sub_edges: Vec<(u32, u32)> = affected
            .iter()
            .map(|&e| {
                let (u, v) = g.edge(e);
                (
                    us.binary_search(&u).expect("relabel map") as u32,
                    vs.binary_search(&v).expect("relabel map") as u32,
                )
            })
            .collect();
        let sub = GraphBuilder::new().nu(us.len()).nv(vs.len()).edges(&sub_edges).build();
        debug_assert_eq!(sub.m(), affected.len());
        rec.enter(Phase::Count);
        let (idx, per_edge) =
            BeIndex::build_with(&sub, self.cfg.engine.threads, self.cfg.engine.kernel);
        let sub_theta = {
            let mut dom = WingDomain::new(&idx, &per_edge, &self.cfg.engine);
            let r = decompose(&mut dom, &self.cfg.engine, rec);
            out.stats = r.stats;
            r.theta
        };
        for (i, &e) in affected.iter().enumerate() {
            theta[e as usize] = sub_theta[i];
        }
        self.theta = theta;
        self.comp = (0..m_new as u32).map(|e| uf.find(e)).collect();
        out
    }
}

// ---------------------------------------------------------------- tip

/// Incrementally maintained tip (vertex) decomposition of one side.
///
/// Vertex ids are stable under edge updates (the vertex universe is
/// fixed), so [`TipIncremental::theta`] indexing never shifts.
pub struct TipIncremental {
    /// Oriented so the peel side is U.
    dg: DynGraph,
    graph: BipartiteGraph,
    side: Side,
    theta: Vec<u64>,
    /// Full-graph per-vertex butterfly counts, delta-maintained.
    counts: Vec<u64>,
    /// Cached butterfly-component root per peel-side vertex.
    comp: Vec<u32>,
    lowers: Vec<u64>,
    cfg: IncrementalConfig,
    init_stats: PeelStats,
}

impl TipIncremental {
    /// Build the state with one full tip decomposition of `side`.
    pub fn new(g: &BipartiteGraph, side: Side, cfg: IncrementalConfig) -> TipIncremental {
        let oriented = match side {
            Side::U => g.clone(),
            Side::V => g.transposed(),
        };
        let mut s = TipIncremental {
            dg: DynGraph::from_graph(&oriented),
            graph: oriented,
            side,
            theta: Vec::new(),
            counts: Vec::new(),
            comp: Vec::new(),
            lowers: Vec::new(),
            cfg,
            init_stats: PeelStats::default(),
        };
        let meters = Meters::new();
        let rec = Recorder::new(&meters);
        s.init_stats = s.rebuild_full(rec);
        s
    }

    /// Current graph, oriented so the peel side is U.
    pub fn graph(&self) -> &BipartiteGraph {
        &self.graph
    }

    pub fn side(&self) -> Side {
        self.side
    }

    /// θ per peel-side vertex.
    pub fn theta(&self) -> &[u64] {
        &self.theta
    }

    /// Delta-maintained per-vertex butterfly counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn init_stats(&self) -> &PeelStats {
        &self.init_stats
    }

    /// Current full-rebuild threshold.
    pub fn fallback_fraction(&self) -> f64 {
        self.cfg.fallback_fraction
    }

    /// Retune the full-rebuild threshold (see [`WingIncremental::set_fallback_fraction`]).
    pub fn set_fallback_fraction(&mut self, f: f64) {
        self.cfg.fallback_fraction = f.clamp(0.0, 1.0);
    }

    fn rebuild_full(&mut self, mut rec: Recorder<'_>) -> PeelStats {
        let threads = self.cfg.engine.threads;
        rec.enter(Phase::Count);
        let (c, raw) = crate::count::pve_bcnt(
            &self.graph,
            crate::count::CountOptions {
                per_edge: false,
                build_blooms: true,
                threads,
                kernel: self.cfg.engine.kernel,
            },
            Some(rec.meters()),
        );
        let nu = self.graph.nu();
        // U-side butterfly components from the blooms: each bloom's
        // distinct U endpoints are pairwise butterfly-adjacent (the
        // dominant pair when it lies in U, all the wedge mids when the
        // dominant pair lies in V).
        let mut uf = UnionFind::new(nu);
        for b in 0..raw.n_blooms() {
            let (s, e) = (raw.offs[b], raw.offs[b + 1]);
            if e - s >= 2 {
                let anchor = self.graph.edge(raw.pairs[s].0).0;
                for &(e1, e2) in &raw.pairs[s..e] {
                    uf.union(anchor, self.graph.edge(e1).0);
                    uf.union(anchor, self.graph.edge(e2).0);
                }
            }
        }
        let (theta, lowers, stats) = {
            let mut dom = TipDomain::new(&self.graph, &c.per_u);
            let rep = decompose(&mut dom, &self.cfg.engine, rec);
            (rep.theta, rep.cd.lowers, rep.stats)
        };
        self.theta = theta;
        self.lowers = lowers;
        self.counts = c.per_u;
        self.comp = (0..nu as u32).map(|u| uf.find(u)).collect();
        stats
    }

    /// Apply one batch (given in the graph's original orientation; it is
    /// transposed internally for side V). Afterwards
    /// [`TipIncremental::theta`] equals a from-scratch tip decomposition
    /// of the updated graph.
    pub fn apply(&mut self, batch: &DeltaBatch) -> UpdateStats {
        let oriented;
        let batch = match self.side {
            Side::U => batch,
            Side::V => {
                oriented = batch.transposed();
                &oriented
            }
        };
        let meters = Meters::new();
        let mut rec = Recorder::new(&meters);
        rec.enter(Phase::Incremental);
        let rep = self.dg.apply_batch(batch);
        if rep.inserted.is_empty() && rep.removed.is_empty() && rep.edge_delta.is_empty() {
            // pure no-op batch: nothing changed, skip even the relabel
            return UpdateStats {
                total_entities: self.graph.nu(),
                total_partitions: self.lowers.len(),
                stats: rec.finish(),
                ..UpdateStats::default()
            };
        }
        let new_graph = self.dg.snapshot();
        let nu = new_graph.nu();

        let mut counts = self.counts.clone();
        let mut dirty: Vec<u32> = Vec::with_capacity(rep.delta_u.len());
        for &(u, d) in &rep.delta_u {
            counts[u as usize] = (counts[u as usize] as i64 + d) as u64;
            dirty.push(u);
        }
        let mut uf = UnionFind::new(nu);
        for u in 0..nu as u32 {
            uf.union(u, self.comp[u as usize]);
        }
        for &(a, b) in &rep.links_u {
            uf.union(a, b);
        }
        let mut aff_root = vec![false; nu];
        for &d in &dirty {
            aff_root[uf.find(d) as usize] = true;
        }
        let affected: Vec<u32> =
            (0..nu as u32).filter(|&u| aff_root[uf.find(u) as usize]).collect();

        let inval = invalidated_partitions(
            &self.lowers,
            affected.iter().map(|&u| self.theta[u as usize]),
        );
        meters.invalidated_parts.add(inval as u64);

        let frac = if nu == 0 {
            0.0
        } else {
            affected.len() as f64 / nu as f64
        };
        let mut out = UpdateStats {
            inserted: rep.inserted.len(),
            removed: rep.removed.len(),
            butterflies_created: rep.butterflies_created,
            butterflies_destroyed: rep.butterflies_destroyed,
            affected_entities: affected.len(),
            total_entities: nu,
            invalidated_partitions: inval,
            total_partitions: self.lowers.len(),
            full_rebuild: frac > self.cfg.fallback_fraction,
            stats: PeelStats::default(),
        };
        let _sp = crate::obs::span(
            crate::obs::Kind::Repeel,
            affected.len() as u64,
            inval as u64,
            u64::from(out.full_rebuild),
        );
        self.graph = new_graph;
        if out.full_rebuild {
            out.stats = self.rebuild_full(rec);
            return out;
        }
        self.counts = counts;
        self.comp = (0..nu as u32).map(|u| uf.find(u)).collect();
        if affected.is_empty() {
            out.stats = rec.finish();
            return out;
        }
        // Induced sub-universe: the affected vertices with *all* their
        // edges — their butterflies live entirely inside their component,
        // so the restricted counts equal the delta-maintained full-graph
        // counts and are reused as initial supports (no recount).
        let g = &self.graph;
        let mut vs: Vec<u32> = Vec::new();
        for &u in &affected {
            for &(v, _) in g.nbrs_u(u) {
                vs.push(v);
            }
        }
        vs.sort_unstable();
        vs.dedup();
        let mut sub_edges: Vec<(u32, u32)> = Vec::new();
        for (i, &u) in affected.iter().enumerate() {
            for &(v, _) in g.nbrs_u(u) {
                sub_edges.push((i as u32, vs.binary_search(&v).expect("relabel map") as u32));
            }
        }
        let sub = GraphBuilder::new()
            .nu(affected.len())
            .nv(vs.len())
            .edges(&sub_edges)
            .build();
        let per_u_sub: Vec<u64> = affected.iter().map(|&u| counts[u as usize]).collect();
        let sub_theta = {
            let mut dom = TipDomain::new(&sub, &per_u_sub);
            let r = decompose(&mut dom, &self.cfg.engine, rec);
            out.stats = r.stats;
            r.theta
        };
        let mut theta = std::mem::take(&mut self.theta);
        for (i, &u) in affected.iter().enumerate() {
            theta[u as usize] = sub_theta[i];
        }
        self.theta = theta;
        out
    }
}

// ----------------------------------------------------- kind erasure

/// Kind-erased incremental state — wing or tip picked at runtime.
///
/// Callers that choose the decomposition from configuration (the `pbng
/// update` CLI, the [`crate::serve`] delta-log updater) hold one of
/// these instead of matching on [`WingIncremental`] / [`TipIncremental`]
/// themselves. The kind vocabulary is
/// [`ForestKind`](crate::index::ForestKind) so an updated state maps
/// directly onto the hierarchy index it refreshes.
pub enum IncrementalState {
    Wing(Box<WingIncremental>),
    Tip(Box<TipIncremental>),
}

impl IncrementalState {
    /// Build the state with one full decomposition of `g`.
    pub fn new(
        g: &BipartiteGraph,
        kind: crate::index::ForestKind,
        cfg: IncrementalConfig,
    ) -> IncrementalState {
        match kind {
            crate::index::ForestKind::Wing => {
                IncrementalState::Wing(Box::new(WingIncremental::new(g, cfg)))
            }
            crate::index::ForestKind::TipU => {
                IncrementalState::Tip(Box::new(TipIncremental::new(g, Side::U, cfg)))
            }
            crate::index::ForestKind::TipV => {
                IncrementalState::Tip(Box::new(TipIncremental::new(g, Side::V, cfg)))
            }
        }
    }

    pub fn kind(&self) -> crate::index::ForestKind {
        match self {
            IncrementalState::Wing(_) => crate::index::ForestKind::Wing,
            IncrementalState::Tip(s) => match s.side() {
                Side::U => crate::index::ForestKind::TipU,
                Side::V => crate::index::ForestKind::TipV,
            },
        }
    }

    /// Apply one batch (original orientation; tip states transpose
    /// internally). Afterwards [`IncrementalState::theta`] equals a
    /// from-scratch decomposition of the updated graph.
    pub fn apply(&mut self, batch: &DeltaBatch) -> UpdateStats {
        match self {
            IncrementalState::Wing(s) => s.apply(batch),
            IncrementalState::Tip(s) => s.apply(batch),
        }
    }

    /// θ per current entity (edge for wing, peel-side vertex for tip).
    pub fn theta(&self) -> &[u64] {
        match self {
            IncrementalState::Wing(s) => s.theta(),
            IncrementalState::Tip(s) => s.theta(),
        }
    }

    /// Current graph; for tip states it is oriented with the peel side
    /// as U (so `tip_pbng(graph, Side::U, ..)` verifies either side).
    pub fn graph(&self) -> &BipartiteGraph {
        match self {
            IncrementalState::Wing(s) => s.graph(),
            IncrementalState::Tip(s) => s.graph(),
        }
    }

    /// Current full-rebuild threshold.
    pub fn fallback_fraction(&self) -> f64 {
        match self {
            IncrementalState::Wing(s) => s.fallback_fraction(),
            IncrementalState::Tip(s) => s.fallback_fraction(),
        }
    }

    /// Retune the full-rebuild threshold.
    pub fn set_fallback_fraction(&mut self, f: f64) {
        match self {
            IncrementalState::Wing(s) => s.set_fallback_fraction(f),
            IncrementalState::Tip(s) => s.set_fallback_fraction(f),
        }
    }

    /// `(nu, nv)` in the *original* (caller-visible) orientation —
    /// the bounds incoming deltas must respect. Tip-V states keep
    /// their graph transposed internally, so the oriented dims are
    /// swapped back here.
    pub fn universe(&self) -> (usize, usize) {
        match self {
            IncrementalState::Wing(s) => (s.graph().nu(), s.graph().nv()),
            IncrementalState::Tip(s) => match s.side() {
                Side::U => (s.graph().nu(), s.graph().nv()),
                Side::V => (s.graph().nv(), s.graph().nu()),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::DeltaOp;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::tip::tip_bup;

    fn cfg(p: usize, threads: usize, fallback: f64) -> IncrementalConfig {
        IncrementalConfig {
            engine: EngineConfig {
                p,
                threads,
                ..Default::default()
            },
            fallback_fraction: fallback,
        }
    }

    #[test]
    fn invalidated_partitions_hits_intervals() {
        let lowers = vec![0u64, 3, 7];
        assert_eq!(invalidated_partitions(&lowers, [0u64].into_iter()), 1);
        assert_eq!(invalidated_partitions(&lowers, [2u64, 3].into_iter()), 2);
        assert_eq!(invalidated_partitions(&lowers, [9u64, 100].into_iter()), 1);
        assert_eq!(invalidated_partitions(&lowers, std::iter::empty()), 0);
        assert_eq!(invalidated_partitions(&[], [5u64].into_iter()), 0);
    }

    #[test]
    fn wing_single_insert_matches_scratch() {
        let g = gen::paper_fig1();
        let mut inc = WingIncremental::new(&g, cfg(4, 2, 1.0));
        assert_eq!(inc.theta(), &wing_bup(&g).theta[..]);
        // close a butterfly across a bridge
        let up = inc.apply(&DeltaBatch::new(vec![DeltaOp::Insert(0, 2)]));
        assert!(!up.full_rebuild);
        let fresh = wing_bup(inc.graph()).theta;
        assert_eq!(inc.theta(), &fresh[..]);
    }

    #[test]
    fn wing_remove_and_reinsert_roundtrips() {
        let g = gen::zipf(20, 20, 120, 1.2, 1.2, 7);
        let mut inc = WingIncremental::new(&g, cfg(4, 1, 1.0));
        let (u, v) = g.edge(0);
        inc.apply(&DeltaBatch::new(vec![DeltaOp::Remove(u, v)]));
        assert_eq!(inc.theta(), &wing_bup(inc.graph()).theta[..]);
        inc.apply(&DeltaBatch::new(vec![DeltaOp::Insert(u, v)]));
        assert_eq!(inc.graph().edges(), g.edges());
        assert_eq!(inc.theta(), &wing_bup(&g).theta[..]);
    }

    #[test]
    fn wing_fallback_path_stays_correct() {
        let g = gen::zipf(20, 20, 100, 1.2, 1.2, 9);
        let mut inc = WingIncremental::new(&g, cfg(4, 2, 0.0));
        let (u, v) = g.edge(1);
        let up = inc.apply(&DeltaBatch::new(vec![DeltaOp::Remove(u, v)]));
        // removing a butterfly-carrying edge must trip the 0.0 threshold
        assert!(up.full_rebuild || up.affected_entities == 0);
        assert_eq!(inc.theta(), &wing_bup(inc.graph()).theta[..]);
    }

    #[test]
    fn tip_both_sides_match_scratch_after_updates() {
        let g = gen::zipf(16, 14, 90, 1.2, 1.2, 11);
        for side in [Side::U, Side::V] {
            let mut inc = TipIncremental::new(&g, side, cfg(3, 2, 1.0));
            assert_eq!(inc.theta(), &tip_bup(&g, side).theta[..]);
            let ops = vec![
                DeltaOp::Insert(0, 0),
                DeltaOp::Insert(1, 13),
                DeltaOp::Remove(g.edge(2).0, g.edge(2).1),
            ];
            inc.apply(&DeltaBatch::new(ops));
            // fresh tip of the updated graph, in original orientation
            let updated = match side {
                Side::U => inc.graph().clone(),
                Side::V => inc.graph().transposed(),
            };
            assert_eq!(inc.theta(), &tip_bup(&updated, side).theta[..]);
        }
    }

    #[test]
    fn kind_erased_state_matches_scratch_for_every_kind() {
        use crate::index::ForestKind;
        let g = gen::zipf(16, 14, 90, 1.2, 1.2, 11);
        let ops = vec![
            DeltaOp::Insert(0, 0),
            DeltaOp::Insert(1, 13),
            DeltaOp::Remove(g.edge(2).0, g.edge(2).1),
        ];
        for kind in [ForestKind::Wing, ForestKind::TipU, ForestKind::TipV] {
            let mut st = IncrementalState::new(&g, kind, cfg(3, 1, 1.0));
            assert_eq!(st.kind(), kind);
            st.apply(&DeltaBatch::new(ops.clone()));
            // the state's graph is oriented peel-side-as-U, so one
            // comparison shape covers all three kinds
            let fresh = match kind {
                ForestKind::Wing => wing_bup(st.graph()).theta,
                ForestKind::TipU | ForestKind::TipV => tip_bup(st.graph(), Side::U).theta,
            };
            assert_eq!(st.theta(), &fresh[..], "{}", kind.name());
        }
    }

    #[test]
    fn butterfly_free_updates_touch_nothing() {
        // a star has no butterflies; adding another leaf keeps it that way
        let g = GraphBuilder::new()
            .nu(5)
            .nv(2)
            .edges(&[(0, 0), (1, 0), (2, 0), (3, 0)])
            .build();
        let mut inc = WingIncremental::new(&g, cfg(2, 1, 1.0));
        let up = inc.apply(&DeltaBatch::new(vec![DeltaOp::Insert(4, 1)]));
        assert_eq!(up.affected_entities, 0);
        assert_eq!(up.invalidated_partitions, 0);
        assert!(inc.theta().iter().all(|&t| t == 0));
        assert_eq!(inc.theta().len(), 5);
    }
}
