//! Generic coarse-grained decomposition driver (Alg. 4 / §3.2).
//!
//! Divides the entity universe into `P` partitions by iteratively
//! peeling, in parallel, *every* entity whose support falls in the
//! current range `[θ(i), θ(i+1))`. Each parallel iteration peels a large
//! set (little synchronization — the ρ reduction that is the paper's
//! core claim) through the domain's peel kernel; the domain decides
//! between the §5.1 batch engine, the one-at-a-time ablation, or (tip)
//! a from-scratch support recount.
//!
//! Outputs per-entity partition assignments, the support-initialization
//! vector ⋈init (supports snapshotted when each partition starts — i.e.
//! the cumulative effect of peeling all lower partitions), and the range
//! bounds — everything [`super::fd::fine_decompose`] needs to peel
//! partitions independently.
//!
//! Kernel selection ([`EngineConfig::kernel`]: wedge-side cost model,
//! SIMD dispatch, scattered vs aggregated support updates) rides along
//! in `cfg` — the domain's peel/recount hooks consume it, so this
//! driver stays kernel-agnostic.

use super::range::{find_range, AdaptiveTarget};
use super::{CdOutput, EngineConfig, PeelDomain, PeelOutcome};
use crate::metrics::Meters;
use crate::obs;

pub fn coarse_decompose<D: PeelDomain>(
    dom: &mut D,
    cfg: &EngineConfig,
    meters: &Meters,
) -> CdOutput {
    let n = dom.n_entities();
    let mut part_of = vec![u32::MAX; n];
    let mut sup_init = vec![0u64; n];
    let mut lowers = Vec::new();
    let mut remaining = n;
    let mut epoch = 0u32;
    let mut lower = 0u64;
    let mut adaptive = AdaptiveTarget::new(cfg.p, cfg.adaptive);
    // reusable range histogram (see engine::range)
    let mut bins: Vec<(u64, u64)> = Vec::new();
    let mut i = 0usize;

    while remaining > 0 {
        // Snapshot ⋈init for alive entities (Alg. 4 lines 6–7). Also
        // accumulates the remaining workload for adaptive targeting.
        let mut remaining_work = 0u64;
        for x in 0..n as u32 {
            if dom.is_alive(x) {
                let s = dom.support(x);
                sup_init[x as usize] = s;
                remaining_work += dom.workload_proxy(x, s);
            }
        }
        // Range upper bound.
        let is_last = i + 1 >= cfg.p;
        let (upper, initial_estimate) = if is_last {
            (u64::MAX, remaining_work)
        } else {
            let tgt = adaptive.target(remaining_work);
            let r = find_range(
                (0..n as u32).filter(|&x| dom.is_alive(x)).map(|x| {
                    let s = dom.support(x);
                    (s, dom.workload_proxy(x, s).max(1))
                }),
                tgt.max(1),
                &mut bins,
            );
            (r.upper.max(lower + 1), r.initial_estimate)
        };
        lowers.push(lower);

        // Initial active set: all alive entities with support < upper.
        let mut active: Vec<u32> = (0..n as u32)
            .filter(|&x| dom.is_alive(x) && dom.support(x) < upper)
            .collect();
        let mut partition_work = 0u64;

        while !active.is_empty() {
            meters.rho.add(1);
            epoch += 1;
            let _sp =
                obs::span(obs::Kind::CdRound, i as u64, u64::from(epoch), active.len() as u64);
            for &x in &active {
                part_of[x as usize] = i as u32;
                partition_work += dom.workload_proxy(x, sup_init[x as usize]);
            }
            remaining -= active.len();
            match dom.peel_set(&active, lower, epoch, remaining, cfg, meters) {
                PeelOutcome::Touched(mut next) => {
                    // next frontier: live entities that dropped under the bound
                    next.sort_unstable();
                    next.dedup();
                    next.retain(|&x| dom.is_alive(x) && dom.support(x) < upper);
                    active = next;
                }
                PeelOutcome::Recounted => {
                    // supports were recomputed from scratch: re-gather
                    active = (0..n as u32)
                        .filter(|&x| dom.is_alive(x) && dom.support(x) < upper)
                        .collect();
                }
            }
        }

        adaptive.record(initial_estimate, partition_work.max(1));
        lower = upper;
        i += 1;
        if is_last {
            break;
        }
    }
    debug_assert_eq!(remaining, 0, "all entities must be assigned");
    CdOutput {
        part_of,
        sup_init,
        lowers,
        n_parts: i,
    }
}
