//! Generic two-phase peeling engine — the PBNG core, entity-agnostic.
//!
//! The paper's contribution is a *scheme*, not an edge- or vertex-specific
//! algorithm: coarse-grained decomposition (CD, Alg. 4) splits the entity
//! spectrum into `P` support ranges and peels each range with large
//! low-synchronization parallel iterations; fine-grained decomposition
//! (FD, Alg. 5 / §3.2) then peels every partition independently on a
//! partition-local substrate, with **zero** cross-partition
//! synchronization. This repo used to implement that scheme twice — once
//! over edges (wing) and once over vertices (tip). This module owns the
//! single copy:
//!
//! * [`EngineConfig`] — the merged configuration (`P`, threads, the §5.1
//!   batch toggle, the §5.2 dynamic-delete toggle, and the adaptive
//!   range-targeting knobs) that replaced the former `CdConfig` /
//!   `TipCdConfig` / `FdConfig` / `TipFdConfig` quartet.
//! * [`PeelDomain`] — the trait a peelable entity universe implements:
//!   entity count, liveness, current support, a workload proxy for range
//!   targeting, the batch peel kernel, and the per-partition
//!   substrate/recount hooks. `wing::WingDomain` (BE-Index edge peeling)
//!   and `tip::TipDomain` (wedge vertex peeling) are the two impls.
//! * [`cd::coarse_decompose`] — the CD driver: ⋈init snapshotting,
//!   adaptive range finding ([`range`]), active-set gathering, partition
//!   bookkeeping.
//! * [`fd::fine_decompose`] — the FD driver: LPT ordering, a lane-affine
//!   dynamic task queue on the persistent pool ([`crate::par::spmd`]),
//!   and element-disjoint θ write-back through [`crate::par::RacyBuf`].
//! * [`decompose`] / [`EngineReport`] — the phase-recorded Coarse →
//!   Partition → Fine pipeline feeding [`crate::metrics::PeelStats`].
//! * [`incremental`] — dynamic-graph maintenance on top of the same
//!   drivers: batched edge deltas, butterfly-component invalidation, and
//!   affected-region re-peeling with a fallback-to-full threshold
//!   ([`incremental::WingIncremental`], [`incremental::TipIncremental`]).
//!
//! The entity-specific counting phase stays with the caller (edge
//! supports need the BE-Index, vertex supports need per-vertex butterfly
//! counts), which is why [`decompose`] accepts a running
//! [`Recorder`](crate::metrics::Recorder) instead of creating one.

pub mod cd;
pub mod fd;
pub mod incremental;
pub mod range;

pub use cd::coarse_decompose;
pub use fd::fine_decompose;
pub use range::{find_range, AdaptiveConfig, AdaptiveTarget, Range};

use crate::metrics::{Meters, Phase, Recorder};

/// Unified two-phase engine configuration (replaces the former
/// `CdConfig`/`TipCdConfig`/`FdConfig`/`TipFdConfig`).
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Number of CD partitions P. Paper: 400/1000 for wing, 150 for tip;
    /// scaled presets here default to 64 (wing) / 32 (tip), see
    /// [`EngineConfig::tip`].
    pub p: usize,
    pub threads: usize,
    /// Batch optimization (§5.1); off = PBNG−− ablation.
    pub batch: bool,
    /// Dynamic substrate deletes (§5.2); off = PBNG− ablation.
    pub dynamic_deletes: bool,
    /// Adaptive range-targeting knobs (§3.1.3).
    pub adaptive: AdaptiveConfig,
    /// Counting/peel kernel selection (wedge-side cost model, SIMD
    /// intersection policy, scattered vs aggregated support updates); see
    /// [`crate::count::KernelConfig`].
    pub kernel: crate::count::KernelConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            p: 64,
            threads: crate::par::default_threads(),
            batch: true,
            dynamic_deletes: true,
            adaptive: AdaptiveConfig::default(),
            kernel: crate::count::KernelConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Wing-scaled defaults (P = 64).
    pub fn wing() -> Self {
        Self::default()
    }

    /// Tip-scaled defaults (P = 32).
    pub fn tip() -> Self {
        EngineConfig {
            p: 32,
            ..Self::default()
        }
    }
}

/// Output of the generic CD driver (partition assignment, shared by both
/// decompositions).
#[derive(Debug)]
pub struct CdOutput {
    /// Partition index per entity.
    pub part_of: Vec<u32>,
    /// ⋈init per entity: support after all lower partitions were peeled
    /// (snapshotted when the entity's partition started).
    pub sup_init: Vec<u64>,
    /// Lower bound θ(i) per partition (`lowers[i] ≤ θ_x < lowers[i+1]`
    /// for x ∈ partition i; the last upper bound is implicit/unbounded).
    pub lowers: Vec<u64>,
    /// Number of partitions actually created.
    pub n_parts: usize,
}

/// What one CD peel iteration did (see [`PeelDomain::peel_set`]).
pub enum PeelOutcome {
    /// Live entities whose support may have changed (duplicates allowed;
    /// the driver dedups and re-filters against the range bound).
    Touched(Vec<u32>),
    /// Supports were recounted from scratch (the tip §5.1 path): the
    /// driver must re-gather the active set over all alive entities.
    Recounted,
}

/// A peelable entity universe. Implementations plug their support
/// storage, peel kernels, and per-partition substrate into the shared
/// CD/FD drivers; everything else — range targeting, active-set
/// management, LPT scheduling, θ write-back — is engine-owned.
///
/// `Sync` is required because the FD driver shares `&self` across the
/// persistent pool's lanes.
pub trait PeelDomain: Sync {
    /// Number of peelable entities (edges for wing, one side's vertices
    /// for tip).
    fn n_entities(&self) -> usize;

    /// Entity not yet peeled/assigned?
    fn is_alive(&self, x: u32) -> bool;

    /// Current support ⋈ of entity `x`.
    fn support(&self, x: u32) -> u64;

    /// Workload proxy for range targeting and LPT accounting. `sup_init`
    /// is the support snapshotted at the current partition's start (wing
    /// peel cost is `O(⋈_e)`, so it returns `sup_init`; tip returns the
    /// static wedge count of `x`).
    fn workload_proxy(&self, x: u32, sup_init: u64) -> u64;

    /// Peel `active` (already assigned to the current partition by the
    /// driver) at `epoch`, clamping support updates to `lower`.
    /// `remaining` counts entities still alive after this set.
    fn peel_set(
        &mut self,
        active: &[u32],
        lower: u64,
        epoch: u32,
        remaining: usize,
        cfg: &EngineConfig,
        meters: &Meters,
    ) -> PeelOutcome;

    /// Build the per-partition FD substrate from the CD assignment
    /// (partitioned BE-Index for wing, induced subgraphs for tip).
    fn build_substrate(&mut self, cd: &CdOutput, cfg: &EngineConfig);

    /// FD workload indicator of partition `part` (LPT ordering). Only
    /// called after [`PeelDomain::build_substrate`].
    fn partition_workload(&self, part: usize, cd: &CdOutput) -> u64;

    /// Sequentially peel partition `part` within `[bounds.0, bounds.1)`,
    /// writing final entity numbers into `theta`. Must only write θ slots
    /// of entities owned by `part` — that disjointness (CD assigns every
    /// entity to exactly one partition, the FD queue claims every
    /// partition exactly once) is what makes the shared
    /// [`crate::par::RacyBuf`] scatter sound; see [`fd::fine_decompose`].
    fn peel_partition(
        &self,
        part: usize,
        bounds: (u64, u64),
        theta: &crate::par::RacyBuf<u64>,
        cd: &CdOutput,
        cfg: &EngineConfig,
        meters: &Meters,
    );
}

/// Result of a full two-phase run.
pub struct EngineReport {
    /// Final entity numbers θ.
    pub theta: Vec<u64>,
    /// The CD partition assignment the run was built on.
    pub cd: CdOutput,
    /// Phase-attributed workload statistics.
    pub stats: crate::metrics::PeelStats,
}

impl EngineReport {
    pub fn into_decomposition(self) -> crate::peel::Decomposition {
        crate::peel::Decomposition {
            theta: self.theta,
            stats: self.stats,
        }
    }
}

/// Run the full Coarse → Partition → Fine pipeline on `dom`.
///
/// The caller owns the counting phase: start a
/// [`Recorder`](crate::metrics::Recorder), enter
/// [`Phase::Count`](crate::metrics::Phase), build the domain, then hand
/// the recorder over. The engine records the remaining phases and
/// finishes the recorder into the report's
/// [`PeelStats`](crate::metrics::PeelStats).
pub fn decompose<D: PeelDomain>(
    dom: &mut D,
    cfg: &EngineConfig,
    mut rec: Recorder<'_>,
) -> EngineReport {
    let meters = rec.meters();
    rec.enter(Phase::Coarse);
    let cd = coarse_decompose(dom, cfg, meters);
    rec.enter(Phase::Partition);
    dom.build_substrate(&cd, cfg);
    rec.enter(Phase::Fine);
    let theta = fine_decompose(dom, &cd, cfg, meters);
    EngineReport {
        theta,
        cd,
        stats: rec.finish(),
    }
}
