//! Generic fine-grained decomposition driver (Alg. 5 / §3.2).
//!
//! Each partition, together with its partition-local substrate (built by
//! [`PeelDomain::build_substrate`]), is peeled *independently* of all
//! other partitions — supports are initialized from ⋈init, so no
//! cross-partition updates are needed and **no global synchronization**
//! happens. Partitions are dispatched to the persistent runtime pool's
//! lanes ([`crate::par::spmd`] — no thread spawning here either) through
//! a workload-sorted task queue (LPT, §3.1.4) with chunk→lane affinity:
//! partitions are pre-assigned to lanes greedily (heaviest first, to the
//! least-loaded lane), each lane drains its own share first, and only
//! then steals from the global LPT order. Affinity keeps a lane on
//! substrate it already pulled into cache; stealing keeps the schedule
//! dynamic, so a mis-estimated heavy partition cannot strand idle lanes.
//!
//! Like the CD driver, kernel selection ([`EngineConfig::kernel`]) is
//! carried opaquely in `cfg` and consumed by the domain's partition
//! peel kernels.

use super::{CdOutput, EngineConfig, PeelDomain};
use crate::metrics::Meters;
use crate::obs;
use crate::par::{spmd, RacyBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// LPT task queue with greedy lane pre-assignment and work stealing.
/// Every partition is claimed exactly once (the `taken` flags), no
/// matter how lanes interleave.
struct LaneQueue {
    /// Per-lane partition lists (greedy LPT assignment).
    lanes: Vec<Vec<usize>>,
    /// Per-lane cursor into the matching `lanes` entry.
    cursors: Vec<AtomicUsize>,
    /// Claim flags, one per partition: exactly-once execution.
    taken: Vec<AtomicBool>,
    /// Global LPT order, scanned once a lane's own list is drained.
    order: Vec<usize>,
    steal: AtomicUsize,
}

impl LaneQueue {
    /// `order` is the global LPT order (heaviest first); `work[i]` the
    /// workload indicator of partition `i`.
    fn new(order: Vec<usize>, work: &[u64], n_lanes: usize) -> LaneQueue {
        let n_lanes = n_lanes.max(1);
        let mut lanes: Vec<Vec<usize>> = vec![Vec::new(); n_lanes];
        let mut load = vec![0u64; n_lanes];
        for &i in &order {
            // least-loaded lane, ties to the lowest id (deterministic)
            let l = (0..n_lanes).min_by_key(|&l| (load[l], l)).expect("n_lanes >= 1");
            load[l] += work[i].max(1);
            lanes[l].push(i);
        }
        LaneQueue {
            lanes,
            cursors: (0..n_lanes).map(|_| AtomicUsize::new(0)).collect(),
            taken: (0..work.len()).map(|_| AtomicBool::new(false)).collect(),
            order,
            steal: AtomicUsize::new(0),
        }
    }

    /// Next partition for logical lane `t`, or `None` once every
    /// partition is claimed. The flag reports provenance: `true` when the
    /// partition came from the global steal path rather than the lane's
    /// own pre-assigned list (obs / balance attribution).
    fn next_task(&self, t: usize) -> Option<(usize, bool)> {
        let lane = t % self.lanes.len();
        let own = &self.lanes[lane];
        let cursor = &self.cursors[lane];
        loop {
            let c = cursor.fetch_add(1, Ordering::Relaxed);
            if c >= own.len() {
                break;
            }
            let i = own[c];
            if !self.taken[i].swap(true, Ordering::Relaxed) {
                return Some((i, false));
            }
        }
        loop {
            let c = self.steal.fetch_add(1, Ordering::Relaxed);
            if c >= self.order.len() {
                return None;
            }
            let i = self.order[c];
            if !self.taken[i].swap(true, Ordering::Relaxed) {
                return Some((i, true));
            }
        }
    }
}

/// Peel all partitions; returns θ per entity. Requires
/// [`PeelDomain::build_substrate`] to have run for this `cd`.
pub fn fine_decompose<D: PeelDomain>(
    dom: &D,
    cd: &CdOutput,
    cfg: &EngineConfig,
    meters: &Meters,
) -> Vec<u64> {
    let p = cd.n_parts;
    let threads = cfg.threads.max(1);

    // LPT order: workload indicator from the domain (Alg. 5 line 4).
    let mut order: Vec<usize> = (0..p).collect();
    let work: Vec<u64> = (0..p).map(|i| dom.partition_workload(i, cd)).collect();
    order.sort_unstable_by(|&a, &b| work[b].cmp(&work[a]));
    let queue = LaneQueue::new(order, &work, threads);

    // θ disjointness contract: CD assigns every entity to exactly one
    // partition, the queue hands every partition to exactly one logical
    // lane, and `peel_partition` only writes θ slots of its own
    // partition's entities — so all element writes into this shared
    // buffer are disjoint (the unsafe writes live in the domain impls,
    // which cite this argument).
    let theta = RacyBuf::new(vec![0u64; dom.n_entities()]);
    spmd(threads, |t| {
        while let Some((i, stolen)) = queue.next_task(t) {
            let _sp = obs::span(obs::Kind::FdTask, i as u64, work[i], u64::from(stolen));
            let lo = cd.lowers.get(i).copied().unwrap_or(0);
            let hi = cd.lowers.get(i + 1).copied().unwrap_or(u64::MAX);
            dom.peel_partition(i, (lo, hi), &theta, cd, cfg, meters);
        }
    });
    theta.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn lane_queue_hands_out_every_partition_exactly_once() {
        let work = vec![5u64, 9, 1, 7, 3, 3, 8, 2];
        let mut order: Vec<usize> = (0..work.len()).collect();
        order.sort_unstable_by(|&a, &b| work[b].cmp(&work[a]));
        let q = LaneQueue::new(order, &work, 3);
        let mut seen = HashSet::new();
        // interleave lanes to exercise both the own-list and steal paths
        let mut done = [false; 3];
        while !done.iter().all(|&d| d) {
            for t in 0..3 {
                if done[t] {
                    continue;
                }
                match q.next_task(t) {
                    Some((i, _)) => assert!(seen.insert(i), "partition {i} handed out twice"),
                    None => done[t] = true,
                }
            }
        }
        assert_eq!(seen.len(), work.len());
    }

    #[test]
    fn lane_queue_single_lane_covers_all() {
        let work = vec![1u64; 5];
        let q = LaneQueue::new((0..5).collect(), &work, 1);
        let mut got = Vec::new();
        while let Some((i, stolen)) = q.next_task(0) {
            assert!(!stolen, "single lane never needs to steal");
            got.push(i);
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lane_queue_steals_when_own_list_is_exhausted() {
        // two lanes, all work pre-assigned alternately; lane 0 alone must
        // still drain everything through the steal path
        let work = vec![4u64, 4, 4, 4];
        let q = LaneQueue::new((0..4).collect(), &work, 2);
        let mut got = Vec::new();
        let mut steals = 0;
        while let Some((i, stolen)) = q.next_task(0) {
            steals += u32::from(stolen);
            got.push(i);
        }
        assert!(steals > 0, "lane 0 must reach lane 1's share via the steal path");
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn lpt_assignment_balances_load() {
        // loads 8,7,2,1 over two lanes: greedy LPT puts 8+1 and 7+2
        let work = vec![8u64, 7, 2, 1];
        let order = vec![0usize, 1, 2, 3]; // already descending
        let q = LaneQueue::new(order, &work, 2);
        let sums: Vec<u64> = q.lanes.iter().map(|l| l.iter().map(|&i| work[i]).sum()).collect();
        assert_eq!(sums, vec![9, 9]);
    }
}
