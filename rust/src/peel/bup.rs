//! BUP — sequential bottom-up wing decomposition (Alg. 2).
//!
//! The paper's baseline: initialize per-edge supports by counting, then
//! repeatedly peel a minimum-support edge, discovering its butterflies by
//! wedge traversal in G (no BE-Index). `θ_e` is the edge's support at
//! peel time (clamped monotone by the running level).

use super::{update_wedge, Decomposition, LazyHeap};
use crate::count::{pve_bcnt, CountOptions};
use crate::graph::BipartiteGraph;
use crate::metrics::{Meters, Phase, Recorder};

pub fn wing_bup(g: &BipartiteGraph) -> Decomposition {
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    let (counts, _) = pve_bcnt(
        g,
        CountOptions {
            per_edge: true,
            build_blooms: false,
            threads: 1,
            kernel: crate::count::KernelConfig::default(),
        },
        Some(&meters),
    );
    rec.enter(Phase::Fine);
    let m = g.m();
    let mut sup = counts.per_edge;
    let mut theta = vec![0u64; m];
    let mut alive = vec![true; m];
    let mut heap = LazyHeap::with_initial(&sup);
    let mut level = 0u64;
    let mut remaining = m;
    while remaining > 0 {
        let (s, e) = heap
            .pop_live(|i| alive[i as usize].then(|| sup[i as usize]))
            .expect("heap exhausted with edges remaining");
        level = level.max(s);
        theta[e as usize] = level;
        alive[e as usize] = false;
        remaining -= 1;
        let mut pushes: Vec<(u32, u64)> = Vec::new();
        update_wedge(g, e, level, &alive, &mut sup, &meters, &mut |ex, ns| {
            pushes.push((ex, ns))
        });
        for (ex, ns) in pushes {
            if alive[ex as usize] {
                heap.push(ns, ex);
            }
        }
    }
    Decomposition {
        theta,
        stats: rec.finish(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::graph::gen;
    use crate::testkit::check_property;

    #[test]
    fn single_butterfly() {
        let g = gen::biclique(2, 2);
        let d = wing_bup(&g);
        assert_eq!(d.theta, vec![1, 1, 1, 1]);
    }

    #[test]
    fn biclique_33() {
        let g = gen::biclique(3, 3);
        let d = wing_bup(&g);
        let expect = brute::brute_wing_numbers(&g);
        assert_eq!(d.theta, expect);
    }

    #[test]
    fn tree_has_zero_wings() {
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 1), (2, 0)])
            .build();
        let d = wing_bup(&g);
        assert!(d.theta.iter().all(|&t| t == 0));
    }

    #[test]
    fn matches_brute_oracle_on_random_graphs() {
        check_property("bup-vs-brute", 0xB0B, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let nu = 4 + rng.usize_below(8);
            let nv = 4 + rng.usize_below(8);
            let m = 8 + rng.usize_below(40);
            let g = gen::erdos(nu, nv, m, seed);
            let fast = wing_bup(&g).theta;
            let slow = brute::brute_wing_numbers(&g);
            if fast != slow {
                return Err(format!("θ mismatch: fast={fast:?} slow={slow:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fig1_has_multiple_levels() {
        let g = gen::paper_fig1();
        let d = wing_bup(&g);
        let mut levels: Vec<u64> = d.theta.clone();
        levels.sort_unstable();
        levels.dedup();
        assert!(levels.len() >= 3, "expected a hierarchy, got {levels:?}");
        // the K_{3,3} core edges share the max wing number
        let max = *d.theta.iter().max().unwrap();
        let core_edges = d.theta.iter().filter(|&&t| t == max).count();
        assert!(core_edges >= 9);
    }

    #[test]
    fn records_metrics() {
        let g = gen::biclique(3, 4);
        let d = wing_bup(&g);
        assert!(d.stats.updates > 0);
        assert!(d.stats.wedges > 0);
    }
}
