//! ParB — level-synchronous parallel bottom-up peeling, modeling the
//! PARBUTTERFLY framework [54] (Julienne-style bucketing [11]).
//!
//! Each round peels *every* edge whose support is at the current minimum
//! level `k`; support updates may drop more edges to `≤ k`, which are
//! peeled in follow-up sub-iterations of the same level. Every
//! sub-iteration is a parallel round requiring a global thread
//! synchronization — the ρ the paper reports in Tables 3/4.
//!
//! Support updates use wedge traversal (no BE-Index), exactly like BUP,
//! so ParB's update count equals BUP's (Table 3 note: "ParB will generate
//! same number of support updates as BUP"). Because the floor-clamped
//! decrements of a round commute, applying the round's peels one after
//! another produces exactly the state a race-free parallel round would;
//! peel rounds are executed that way here, and ρ / updates / θ are all
//! schedule-independent. The counting phase runs on the runtime pool
//! with the caller's `threads` (its counters are traversal-exact, so
//! they stay deterministic across thread counts too).

use super::{update_wedge, Decomposition, LazyHeap};
use crate::count::{pve_bcnt, CountOptions};
use crate::graph::BipartiteGraph;
use crate::metrics::{Meters, Phase, Recorder};

pub fn wing_parb(g: &BipartiteGraph, threads: usize) -> Decomposition {
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    let (counts, _) = pve_bcnt(
        g,
        CountOptions {
            per_edge: true,
            build_blooms: false,
            threads,
            kernel: crate::count::KernelConfig::default(),
        },
        Some(&meters),
    );
    rec.enter(Phase::Fine);
    let m = g.m();
    let mut sup = counts.per_edge;
    let mut theta = vec![0u64; m];
    let mut alive = vec![true; m];
    // in-bucket bitmap: stale heap duplicates of an edge would otherwise
    // need an O(bucket) `contains` scan per pop (O(bucket²) per level).
    // Never cleared — every bucketed edge is peeled at its level, so a
    // set bit can only belong to a dead edge afterwards.
    let mut in_bucket = vec![false; m];
    let mut heap = LazyHeap::with_initial(&sup);
    let mut remaining = m;
    while remaining > 0 {
        // next level = current minimum support
        let (k, first) = heap
            .pop_live(|i| alive[i as usize].then(|| sup[i as usize]))
            .expect("heap exhausted");
        // gather the whole bucket at level k
        in_bucket[first as usize] = true;
        let mut active = vec![first];
        while let Some((s, e)) = heap.pop_live(|i| alive[i as usize].then(|| sup[i as usize])) {
            if s > k {
                heap.push(s, e); // belongs to a later level
                break;
            }
            if !in_bucket[e as usize] {
                in_bucket[e as usize] = true;
                active.push(e);
            }
        }
        // touched edges at this level, for one heap refresh at the end
        let mut touched: Vec<u32> = Vec::new();
        // sub-iterations at this level
        while !active.is_empty() {
            meters.rho.add(1); // one parallel round = one synchronization
            let mut next: Vec<u32> = Vec::new();
            for &e in &active {
                if !alive[e as usize] {
                    continue;
                }
                theta[e as usize] = k;
                alive[e as usize] = false;
                remaining -= 1;
                update_wedge(g, e, k, &alive, &mut sup, &meters, &mut |ex, ns| {
                    if ns <= k {
                        next.push(ex);
                    } else {
                        touched.push(ex);
                    }
                });
            }
            next.sort_unstable();
            next.dedup();
            next.retain(|&e| alive[e as usize] && sup[e as usize] <= k);
            active = next;
        }
        // refresh heap entries for edges whose support changed but stayed
        // above this level
        touched.sort_unstable();
        touched.dedup();
        for &e in &touched {
            if alive[e as usize] {
                heap.push(sup[e as usize], e);
            }
        }
    }
    Decomposition {
        theta,
        stats: rec.finish(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::peel::bup::wing_bup;
    use crate::testkit::check_property;

    #[test]
    fn matches_bup_on_random_graphs() {
        check_property("parb-vs-bup", 0x9A4B, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let nu = 5 + rng.usize_below(15);
            let nv = 5 + rng.usize_below(15);
            let m = 15 + rng.usize_below(80);
            let g = gen::erdos(nu, nv, m, seed);
            let a = wing_parb(&g, 2).theta;
            let b = wing_bup(&g).theta;
            if a != b {
                return Err(format!("θ mismatch: parb={a:?} bup={b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_bup_on_structured_graphs() {
        for g in [gen::biclique(4, 4), gen::paper_fig1(), gen::nested_blocks(3, 3, 2)] {
            assert_eq!(wing_parb(&g, 2).theta, wing_bup(&g).theta);
        }
    }

    #[test]
    fn rho_counts_rounds() {
        let g = gen::biclique(3, 3);
        let d = wing_parb(&g, 1);
        assert!(d.stats.rho >= 1);
        assert!(d.stats.rho <= g.m() as u64);
    }

    #[test]
    fn updates_equal_bup() {
        let g = gen::zipf(25, 25, 120, 1.1, 1.1, 17);
        let a = wing_parb(&g, 2);
        let b = wing_bup(&g);
        assert_eq!(a.stats.updates, b.stats.updates);
    }

    #[test]
    fn rho_less_than_edge_count_on_planted_graph() {
        let g = gen::planted_blocks(
            120,
            120,
            300,
            &[gen::Block { rows: 10, cols: 10, density: 1.0 }],
            3,
        );
        let d = wing_parb(&g, 2);
        // batching whole levels must beat one-edge-at-a-time
        assert!(d.stats.rho < g.m() as u64 / 2);
    }
}
