//! Shared peeling machinery: lazy min-heap for bottom-up selection and
//! the wedge-traversal support-update kernel (Alg. 2's `update`).
//!
//! The BE-Index based algorithms live in [`crate::wing`]; this module
//! hosts the index-free baselines (BUP, ParB) the paper compares against.

pub mod bup;
pub mod parb;

use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Decomposition result: per-entity numbers + run metrics.
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// θ per entity (edge for wing, peel-side vertex for tip).
    pub theta: Vec<u64>,
    pub stats: crate::metrics::PeelStats,
}

/// Lazy min-heap over `(support, entity)`: stale entries (whose recorded
/// support no longer matches) are skipped on pop. Push on every support
/// change; amortized `O(updates · log)`.
pub struct LazyHeap {
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl LazyHeap {
    pub fn new() -> Self {
        LazyHeap {
            heap: BinaryHeap::new(),
        }
    }

    pub fn with_initial(sup: &[u64]) -> Self {
        let mut heap = BinaryHeap::with_capacity(sup.len());
        for (i, &s) in sup.iter().enumerate() {
            heap.push(Reverse((s, i as u32)));
        }
        LazyHeap { heap }
    }

    #[inline]
    pub fn push(&mut self, support: u64, id: u32) {
        self.heap.push(Reverse((support, id)));
    }

    /// Pop the minimum live entry; `current(id)` returns the entity's
    /// current support or `None` if it is already peeled.
    pub fn pop_live<F: Fn(u32) -> Option<u64>>(&mut self, current: F) -> Option<(u64, u32)> {
        while let Some(Reverse((s, id))) = self.heap.pop() {
            match current(id) {
                Some(cur) if cur == s => return Some((s, id)),
                _ => continue, // stale or peeled
            }
        }
        None
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl Default for LazyHeap {
    fn default() -> Self {
        Self::new()
    }
}

/// Clamped bucket queue for FD partition peeling.
///
/// A partition `L_i` owns the support range `[lo, hi)`: every θ assigned
/// while peeling it falls in that range (Theorem 1), so min-selection
/// only needs exact ordering below `hi`. Entries with support ≥ hi are
/// parked in one overflow bucket that provably never pops while a
/// below-`hi` entry exists. Pushes are O(1) vector appends — the "simple
/// array" updates the paper contrasts with the baselines' priority
/// queues (§6.2.1). Lazy deletion: stale entries are skipped on pop.
///
/// Falls back to a [`LazyHeap`] when the range is too wide to allocate
/// buckets (tip supports can span billions).
pub enum BucketQueue {
    Buckets {
        lo: u64,
        /// `buckets[width]` is the ≥ hi overflow bucket.
        buckets: Vec<Vec<u32>>,
        cur: usize,
    },
    Heap(LazyHeap),
}

/// Ranges wider than this use the heap fallback (8M buckets ≈ 200 MB of
/// empty Vec headers would be wasteful).
const MAX_BUCKET_WIDTH: u64 = 1 << 23;

impl BucketQueue {
    /// Queue for supports in `[lo, hi)`; `hi = u64::MAX` is allowed (the
    /// caller should pass `max_support + 1` instead when known).
    pub fn new(lo: u64, hi: u64) -> Self {
        let width = hi.saturating_sub(lo);
        if width > MAX_BUCKET_WIDTH {
            return BucketQueue::Heap(LazyHeap::new());
        }
        BucketQueue::Buckets {
            lo,
            buckets: (0..=width as usize + 1).map(|_| Vec::new()).collect(),
            cur: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, support: u64, id: u32) {
        match self {
            BucketQueue::Buckets { lo, buckets, .. } => {
                let idx = (support.saturating_sub(*lo) as usize).min(buckets.len() - 1);
                buckets[idx].push(id);
            }
            BucketQueue::Heap(h) => h.push(support, id),
        }
    }

    /// Pop the live entry with minimum support (`current(id)` = current
    /// support, or None if peeled).
    pub fn pop_live<F: Fn(u32) -> Option<u64>>(&mut self, current: F) -> Option<(u64, u32)> {
        match self {
            BucketQueue::Buckets { lo, buckets, cur } => {
                let n = buckets.len();
                while *cur < n {
                    // pop from the current bucket, skipping stale entries
                    while let Some(id) = buckets[*cur].pop() {
                        let Some(s) = current(id) else { continue };
                        let key = (s.saturating_sub(*lo) as usize).min(n - 1);
                        if key == *cur {
                            return Some((s, id));
                        }
                        // stale: the entry's support moved since this
                        // entry was pushed. A fresh entry exists in the
                        // right bucket (every applied decrease pushes
                        // one, and supports never drop below the current
                        // level = cur), so drop this one.
                    }
                    *cur += 1;
                }
                None
            }
            BucketQueue::Heap(h) => h.pop_live(current),
        }
    }
}

/// Support updates from peeling edge `e`, by wedge traversal in `G`
/// (Alg. 2, lines 6–11): every butterfly containing `e` and three alive
/// edges `e1, e2, e3` decrements each of their supports by one, clamped
/// at `floor` (the level currently being peeled).
///
/// Calls `touch(edge, new_support)` for every applied decrement so the
/// caller can maintain its frontier/heap.
pub fn update_wedge<F: FnMut(u32, u64)>(
    g: &BipartiteGraph,
    e: u32,
    floor: u64,
    alive: &[bool],
    sup: &mut [u64],
    meters: &Meters,
    touch: &mut F,
) {
    let (u, v) = g.edge(e);
    let mut updates = 0u64;
    let mut wedges = 0u64;
    for &(v2, e1) in g.nbrs_u(u) {
        if v2 == v || !alive[e1 as usize] {
            continue;
        }
        for &(u2, e3) in g.nbrs_v(v2) {
            wedges += 1;
            if u2 == u || !alive[e3 as usize] {
                continue;
            }
            // butterfly (u, v, u2, v2) exists iff (u2, v) is an alive edge
            if let Some(e2) = g.edge_id(u2, v) {
                if alive[e2 as usize] {
                    for &ex in &[e1, e2, e3] {
                        let s = sup[ex as usize].saturating_sub(1).max(floor);
                        sup[ex as usize] = s;
                        touch(ex, s);
                    }
                    updates += 3;
                }
            }
        }
    }
    meters.updates.add(updates);
    meters.wedges.add(wedges);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn lazy_heap_pops_minimum_live() {
        let sup = vec![5u64, 3, 7];
        let mut h = LazyHeap::with_initial(&sup);
        let cur = sup.clone();
        let (s, id) = h.pop_live(|i| Some(cur[i as usize])).unwrap();
        assert_eq!((s, id), (3, 1));
    }

    #[test]
    fn lazy_heap_skips_stale() {
        let mut h = LazyHeap::new();
        h.push(3, 0);
        h.push(5, 0); // stale duplicate
        h.push(4, 1);
        // entity 0's current support is 5 → the (3,0) entry is stale
        let cur = [5u64, 4];
        let (s, id) = h.pop_live(|i| Some(cur[i as usize])).unwrap();
        assert_eq!((s, id), (4, 1));
        let (s, id) = h.pop_live(|i| Some(cur[i as usize])).unwrap();
        assert_eq!((s, id), (5, 0));
    }

    #[test]
    fn lazy_heap_skips_peeled() {
        let mut h = LazyHeap::new();
        h.push(1, 0);
        h.push(2, 1);
        let (_, id) = h
            .pop_live(|i| if i == 0 { None } else { Some(2) })
            .unwrap();
        assert_eq!(id, 1);
    }

    #[test]
    fn bucket_queue_pops_in_order() {
        let sup = vec![5u64, 3, 7, 3];
        let mut q = BucketQueue::new(0, 10);
        for (i, &s) in sup.iter().enumerate() {
            q.push(s, i as u32);
        }
        let mut order = Vec::new();
        while let Some((s, id)) = q.pop_live(|i| Some(sup[i as usize])) {
            order.push((s, id));
        }
        let keys: Vec<u64> = order.iter().map(|&(s, _)| s).collect();
        assert!(keys.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn bucket_queue_skips_stale_and_uses_fresh_entry() {
        // entity 0 starts at 8, drops to 2 (fresh push); stale entry at 8
        let mut q = BucketQueue::new(0, 10);
        q.push(8, 0);
        q.push(4, 1);
        q.push(2, 0); // fresh after decrease
        let cur = [2u64, 4];
        let (s, id) = q.pop_live(|i| Some(cur[i as usize])).unwrap();
        assert_eq!((s, id), (2, 0));
        let (s, id) = q.pop_live(|i| Some(cur[i as usize])).unwrap();
        assert_eq!((s, id), (4, 1));
        // the stale (8, 0) entry is dropped, not returned again
        assert!(q.pop_live(|i| Some(cur[i as usize])).is_none());
    }

    #[test]
    fn bucket_queue_overflow_bucket_clamps() {
        // range [10, 20): supports >= 20 park in overflow, still pop last
        let mut q = BucketQueue::new(10, 20);
        q.push(100, 0);
        q.push(12, 1);
        let cur = [100u64, 12];
        assert_eq!(q.pop_live(|i| Some(cur[i as usize])).unwrap(), (12, 1));
        assert_eq!(q.pop_live(|i| Some(cur[i as usize])).unwrap(), (100, 0));
    }

    #[test]
    fn bucket_queue_skips_peeled() {
        let mut q = BucketQueue::new(0, 5);
        q.push(1, 0);
        q.push(2, 1);
        let (_, id) = q
            .pop_live(|i| if i == 0 { None } else { Some(2) })
            .unwrap();
        assert_eq!(id, 1);
    }

    #[test]
    fn bucket_queue_wide_range_falls_back_to_heap() {
        let mut q = BucketQueue::new(0, u64::MAX / 2);
        assert!(matches!(q, BucketQueue::Heap(_)));
        q.push(1_000_000_000_000, 0);
        q.push(5, 1);
        let cur = [1_000_000_000_000u64, 5];
        assert_eq!(q.pop_live(|i| Some(cur[i as usize])).unwrap(), (5, 1));
    }

    #[test]
    fn bucket_queue_matches_heap_on_random_sequences() {
        crate::testkit::check_property("bucket-vs-heap", 0xB0C4E7, 12, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let n = 2 + rng.usize_below(40);
            let lo = rng.below(5);
            // FD contract (Theorem 1): every pop-time support lies in
            // [lo, hi). The synthetic run keeps all supports < hi so the
            // overflow bucket is never popped live (overflow ordering is
            // exercised by `bucket_queue_overflow_bucket_clamps`).
            let hi = lo + 45;
            // simulate a peeling run: supports only decrease, floor rises
            let mut sup: Vec<u64> = (0..n).map(|_| lo + rng.below(40)).collect();
            let mut bq = BucketQueue::new(lo, hi);
            let mut lh = LazyHeap::new();
            for (i, &s) in sup.iter().enumerate() {
                bq.push(s, i as u32);
                lh.push(s, i as u32);
            }
            let mut peeled = vec![false; n];
            let mut level = lo;
            for _ in 0..n {
                let a = bq.pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]));
                let b = lh.pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]));
                let (sa, ia) = a.ok_or("bucket queue exhausted early")?;
                let (sb, ib) = b.ok_or("heap exhausted early")?;
                if sa != sb {
                    return Err(format!("min mismatch: bucket {sa} heap {sb}"));
                }
                level = level.max(sa);
                peeled[ia as usize] = true;
                if ib != ia {
                    // tie broken differently: return the heap's pick so it
                    // stays poppable (supports are what we compare)
                    lh.push(sb, ib);
                }
                // decrease a few random survivors with floor clamp
                for _ in 0..rng.usize_below(4) {
                    let j = rng.usize_below(n);
                    if !peeled[j] {
                        let ns = sup[j].saturating_sub(1 + rng.below(3)).max(level);
                        if ns != sup[j] {
                            sup[j] = ns;
                            bq.push(ns, j as u32);
                            lh.push(ns, j as u32);
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn update_wedge_single_butterfly() {
        let g = gen::biclique(2, 2);
        let m = Meters::new();
        let mut sup = vec![1u64; 4];
        let alive = vec![true; 4];
        let e = 0u32;
        let mut touched = Vec::new();
        update_wedge(&g, e, 0, &alive, &mut sup, &m, &mut |ex, s| {
            touched.push((ex, s))
        });
        // the other three edges drop to 0
        assert_eq!(sup.iter().sum::<u64>(), 1); // only e keeps its 1
        assert_eq!(m.updates.get(), 3);
        assert_eq!(touched.len(), 3);
    }

    #[test]
    fn update_wedge_respects_floor() {
        let g = gen::biclique(2, 2);
        let m = Meters::new();
        let mut sup = vec![1u64; 4];
        let alive = vec![true; 4];
        update_wedge(&g, 0, 1, &alive, &mut sup, &m, &mut |_, _| {});
        assert!(sup.iter().all(|&s| s == 1)); // clamped at floor
    }

    #[test]
    fn update_wedge_skips_dead_edges() {
        let g = gen::biclique(2, 2);
        let m = Meters::new();
        let mut sup = vec![1u64; 4];
        let mut alive = vec![true; 4];
        alive[1] = false; // kill one wing of the butterfly
        update_wedge(&g, 0, 0, &alive, &mut sup, &m, &mut |_, _| {});
        assert_eq!(m.updates.get(), 0);
    }
}
