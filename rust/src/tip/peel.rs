//! Vertex-peeling kernel for tip decomposition (§3.2).
//!
//! Peeling a U-vertex `u` traverses all wedges `u — v — u'` and, for each
//! alive `u'` with `c ≥ 2` common neighbors (wedge ends), removes
//! `C(c, 2)` butterflies from `⋈_{u'}`. A butterfly has exactly two
//! U-vertices, so updates from concurrently peeled vertices touch
//! disjoint butterflies and can be aggregated atomically without conflict
//! resolution (unlike wing peeling).
//!
//! The V-side adjacency is kept in a compactable structure ([`VAdj`]) so
//! the §5.2 dynamic-deletes optimization can drop peeled endpoints.

use crate::count::{KernelConfig, UpdateKernel};
use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use crate::par::{parallel_for_chunked, SupportCell};
use std::sync::atomic::{AtomicU32, Ordering};

pub const ALIVE: u32 = u32::MAX;

/// Mutable V-side adjacency (`v -> [u]` lists with active prefix length).
pub struct VAdj {
    offs: Vec<usize>,
    adj: Vec<u32>,
    len: Vec<u32>,
}

impl VAdj {
    pub fn from_graph(g: &BipartiteGraph) -> Self {
        let nv = g.nv();
        let mut offs = vec![0usize; nv + 1];
        for v in 0..nv as u32 {
            offs[v as usize + 1] = offs[v as usize] + g.deg_v(v);
        }
        let mut adj = vec![0u32; g.m()];
        let mut cur = offs.clone();
        for v in 0..nv as u32 {
            for &(u, _) in g.nbrs_v(v) {
                adj[cur[v as usize]] = u;
                cur[v as usize] += 1;
            }
        }
        let len: Vec<u32> = (0..nv).map(|v| (offs[v + 1] - offs[v]) as u32).collect();
        VAdj { offs, adj, len }
    }

    #[inline]
    pub fn list(&self, v: u32) -> &[u32] {
        let s = self.offs[v as usize];
        &self.adj[s..s + self.len[v as usize] as usize]
    }

    #[inline]
    pub fn live_len(&self, v: u32) -> u32 {
        self.len[v as usize]
    }

    /// Drop peeled vertices from `v`'s list.
    pub fn compact(&mut self, v: u32, epoch: &[AtomicU32]) {
        let s = self.offs[v as usize];
        let len = self.len[v as usize] as usize;
        let mut w = 0usize;
        for r in 0..len {
            let u = self.adj[s + r];
            if epoch[u as usize].load(Ordering::Relaxed) == ALIVE {
                self.adj[s + w] = self.adj[s + r];
                w += 1;
            }
        }
        self.len[v as usize] = w as u32;
    }
}

/// Peel a set of U vertices in one parallel iteration. `active` must be
/// pre-marked at `epoch`. Returns alive vertices whose support changed.
///
/// If `deletes` is set, V-lists touched by the batch are compacted after
/// updates (disjoint parallel pass).
///
/// `upd` selects the support-update kernel: `Scattered` = one atomic
/// `sub_clamped` per wedge-end hit; `Aggregated` = per-lane
/// `(vertex, C(c,2))` logs flushed once per batch
/// ([`crate::count::kernel::flush_runs`]). Value-equivalent because
/// supports are write-only during the batch and clamped subtraction to
/// the common `floor` commutes; `updates`/touched bookkeeping happens at
/// hit time in both modes.
#[allow(clippy::too_many_arguments)]
pub fn peel_batch_tip(
    g: &BipartiteGraph,
    vadj: &mut VAdj,
    active: &[u32],
    floor: u64,
    epoch: &[AtomicU32],
    sup: &[SupportCell],
    threads: usize,
    deletes: bool,
    upd: UpdateKernel,
    meters: &Meters,
) -> Vec<u32> {
    let threads = threads.max(1);
    // Pool-owned per-lane scratch: the dense wedge counter (`cnt`, kept
    // all-zero between regions), the per-vertex wedge-end list (`a`) and
    // the touched-output collector (`b`) all keep their capacity across
    // the ρ peel iterations instead of being reallocated per call.
    let mut scratch = crate::par::ScratchSet::take(crate::par::max_lanes(threads));
    let vadj_ref: &VAdj = vadj;

    parallel_for_chunked(active.len(), threads, 8, |t, lo, hi| {
        // SAFETY: the pool drives each lane id from at most one thread
        // per region, so slot `t` is exclusively ours inside this chunk.
        let mut sc = unsafe { scratch.lane(t) };
        let (cnt, wedge_ends, out, pairs) = sc.split(g.nu());
        let mut wedges = 0u64;
        let mut updates = 0u64;
        for &u in &active[lo..hi] {
            for &(v, _) in g.nbrs_u(u) {
                for &u2 in vadj_ref.list(v) {
                    wedges += 1;
                    if u2 == u || epoch[u2 as usize].load(Ordering::Relaxed) != ALIVE {
                        continue;
                    }
                    if cnt[u2 as usize] == 0 {
                        wedge_ends.push(u2);
                    }
                    cnt[u2 as usize] += 1;
                }
            }
            for &u2 in wedge_ends.iter() {
                let c = cnt[u2 as usize] as u64;
                cnt[u2 as usize] = 0; // restore the all-zero invariant
                if c >= 2 {
                    match upd {
                        UpdateKernel::Scattered => {
                            sup[u2 as usize].sub_clamped(c * (c - 1) / 2, floor);
                        }
                        UpdateKernel::Aggregated => pairs.push((u2, c * (c - 1) / 2)),
                    }
                    updates += 1;
                    out.push(u2);
                }
            }
            wedge_ends.clear();
        }
        meters.wedges.add(wedges);
        meters.updates.add(updates);
    });

    let mut touched: Vec<u32> = Vec::new();
    scratch.for_each(|sc| {
        touched.extend_from_slice(&sc.b);
        sc.b.clear();
    });
    if upd == UpdateKernel::Aggregated {
        // one flush per batch: per-lane sort + run-sum, one atomic op
        // per distinct wedge-end vertex per lane
        crate::count::kernel::flush_runs(&scratch, |u2, d| {
            sup[u2 as usize].sub_clamped(d, floor);
        });
    }

    if deletes {
        // compact every V list adjacent to a peeled vertex (disjoint v's)
        let mut vs: Vec<u32> = active
            .iter()
            .flat_map(|&u| g.nbrs_u(u).iter().map(|&(v, _)| v))
            .collect();
        vs.sort_unstable();
        vs.dedup();
        for v in vs {
            vadj.compact(v, epoch);
        }
    }
    touched
}

/// Estimated wedge workload of peeling `active` on the current graph
/// (Λ(activeSet), §5.1): Σ_{u ∈ active} Σ_{v ∈ N_u} |live N_v|.
pub fn peel_workload(g: &BipartiteGraph, vadj: &VAdj, active: &[u32]) -> u64 {
    active
        .iter()
        .map(|&u| {
            g.nbrs_u(u)
                .iter()
                .map(|&(v, _)| vadj.live_len(v) as u64)
                .sum::<u64>()
        })
        .sum()
}

/// Re-count supports of all alive U vertices from scratch (§5.1 batch
/// optimization): build the remaining graph and run butterfly counting.
/// Returns the rebuilt `VAdj` (fully compacted) as a side effect.
pub fn recount(
    g: &BipartiteGraph,
    epoch: &[AtomicU32],
    sup: &[SupportCell],
    threads: usize,
    kernel: KernelConfig,
    meters: &Meters,
) -> VAdj {
    // remaining graph: edges of alive U vertices
    let mut edges = Vec::new();
    for u in 0..g.nu() as u32 {
        if epoch[u as usize].load(Ordering::Relaxed) == ALIVE {
            for &(v, _) in g.nbrs_u(u) {
                edges.push((u, v));
            }
        }
    }
    let rg = crate::graph::GraphBuilder::new()
        .nu(g.nu())
        .nv(g.nv())
        .edges(&edges)
        .build();
    let (counts, _) = crate::count::pve_bcnt(
        &rg,
        crate::count::CountOptions {
            per_edge: false,
            build_blooms: false,
            threads,
            kernel,
        },
        Some(meters),
    );
    for u in 0..g.nu() {
        if epoch[u].load(Ordering::Relaxed) == ALIVE {
            sup[u].set(counts.per_u[u]);
            meters.updates.add(1);
        }
    }
    VAdj::from_graph(&rg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn setup(g: &BipartiteGraph) -> (Vec<SupportCell>, Vec<AtomicU32>, VAdj) {
        let (c, _) = crate::count::pve_bcnt(
            g,
            crate::count::CountOptions {
                per_edge: false,
                build_blooms: false,
                threads: 1,
                kernel: KernelConfig::default(),
            },
            None,
        );
        let sup: Vec<SupportCell> = c.per_u.iter().map(|&s| SupportCell::new(s)).collect();
        let epoch: Vec<AtomicU32> = (0..g.nu()).map(|_| AtomicU32::new(ALIVE)).collect();
        let vadj = VAdj::from_graph(g);
        (sup, epoch, vadj)
    }

    #[test]
    fn peel_one_vertex_of_biclique() {
        // K_{3,3}: each u in 2*C(3,2)... per_u = C(3,2) * (3-1)? check via
        // setup; peel u0: others lose butterflies shared with u0.
        let g = gen::biclique(3, 3);
        let (sup, epoch, mut vadj) = setup(&g);
        let before = sup[1].get();
        let m = Meters::new();
        epoch[0].store(1, Ordering::Relaxed);
        peel_batch_tip(
            &g,
            &mut vadj,
            &[0],
            0,
            &epoch,
            &sup,
            1,
            true,
            UpdateKernel::Aggregated,
            &m,
        );
        // butterflies between u0 and u1: C(3,2) = 3
        assert_eq!(sup[1].get(), before - 3);
        assert_eq!(sup[2].get(), before - 3);
    }

    #[test]
    fn batch_matches_oracle_removal() {
        crate::testkit::check_property("tip-batch-vs-oracle", 0x717, 10, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                6 + rng.usize_below(12),
                6 + rng.usize_below(12),
                20 + rng.usize_below(60),
                seed,
            );
            let (sup, epoch, mut vadj) = setup(&g);
            let active: Vec<u32> =
                (0..g.nu() as u32).filter(|_| rng.chance(0.3)).collect();
            if active.is_empty() {
                return Ok(());
            }
            let m = Meters::new();
            for &u in &active {
                epoch[u as usize].store(1, Ordering::Relaxed);
            }
            // alternate update kernels across iterations: both must match
            // the brute-force oracle
            let upd = if seed % 2 == 0 {
                UpdateKernel::Aggregated
            } else {
                UpdateKernel::Scattered
            };
            peel_batch_tip(&g, &mut vadj, &active, 0, &epoch, &sup, 2, true, upd, &m);
            let alive: Vec<bool> = (0..g.nu())
                .map(|u| epoch[u].load(Ordering::Relaxed) == ALIVE)
                .collect();
            let oracle = crate::count::brute::vertex_support_restricted(&g, &alive);
            for u in 0..g.nu() {
                if alive[u] && sup[u].get() != oracle[u] {
                    return Err(format!(
                        "u{u}: got {} want {} (active {:?})",
                        sup[u].get(),
                        oracle[u],
                        active
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn recount_matches_batch_updates() {
        let g = gen::zipf(30, 30, 200, 1.2, 1.2, 7);
        let (sup_a, epoch_a, mut vadj_a) = setup(&g);
        let (sup_b, epoch_b, _) = setup(&g);
        let active: Vec<u32> = (0..10u32).collect();
        let m = Meters::new();
        for &u in &active {
            epoch_a[u as usize].store(1, Ordering::Relaxed);
            epoch_b[u as usize].store(1, Ordering::Relaxed);
        }
        peel_batch_tip(
            &g,
            &mut vadj_a,
            &active,
            0,
            &epoch_a,
            &sup_a,
            2,
            true,
            UpdateKernel::Aggregated,
            &m,
        );
        recount(&g, &epoch_b, &sup_b, 1, KernelConfig::default(), &m);
        for u in 10..g.nu() {
            assert_eq!(sup_a[u].get(), sup_b[u].get(), "u{u}");
        }
    }

    #[test]
    fn compaction_shrinks_lists() {
        let g = gen::biclique(3, 3);
        let (sup, epoch, mut vadj) = setup(&g);
        let m = Meters::new();
        epoch[0].store(1, Ordering::Relaxed);
        peel_batch_tip(
            &g,
            &mut vadj,
            &[0],
            0,
            &epoch,
            &sup,
            1,
            true,
            UpdateKernel::Scattered,
            &m,
        );
        for v in 0..3u32 {
            assert_eq!(vadj.live_len(v), 2);
        }
    }

    #[test]
    fn workload_estimate_reflects_compaction() {
        let g = gen::biclique(4, 4);
        let (sup, epoch, mut vadj) = setup(&g);
        let all: Vec<u32> = (0..4u32).collect();
        let w0 = peel_workload(&g, &vadj, &all);
        assert_eq!(w0, 4 * 4 * 4); // 4 us × 4 vs × 4 per list
        let m = Meters::new();
        epoch[0].store(1, Ordering::Relaxed);
        peel_batch_tip(
            &g,
            &mut vadj,
            &[0],
            0,
            &epoch,
            &sup,
            1,
            true,
            UpdateKernel::Aggregated,
            &m,
        );
        let w1 = peel_workload(&g, &vadj, &all[1..]);
        assert_eq!(w1, 3 * 4 * 3);
    }
}
