//! PBNG Coarse-grained Decomposition for tip decomposition (§3.2).
//!
//! Partitions the peel side's vertex set into `P` ranges of tip numbers.
//! Range determination uses each vertex's wedge count Σ_{v∈N_u} d_v as
//! the workload proxy. Iterations peel every vertex with support in the
//! current range; when the estimated peel traversal Λ(activeSet) exceeds
//! the counting bound Λ_cnt, the batch optimization (§5.1) re-counts all
//! remaining supports from scratch instead.

use super::peel::{peel_batch_tip, peel_workload, recount, VAdj, ALIVE};
use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use crate::par::SupportCell;
use crate::wing::range::{find_range, AdaptiveTarget};
use std::sync::atomic::{AtomicU32, Ordering};

#[derive(Clone, Copy, Debug)]
pub struct TipCdConfig {
    pub p: usize,
    pub threads: usize,
    /// §5.1 re-counting batch optimization; off = PBNG−−.
    pub batch: bool,
    /// §5.2 dynamic adjacency deletes; off = PBNG−.
    pub dynamic_deletes: bool,
}

impl Default for TipCdConfig {
    fn default() -> Self {
        TipCdConfig {
            p: 32,
            threads: crate::par::default_threads(),
            batch: true,
            dynamic_deletes: true,
        }
    }
}

#[derive(Debug)]
pub struct TipCdOutput {
    /// Partition per U vertex.
    pub part_of: Vec<u32>,
    /// ⋈init per U vertex.
    pub sup_init: Vec<u64>,
    /// θ(i) lower bound per partition.
    pub lowers: Vec<u64>,
    pub n_parts: usize,
}

/// Coarse decomposition of side U of `g` (callers transpose for side V).
pub fn coarse_decompose_tip(
    g: &BipartiteGraph,
    per_u: &[u64],
    cfg: TipCdConfig,
    meters: &Meters,
) -> TipCdOutput {
    let nu = g.nu();
    let sup: Vec<SupportCell> = per_u.iter().map(|&s| SupportCell::new(s)).collect();
    let epoch: Vec<AtomicU32> = (0..nu).map(|_| AtomicU32::new(ALIVE)).collect();
    let mut vadj = VAdj::from_graph(g);
    // static workload proxy: wedge count of u in G
    let wedge_proxy: Vec<u64> = (0..nu as u32)
        .map(|u| {
            g.nbrs_u(u)
                .iter()
                .map(|&(v, _)| g.deg_v(v) as u64)
                .sum()
        })
        .collect();
    let lambda_cnt = g.count_workload_bound();

    let mut part_of = vec![u32::MAX; nu];
    let mut sup_init = vec![0u64; nu];
    let mut lowers = Vec::new();
    let mut remaining = nu;
    let mut cur_epoch = 0u32;
    let mut lower = 0u64;
    let mut adaptive = AdaptiveTarget::new(cfg.p);
    let mut i = 0usize;

    while remaining > 0 {
        let mut remaining_work = 0u64;
        for u in 0..nu {
            if epoch[u].load(Ordering::Relaxed) == ALIVE {
                sup_init[u] = sup[u].get();
                remaining_work += wedge_proxy[u];
            }
        }
        let is_last = i + 1 >= cfg.p;
        let (upper, initial_estimate) = if is_last {
            (u64::MAX, remaining_work)
        } else {
            let tgt = adaptive.target(remaining_work);
            let r = find_range(
                (0..nu as u32)
                    .filter(|&u| epoch[u as usize].load(Ordering::Relaxed) == ALIVE)
                    .map(|u| (sup[u as usize].get(), wedge_proxy[u as usize].max(1))),
                tgt.max(1),
            );
            (r.upper.max(lower + 1), r.initial_estimate)
        };
        lowers.push(lower);

        let mut active: Vec<u32> = (0..nu as u32)
            .filter(|&u| {
                epoch[u as usize].load(Ordering::Relaxed) == ALIVE
                    && sup[u as usize].get() < upper
            })
            .collect();
        let mut partition_work = 0u64;

        while !active.is_empty() {
            meters.rho.add(1);
            cur_epoch += 1;
            for &u in &active {
                part_of[u as usize] = i as u32;
                partition_work += wedge_proxy[u as usize];
                epoch[u as usize].store(cur_epoch, Ordering::Relaxed);
            }
            remaining -= active.len();
            // §5.1: re-count instead of peeling when cheaper
            let use_recount =
                cfg.batch && peel_workload(g, &vadj, &active) > lambda_cnt && remaining > 0;
            if use_recount {
                vadj = recount(g, &epoch, &sup, cfg.threads, meters);
                active = (0..nu as u32)
                    .filter(|&u| {
                        epoch[u as usize].load(Ordering::Relaxed) == ALIVE
                            && sup[u as usize].get() < upper
                    })
                    .collect();
            } else {
                let mut touched = peel_batch_tip(
                    g,
                    &mut vadj,
                    &active,
                    lower,
                    &epoch,
                    &sup,
                    cfg.threads,
                    cfg.dynamic_deletes,
                    meters,
                );
                touched.sort_unstable();
                touched.dedup();
                touched.retain(|&u| {
                    epoch[u as usize].load(Ordering::Relaxed) == ALIVE
                        && sup[u as usize].get() < upper
                });
                active = touched;
            }
        }
        adaptive.record(initial_estimate, partition_work.max(1));
        lower = upper;
        i += 1;
        if is_last {
            break;
        }
    }
    TipCdOutput {
        part_of,
        sup_init,
        lowers,
        n_parts: i,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::graph::gen;

    fn counts_u(g: &BipartiteGraph) -> Vec<u64> {
        crate::count::pve_bcnt(
            g,
            crate::count::CountOptions {
                per_edge: false,
                build_blooms: false,
                threads: 1,
            },
            None,
        )
        .0
        .per_u
    }

    #[test]
    fn partitions_bracket_tip_numbers() {
        crate::testkit::check_property("tipcd-brackets", 0x71CD, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(50),
                seed,
            );
            let theta = brute::brute_tip_numbers(&g, crate::graph::Side::U);
            let per_u = counts_u(&g);
            let meters = Meters::new();
            let p = 1 + rng.usize_below(4);
            let out = coarse_decompose_tip(
                &g,
                &per_u,
                TipCdConfig { p, threads: 2, batch: true, dynamic_deletes: true },
                &meters,
            );
            for u in 0..g.nu() {
                let i = out.part_of[u] as usize;
                let lo = out.lowers[i];
                let hi = out.lowers.get(i + 1).copied().unwrap_or(u64::MAX);
                if theta[u] < lo || theta[u] >= hi {
                    return Err(format!(
                        "u{u}: θ={} outside partition {i} [{lo},{hi})",
                        theta[u]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sup_init_counts_higher_universe() {
        let g = gen::zipf(25, 25, 150, 1.2, 1.2, 3);
        let per_u = counts_u(&g);
        let meters = Meters::new();
        let out = coarse_decompose_tip(
            &g,
            &per_u,
            TipCdConfig { p: 3, threads: 1, batch: false, dynamic_deletes: true },
            &meters,
        );
        for i in 0..out.n_parts as u32 {
            let alive: Vec<bool> = (0..g.nu()).map(|u| out.part_of[u] >= i).collect();
            let oracle = brute::vertex_support_restricted(&g, &alive);
            for u in 0..g.nu() {
                if out.part_of[u] == i {
                    assert_eq!(out.sup_init[u], oracle[u], "u{u} part {i}");
                }
            }
        }
    }

    #[test]
    fn recount_and_peel_paths_agree() {
        let g = gen::zipf(40, 20, 300, 1.3, 1.1, 5);
        let per_u = counts_u(&g);
        let meters = Meters::new();
        let a = coarse_decompose_tip(
            &g,
            &per_u,
            TipCdConfig { p: 4, threads: 2, batch: true, dynamic_deletes: true },
            &meters,
        );
        let b = coarse_decompose_tip(
            &g,
            &per_u,
            TipCdConfig { p: 4, threads: 1, batch: false, dynamic_deletes: false },
            &meters,
        );
        assert_eq!(a.part_of, b.part_of);
        assert_eq!(a.sup_init, b.sup_init);
    }
}
