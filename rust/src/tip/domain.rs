//! Tip (vertex) peel domain: plugs wedge-based vertex peeling into the
//! generic two-phase engine ([`crate::engine`]).
//!
//! * CD hook — atomic support cells + peel epochs over side U, the
//!   [`peel_batch_tip`] wedge kernel, and the §5.1 *recount* escape
//!   hatch: when the estimated peel traversal Λ(activeSet) exceeds the
//!   counting bound Λ_cnt, supports of all remaining vertices are
//!   re-counted from scratch instead ([`PeelOutcome::Recounted`]). The
//!   workload proxy is the static wedge count Σ_{v∈N_u} d_v.
//! * FD substrate — induced subgraphs `G_i = G[(U_i, V)]`
//!   ([`build_partitions`]): a butterfly has exactly two U-vertices, so
//!   `G_i` preserves precisely the butterflies with both endpoints in
//!   `U_i`; everything else is baked into ⋈init.

use super::peel::{peel_batch_tip, peel_workload, recount, VAdj, ALIVE};
use crate::engine::{CdOutput, EngineConfig, PeelDomain, PeelOutcome};
use crate::graph::induced::{build_partitions, InducedSubgraph};
use crate::graph::BipartiteGraph;
use crate::metrics::Meters;
use crate::par::SupportCell;
use crate::peel::BucketQueue;
use std::sync::atomic::{AtomicU32, Ordering};

pub struct TipDomain<'a> {
    g: &'a BipartiteGraph,
    sup: Vec<SupportCell>,
    epoch: Vec<AtomicU32>,
    vadj: VAdj,
    /// Static workload proxy: wedge count of u in G.
    wedge_proxy: Vec<u64>,
    /// §5.1 counting bound Λ_cnt.
    lambda_cnt: u64,
    /// FD substrate (set by `build_substrate`).
    subs: Vec<InducedSubgraph>,
}

impl<'a> TipDomain<'a> {
    /// `per_u` are the initial butterfly counts of side U of `g`
    /// (callers transpose the graph for side V).
    pub fn new(g: &'a BipartiteGraph, per_u: &[u64]) -> Self {
        let nu = g.nu();
        let wedge_proxy: Vec<u64> = (0..nu as u32)
            .map(|u| g.nbrs_u(u).iter().map(|&(v, _)| g.deg_v(v) as u64).sum())
            .collect();
        TipDomain {
            g,
            sup: per_u.iter().map(|&s| SupportCell::new(s)).collect(),
            epoch: (0..nu).map(|_| AtomicU32::new(ALIVE)).collect(),
            vadj: VAdj::from_graph(g),
            wedge_proxy,
            lambda_cnt: g.count_workload_bound(),
            subs: Vec::new(),
        }
    }
}

impl PeelDomain for TipDomain<'_> {
    fn n_entities(&self) -> usize {
        self.sup.len()
    }

    fn is_alive(&self, u: u32) -> bool {
        self.epoch[u as usize].load(Ordering::Relaxed) == ALIVE
    }

    fn support(&self, u: u32) -> u64 {
        self.sup[u as usize].get()
    }

    fn workload_proxy(&self, u: u32, _sup_init: u64) -> u64 {
        self.wedge_proxy[u as usize]
    }

    fn peel_set(
        &mut self,
        active: &[u32],
        lower: u64,
        epoch: u32,
        remaining: usize,
        cfg: &EngineConfig,
        meters: &Meters,
    ) -> PeelOutcome {
        for &u in active {
            self.epoch[u as usize].store(epoch, Ordering::Relaxed);
        }
        // §5.1: re-count instead of peeling when cheaper
        let use_recount = cfg.batch
            && remaining > 0
            && peel_workload(self.g, &self.vadj, active) > self.lambda_cnt;
        if use_recount {
            self.vadj = recount(
                self.g,
                &self.epoch,
                &self.sup,
                cfg.threads,
                cfg.kernel,
                meters,
            );
            PeelOutcome::Recounted
        } else {
            PeelOutcome::Touched(peel_batch_tip(
                self.g,
                &mut self.vadj,
                active,
                lower,
                &self.epoch,
                &self.sup,
                cfg.threads,
                cfg.dynamic_deletes,
                cfg.kernel.updates,
                meters,
            ))
        }
    }

    fn build_substrate(&mut self, cd: &CdOutput, _cfg: &EngineConfig) {
        self.subs = build_partitions(self.g, &cd.part_of, cd.n_parts);
    }

    fn partition_workload(&self, part: usize, _cd: &CdOutput) -> u64 {
        // wedges with both endpoints in the partition (§3.2)
        self.subs[part].wedge_workload()
    }

    fn peel_partition(
        &self,
        part: usize,
        bounds: (u64, u64),
        theta: &crate::par::RacyBuf<u64>,
        cd: &CdOutput,
        cfg: &EngineConfig,
        meters: &Meters,
    ) {
        peel_induced(
            &self.subs[part],
            &cd.sup_init,
            bounds,
            theta,
            cfg.dynamic_deletes,
            meters,
        );
    }
}

/// Sequential bottom-up tip peel of one induced subgraph.
fn peel_induced(
    s: &InducedSubgraph,
    sup_init: &[u64],
    (range_lo, range_hi): (u64, u64),
    theta: &crate::par::RacyBuf<u64>,
    dynamic_deletes: bool,
    meters: &Meters,
) {
    let n = s.n_users();
    if n == 0 {
        return;
    }
    let mut sup: Vec<u64> = s.users.iter().map(|&u| sup_init[u as usize]).collect();
    let mut peeled = vec![false; n];
    // local mutable v-side adjacency (lists of local u ids)
    let mut adj_v: Vec<u32> = s.adj_v.clone();
    let mut len_v: Vec<u32> = (0..s.n_items())
        .map(|v| (s.offs_v[v + 1] - s.offs_v[v]) as u32)
        .collect();
    let hi = if range_hi == u64::MAX {
        sup.iter().copied().max().unwrap_or(range_lo) + 1
    } else {
        range_hi
    };
    let mut heap = BucketQueue::new(range_lo, hi);
    for (lu, &su) in sup.iter().enumerate() {
        heap.push(su, lu as u32);
    }
    let mut cnt = vec![0u32; n];
    let mut touched: Vec<u32> = Vec::new();
    let mut level = 0u64;
    let mut remaining = n;
    let mut wedges = 0u64;
    let mut updates = 0u64;
    while remaining > 0 {
        let (su, lu) = heap
            .pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]))
            .expect("induced heap exhausted early");
        let lu = lu as usize;
        level = level.max(su);
        // SAFETY: CD assigns every vertex to exactly one partition and
        // this task owns its partition exclusively, so no other lane
        // touches θ[users[lu]] (the FD driver's disjointness contract,
        // `engine::fd::fine_decompose`).
        unsafe { theta.set(s.users[lu] as usize, level) };
        peeled[lu] = true;
        remaining -= 1;
        // wedge traversal within the induced subgraph
        for &lv in s.nbrs_u(lu) {
            let base = s.offs_v[lv as usize];
            let llen = len_v[lv as usize] as usize;
            let mut w = 0usize;
            for r in 0..llen {
                let u2 = adj_v[base + r];
                wedges += 1;
                if peeled[u2 as usize] {
                    if !dynamic_deletes {
                        adj_v[base + w] = adj_v[base + r];
                        w += 1;
                    }
                    continue;
                }
                if cnt[u2 as usize] == 0 {
                    touched.push(u2);
                }
                cnt[u2 as usize] += 1;
                adj_v[base + w] = adj_v[base + r];
                w += 1;
            }
            if dynamic_deletes {
                len_v[lv as usize] = w as u32;
            }
        }
        for &u2 in &touched {
            let c = cnt[u2 as usize] as u64;
            cnt[u2 as usize] = 0;
            if c >= 2 {
                let ns = sup[u2 as usize].saturating_sub(c * (c - 1) / 2).max(level);
                if ns != sup[u2 as usize] {
                    sup[u2 as usize] = ns;
                    heap.push(ns, u2);
                }
                updates += 1;
            }
        }
        touched.clear();
    }
    meters.wedges.add(wedges);
    meters.updates.add(updates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::engine::coarse_decompose;
    use crate::graph::gen;
    use crate::graph::Side;
    use crate::tip::tip_pbng;

    fn cfg(p: usize, threads: usize, batch: bool, dynamic_deletes: bool) -> EngineConfig {
        EngineConfig {
            p,
            threads,
            batch,
            dynamic_deletes,
            ..Default::default()
        }
    }

    fn counts_u(g: &BipartiteGraph) -> Vec<u64> {
        crate::count::pve_bcnt(
            g,
            crate::count::CountOptions {
                per_edge: false,
                build_blooms: false,
                threads: 1,
                kernel: crate::count::KernelConfig::default(),
            },
            None,
        )
        .0
        .per_u
    }

    fn run_cd(g: &BipartiteGraph, c: &EngineConfig) -> CdOutput {
        let per_u = counts_u(g);
        let meters = Meters::new();
        let mut dom = TipDomain::new(g, &per_u);
        coarse_decompose(&mut dom, c, &meters)
    }

    #[test]
    fn partitions_bracket_tip_numbers() {
        crate::testkit::check_property("tipcd-brackets", 0x71CD, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(50),
                seed,
            );
            let theta = brute::brute_tip_numbers(&g, Side::U);
            let p = 1 + rng.usize_below(4);
            let out = run_cd(&g, &cfg(p, 2, true, true));
            for u in 0..g.nu() {
                let i = out.part_of[u] as usize;
                let lo = out.lowers[i];
                let hi = out.lowers.get(i + 1).copied().unwrap_or(u64::MAX);
                if theta[u] < lo || theta[u] >= hi {
                    return Err(format!(
                        "u{u}: θ={} outside partition {i} [{lo},{hi})",
                        theta[u]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sup_init_counts_higher_universe() {
        let g = gen::zipf(25, 25, 150, 1.2, 1.2, 3);
        let out = run_cd(&g, &cfg(3, 1, false, true));
        for i in 0..out.n_parts as u32 {
            let alive: Vec<bool> = (0..g.nu()).map(|u| out.part_of[u] >= i).collect();
            let oracle = brute::vertex_support_restricted(&g, &alive);
            for u in 0..g.nu() {
                if out.part_of[u] == i {
                    assert_eq!(out.sup_init[u], oracle[u], "u{u} part {i}");
                }
            }
        }
    }

    #[test]
    fn recount_and_peel_paths_agree() {
        let g = gen::zipf(40, 20, 300, 1.3, 1.1, 5);
        let a = run_cd(&g, &cfg(4, 2, true, true));
        let b = run_cd(&g, &cfg(4, 1, false, false));
        assert_eq!(a.part_of, b.part_of);
        assert_eq!(a.sup_init, b.sup_init);
    }

    #[test]
    fn matches_brute_on_biclique() {
        let g = gen::biclique(4, 3);
        let got = tip_pbng(&g, Side::U, cfg(2, 2, true, true)).theta;
        assert_eq!(got, brute::brute_tip_numbers(&g, Side::U));
    }

    #[test]
    fn matches_brute_on_random_graphs() {
        crate::testkit::check_property("tip-fd-vs-brute", 0x71FD, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(50),
                seed,
            );
            let p = 1 + rng.usize_below(4);
            let threads = 1 + rng.usize_below(3);
            let got = tip_pbng(&g, Side::U, cfg(p, threads, true, true)).theta;
            let want = brute::brute_tip_numbers(&g, Side::U);
            if got != want {
                return Err(format!("P={p} T={threads}: got={got:?} want={want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_on_fig1() {
        let g = gen::paper_fig1();
        let got = tip_pbng(&g, Side::U, cfg(3, 2, true, true)).theta;
        assert_eq!(got, brute::brute_tip_numbers(&g, Side::U));
    }

    #[test]
    fn deletes_off_same_output() {
        let g = gen::zipf(30, 30, 200, 1.2, 1.2, 9);
        let got = tip_pbng(&g, Side::U, cfg(4, 1, true, false)).theta;
        assert_eq!(got, brute::brute_tip_numbers(&g, Side::U));
    }
}
