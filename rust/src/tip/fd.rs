//! PBNG Fine-grained Decomposition for tip decomposition (§3.2).
//!
//! Each partition `U_i` is peeled on its *induced subgraph*
//! `G_i = G[(U_i, V)]` — a butterfly has exactly two U-vertices, so `G_i`
//! preserves precisely the butterflies with both U-endpoints in `U_i`;
//! everything else is already baked into ⋈init. Partitions are pulled
//! from an LPT-ordered dynamic task queue by the persistent runtime
//! pool's lanes ([`crate::par::spmd`]) and peeled sequentially with a
//! range-clamped bucket queue; no global synchronization.

use crate::graph::induced::{build_partitions, InducedSubgraph};
use crate::metrics::Meters;
use crate::par::{spmd, RacyCell};
use crate::peel::BucketQueue;
use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Clone, Copy, Debug)]
pub struct TipFdConfig {
    pub threads: usize,
    /// §5.2 dynamic adjacency deletes in the induced subgraphs.
    pub dynamic_deletes: bool,
}

/// Peel all partitions; returns θ per U vertex.
pub fn fine_decompose_tip(
    g: &crate::graph::BipartiteGraph,
    part_of: &[u32],
    sup_init: &[u64],
    lowers: &[u64],
    n_parts: usize,
    cfg: TipFdConfig,
    meters: &Meters,
) -> Vec<u64> {
    let subs = build_partitions(g, part_of, n_parts);
    // LPT: workload = wedges with both endpoints in the partition (§3.2)
    let mut order: Vec<usize> = (0..n_parts).collect();
    let work: Vec<u64> = subs.iter().map(|s| s.wedge_workload()).collect();
    order.sort_unstable_by(|&a, &b| work[b].cmp(&work[a]));

    let theta_cell = RacyCell::new(vec![0u64; g.nu()]);
    let next = AtomicUsize::new(0);
    let subs_ref = &subs;
    spmd(cfg.threads.max(1), |_| loop {
        let t = next.fetch_add(1, Ordering::Relaxed);
        if t >= n_parts {
            break;
        }
        let i = order[t];
        // SAFETY: partitions own disjoint U vertices.
        let theta = unsafe { theta_cell.get_mut() };
        let lo = lowers.get(i).copied().unwrap_or(0);
        let hi = lowers.get(i + 1).copied().unwrap_or(u64::MAX);
        peel_induced(&subs_ref[i], sup_init, (lo, hi), theta, cfg.dynamic_deletes, meters);
    });
    theta_cell.into_inner()
}

/// Sequential bottom-up tip peel of one induced subgraph.
fn peel_induced(
    s: &InducedSubgraph,
    sup_init: &[u64],
    (range_lo, range_hi): (u64, u64),
    theta: &mut [u64],
    dynamic_deletes: bool,
    meters: &Meters,
) {
    let n = s.n_users();
    if n == 0 {
        return;
    }
    let mut sup: Vec<u64> = s.users.iter().map(|&u| sup_init[u as usize]).collect();
    let mut peeled = vec![false; n];
    // local mutable v-side adjacency (lists of local u ids)
    let mut adj_v: Vec<u32> = s.adj_v.clone();
    let mut len_v: Vec<u32> = (0..s.n_items())
        .map(|v| (s.offs_v[v + 1] - s.offs_v[v]) as u32)
        .collect();
    let hi = if range_hi == u64::MAX {
        sup.iter().copied().max().unwrap_or(range_lo) + 1
    } else {
        range_hi
    };
    let mut heap = BucketQueue::new(range_lo, hi);
    for (lu, &su) in sup.iter().enumerate() {
        heap.push(su, lu as u32);
    }
    let mut cnt = vec![0u32; n];
    let mut touched: Vec<u32> = Vec::new();
    let mut level = 0u64;
    let mut remaining = n;
    let mut wedges = 0u64;
    let mut updates = 0u64;
    while remaining > 0 {
        let (su, lu) = heap
            .pop_live(|i| (!peeled[i as usize]).then(|| sup[i as usize]))
            .expect("induced heap exhausted early");
        let lu = lu as usize;
        level = level.max(su);
        theta[s.users[lu] as usize] = level;
        peeled[lu] = true;
        remaining -= 1;
        // wedge traversal within the induced subgraph
        for &lv in s.nbrs_u(lu) {
            let base = s.offs_v[lv as usize];
            let llen = len_v[lv as usize] as usize;
            let mut w = 0usize;
            for r in 0..llen {
                let u2 = adj_v[base + r];
                wedges += 1;
                if peeled[u2 as usize] {
                    if !dynamic_deletes {
                        adj_v[base + w] = adj_v[base + r];
                        w += 1;
                    }
                    continue;
                }
                if cnt[u2 as usize] == 0 {
                    touched.push(u2);
                }
                cnt[u2 as usize] += 1;
                adj_v[base + w] = adj_v[base + r];
                w += 1;
            }
            if dynamic_deletes {
                len_v[lv as usize] = w as u32;
            }
        }
        for &u2 in &touched {
            let c = cnt[u2 as usize] as u64;
            cnt[u2 as usize] = 0;
            if c >= 2 {
                let ns = sup[u2 as usize].saturating_sub(c * (c - 1) / 2).max(level);
                if ns != sup[u2 as usize] {
                    sup[u2 as usize] = ns;
                    heap.push(ns, u2);
                }
                updates += 1;
            }
        }
        touched.clear();
    }
    meters.wedges.add(wedges);
    meters.updates.add(updates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::graph::gen;
    use crate::graph::Side;
    use crate::tip::cd::{coarse_decompose_tip, TipCdConfig};

    fn pbng_tip_theta(g: &crate::graph::BipartiteGraph, p: usize, threads: usize) -> Vec<u64> {
        let per_u = crate::count::pve_bcnt(
            g,
            crate::count::CountOptions {
                per_edge: false,
                build_blooms: false,
                threads,
            },
            None,
        )
        .0
        .per_u;
        let meters = Meters::new();
        let cd = coarse_decompose_tip(
            g,
            &per_u,
            TipCdConfig { p, threads, batch: true, dynamic_deletes: true },
            &meters,
        );
        fine_decompose_tip(
            g,
            &cd.part_of,
            &cd.sup_init,
            &cd.lowers,
            cd.n_parts,
            TipFdConfig { threads, dynamic_deletes: true },
            &meters,
        )
    }

    #[test]
    fn matches_brute_on_biclique() {
        let g = gen::biclique(4, 3);
        assert_eq!(pbng_tip_theta(&g, 2, 2), brute::brute_tip_numbers(&g, Side::U));
    }

    #[test]
    fn matches_brute_on_random_graphs() {
        crate::testkit::check_property("tip-fd-vs-brute", 0x71FD, 8, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(50),
                seed,
            );
            let p = 1 + rng.usize_below(4);
            let threads = 1 + rng.usize_below(3);
            let got = pbng_tip_theta(&g, p, threads);
            let want = brute::brute_tip_numbers(&g, Side::U);
            if got != want {
                return Err(format!("P={p} T={threads}: got={got:?} want={want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_on_fig1() {
        let g = gen::paper_fig1();
        assert_eq!(pbng_tip_theta(&g, 3, 2), brute::brute_tip_numbers(&g, Side::U));
    }

    #[test]
    fn deletes_off_same_output() {
        let g = gen::zipf(30, 30, 200, 1.2, 1.2, 9);
        let per_u = crate::count::pve_bcnt(
            &g,
            crate::count::CountOptions { per_edge: false, build_blooms: false, threads: 1 },
            None,
        )
        .0
        .per_u;
        let meters = Meters::new();
        let cd = coarse_decompose_tip(
            &g,
            &per_u,
            TipCdConfig { p: 4, threads: 1, batch: true, dynamic_deletes: false },
            &meters,
        );
        let theta = fine_decompose_tip(
            &g,
            &cd.part_of,
            &cd.sup_init,
            &cd.lowers,
            cd.n_parts,
            TipFdConfig { threads: 1, dynamic_deletes: false },
            &meters,
        );
        assert_eq!(theta, brute::brute_tip_numbers(&g, Side::U));
    }
}
