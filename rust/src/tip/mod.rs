//! Tip decomposition (vertex peeling): the PBNG pipeline on the generic
//! two-phase engine, plus the BUP / ParB baselines.
//!
//! Tip decomposition peels exactly one side of the bipartition (a k-tip
//! contains all of the other side, Defn. 2). All algorithms here peel
//! side `U`; entry points take a [`Side`] and transpose internally.
//!
//! Since the engine refactor, this module holds **no CD/FD driver of its
//! own**: [`tip_pbng`] counts butterflies per vertex (the counting
//! phase), wraps the graph in [`domain::TipDomain`] — the
//! [`crate::engine::PeelDomain`] impl for vertices, including the §5.1
//! recount hook and the induced-subgraph FD substrate — and hands off to
//! [`crate::engine::decompose`]. What remains here is strictly
//! vertex-specific: the wedge peel kernel ([`peel`]), the per-partition
//! induced peel ([`domain`]), and the baselines.
//!
//! Configuration: the former `TipConfig`/`TipCdConfig`/`TipFdConfig`
//! trio is replaced by [`crate::engine::EngineConfig`] (tip-scaled
//! defaults via [`EngineConfig::tip`]); `TipConfig` remains as an alias
//! for downstream code.

pub mod domain;
pub mod peel;

use crate::count::{KernelConfig, UpdateKernel};
use crate::engine::{self, EngineConfig};
use crate::graph::{BipartiteGraph, Side};
use crate::metrics::{Meters, Phase, Recorder};
use crate::peel::{Decomposition, LazyHeap};
use domain::TipDomain;
use peel::{peel_batch_tip, VAdj, ALIVE};
use std::sync::atomic::{AtomicU32, Ordering};

/// Back-compat alias: the tip pipeline is configured by the shared
/// engine config since the `pbng::engine` refactor. Note that
/// `TipConfig::default()` now carries the engine-wide default `P = 64`;
/// use [`EngineConfig::tip`] for the tip-scaled `P = 32`.
pub type TipConfig = EngineConfig;

fn oriented(g: &BipartiteGraph, side: Side) -> std::borrow::Cow<'_, BipartiteGraph> {
    match side {
        Side::U => std::borrow::Cow::Borrowed(g),
        Side::V => std::borrow::Cow::Owned(g.transposed()),
    }
}

fn count_side(
    g: &BipartiteGraph,
    threads: usize,
    kernel: KernelConfig,
    meters: &Meters,
) -> Vec<u64> {
    crate::count::pve_bcnt(
        g,
        crate::count::CountOptions {
            per_edge: false,
            build_blooms: false,
            threads,
            kernel,
        },
        Some(meters),
    )
    .0
    .per_u
}

/// PBNG tip decomposition of `side` (two-phased peeling on the generic
/// engine).
pub fn tip_pbng(g: &BipartiteGraph, side: Side, cfg: TipConfig) -> Decomposition {
    let g = oriented(g, side);
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    // the counting kernel emits its own CountKernel span (with the
    // resolved wedge side and SIMD flag) from inside pve_bcnt
    let per_u = count_side(&g, cfg.threads, cfg.kernel, &meters);
    let mut dom = TipDomain::new(&g, &per_u);
    engine::decompose(&mut dom, &cfg, rec).into_decomposition()
}

/// Sequential bottom-up tip decomposition (baseline).
pub fn tip_bup(g: &BipartiteGraph, side: Side) -> Decomposition {
    let g = oriented(g, side);
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    let per_u = count_side(&g, 1, KernelConfig::default(), &meters);
    rec.enter(Phase::Fine);
    let nu = g.nu();
    let sup: Vec<crate::par::SupportCell> = per_u
        .iter()
        .map(|&s| crate::par::SupportCell::new(s))
        .collect();
    let epoch: Vec<AtomicU32> = (0..nu).map(|_| AtomicU32::new(ALIVE)).collect();
    let mut vadj = VAdj::from_graph(&g);
    let mut theta = vec![0u64; nu];
    let mut heap = LazyHeap::new();
    for (u, &s) in per_u.iter().enumerate() {
        heap.push(s, u as u32);
    }
    let mut level = 0u64;
    let mut remaining = nu;
    let mut ep = 0u32;
    while remaining > 0 {
        let (s, u) = heap
            .pop_live(|i| {
                (epoch[i as usize].load(Ordering::Relaxed) == ALIVE)
                    .then(|| sup[i as usize].get())
            })
            .expect("tip heap exhausted");
        level = level.max(s);
        theta[u as usize] = level;
        ep += 1;
        epoch[u as usize].store(ep, Ordering::Relaxed);
        remaining -= 1;
        let touched = peel_batch_tip(
            &g,
            &mut vadj,
            &[u],
            level,
            &epoch,
            &sup,
            1,
            false,
            UpdateKernel::Scattered,
            &meters,
        );
        for t in touched {
            if epoch[t as usize].load(Ordering::Relaxed) == ALIVE {
                heap.push(sup[t as usize].get(), t);
            }
        }
    }
    Decomposition {
        theta,
        stats: rec.finish(),
    }
}

/// ParB-style level-synchronous tip decomposition (baseline). See
/// [`crate::peel::parb`] for the modeling notes; ρ counts parallel
/// sub-iterations. The counting phase runs on the runtime pool with the
/// caller's `threads` (counters stay deterministic across thread counts).
pub fn tip_parb(g: &BipartiteGraph, side: Side, threads: usize) -> Decomposition {
    let g = oriented(g, side);
    let meters = Meters::new();
    let mut rec = Recorder::new(&meters);
    rec.enter(Phase::Count);
    let per_u = count_side(&g, threads, KernelConfig::default(), &meters);
    rec.enter(Phase::Fine);
    let nu = g.nu();
    let sup: Vec<crate::par::SupportCell> = per_u
        .iter()
        .map(|&s| crate::par::SupportCell::new(s))
        .collect();
    let epoch: Vec<AtomicU32> = (0..nu).map(|_| AtomicU32::new(ALIVE)).collect();
    let mut vadj = VAdj::from_graph(&g);
    let mut theta = vec![0u64; nu];
    let mut heap = LazyHeap::new();
    for (u, &s) in per_u.iter().enumerate() {
        heap.push(s, u as u32);
    }
    let mut remaining = nu;
    let mut ep = 0u32;
    let alive = |epoch: &[AtomicU32], i: u32| epoch[i as usize].load(Ordering::Relaxed) == ALIVE;
    // in-bucket bitmap replacing an O(bucket) `contains` scan per pop
    // (see peel::parb); never cleared — bucketed vertices are peeled at
    // their level, so stale bits only ever shadow dead vertices.
    let mut in_bucket = vec![false; nu];
    while remaining > 0 {
        let (k, first) = heap
            .pop_live(|i| alive(&epoch, i).then(|| sup[i as usize].get()))
            .expect("tip heap exhausted");
        in_bucket[first as usize] = true;
        let mut active = vec![first];
        while let Some((s, u)) = heap.pop_live(|i| alive(&epoch, i).then(|| sup[i as usize].get()))
        {
            if s > k {
                heap.push(s, u);
                break;
            }
            if !in_bucket[u as usize] {
                in_bucket[u as usize] = true;
                active.push(u);
            }
        }
        while !active.is_empty() {
            meters.rho.add(1);
            ep += 1;
            for &u in &active {
                theta[u as usize] = k;
                epoch[u as usize].store(ep, Ordering::Relaxed);
            }
            remaining -= active.len();
            let mut touched = peel_batch_tip(
                &g,
                &mut vadj,
                &active,
                k,
                &epoch,
                &sup,
                1,
                false,
                UpdateKernel::Scattered,
                &meters,
            );
            touched.sort_unstable();
            touched.dedup();
            let mut next = Vec::new();
            for &u in &touched {
                if alive(&epoch, u) {
                    let s = sup[u as usize].get();
                    if s <= k {
                        next.push(u);
                    } else {
                        heap.push(s, u);
                    }
                }
            }
            active = next;
        }
    }
    Decomposition {
        theta,
        stats: rec.finish(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::graph::gen;

    #[test]
    fn all_tip_algorithms_agree() {
        crate::testkit::check_property("tip-all-agree", 0x71A, 6, |seed| {
            let mut rng = crate::testkit::Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(60),
                seed,
            );
            for side in [Side::U, Side::V] {
                let want = brute::brute_tip_numbers(&g, side);
                let bup = tip_bup(&g, side).theta;
                let parb = tip_parb(&g, side, 2).theta;
                let pbng = tip_pbng(&g, side, TipConfig { p: 3, threads: 2, ..Default::default() }).theta;
                if bup != want {
                    return Err(format!("bup {side:?}: {bup:?} want {want:?}"));
                }
                if parb != want {
                    return Err(format!("parb {side:?}: {parb:?} want {want:?}"));
                }
                if pbng != want {
                    return Err(format!("pbng {side:?}: {pbng:?} want {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pbng_rho_beats_parb() {
        let g = gen::zipf(80, 40, 500, 1.3, 1.2, 71);
        let pbng = tip_pbng(&g, Side::U, TipConfig { p: 4, threads: 2, ..Default::default() });
        let parb = tip_parb(&g, Side::U, 2);
        assert!(
            pbng.stats.rho <= parb.stats.rho,
            "pbng rho {} > parb rho {}",
            pbng.stats.rho,
            parb.stats.rho
        );
    }

    #[test]
    fn sides_are_independent() {
        let g = gen::biclique(3, 5);
        let u = tip_pbng(&g, Side::U, EngineConfig::tip());
        let v = tip_pbng(&g, Side::V, EngineConfig::tip());
        assert_eq!(u.theta.len(), 3);
        assert_eq!(v.theta.len(), 5);
        // K_{3,5}: u vertices participate in C(5,2)*(3-1)... just check
        // uniformity within each side
        assert!(u.theta.iter().all(|&t| t == u.theta[0]));
        assert!(v.theta.iter().all(|&t| t == v.theta[0]));
    }

    #[test]
    fn ablations_preserve_output() {
        let g = gen::zipf(30, 30, 200, 1.2, 1.2, 72);
        let base = tip_pbng(&g, Side::U, TipConfig { p: 4, threads: 2, ..Default::default() }).theta;
        let m1 = tip_pbng(
            &g,
            Side::U,
            TipConfig { p: 4, threads: 2, dynamic_deletes: false, ..Default::default() },
        )
        .theta;
        let m2 = tip_pbng(
            &g,
            Side::U,
            TipConfig { p: 4, threads: 2, batch: false, dynamic_deletes: false, ..Default::default() },
        )
        .theta;
        assert_eq!(base, m1);
        assert_eq!(base, m2);
    }

    #[test]
    fn planted_block_has_high_tips() {
        let g = gen::planted_blocks(
            100,
            100,
            200,
            &[gen::Block { rows: 8, cols: 8, density: 1.0 }],
            5,
        );
        let d = tip_pbng(&g, Side::U, TipConfig { p: 4, threads: 1, ..Default::default() });
        // the 8 block rows must hold the highest tip numbers
        let max = *d.theta.iter().max().unwrap();
        let top: Vec<usize> = (0..g.nu()).filter(|&u| d.theta[u] == max).collect();
        assert!(top.iter().all(|&u| u < 8), "top tips outside block: {top:?}");
    }
}
