//! Minimal Rust source scanner for `pbng-lint` (no syn, no deps).
//!
//! The lint rules only need token-level facts ("does this line *execute*
//! an `unsafe` block / an `Ordering::` op / a `.unwrap()`?"), so this
//! module does the one piece of real lexing those facts require:
//! splitting each physical line into its **code** half and its
//! **comment** half, with string/char literal *contents* stripped from
//! the code so a `"contains unsafe"` literal can never trip a rule. The
//! state machine understands line comments (`//`, `///`, `//!`), nested
//! block comments, plain and raw strings (`r"…"`, `r#"…"#`, byte
//! variants), char literals, and the char-vs-lifetime ambiguity of `'`.

/// One physical source line. `code` holds everything outside comments,
/// with literal contents blanked (delimiting quotes are kept so call
/// shapes like `.expect(` stay recognizable); `comment` holds the text
/// of every comment that touches the line.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

enum St {
    Code,
    LineComment,
    /// Nesting depth — Rust block comments nest.
    BlockComment(u32),
    Str,
    /// Number of `#`s in the opening delimiter.
    RawStr(u32),
    Char,
}

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Detect a raw-string opener (`r"`, `r#"`, `br##"`, …) starting at `i`.
/// Returns the hash count and the index just past the opening quote.
fn raw_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Split `src` into per-line (code, comment) halves; see [`Line`].
pub fn split_lines(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && (i == 0 || !is_word(chars[i - 1])) {
                    if let Some((hashes, after)) = raw_open(&chars, i) {
                        cur.code.push('"');
                        st = St::RawStr(hashes);
                        i = after;
                    } else if c == 'b' && next == Some('"') {
                        cur.code.push('b');
                        cur.code.push('"');
                        st = St::Str;
                        i += 2;
                    } else if c == 'b' && next == Some('\'') {
                        cur.code.push('b');
                        cur.code.push('\'');
                        st = St::Char;
                        i += 2;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: `'x'` / `'\n'` are chars;
                    // `'a` followed by anything but a closing quote is a
                    // lifetime (or loop label).
                    let is_char = next == Some('\\')
                        || (next.is_some() && chars.get(i + 2) == Some(&'\''));
                    cur.code.push('\'');
                    if is_char {
                        st = St::Char;
                    }
                    i += 1;
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let n = hashes as usize;
                    let closed = (1..=n).all(|k| chars.get(i + k) == Some(&'#'));
                    if closed {
                        cur.code.push('"');
                        st = St::Code;
                        i += 1 + n;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    cur.code.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        split_lines(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn comments_split_from_code() {
        let ls = split_lines("let x = 1; // trailing\n// full line\nlet y = 2;\n");
        assert_eq!(ls.len(), 3);
        assert_eq!(ls[0].code.trim(), "let x = 1;");
        assert!(ls[0].comment.contains("trailing"));
        assert!(ls[1].code.trim().is_empty());
        assert!(ls[1].comment.contains("full line"));
        assert_eq!(ls[2].code.trim(), "let y = 2;");
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = codes("let s = \"unsafe // not a comment\"; let t = 1;\n");
        assert!(!c[0].contains("unsafe"));
        assert!(c[0].contains("let t = 1;"));
    }

    #[test]
    fn string_escapes_do_not_end_the_literal() {
        let c = codes("let s = \"a\\\"unsafe\"; done();\n");
        assert!(!c[0].contains("unsafe"));
        assert!(c[0].contains("done();"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = codes("let s = r#\"unsafe \" quote\"#; let t = 1;\n");
        assert!(!c[0].contains("unsafe"));
        assert!(c[0].contains("let t = 1;"));
        let c = codes("let b = br\"Mutex\"; ok();\n");
        assert!(!c[0].contains("Mutex"));
        assert!(c[0].contains("ok();"));
    }

    #[test]
    fn char_vs_lifetime() {
        let c = codes("fn f<'a>(x: &'a str) -> char { '\\'' }\nlet c = 'x'; let b = b'y';\n");
        assert!(c[0].contains("'a str"), "{:?}", c[0]);
        assert!(!c[1].contains('x'), "{:?}", c[1]);
        assert!(!c[1].contains('y'), "{:?}", c[1]);
        assert!(c[1].contains("let b = b'"), "{:?}", c[1]);
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let ls = split_lines("a /* c1 /* nested */ still */ b\n/* open\nclose */ c\n");
        assert!(ls[0].code.contains('a') && ls[0].code.contains('b'));
        assert!(!ls[0].code.contains("c1"));
        assert!(ls[0].comment.contains("c1"));
        assert!(ls[1].code.trim().is_empty());
        assert!(ls[2].code.contains('c'));
        assert!(!ls[2].code.contains("close"));
    }

    #[test]
    fn last_line_without_newline_is_kept() {
        let ls = split_lines("let a = 1;");
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].code, "let a = 1;");
    }
}
