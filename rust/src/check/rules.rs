//! The `pbng-lint` rule set: concurrency-correctness conventions the
//! crate commits to (see `lib.rs` "Unsafe policy"), checked per file.
//!
//! Rules (all diagnostics carry these names):
//!
//! * `safety-comment` — every line that executes `unsafe` must be
//!   justified by an adjacent `// SAFETY:` comment (or a `# Safety` doc
//!   section on the item). Enforced everywhere, tests included.
//! * `ordering-comment` — every `Ordering::` use in `par/`, `obs/`,
//!   `serve/` must carry an `// ORDERING:` justification.
//! * `transmute-allowlist` — `transmute` is forbidden outside the
//!   allowlisted wrapper (`par/pool.rs::erase_lifetime`).
//! * `hot-path-lock` — no `Mutex`/`RwLock` in the hot-path modules
//!   (`engine/`, `wing/`, `tip/`, `count/`, `par/`); the pool's own
//!   park/wake lock is allowlisted.
//! * `serve-unwrap` — no `.unwrap()`/`.expect(` on serving paths
//!   (`serve/`); shedding beats aborting.
//!
//! "Adjacent" means the justification survives this walk-up from the
//! flagged line: same-line trailing comments count; pure comment lines,
//! attribute lines, and lines belonging to the same cluster (another
//! line of the same `unsafe` block / atomic group) are stepped over;
//! any other code line or a blank line breaks the search.

use super::lexer::{split_lines, Line};

pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_ORDERING: &str = "ordering-comment";
pub const RULE_TRANSMUTE: &str = "transmute-allowlist";
pub const RULE_LOCK: &str = "hot-path-lock";
pub const RULE_UNWRAP: &str = "serve-unwrap";

const MSG_SAFETY: &str = "`unsafe` without an adjacent `// SAFETY:` comment";
const MSG_ORDERING: &str = "`Ordering::` use without an `// ORDERING:` justification";
const MSG_TRANSMUTE: &str = "`transmute` outside the allowlist (par/pool.rs::erase_lifetime)";
const MSG_LOCK: &str = "blocking lock (`Mutex`/`RwLock`) in a hot-path module";
const MSG_UNWRAP: &str = "`.unwrap()`/`.expect()` on a serving path — shed, don't abort";

/// Modules whose atomics must justify their memory ordering.
const ORDERING_SCOPE: [&str; 3] = ["par/", "obs/", "serve/"];
/// Hot-path modules where blocking locks are forbidden.
const LOCK_SCOPE: [&str; 5] = ["engine/", "wing/", "tip/", "count/", "par/"];
/// `(file suffix, enclosing fn)` pairs allowed to use `transmute`.
const TRANSMUTE_ALLOWLIST: [(&str, &str); 1] = [("par/pool.rs", "erase_lifetime")];
/// Files in `LOCK_SCOPE` allowed to name locks: the pool's park/wake
/// machinery *is* a lock by design (Mutex + Condvar worker parking).
const LOCK_ALLOWLIST: [&str; 1] = ["par/pool.rs"];

/// Comment markers that justify an `unsafe` site.
const SAFETY_MARKERS: [&str; 2] = ["SAFETY:", "# Safety"];
/// Comment markers that justify an `Ordering::` site.
const ORDERING_MARKERS: [&str; 1] = ["ORDERING:"];

/// One lint violation, pointing at a 1-based line of `file`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: &'static str,
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-boundary token search (so `unsafe_op_in_unsafe_fn` is not an
/// `unsafe` token and `TRANSMUTE_ALLOWLIST` is not a `transmute` one).
fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(pos) = code[search..].find(tok) {
        let p = search + pos;
        let before_ok = p == 0 || !is_word_byte(bytes[p - 1]);
        let end = p + tok.len();
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        search = p + 1;
    }
    false
}

fn contains_marker(comment: &str, markers: &[&str]) -> bool {
    markers.iter().any(|m| comment.contains(m))
}

fn in_scope(path: &str, scope: &[&str]) -> bool {
    scope.iter().any(|pre| path.starts_with(pre))
}

/// Is the `cluster`-bearing code on line `idx` justified by a marker
/// comment? Implements the walk-up documented in the module header.
fn justified(lines: &[Line], idx: usize, markers: &[&str], cluster: &str) -> bool {
    if contains_marker(&lines[idx].comment, markers) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if contains_marker(&l.comment, markers) {
            return true;
        }
        let code = l.code.trim();
        if code.is_empty() {
            if l.comment.trim().is_empty() {
                return false; // blank line breaks the cluster
            }
            continue; // pure comment without the marker — keep walking
        }
        if code.starts_with("#[") || code.starts_with("#![") {
            continue; // attributes sit between a justification and its item
        }
        if code.contains(cluster) {
            continue; // same cluster (e.g. the `unsafe {` opener) — keep walking
        }
        return false; // unrelated code breaks the search
    }
    false
}

/// Extract the name declared by a `fn` token on this line, if any.
fn fn_decl_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(pos) = code[search..].find("fn") {
        let p = search + pos;
        let before_ok = p == 0 || !is_word_byte(bytes[p - 1]);
        let end = p + 2;
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            let name: String = code[end..]
                .trim_start()
                .chars()
                .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        search = p + 2;
    }
    None
}

/// Run every rule over one file. `path` must be `/`-separated and
/// relative to the scan root (e.g. `par/pool.rs`) for the scoped rules
/// to apply.
pub fn check_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let lines = split_lines(src);
    let mut out = Vec::new();

    let ordering_scoped = in_scope(path, &ORDERING_SCOPE);
    let lock_scoped =
        in_scope(path, &LOCK_SCOPE) && !LOCK_ALLOWLIST.iter().any(|p| path.ends_with(p));
    let serve_scoped = in_scope(path, &["serve/"]);

    // Brace-depth bookkeeping: `#[cfg(test)]`-gated regions are exempt
    // from the scoped rules (ordering / lock / unwrap), and the name of
    // the enclosing fn feeds the transmute allowlist.
    let mut depth: i64 = 0;
    let mut test_depth: Option<i64> = None;
    let mut pending_test = false;
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    let mut pending_fn: Option<String> = None;

    for (idx, line) in lines.iter().enumerate() {
        let in_test = test_depth.is_some();
        let code = line.code.as_str();
        let lineno = idx + 1;
        let mut diag = |rule: &'static str, msg: &'static str| {
            out.push(Diagnostic {
                file: path.to_string(),
                line: lineno,
                rule,
                msg,
            });
        };

        if has_token(code, "unsafe") && !justified(&lines, idx, &SAFETY_MARKERS, "unsafe") {
            diag(RULE_SAFETY, MSG_SAFETY);
        }
        if ordering_scoped
            && !in_test
            && code.contains("Ordering::")
            && !justified(&lines, idx, &ORDERING_MARKERS, "Ordering::")
        {
            diag(RULE_ORDERING, MSG_ORDERING);
        }
        if has_token(code, "transmute") {
            let in_fn = fn_stack.last().map(|(n, _)| n.as_str()).unwrap_or("");
            let cur_fn = pending_fn.as_deref().unwrap_or(in_fn);
            let allowed = TRANSMUTE_ALLOWLIST
                .iter()
                .any(|(file, func)| path.ends_with(file) && cur_fn == *func);
            if !allowed {
                diag(RULE_TRANSMUTE, MSG_TRANSMUTE);
            }
        }
        if lock_scoped && !in_test && (has_token(code, "Mutex") || has_token(code, "RwLock")) {
            diag(RULE_LOCK, MSG_LOCK);
        }
        if serve_scoped && !in_test && (code.contains(".unwrap()") || code.contains(".expect(")) {
            diag(RULE_UNWRAP, MSG_UNWRAP);
        }

        // --- region bookkeeping for the lines that follow ---
        if code.contains("#[cfg(test)]") {
            pending_test = true;
        }
        if let Some(name) = fn_decl_name(code) {
            pending_fn = Some(name);
        }
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending_test && test_depth.is_none() {
                        test_depth = Some(depth);
                        pending_test = false;
                    }
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((name, depth));
                    }
                }
                '}' => {
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                    while fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                        fn_stack.pop();
                    }
                    depth -= 1;
                }
                ';' => {
                    // A `;` at pending state means the attr / signature
                    // never opened a body (`#[cfg(test)] mod tests;`,
                    // trait method decls) — drop the pending flags.
                    pending_fn = None;
                    pending_test = false;
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(path: &str, src: &str) -> Vec<&'static str> {
        check_source(path, src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "pub fn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
        assert_eq!(rules_fired("graph/x.rs", bad), vec![RULE_SAFETY]);
        let good =
            "pub fn f(p: *mut u32) {\n    // SAFETY: caller owns p.\n    unsafe { *p = 1 };\n}\n";
        assert!(rules_fired("graph/x.rs", good).is_empty());
        let trailing =
            "pub fn f(p: *mut u32) {\n    unsafe { *p = 1 }; // SAFETY: caller owns p.\n}\n";
        assert!(rules_fired("graph/x.rs", trailing).is_empty());
    }

    #[test]
    fn safety_walkup_skips_attrs_comments_and_cluster_lines() {
        let src = "// SAFETY: fine for both sites below.\n\
                   #[allow(dead_code)]\n\
                   unsafe fn g(p: *mut u32) {\n\
                   \x20   unsafe { *p = 1 };\n\
                   }\n";
        assert!(rules_fired("graph/x.rs", src).is_empty());
        // A blank line breaks the walk-up.
        let broken =
            "// SAFETY: too far away.\n\npub fn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
        assert_eq!(rules_fired("graph/x.rs", broken), vec![RULE_SAFETY]);
    }

    #[test]
    fn safety_doc_heading_counts_for_unsafe_fns() {
        let src = "/// Does things.\n\
                   ///\n\
                   /// # Safety\n\
                   ///\n\
                   /// Caller must own `p`.\n\
                   pub unsafe fn f(p: *mut u32) {\n\
                   \x20   // SAFETY: contract forwarded from the fn header.\n\
                   \x20   unsafe { *p = 1 };\n\
                   }\n";
        assert!(rules_fired("graph/x.rs", src).is_empty());
    }

    #[test]
    fn ordering_rule_is_scoped_and_test_exempt() {
        let bad = "use std::sync::atomic::{AtomicU64, Ordering};\n\
                   fn f(a: &AtomicU64) -> u64 {\n\
                   \x20   a.load(Ordering::Relaxed)\n\
                   }\n";
        assert_eq!(rules_fired("par/x.rs", bad), vec![RULE_ORDERING]);
        assert!(rules_fired("graph/x.rs", bad).is_empty(), "out of scope");
        let good = "fn f(a: &A) -> u64 {\n\
                    \x20   // ORDERING: Relaxed — standalone counter.\n\
                    \x20   a.load(Ordering::Relaxed)\n\
                    }\n";
        assert!(rules_fired("obs/x.rs", good).is_empty());
        let tested = "#[cfg(test)]\n\
                      mod tests {\n\
                      \x20   fn f(a: &A) -> u64 {\n\
                      \x20       a.load(Ordering::Relaxed)\n\
                      \x20   }\n\
                      }\n";
        assert!(rules_fired("serve/x.rs", tested).is_empty());
    }

    #[test]
    fn ordering_cluster_covers_adjacent_atomic_lines() {
        let src = "fn f(a: &A, b: &A) {\n\
                   \x20   // ORDERING: Relaxed on both — monotonic stats.\n\
                   \x20   a.store(1, Ordering::Relaxed);\n\
                   \x20   b.store(2, Ordering::Relaxed);\n\
                   }\n";
        assert!(rules_fired("par/x.rs", src).is_empty());
    }

    #[test]
    fn transmute_allowed_only_in_named_wrapper() {
        let src = "// SAFETY: test stand-in for the pool's wrapper.\n\
                   unsafe fn erase_lifetime(x: u8) -> i8 {\n\
                   \x20   // SAFETY: same-size integer cast.\n\
                   \x20   unsafe { std::mem::transmute::<u8, i8>(x) }\n\
                   }\n";
        assert!(rules_fired("par/pool.rs", src).is_empty());
        assert_eq!(rules_fired("par/other.rs", src), vec![RULE_TRANSMUTE]);
        assert_eq!(
            rules_fired("par/pool.rs", &src.replace("erase_lifetime", "other_name")),
            vec![RULE_TRANSMUTE]
        );
    }

    #[test]
    fn locks_forbidden_in_hot_paths_only() {
        let src = "pub struct S {\n    m: std::sync::Mutex<u64>,\n}\n";
        assert_eq!(rules_fired("wing/x.rs", src), vec![RULE_LOCK]);
        assert_eq!(rules_fired("engine/x.rs", src), vec![RULE_LOCK]);
        assert!(rules_fired("serve/x.rs", src).is_empty(), "out of scope");
        assert!(rules_fired("par/pool.rs", src).is_empty(), "allowlisted");
    }

    #[test]
    fn serve_unwrap_flagged_outside_tests() {
        let src = "fn f(s: &str) -> u64 {\n    s.parse().unwrap()\n}\n";
        assert_eq!(rules_fired("serve/x.rs", src), vec![RULE_UNWRAP]);
        assert!(rules_fired("cli/x.rs", src).is_empty(), "out of scope");
        let or_else = "fn f(s: &str) -> u64 {\n    s.parse().unwrap_or_else(|_| 0)\n}\n";
        assert!(rules_fired("serve/x.rs", or_else).is_empty());
        let expect = "fn f(s: &str) -> u64 {\n    s.parse().expect(\"k\")\n}\n";
        assert_eq!(rules_fired("serve/x.rs", expect), vec![RULE_UNWRAP]);
    }

    #[test]
    fn literals_and_comments_never_trip_rules() {
        let src = "fn f() -> &'static str {\n\
                   \x20   // unsafe Mutex Ordering::Relaxed .unwrap() transmute\n\
                   \x20   \"unsafe Mutex Ordering::Relaxed .unwrap() transmute\"\n\
                   }\n";
        for path in ["par/x.rs", "serve/x.rs", "engine/x.rs"] {
            assert!(rules_fired(path, src).is_empty(), "{path}");
        }
    }

    #[test]
    fn diagnostics_carry_file_and_line() {
        let src = "fn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
        let ds = check_source("graph/x.rs", src);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].file, "graph/x.rs");
        assert_eq!(ds[0].line, 2);
        assert_eq!(ds[0].rule, RULE_SAFETY);
    }
}
