//! `check` — the crate's own static analyzer (`pbng-lint`).
//!
//! A dependency-free lint that enforces the concurrency-correctness
//! conventions documented in `lib.rs` ("Unsafe policy"): SAFETY comments
//! on every `unsafe` site, ORDERING justifications on every atomic in
//! `par`/`obs`/`serve`, a one-entry `transmute` allowlist, no blocking
//! locks in hot-path modules, and no `.unwrap()` on serving paths. The
//! rules live in [`rules`], the comment/string-aware line splitter in
//! [`lexer`], and the `pbng_lint` binary (`src/bin/pbng_lint.rs`) is a
//! thin CLI over [`check_tree`]. CI runs it on every push; the fixture
//! tree under `tests/fixtures/lint_violations/` proves each rule fires.

pub mod lexer;
pub mod rules;

pub use rules::{check_source, Diagnostic};

use crate::jsonio::Value;
use std::fs;
use std::io;
use std::path::Path;

/// Result of scanning a source tree.
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Every violation, in (file, line) order.
    pub violations: Vec<Diagnostic>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Machine-readable form, for `pbng_lint --json`.
    pub fn to_json(&self) -> Value {
        let mut viols = Vec::new();
        for d in &self.violations {
            let v = Value::obj()
                .with("file", d.file.as_str())
                .with("line", d.line as u64)
                .with("rule", d.rule)
                .with("msg", d.msg);
            viols.push(v);
        }
        Value::obj()
            .with("files_scanned", self.files_scanned as u64)
            .with("count", self.violations.len() as u64)
            .with("violations", viols)
    }
}

/// Recursively lint every `.rs` file under `root`. Paths in the report
/// are `/`-separated and relative to `root`, which is what scopes the
/// per-module rules (see [`rules::check_source`]).
pub fn check_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for rel in &files {
        let src = fs::read_to_string(root.join(rel))?;
        violations.extend(check_source(rel, &src));
    }
    Ok(Report {
        files_scanned: files.len(),
        violations,
    })
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            let mut parts = Vec::new();
            for c in rel.components() {
                parts.push(c.as_os_str().to_string_lossy().into_owned());
            }
            out.push(parts.join("/"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let report = Report {
            files_scanned: 3,
            violations: vec![Diagnostic {
                file: "par/x.rs".to_string(),
                line: 7,
                rule: rules::RULE_SAFETY,
                msg: "m",
            }],
        };
        let v = report.to_json();
        assert_eq!(v.req_u64("files_scanned").unwrap(), 3);
        assert_eq!(v.req_u64("count").unwrap(), 1);
        let viols = v.req_arr("violations").unwrap();
        assert_eq!(viols.len(), 1);
        assert_eq!(viols[0].req_u64("line").unwrap(), 7);
        assert!(!report.is_clean());
    }

    #[test]
    fn empty_report_is_clean() {
        let report = Report {
            files_scanned: 0,
            violations: Vec::new(),
        };
        assert!(report.is_clean());
        assert_eq!(report.to_json().req_u64("count").unwrap(), 0);
    }
}
