//! Seeded synthetic bipartite-graph generators.
//!
//! The paper evaluates on 12 KONECT / NetworkRepository datasets that are
//! not redistributable and not reachable from this offline environment
//! (DESIGN.md §Substitutions). These generators produce graphs with the
//! structural drivers that matter for peeling behaviour:
//!
//! * heavy-tailed degree distributions (`zipf`) — butterfly counts grow
//!   super-linearly in edges, peeling has a long level tail;
//! * planted dense blocks (`planted_blocks`, `nested_blocks`) — a known
//!   ground-truth hierarchy of k-wing/k-tip levels;
//! * uniform background (`erdos`) — the low-density base of the hierarchy.

use super::{BipartiteGraph, GraphBuilder};
use crate::testkit::{Rng, ZipfSampler};

/// Uniform random bipartite graph with ~`m` distinct edges.
pub fn erdos(nu: usize, nv: usize, m: usize, seed: u64) -> BipartiteGraph {
    assert!(nu > 0 && nv > 0);
    let mut rng = Rng::new(seed);
    let cap = nu.saturating_mul(nv);
    let m = m.min(cap);
    let mut edges = Vec::with_capacity(m * 11 / 10);
    for _ in 0..m * 2 {
        // oversample; builder dedups
        edges.push((rng.usize_below(nu) as u32, rng.usize_below(nv) as u32));
        if edges.len() >= m * 2 {
            break;
        }
    }
    edges.sort_unstable();
    edges.dedup();
    edges.truncate(m);
    GraphBuilder::new().nu(nu).nv(nv).edges(&edges).build()
}

/// Heavy-tailed bipartite graph: endpoints drawn Zipf(αu), Zipf(αv).
/// Mimics the skew of real web/rating networks (paper's Tr, De-ut, ...).
pub fn zipf(nu: usize, nv: usize, m: usize, alpha_u: f64, alpha_v: f64, seed: u64) -> BipartiteGraph {
    assert!(nu > 0 && nv > 0);
    let mut rng = Rng::new(seed);
    let zu = ZipfSampler::new(nu, alpha_u);
    let zv = ZipfSampler::new(nv, alpha_v);
    // Heavy-tailed sampling collides often (hub pairs repeat); sample in
    // rounds until we reach ~m distinct edges or exhaust the budget.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(m * 2);
    let mut distinct = 0usize;
    for _round in 0..24 {
        if distinct >= m {
            break;
        }
        for _ in 0..(m - distinct).max(m / 8) * 2 {
            edges.push((zu.sample(&mut rng) as u32, zv.sample(&mut rng) as u32));
        }
        edges.sort_unstable();
        edges.dedup();
        distinct = edges.len();
    }
    // Deterministic truncation to at most m edges, spread across the list
    // so we do not bias toward low ids.
    if edges.len() > m {
        let mut rng2 = Rng::new(seed ^ 0xA5A5_5A5A);
        rng2.shuffle(&mut edges);
        edges.truncate(m);
    }
    GraphBuilder::new().nu(nu).nv(nv).edges(&edges).build()
}

/// A dense block specification: a `rows × cols` near-biclique with edge
/// retention probability `density`, planted at a vertex offset.
#[derive(Clone, Copy, Debug)]
pub struct Block {
    pub rows: usize,
    pub cols: usize,
    pub density: f64,
}

/// Sparse background + planted dense blocks. Blocks are placed on disjoint
/// vertex ranges (block b uses rows `[row_off_b, row_off_b + rows)`), so
/// each survives as a distinct dense region in the decomposition.
pub fn planted_blocks(
    nu: usize,
    nv: usize,
    background_m: usize,
    blocks: &[Block],
    seed: u64,
) -> BipartiteGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    // background
    for _ in 0..background_m {
        edges.push((rng.usize_below(nu) as u32, rng.usize_below(nv) as u32));
    }
    // blocks on disjoint ranges
    let mut row_off = 0usize;
    let mut col_off = 0usize;
    for b in blocks {
        assert!(row_off + b.rows <= nu, "blocks exceed nu");
        assert!(col_off + b.cols <= nv, "blocks exceed nv");
        for r in 0..b.rows {
            for c in 0..b.cols {
                if rng.chance(b.density) {
                    edges.push(((row_off + r) as u32, (col_off + c) as u32));
                }
            }
        }
        row_off += b.rows;
        col_off += b.cols;
    }
    GraphBuilder::new().nu(nu).nv(nv).edges(&edges).build()
}

/// Nested-community graph: a chain of bicliques K_{s,s}, K_{2s,2s}, ... each
/// containing the previous one (rows/cols `[0, s·2^i)`), with decreasing
/// density outward. Yields a clean nested k-wing hierarchy — the structure
/// the paper's Fig. 1b illustrates.
pub fn nested_blocks(levels: usize, s: usize, seed: u64) -> BipartiteGraph {
    let mut rng = Rng::new(seed);
    let side = s << (levels - 1);
    let mut edges = Vec::new();
    for lvl in 0..levels {
        let dim = s << lvl;
        // density decays sharply with level so inner blocks are strictly
        // denser and the k-wing hierarchy concentrates inward
        let density = 0.55f64.powi(lvl as i32);
        for r in 0..dim {
            for c in 0..dim {
                if rng.chance(density) {
                    edges.push((r as u32, c as u32));
                }
            }
        }
    }
    GraphBuilder::new().nu(side).nv(side).edges(&edges).build()
}

/// Banded "grid" bipartite graph: U-vertex `i` connects to the V-window
/// centred at `i·nv/nu` with half-width `band`, each edge kept with
/// probability `density`. Consecutive rows share most of their windows,
/// so butterflies are abundant but *local* — degrees stay bounded by
/// `2·band + 1`. The anti-hub complement to [`zipf`] in the bench suites:
/// peeling proceeds in many shallow, wide levels instead of a deep tail.
pub fn grid(nu: usize, nv: usize, band: usize, density: f64, seed: u64) -> BipartiteGraph {
    assert!(nu > 0 && nv > 0);
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(nu * (2 * band + 1));
    for i in 0..nu {
        let c = i * nv / nu;
        let lo = c.saturating_sub(band);
        let hi = (c + band + 1).min(nv);
        for j in lo..hi {
            if rng.chance(density) {
                edges.push((i as u32, j as u32));
            }
        }
    }
    GraphBuilder::new().nu(nu).nv(nv).edges(&edges).build()
}

/// Complete biclique K_{a,b} — every edge is in `(a-1)(b-1)` butterflies.
pub fn biclique(a: usize, b: usize) -> BipartiteGraph {
    let mut edges = Vec::with_capacity(a * b);
    for u in 0..a {
        for v in 0..b {
            edges.push((u as u32, v as u32));
        }
    }
    GraphBuilder::new().nu(a).nv(b).edges(&edges).build()
}

/// The running example of the paper's Fig. 1: a small connected 1-wing
/// whose wing decomposition has four levels (wing numbers 1..4 in the
/// paper's coloring). We reconstruct a graph with the same qualitative
/// structure: a chain of increasingly dense bicliques —
/// K_{2,2} (θ=1), K_{2,3} (θ=2), K_{2,4} (θ=3), K_{3,3} (θ=4) —
/// connected by butterfly-free bridge edges (θ=0).
pub fn paper_fig1() -> BipartiteGraph {
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut block = |rows: std::ops::Range<u32>, cols: std::ops::Range<u32>| {
        for u in rows.clone() {
            for v in cols.clone() {
                edges.push((u, v));
            }
        }
    };
    block(0..2, 0..2); // K_{2,2}: θ = 1
    block(2..4, 2..5); // K_{2,3}: θ = 2
    block(4..6, 5..9); // K_{2,4}: θ = 3
    block(6..9, 9..12); // K_{3,3}: θ = 4
    // bridges keep the graph connected without adding butterflies
    edges.extend_from_slice(&[(1, 2), (3, 5), (5, 9)]);
    GraphBuilder::new().nu(9).nv(12).edges(&edges).build()
}

/// Named dataset presets standing in for the paper's Table 2 datasets.
/// Sizes are scaled to a single-core container; skew parameters chosen to
/// mimic each family (see DESIGN.md §Substitutions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// Discogs-like: moderate skew both sides (Di-af analog).
    DiAfS,
    /// Delicious-like: strong item skew (De-ti analog).
    DeTiS,
    /// Wikipedia-edits-like: few very hot pages (Fr analog).
    FrS,
    /// Few-category side: tiny V with huge degrees (Di-st analog).
    DiStS,
    /// Ratings burst: hot items + hot users (Digg analog).
    DiggS,
    /// Trackers-like: extreme skew, butterfly explosion (Tr analog).
    TrS,
    /// Membership-like: Zipf both sides, larger (Lj/Or analog).
    OrS,
    /// Planted hierarchy with ground-truth dense blocks.
    PlantedS,
    /// Nested biclique chain (clean hierarchy).
    NestedS,
    /// Banded grid: bounded degrees, local butterflies (no hubs).
    GridS,
    /// Medium heavy-tail graph for the larger benchmark tier.
    TrM,
    /// Medium membership-like graph for the larger benchmark tier.
    OrM,
}

impl Preset {
    pub fn name(self) -> &'static str {
        match self {
            Preset::DiAfS => "di-af-s",
            Preset::DeTiS => "de-ti-s",
            Preset::FrS => "fr-s",
            Preset::DiStS => "di-st-s",
            Preset::DiggS => "digg-s",
            Preset::TrS => "tr-s",
            Preset::OrS => "or-s",
            Preset::PlantedS => "planted-s",
            Preset::NestedS => "nested-s",
            Preset::GridS => "grid-s",
            Preset::TrM => "tr-m",
            Preset::OrM => "or-m",
        }
    }

    pub fn all_small() -> &'static [Preset] {
        &[
            Preset::DiAfS,
            Preset::DeTiS,
            Preset::FrS,
            Preset::DiStS,
            Preset::DiggS,
            Preset::TrS,
            Preset::OrS,
            Preset::PlantedS,
            Preset::NestedS,
            Preset::GridS,
        ]
    }

    pub fn all_medium() -> &'static [Preset] {
        &[Preset::TrM, Preset::OrM]
    }

    pub fn from_name(name: &str) -> Option<Preset> {
        Preset::all_small()
            .iter()
            .chain(Preset::all_medium())
            .copied()
            .find(|p| p.name() == name)
    }

    pub fn build(self) -> BipartiteGraph {
        match self {
            Preset::DiAfS => zipf(3000, 800, 12_000, 1.0, 1.0, 101),
            Preset::DeTiS => zipf(4000, 600, 16_000, 0.8, 1.4, 102),
            Preset::FrS => zipf(600, 900, 10_000, 1.2, 1.2, 103),
            Preset::DiStS => zipf(3000, 48, 9_000, 0.8, 1.1, 104),
            Preset::DiggS => zipf(1500, 300, 14_000, 1.1, 1.3, 105),
            Preset::TrS => zipf(5000, 2500, 20_000, 1.5, 1.5, 106),
            Preset::OrS => zipf(2500, 5000, 25_000, 1.0, 1.2, 107),
            Preset::PlantedS => planted_blocks(
                1200,
                1200,
                6_000,
                &[
                    Block { rows: 24, cols: 24, density: 0.9 },
                    Block { rows: 16, cols: 16, density: 0.95 },
                    Block { rows: 40, cols: 12, density: 0.8 },
                ],
                108,
            ),
            Preset::NestedS => nested_blocks(4, 6, 109),
            Preset::GridS => grid(400, 400, 6, 0.9, 112),
            Preset::TrM => zipf(40_000, 20_000, 200_000, 1.5, 1.5, 110),
            Preset::OrM => zipf(25_000, 50_000, 250_000, 1.0, 1.2, 111),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Side;

    #[test]
    fn erdos_size_and_determinism() {
        let g1 = erdos(100, 80, 500, 7);
        let g2 = erdos(100, 80, 500, 7);
        assert_eq!(g1.m(), g2.m());
        assert!(g1.m() <= 500 && g1.m() > 300);
        assert_eq!(g1.edges(), g2.edges());
    }

    #[test]
    fn erdos_different_seeds_differ() {
        let g1 = erdos(100, 80, 500, 7);
        let g2 = erdos(100, 80, 500, 8);
        assert_ne!(g1.edges(), g2.edges());
    }

    #[test]
    fn zipf_is_skewed() {
        let g = zipf(500, 500, 4000, 1.4, 1.4, 3);
        let dmax = (0..g.nu() as u32).map(|u| g.deg_u(u)).max().unwrap();
        let davg = g.m() as f64 / g.nu() as f64;
        assert!(
            (dmax as f64) > 8.0 * davg,
            "zipf hub not prominent: dmax={dmax} davg={davg}"
        );
    }

    #[test]
    fn biclique_complete() {
        let g = biclique(3, 4);
        assert_eq!(g.m(), 12);
        assert_eq!(g.wedge_count(Side::U), 4 * 3); // Σ_v C(3,2)=3 over 4 vs
    }

    #[test]
    fn planted_blocks_are_dense() {
        let g = planted_blocks(
            200,
            200,
            100,
            &[Block { rows: 10, cols: 10, density: 1.0 }],
            5,
        );
        // block rows 0..10 fully connected to cols 0..10
        for r in 0..10 {
            assert!(g.deg_u(r) >= 10);
        }
    }

    #[test]
    fn nested_blocks_monotone_density() {
        let g = nested_blocks(3, 4, 9);
        // inner 4x4 rows should have ~full degree over inner cols
        for r in 0..4u32 {
            assert!(g.deg_u(r) >= 8, "inner row degree {}", g.deg_u(r));
        }
        assert_eq!(g.nu(), 16);
    }

    #[test]
    fn grid_is_banded_and_deterministic() {
        let a = grid(50, 50, 3, 1.0, 9);
        let b = grid(50, 50, 3, 1.0, 9);
        assert_eq!(a.edges(), b.edges());
        // full density: every row has its complete window
        assert_eq!(a.m(), 50 * 7 - 6 - 6); // rows 0..3 / 47..50 clip 1+2+3 each
        for u in 0..50u32 {
            assert!(a.deg_u(u) <= 7);
            // edges stay within the band around the window centre (= u,
            // since nu == nv here)
            for &(v, _) in a.nbrs_u(u) {
                assert!((v as i64 - u as i64).abs() <= 3, "edge ({u},{v}) outside band");
            }
        }
        // sparser seed-controlled variant differs but stays deterministic
        let c = grid(50, 50, 3, 0.5, 9);
        assert!(c.m() < a.m());
        assert_eq!(c.edges(), grid(50, 50, 3, 0.5, 9).edges());
    }

    #[test]
    fn fig1_is_one_wing_sized() {
        let g = paper_fig1();
        assert_eq!(g.nu(), 9);
        assert_eq!(g.nv(), 12);
        assert_eq!(g.m(), 4 + 6 + 8 + 9 + 3);
    }

    #[test]
    fn fig1_has_four_wing_levels() {
        let g = paper_fig1();
        let theta = crate::count::brute::brute_wing_numbers(&g);
        let mut levels: Vec<u64> = theta.clone();
        levels.sort_unstable();
        levels.dedup();
        assert_eq!(levels, vec![0, 1, 2, 3, 4]);
        // the K_{3,3} block is the densest level
        let top = theta.iter().filter(|&&t| t == 4).count();
        assert_eq!(top, 9);
    }

    #[test]
    fn presets_build_and_are_deterministic() {
        for p in Preset::all_small() {
            let a = p.build();
            let b = p.build();
            assert_eq!(a.edges(), b.edges(), "preset {} not deterministic", p.name());
            assert!(a.m() > 0);
        }
    }

    #[test]
    fn preset_lookup_by_name() {
        assert_eq!(Preset::from_name("tr-s"), Some(Preset::TrS));
        assert_eq!(Preset::from_name("nope"), None);
    }
}
