//! Bipartite graph substrate: CSR in both directions with edge ids, plus
//! the degree-priority relabeling the counting algorithm (Alg. 1) needs.
//!
//! Vertices are split into `U` (ids `0..nu`) and `V` (ids `0..nv`); a
//! *wid* ("whole-graph id") addresses the union: `wid(u) = u`,
//! `wid(v) = nu + v`. Edges carry stable ids `0..m` so that edge-indexed
//! state (supports, wing numbers, partitions) is a flat vector.

pub mod builder;
pub mod dynamic;
pub mod gen;
pub mod induced;
pub mod io;

pub use builder::GraphBuilder;

/// Which side of the bipartition a vertex set refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    U,
    V,
}

impl Side {
    pub fn other(self) -> Side {
        match self {
            Side::U => Side::V,
            Side::V => Side::U,
        }
    }
}

/// Immutable bipartite graph in CSR form (both directions).
#[derive(Clone, Debug)]
pub struct BipartiteGraph {
    nu: usize,
    nv: usize,
    /// CSR offsets for U-side adjacency, length `nu + 1`.
    offs_u: Vec<usize>,
    /// `(v, edge_id)` slots, sorted by `v` within each `u`.
    adj_u: Vec<(u32, u32)>,
    /// CSR offsets for V-side adjacency, length `nv + 1`.
    offs_v: Vec<usize>,
    /// `(u, edge_id)` slots, sorted by `u` within each `v`.
    adj_v: Vec<(u32, u32)>,
    /// `edge_id -> (u, v)`.
    edges: Vec<(u32, u32)>,
}

impl BipartiteGraph {
    /// Construct from a deduplicated edge list. Prefer [`GraphBuilder`].
    pub(crate) fn from_clean_edges(nu: usize, nv: usize, edges: Vec<(u32, u32)>) -> Self {
        let m = edges.len();
        let mut deg_u = vec![0usize; nu];
        let mut deg_v = vec![0usize; nv];
        for &(u, v) in &edges {
            deg_u[u as usize] += 1;
            deg_v[v as usize] += 1;
        }
        let mut offs_u = vec![0usize; nu + 1];
        for i in 0..nu {
            offs_u[i + 1] = offs_u[i] + deg_u[i];
        }
        let mut offs_v = vec![0usize; nv + 1];
        for i in 0..nv {
            offs_v[i + 1] = offs_v[i] + deg_v[i];
        }
        let mut adj_u = vec![(0u32, 0u32); m];
        let mut adj_v = vec![(0u32, 0u32); m];
        let mut cur_u = offs_u.clone();
        let mut cur_v = offs_v.clone();
        for (eid, &(u, v)) in edges.iter().enumerate() {
            adj_u[cur_u[u as usize]] = (v, eid as u32);
            cur_u[u as usize] += 1;
            adj_v[cur_v[v as usize]] = (u, eid as u32);
            cur_v[v as usize] += 1;
        }
        // sort neighbor slots by neighbor id for binary-search edge lookup
        for u in 0..nu {
            adj_u[offs_u[u]..offs_u[u + 1]].sort_unstable();
        }
        for v in 0..nv {
            adj_v[offs_v[v]..offs_v[v + 1]].sort_unstable();
        }
        BipartiteGraph {
            nu,
            nv,
            offs_u,
            adj_u,
            offs_v,
            adj_v,
            edges,
        }
    }

    pub fn nu(&self) -> usize {
        self.nu
    }
    pub fn nv(&self) -> usize {
        self.nv
    }
    /// Total vertex count `|W| = |U| + |V|`.
    pub fn nw(&self) -> usize {
        self.nu + self.nv
    }
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    #[inline]
    pub fn deg_u(&self, u: u32) -> usize {
        self.offs_u[u as usize + 1] - self.offs_u[u as usize]
    }
    #[inline]
    pub fn deg_v(&self, v: u32) -> usize {
        self.offs_v[v as usize + 1] - self.offs_v[v as usize]
    }

    /// Degree of a vertex addressed by wid.
    #[inline]
    pub fn deg_w(&self, w: usize) -> usize {
        if w < self.nu {
            self.deg_u(w as u32)
        } else {
            self.deg_v((w - self.nu) as u32)
        }
    }

    /// `(neighbor, edge_id)` slots of `u`, sorted by neighbor.
    #[inline]
    pub fn nbrs_u(&self, u: u32) -> &[(u32, u32)] {
        &self.adj_u[self.offs_u[u as usize]..self.offs_u[u as usize + 1]]
    }
    /// `(neighbor, edge_id)` slots of `v`, sorted by neighbor.
    #[inline]
    pub fn nbrs_v(&self, v: u32) -> &[(u32, u32)] {
        &self.adj_v[self.offs_v[v as usize]..self.offs_v[v as usize + 1]]
    }

    /// Neighbors of a wid, as `(neighbor_wid, edge_id)` iterator data.
    /// U vertices' neighbors are V vertices and vice versa.
    #[inline]
    pub fn nbrs_w(&self, w: usize) -> (&[(u32, u32)], usize) {
        if w < self.nu {
            // neighbors are V side: wid = nu + v
            (self.nbrs_u(w as u32), self.nu)
        } else {
            (self.nbrs_v((w - self.nu) as u32), 0)
        }
    }

    #[inline]
    pub fn edge(&self, e: u32) -> (u32, u32) {
        self.edges[e as usize]
    }

    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    /// Edge id of `(u, v)` if present (binary search on the smaller list).
    pub fn edge_id(&self, u: u32, v: u32) -> Option<u32> {
        let (list, key) = if self.deg_u(u) <= self.deg_v(v) {
            (self.nbrs_u(u), v)
        } else {
            (self.nbrs_v(v), u)
        };
        list.binary_search_by_key(&key, |&(x, _)| x)
            .ok()
            .map(|i| list[i].1)
    }

    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.edge_id(u, v).is_some()
    }

    /// Degree-priority labels over the whole vertex set `W` (Alg. 1 line 2):
    /// label 0 = highest degree. Returns `label[wid]`.
    ///
    /// Ties are broken by wid for determinism.
    pub fn priority_labels(&self) -> Vec<u32> {
        let nw = self.nw();
        let mut order: Vec<u32> = (0..nw as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            self.deg_w(b as usize)
                .cmp(&self.deg_w(a as usize))
                .then(a.cmp(&b))
        });
        let mut label = vec![0u32; nw];
        for (rank, &w) in order.iter().enumerate() {
            label[w as usize] = rank as u32;
        }
        label
    }

    /// Sum over edges of `min(du, dv)` — the Chiba–Nishizeki wedge bound
    /// `O(α·m)` used as the re-counting workload estimate Λ_cnt (§5.1).
    pub fn count_workload_bound(&self) -> u64 {
        self.edges
            .iter()
            .map(|&(u, v)| self.deg_u(u).min(self.deg_v(v)) as u64)
            .sum()
    }

    /// Total wedges with both endpoints in U: Σ_v C(d_v, 2) — tip-peeling
    /// workload for side U; and symmetric for V.
    pub fn wedge_count(&self, endpoints: Side) -> u64 {
        match endpoints {
            Side::U => (0..self.nv as u32)
                .map(|v| {
                    let d = self.deg_v(v) as u64;
                    d * (d.saturating_sub(1)) / 2
                })
                .sum(),
            Side::V => (0..self.nu as u32)
                .map(|u| {
                    let d = self.deg_u(u) as u64;
                    d * (d.saturating_sub(1)) / 2
                })
                .sum(),
        }
    }

    /// Peeling-side vertex count.
    pub fn n_side(&self, side: Side) -> usize {
        match side {
            Side::U => self.nu,
            Side::V => self.nv,
        }
    }

    /// Swap the roles of U and V (used to peel the other side in tip
    /// decomposition without duplicating code).
    pub fn transposed(&self) -> BipartiteGraph {
        BipartiteGraph {
            nu: self.nv,
            nv: self.nu,
            offs_u: self.offs_v.clone(),
            adj_u: self.adj_v.clone(),
            offs_v: self.offs_u.clone(),
            adj_v: self.adj_u.clone(),
            edges: self.edges.iter().map(|&(u, v)| (v, u)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> BipartiteGraph {
        // 2x2 biclique plus a pendant edge (u2, v0)
        GraphBuilder::new()
            .edges(&[(0, 0), (0, 1), (1, 0), (1, 1), (2, 0)])
            .build()
    }

    #[test]
    fn csr_shapes() {
        let g = toy();
        assert_eq!(g.nu(), 3);
        assert_eq!(g.nv(), 2);
        assert_eq!(g.m(), 5);
        assert_eq!(g.deg_u(0), 2);
        assert_eq!(g.deg_v(0), 3);
        assert_eq!(g.deg_u(2), 1);
    }

    #[test]
    fn edge_lookup() {
        let g = toy();
        assert!(g.has_edge(0, 0));
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(2, 1));
        let e = g.edge_id(1, 1).unwrap();
        assert_eq!(g.edge(e), (1, 1));
    }

    #[test]
    fn adjacency_is_sorted() {
        let g = toy();
        for u in 0..g.nu() as u32 {
            let ns = g.nbrs_u(u);
            assert!(ns.windows(2).all(|w| w[0].0 < w[1].0));
        }
        for v in 0..g.nv() as u32 {
            let ns = g.nbrs_v(v);
            assert!(ns.windows(2).all(|w| w[0].0 < w[1].0));
        }
    }

    #[test]
    fn priority_labels_rank_by_degree() {
        let g = toy();
        let label = g.priority_labels();
        // v0 (wid 3) has degree 3 — the unique max — so label 0.
        assert_eq!(label[3], 0);
        // pendant u2 (wid 2, degree 1) has the largest label.
        assert_eq!(label[2] as usize, g.nw() - 1);
    }

    #[test]
    fn wedge_counts() {
        let g = toy();
        // side U endpoints: Σ_v C(dv,2) = C(3,2) + C(2,2) = 3 + 1 = 4
        assert_eq!(g.wedge_count(Side::U), 4);
        // side V endpoints: Σ_u C(du,2) = 1 + 1 + 0 = 2
        assert_eq!(g.wedge_count(Side::V), 2);
    }

    #[test]
    fn transpose_roundtrip() {
        let g = toy();
        let t = g.transposed();
        assert_eq!(t.nu(), g.nv());
        assert_eq!(t.nv(), g.nu());
        assert_eq!(t.m(), g.m());
        assert!(t.has_edge(0, 2));
        assert_eq!(t.wedge_count(Side::U), g.wedge_count(Side::V));
        // edge ids preserved under transpose
        for e in 0..g.m() as u32 {
            let (u, v) = g.edge(e);
            assert_eq!(t.edge(e), (v, u));
        }
    }
}
