//! Edge-list ingestion: dedup, vertex-count inference, validation.

use super::BipartiteGraph;

/// Builder for [`BipartiteGraph`] from raw `(u, v)` pairs.
///
/// Duplicate edges are removed (the decomposition definitions assume a
/// simple graph); vertex counts default to `max id + 1` but can be forced
/// larger to keep isolated vertices.
#[derive(Default)]
pub struct GraphBuilder {
    edges: Vec<(u32, u32)>,
    nu: Option<usize>,
    nv: Option<usize>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn nu(mut self, nu: usize) -> Self {
        self.nu = Some(nu);
        self
    }

    pub fn nv(mut self, nv: usize) -> Self {
        self.nv = Some(nv);
        self
    }

    pub fn edge(mut self, u: u32, v: u32) -> Self {
        self.edges.push((u, v));
        self
    }

    pub fn edges(mut self, es: &[(u32, u32)]) -> Self {
        self.edges.extend_from_slice(es);
        self
    }

    pub fn build(self) -> BipartiteGraph {
        let mut edges = self.edges;
        edges.sort_unstable();
        edges.dedup();
        let nu = self
            .nu
            .unwrap_or_else(|| edges.iter().map(|&(u, _)| u as usize + 1).max().unwrap_or(0));
        let nv = self
            .nv
            .unwrap_or_else(|| edges.iter().map(|&(_, v)| v as usize + 1).max().unwrap_or(0));
        assert!(
            edges.iter().all(|&(u, v)| (u as usize) < nu && (v as usize) < nv),
            "edge endpoint out of declared vertex range"
        );
        BipartiteGraph::from_clean_edges(nu, nv, edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_edges() {
        let g = GraphBuilder::new().edges(&[(0, 0), (0, 0), (1, 1)]).build();
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn infers_sizes() {
        let g = GraphBuilder::new().edges(&[(3, 5)]).build();
        assert_eq!(g.nu(), 4);
        assert_eq!(g.nv(), 6);
    }

    #[test]
    fn keeps_isolated_vertices() {
        let g = GraphBuilder::new().nu(10).nv(10).edges(&[(0, 0)]).build();
        assert_eq!(g.nu(), 10);
        assert_eq!(g.deg_u(9), 0);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new().build();
        assert_eq!(g.m(), 0);
        assert_eq!(g.nw(), 0);
    }

    #[test]
    #[should_panic(expected = "out of declared vertex range")]
    fn rejects_out_of_range() {
        GraphBuilder::new().nu(1).nv(1).edges(&[(2, 0)]).build();
    }
}
