//! Dynamic bipartite graph: batched edge updates with per-entity
//! butterfly-count deltas — the substrate of
//! [`crate::engine::incremental`].
//!
//! [`DynGraph`] keeps mutable sorted adjacency over a *fixed* vertex
//! universe (`nu`/`nv` never change, so vertex-indexed state — tip
//! numbers, per-vertex counts — stays valid across updates; edge ids are
//! reassigned by [`DynGraph::snapshot`], and edge-indexed state is keyed
//! by `(u, v)` pairs until remapped). [`DynGraph::apply_batch`] applies a
//! [`DeltaBatch`] one effective operation at a time and, for each edge
//! actually inserted or removed, enumerates exactly the butterflies that
//! operation creates or destroys by restricting the counting recurrence
//! to the wedges incident to the changed edge: for `(u, v)` every
//! `u' ∈ N(v)` is intersected with `N(u)`, so the cost is
//! `O(Σ_{u'∈N(v)} (d_u + d_{u'}))` per changed edge instead of a full
//! `O(α·m)` recount.
//!
//! The resulting [`DeltaReport`] is the contract the incremental engine
//! builds on:
//!
//! * **net count deltas** per edge / per vertex (old count + delta ==
//!   fresh count of the updated graph — pinned by the unit tests below);
//! * **touch entries**: an edge/vertex participating in any created *or*
//!   destroyed butterfly gets a delta entry *even when the net delta is
//!   zero* — membership, not magnitude, is the dirtiness signal
//!   (offsetting gains and losses still change the level structure);
//! * **adjacency links** of every created butterfly (edge-granular for
//!   wing, U-vertex-granular for tip), which the incremental engine
//!   unions into its cached butterfly-component labels. Destroyed
//!   butterflies need no links: their edges were already co-component in
//!   the pre-update graph.
//!
//! All report sections are sorted (`BTreeMap`/`BTreeSet` internally), so
//! downstream consumers are deterministic regardless of hash seeds.

use super::{BipartiteGraph, GraphBuilder};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::BufRead;
use std::path::Path;

/// One edge mutation. Set semantics: inserting a present edge or
/// removing an absent one is a no-op (not an error).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaOp {
    Insert(u32, u32),
    Remove(u32, u32),
}

impl DeltaOp {
    /// On-wire size of one encoded op (see [`DeltaOp::encode_into`]).
    pub const WIRE_LEN: usize = 9;

    /// Swap the U/V roles (used to orient deltas for tip side V).
    pub fn transposed(self) -> DeltaOp {
        match self {
            DeltaOp::Insert(u, v) => DeltaOp::Insert(v, u),
            DeltaOp::Remove(u, v) => DeltaOp::Remove(v, u),
        }
    }

    /// The edge this op concerns, regardless of direction.
    pub fn key(self) -> (u32, u32) {
        match self {
            DeltaOp::Insert(u, v) | DeltaOp::Remove(u, v) => (u, v),
        }
    }

    /// Append the 9-byte wire form: tag (0 insert / 1 remove), then both
    /// endpoints as `u32` little-endian — the record payload unit of
    /// [`crate::wal`].
    pub fn encode_into(self, out: &mut Vec<u8>) {
        let (tag, u, v) = match self {
            DeltaOp::Insert(u, v) => (0u8, u, v),
            DeltaOp::Remove(u, v) => (1u8, u, v),
        };
        out.push(tag);
        out.extend_from_slice(&u.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Decode one 9-byte wire op; rejects unknown tags.
    pub fn decode(b: &[u8]) -> Result<DeltaOp> {
        anyhow::ensure!(
            b.len() == Self::WIRE_LEN,
            "delta op wire form is {} bytes, got {}",
            Self::WIRE_LEN,
            b.len()
        );
        let u = u32::from_le_bytes([b[1], b[2], b[3], b[4]]);
        let v = u32::from_le_bytes([b[5], b[6], b[7], b[8]]);
        match b[0] {
            0 => Ok(DeltaOp::Insert(u, v)),
            1 => Ok(DeltaOp::Remove(u, v)),
            t => anyhow::bail!("unknown delta op tag {t}"),
        }
    }
}

/// A batch of edge mutations, applied in order within one
/// [`DynGraph::apply_batch`] call.
#[derive(Clone, Debug, Default)]
pub struct DeltaBatch {
    pub ops: Vec<DeltaOp>,
}

impl DeltaBatch {
    pub fn new(ops: Vec<DeltaOp>) -> DeltaBatch {
        DeltaBatch { ops }
    }

    /// The batch with U/V roles swapped.
    pub fn transposed(&self) -> DeltaBatch {
        DeltaBatch {
            ops: self.ops.iter().map(|op| op.transposed()).collect(),
        }
    }
}

/// What one applied batch changed. See the module docs for the
/// touch-entry and link contracts.
#[derive(Clone, Debug, Default)]
pub struct DeltaReport {
    /// Edges present after the batch that were absent before, sorted.
    pub inserted: Vec<(u32, u32)>,
    /// Edges absent after the batch that were present before, sorted.
    pub removed: Vec<(u32, u32)>,
    /// `((u, v), net butterfly delta)` for every *touched* edge, sorted
    /// by key. Keys may refer to edges removed by the batch.
    pub edge_delta: Vec<((u32, u32), i64)>,
    /// `(u, net delta)` for every touched U vertex, sorted.
    pub delta_u: Vec<(u32, i64)>,
    /// `(v, net delta)` for every touched V vertex, sorted.
    pub delta_v: Vec<(u32, i64)>,
    /// Butterfly-adjacency links created by insertions: the changed edge
    /// paired with each of the three partner edges of a created
    /// butterfly. Canonically ordered and deduplicated.
    pub links: Vec<((u32, u32), (u32, u32))>,
    /// Same links at U-vertex granularity (the two U endpoints of each
    /// created butterfly).
    pub links_u: Vec<(u32, u32)>,
    pub butterflies_created: u64,
    pub butterflies_destroyed: u64,
}

fn ord_pair<T: Ord>(a: T, b: T) -> (T, T) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Mutable bipartite graph over a fixed vertex universe.
#[derive(Clone, Debug)]
pub struct DynGraph {
    nu: usize,
    nv: usize,
    /// Sorted V-neighbor list per U vertex.
    adj_u: Vec<Vec<u32>>,
    /// Sorted U-neighbor list per V vertex.
    adj_v: Vec<Vec<u32>>,
    m: usize,
}

impl DynGraph {
    pub fn new(nu: usize, nv: usize) -> DynGraph {
        DynGraph {
            nu,
            nv,
            adj_u: vec![Vec::new(); nu],
            adj_v: vec![Vec::new(); nv],
            m: 0,
        }
    }

    pub fn from_graph(g: &BipartiteGraph) -> DynGraph {
        let mut dg = DynGraph::new(g.nu(), g.nv());
        for u in 0..g.nu() as u32 {
            dg.adj_u[u as usize] = g.nbrs_u(u).iter().map(|&(v, _)| v).collect();
        }
        for v in 0..g.nv() as u32 {
            dg.adj_v[v as usize] = g.nbrs_v(v).iter().map(|&(u, _)| u).collect();
        }
        dg.m = g.m();
        dg
    }

    pub fn nu(&self) -> usize {
        self.nu
    }
    pub fn nv(&self) -> usize {
        self.nv
    }
    pub fn m(&self) -> usize {
        self.m
    }

    /// Out-of-range endpoints are simply absent, never a panic.
    pub fn has(&self, u: u32, v: u32) -> bool {
        (u as usize) < self.nu
            && (v as usize) < self.nv
            && self.adj_u[u as usize].binary_search(&v).is_ok()
    }

    /// Insert `(u, v)`; returns false if already present.
    pub fn insert(&mut self, u: u32, v: u32) -> bool {
        assert!((u as usize) < self.nu && (v as usize) < self.nv, "edge out of range");
        match self.adj_u[u as usize].binary_search(&v) {
            Ok(_) => false,
            Err(i) => {
                self.adj_u[u as usize].insert(i, v);
                let j = self.adj_v[v as usize]
                    .binary_search(&u)
                    .expect_err("adjacency sides out of sync");
                self.adj_v[v as usize].insert(j, u);
                self.m += 1;
                true
            }
        }
    }

    /// Remove `(u, v)`; returns false if absent (including out-of-range
    /// endpoints).
    pub fn remove(&mut self, u: u32, v: u32) -> bool {
        if !self.has(u, v) {
            return false;
        }
        match self.adj_u[u as usize].binary_search(&v) {
            Err(_) => false,
            Ok(i) => {
                self.adj_u[u as usize].remove(i);
                let j = self.adj_v[v as usize]
                    .binary_search(&u)
                    .expect("adjacency sides out of sync");
                self.adj_v[v as usize].remove(j);
                self.m -= 1;
                true
            }
        }
    }

    /// Immutable CSR snapshot of the current edge set. Edge ids are
    /// positions in the sorted `(u, v)` list, as everywhere else.
    pub fn snapshot(&self) -> BipartiteGraph {
        let mut edges = Vec::with_capacity(self.m);
        for (u, nbrs) in self.adj_u.iter().enumerate() {
            for &v in nbrs {
                edges.push((u as u32, v));
            }
        }
        GraphBuilder::new().nu(self.nu).nv(self.nv).edges(&edges).build()
    }

    /// Visit every butterfly through edge `(u, v)` in the *current*
    /// state, which must contain the edge: `f(u2, v2)` is called once per
    /// butterfly `{(u,v), (u,v2), (u2,v), (u2,v2)}`.
    fn butterflies_through<F: FnMut(u32, u32)>(&self, u: u32, v: u32, mut f: F) {
        debug_assert!(self.has(u, v));
        let mine = &self.adj_u[u as usize];
        for &u2 in &self.adj_v[v as usize] {
            if u2 == u {
                continue;
            }
            let other = &self.adj_u[u2 as usize];
            let (mut i, mut j) = (0usize, 0usize);
            while i < mine.len() && j < other.len() {
                match mine[i].cmp(&other[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if mine[i] != v {
                            f(u2, mine[i]);
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }

    /// Apply `batch` in order and report butterfly-count deltas. Each
    /// effective operation is counted against the intermediate state it
    /// executes in, so the net deltas telescope to
    /// `count(after) - count(before)` exactly.
    pub fn apply_batch(&mut self, batch: &DeltaBatch) -> DeltaReport {
        let mut presence: BTreeMap<(u32, u32), i32> = BTreeMap::new();
        let mut edge_delta: BTreeMap<(u32, u32), i64> = BTreeMap::new();
        let mut delta_u: BTreeMap<u32, i64> = BTreeMap::new();
        let mut delta_v: BTreeMap<u32, i64> = BTreeMap::new();
        let mut links: BTreeSet<((u32, u32), (u32, u32))> = BTreeSet::new();
        let mut links_u: BTreeSet<(u32, u32)> = BTreeSet::new();
        let mut created = 0u64;
        let mut destroyed = 0u64;

        for &op in &batch.ops {
            match op {
                DeltaOp::Insert(u, v) => {
                    if !self.insert(u, v) {
                        continue;
                    }
                    *presence.entry((u, v)).or_insert(0) += 1;
                    self.butterflies_through(u, v, |u2, v2| {
                        created += 1;
                        for key in [(u, v), (u, v2), (u2, v), (u2, v2)] {
                            *edge_delta.entry(key).or_insert(0) += 1;
                        }
                        *delta_u.entry(u).or_insert(0) += 1;
                        *delta_u.entry(u2).or_insert(0) += 1;
                        *delta_v.entry(v).or_insert(0) += 1;
                        *delta_v.entry(v2).or_insert(0) += 1;
                        for other in [(u, v2), (u2, v), (u2, v2)] {
                            links.insert(ord_pair((u, v), other));
                        }
                        links_u.insert(ord_pair(u, u2));
                    });
                }
                DeltaOp::Remove(u, v) => {
                    if !self.has(u, v) {
                        continue;
                    }
                    self.butterflies_through(u, v, |u2, v2| {
                        destroyed += 1;
                        for key in [(u, v), (u, v2), (u2, v), (u2, v2)] {
                            *edge_delta.entry(key).or_insert(0) -= 1;
                        }
                        *delta_u.entry(u).or_insert(0) -= 1;
                        *delta_u.entry(u2).or_insert(0) -= 1;
                        *delta_v.entry(v).or_insert(0) -= 1;
                        *delta_v.entry(v2).or_insert(0) -= 1;
                    });
                    self.remove(u, v);
                    *presence.entry((u, v)).or_insert(0) -= 1;
                }
            }
        }

        DeltaReport {
            inserted: presence
                .iter()
                .filter(|&(_, &d)| d > 0)
                .map(|(&e, _)| e)
                .collect(),
            removed: presence
                .iter()
                .filter(|&(_, &d)| d < 0)
                .map(|(&e, _)| e)
                .collect(),
            edge_delta: edge_delta.into_iter().collect(),
            delta_u: delta_u.into_iter().collect(),
            delta_v: delta_v.into_iter().collect(),
            links: links.into_iter().collect(),
            links_u: links_u.into_iter().collect(),
            butterflies_created: created,
            butterflies_destroyed: destroyed,
        }
    }
}

/// Parse an edge-delta file: one op per line, `+ u v` inserts and
/// `- u v` removes; `%`/`#` comment lines and blanks are skipped
/// (the format `pbng update` consumes).
pub fn load_deltas(path: &Path) -> Result<Vec<DeltaOp>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening delta file {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut ops = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let sign = it
            .next()
            .with_context(|| format!("line {}: missing op sign", lineno + 1))?;
        let u: u32 = it
            .next()
            .with_context(|| format!("line {}: missing u", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad u", lineno + 1))?;
        let v: u32 = it
            .next()
            .with_context(|| format!("line {}: missing v", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad v", lineno + 1))?;
        match sign {
            "+" => ops.push(DeltaOp::Insert(u, v)),
            "-" => ops.push(DeltaOp::Remove(u, v)),
            s => anyhow::bail!("line {}: op must be '+' or '-', got '{s}'", lineno + 1),
        }
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute;
    use crate::graph::gen;
    use crate::testkit::{check_property, Rng};

    fn edge_counts_by_key(g: &BipartiteGraph) -> BTreeMap<(u32, u32), u64> {
        let c = brute::brute_counts(g);
        (0..g.m() as u32)
            .map(|e| (g.edge(e), c.per_edge[e as usize]))
            .collect()
    }

    fn random_batch(rng: &mut Rng, dg: &DynGraph, n_ops: usize) -> DeltaBatch {
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let u = rng.usize_below(dg.nu()) as u32;
            let v = rng.usize_below(dg.nv()) as u32;
            if rng.chance(0.5) {
                ops.push(DeltaOp::Insert(u, v));
            } else {
                ops.push(DeltaOp::Remove(u, v));
            }
        }
        DeltaBatch::new(ops)
    }

    #[test]
    fn insert_remove_roundtrip_and_snapshot() {
        let g = gen::erdos(12, 12, 40, 3);
        let mut dg = DynGraph::from_graph(&g);
        assert_eq!(dg.m(), g.m());
        assert_eq!(dg.snapshot().edges(), g.edges());
        // insert an absent edge, remove it again: back to the original
        let (u, v) = (0..12u32)
            .flat_map(|u| (0..12u32).map(move |v| (u, v)))
            .find(|&(u, v)| !g.has_edge(u, v))
            .unwrap();
        assert!(dg.insert(u, v));
        assert!(!dg.insert(u, v)); // already present
        assert_eq!(dg.m(), g.m() + 1);
        assert!(dg.remove(u, v));
        assert!(!dg.remove(u, v)); // already absent
        assert_eq!(dg.snapshot().edges(), g.edges());
    }

    #[test]
    fn noop_batch_reports_nothing() {
        let g = gen::biclique(3, 3);
        let mut dg = DynGraph::from_graph(&g);
        let rep = dg.apply_batch(&DeltaBatch::new(vec![
            DeltaOp::Insert(0, 0), // present
            DeltaOp::Remove(2, 2), // removed below, then re-added: net zero
            DeltaOp::Insert(2, 2),
        ]));
        assert!(rep.inserted.is_empty());
        assert!(rep.removed.is_empty());
        assert_eq!(rep.butterflies_created, rep.butterflies_destroyed);
        // every touched edge nets to zero
        assert!(rep.edge_delta.iter().all(|&(_, d)| d == 0));
        assert!(rep.delta_u.iter().all(|&(_, d)| d == 0));
        assert_eq!(dg.snapshot().edges(), g.edges());
    }

    #[test]
    fn single_insert_creates_the_closing_butterfly() {
        // path u0-v0, u1-v0, u1-v1: inserting (u0, v1) closes one butterfly
        let g = GraphBuilder::new()
            .nu(2)
            .nv(2)
            .edges(&[(0, 0), (1, 0), (1, 1)])
            .build();
        let mut dg = DynGraph::from_graph(&g);
        let rep = dg.apply_batch(&DeltaBatch::new(vec![DeltaOp::Insert(0, 1)]));
        assert_eq!(rep.inserted, vec![(0, 1)]);
        assert_eq!(rep.butterflies_created, 1);
        assert_eq!(rep.butterflies_destroyed, 0);
        // all four edges gain one butterfly
        assert_eq!(
            rep.edge_delta,
            vec![((0, 0), 1), ((0, 1), 1), ((1, 0), 1), ((1, 1), 1)]
        );
        assert_eq!(rep.delta_u, vec![(0, 1), (1, 1)]);
        assert_eq!(rep.delta_v, vec![(0, 1), (1, 1)]);
        // the inserted edge is linked to the three partners
        assert_eq!(rep.links.len(), 3);
        assert!(rep.links.iter().all(|&(a, b)| a == (0, 1) || b == (0, 1)));
        assert_eq!(rep.links_u, vec![(0, 1)]);
    }

    #[test]
    fn deltas_telescope_to_fresh_counts() {
        check_property("dyn-deltas-vs-brute", 0xD41A, 8, |seed| {
            let mut rng = Rng::new(seed);
            let g = gen::erdos(
                5 + rng.usize_below(10),
                5 + rng.usize_below(10),
                15 + rng.usize_below(50),
                seed,
            );
            let before = brute::brute_counts(&g);
            let edge_before = edge_counts_by_key(&g);
            let mut dg = DynGraph::from_graph(&g);
            let batch = random_batch(&mut rng, &dg, 1 + rng.usize_below(40));
            let rep = dg.apply_batch(&batch);
            let g2 = dg.snapshot();
            let after = brute::brute_counts(&g2);
            let edge_after = edge_counts_by_key(&g2);
            // per-edge: old + delta == fresh, for every surviving edge
            let delta: BTreeMap<(u32, u32), i64> = rep.edge_delta.iter().copied().collect();
            for (&key, &cnt) in &edge_after {
                let base = edge_before.get(&key).copied().unwrap_or(0) as i64;
                let d = delta.get(&key).copied().unwrap_or(0);
                if base + d != cnt as i64 {
                    return Err(format!("edge {key:?}: {base} + {d} != {cnt}"));
                }
            }
            // per-vertex, both sides
            let du: BTreeMap<u32, i64> = rep.delta_u.iter().copied().collect();
            for u in 0..g.nu() {
                let want = after.per_u[u] as i64;
                let got = before.per_u[u] as i64 + du.get(&(u as u32)).copied().unwrap_or(0);
                if got != want {
                    return Err(format!("u{u}: {got} != {want}"));
                }
            }
            let dv: BTreeMap<u32, i64> = rep.delta_v.iter().copied().collect();
            for v in 0..g.nv() {
                let want = after.per_v[v] as i64;
                let got = before.per_v[v] as i64 + dv.get(&(v as u32)).copied().unwrap_or(0);
                if got != want {
                    return Err(format!("v{v}: {got} != {want}"));
                }
            }
            // net totals telescope too
            let net = rep.butterflies_created as i64 - rep.butterflies_destroyed as i64;
            if before.total as i64 + net != after.total as i64 {
                return Err(format!(
                    "total: {} + {net} != {}",
                    before.total, after.total
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn delta_op_wire_roundtrip_and_rejects() {
        let ops = [
            DeltaOp::Insert(0, 0),
            DeltaOp::Remove(7, 3),
            DeltaOp::Insert(u32::MAX, 1),
        ];
        for op in ops {
            let mut buf = Vec::new();
            op.encode_into(&mut buf);
            assert_eq!(buf.len(), DeltaOp::WIRE_LEN);
            assert_eq!(DeltaOp::decode(&buf).unwrap(), op);
        }
        // bad tag and bad length are rejected
        let mut buf = Vec::new();
        DeltaOp::Insert(1, 2).encode_into(&mut buf);
        buf[0] = 9;
        assert!(DeltaOp::decode(&buf).is_err());
        assert!(DeltaOp::decode(&buf[..5]).is_err());
        assert_eq!(DeltaOp::Remove(4, 5).key(), (4, 5));
    }

    #[test]
    fn load_deltas_parses_and_rejects() {
        let dir = crate::testkit::TempDir::new("deltas").unwrap();
        let p = dir.file("d.txt");
        std::fs::write(&p, "% comment\n+ 1 2\n\n- 3 4\n# note\n+ 0 0\n").unwrap();
        let ops = load_deltas(&p).unwrap();
        assert_eq!(
            ops,
            vec![
                DeltaOp::Insert(1, 2),
                DeltaOp::Remove(3, 4),
                DeltaOp::Insert(0, 0)
            ]
        );
        std::fs::write(&p, "* 1 2\n").unwrap();
        assert!(load_deltas(&p).is_err());
        std::fs::write(&p, "+ 1\n").unwrap();
        assert!(load_deltas(&p).is_err());
    }
}
