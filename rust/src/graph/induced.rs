//! Induced subgraphs for PBNG tip fine-grained decomposition (§3.2).
//!
//! A tip partition `U_i` induces `G_i` on `(U_i, V)`. Because U partitions
//! are disjoint, every edge of `G` lands in exactly one `G_i`, so the
//! collective storage is `O(m)` (Theorem 6). Vertices are renumbered to
//! compact local ids so each partition peels over dense arrays.

use super::BipartiteGraph;

/// Compact edge-induced subgraph for one tip partition.
#[derive(Debug)]
pub struct InducedSubgraph {
    /// Global U ids; local u id = position.
    pub users: Vec<u32>,
    /// Global V ids of touched V vertices; local v id = position.
    pub items: Vec<u32>,
    /// CSR u(local) -> v(local).
    pub offs_u: Vec<usize>,
    pub adj_u: Vec<u32>,
    /// CSR v(local) -> u(local).
    pub offs_v: Vec<usize>,
    pub adj_v: Vec<u32>,
}

impl InducedSubgraph {
    pub fn n_users(&self) -> usize {
        self.users.len()
    }
    pub fn n_items(&self) -> usize {
        self.items.len()
    }
    pub fn m(&self) -> usize {
        self.adj_u.len()
    }

    #[inline]
    pub fn nbrs_u(&self, lu: usize) -> &[u32] {
        &self.adj_u[self.offs_u[lu]..self.offs_u[lu + 1]]
    }
    #[inline]
    pub fn nbrs_v(&self, lv: usize) -> &[u32] {
        &self.adj_v[self.offs_v[lv]..self.offs_v[lv + 1]]
    }

    /// Wedges with both endpoints in this partition: Σ_v C(d_v, 2).
    /// This is the FD workload indicator used for LPT scheduling (§3.2).
    pub fn wedge_workload(&self) -> u64 {
        (0..self.n_items())
            .map(|lv| {
                let d = (self.offs_v[lv + 1] - self.offs_v[lv]) as u64;
                d * d.saturating_sub(1) / 2
            })
            .sum()
    }
}

/// Build all partition subgraphs in one sweep.
///
/// `part_of[u]` gives the partition index of U vertex `u` (must be `< p`).
pub fn build_partitions(g: &BipartiteGraph, part_of: &[u32], p: usize) -> Vec<InducedSubgraph> {
    assert_eq!(part_of.len(), g.nu());
    // users per partition
    let mut users: Vec<Vec<u32>> = vec![Vec::new(); p];
    for u in 0..g.nu() as u32 {
        let pi = part_of[u as usize];
        assert!((pi as usize) < p, "partition index out of range");
        users[pi as usize].push(u);
    }
    users
        .into_iter()
        .map(|us| build_one(g, us))
        .collect()
}

fn build_one(g: &BipartiteGraph, users: Vec<u32>) -> InducedSubgraph {
    let mut local_u = std::collections::HashMap::with_capacity(users.len());
    for (i, &u) in users.iter().enumerate() {
        local_u.insert(u, i as u32);
    }
    // collect touched items
    let mut items: Vec<u32> = users
        .iter()
        .flat_map(|&u| g.nbrs_u(u).iter().map(|&(v, _)| v))
        .collect();
    items.sort_unstable();
    items.dedup();
    let mut local_v = std::collections::HashMap::with_capacity(items.len());
    for (i, &v) in items.iter().enumerate() {
        local_v.insert(v, i as u32);
    }
    // u-side CSR
    let mut offs_u = Vec::with_capacity(users.len() + 1);
    offs_u.push(0usize);
    let mut adj_u = Vec::new();
    for &u in &users {
        for &(v, _) in g.nbrs_u(u) {
            adj_u.push(local_v[&v]);
        }
        offs_u.push(adj_u.len());
    }
    // v-side CSR (restricted to partition users)
    let mut deg_v = vec![0usize; items.len()];
    for &lv in &adj_u {
        deg_v[lv as usize] += 1;
    }
    let mut offs_v = vec![0usize; items.len() + 1];
    for i in 0..items.len() {
        offs_v[i + 1] = offs_v[i] + deg_v[i];
    }
    let mut adj_v = vec![0u32; adj_u.len()];
    let mut cur = offs_v.clone();
    for (lu, &u) in users.iter().enumerate() {
        let _ = u;
        for &lv in &adj_u[offs_u[lu]..offs_u[lu + 1]] {
            adj_v[cur[lv as usize]] = lu as u32;
            cur[lv as usize] += 1;
        }
    }
    InducedSubgraph {
        users,
        items,
        offs_u,
        adj_u,
        offs_v,
        adj_v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn partitions_cover_all_edges_once() {
        let g = gen::erdos(50, 40, 300, 2);
        // assign u to partition u % 3
        let part: Vec<u32> = (0..g.nu() as u32).map(|u| u % 3).collect();
        let subs = build_partitions(&g, &part, 3);
        let total: usize = subs.iter().map(|s| s.m()).sum();
        assert_eq!(total, g.m());
    }

    #[test]
    fn adjacency_is_consistent() {
        let g = gen::erdos(30, 30, 150, 3);
        let part: Vec<u32> = (0..g.nu() as u32).map(|u| u % 2).collect();
        let subs = build_partitions(&g, &part, 2);
        for s in &subs {
            for lu in 0..s.n_users() {
                let gu = s.users[lu];
                for &lv in s.nbrs_u(lu) {
                    let gv = s.items[lv as usize];
                    assert!(g.has_edge(gu, gv));
                    // reverse direction contains lu
                    assert!(s.nbrs_v(lv as usize).contains(&(lu as u32)));
                }
            }
        }
    }

    #[test]
    fn wedge_workload_matches_manual() {
        // biclique 3x3, single partition: Σ_v C(3,2) = 9
        let g = gen::biclique(3, 3);
        let part = vec![0u32; 3];
        let subs = build_partitions(&g, &part, 1);
        assert_eq!(subs[0].wedge_workload(), 9);
    }

    #[test]
    fn empty_partition_is_ok() {
        let g = gen::biclique(2, 2);
        let part = vec![1u32; 2]; // partition 0 empty
        let subs = build_partitions(&g, &part, 2);
        assert_eq!(subs[0].m(), 0);
        assert_eq!(subs[1].m(), 4);
    }
}
