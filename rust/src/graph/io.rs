//! Text I/O for bipartite graphs.
//!
//! Format: KONECT-style whitespace-separated `u v` pairs, one edge per
//! line; `%`- or `#`-prefixed comment lines are skipped. An optional
//! header comment `% bip <nu> <nv>` pins vertex counts (otherwise they are
//! inferred from max ids).

use super::{BipartiteGraph, GraphBuilder};
use anyhow::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

pub fn load(path: &Path) -> Result<BipartiteGraph> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening graph file {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    parse(reader)
}

pub fn parse<R: BufRead>(reader: R) -> Result<BipartiteGraph> {
    let mut b = GraphBuilder::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if let Some(rest) = t.strip_prefix('%') {
            let toks: Vec<&str> = rest.split_whitespace().collect();
            if toks.len() == 3 && toks[0] == "bip" {
                let nu: usize = toks[1].parse().context("bad nu in header")?;
                let nv: usize = toks[2].parse().context("bad nv in header")?;
                b = b.nu(nu).nv(nv);
            }
            continue;
        }
        if t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it
            .next()
            .with_context(|| format!("line {}: missing u", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad u", lineno + 1))?;
        let v: u32 = it
            .next()
            .with_context(|| format!("line {}: missing v", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad v", lineno + 1))?;
        edges.push((u, v));
    }
    // KONECT dumps routinely repeat `u v` lines; parallel edges would
    // inflate butterfly counts. `GraphBuilder::build` collapses
    // duplicates (simple-graph invariant) — pinned down by the
    // `duplicate_edge_lines_do_not_change_theta` regression test.
    Ok(b.edges(&edges).build())
}

pub fn save(g: &BipartiteGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating graph file {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "% bip {} {}", g.nu(), g.nv())?;
    for &(u, v) in g.edges() {
        writeln!(w, "{} {}", u, v)?;
    }
    Ok(())
}

/// Write per-entity decomposition output: `id value` per line.
pub fn save_numbers(nums: &[u64], path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating output file {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for (i, x) in nums.iter().enumerate() {
        writeln!(w, "{} {}", i, x)?;
    }
    Ok(())
}

/// Read decomposition output written by [`save_numbers`]: `id value` per
/// line, ids contiguous from 0 (so precomputed θ files can seed the
/// hierarchy index without re-peeling).
pub fn load_numbers(path: &Path) -> Result<Vec<u64>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening numbers file {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let id: usize = it
            .next()
            .with_context(|| format!("line {}: missing id", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad id", lineno + 1))?;
        let val: u64 = it
            .next()
            .with_context(|| format!("line {}: missing value", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        if id != out.len() {
            anyhow::bail!(
                "line {}: ids must be contiguous from 0 (got {id}, expected {})",
                lineno + 1,
                out.len()
            );
        }
        out.push(val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let g = parse(Cursor::new("0 1\n1 0\n")).unwrap();
        assert_eq!(g.m(), 2);
        assert_eq!(g.nu(), 2);
        assert_eq!(g.nv(), 2);
    }

    #[test]
    fn parse_header_and_comments() {
        let g = parse(Cursor::new("% bip 5 7\n# c\n0 1\n\n%x\n2 3\n")).unwrap();
        assert_eq!(g.nu(), 5);
        assert_eq!(g.nv(), 7);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse(Cursor::new("0 x\n")).is_err());
        assert!(parse(Cursor::new("0\n")).is_err());
    }

    #[test]
    fn numbers_roundtrip_and_validation() {
        // TempDir (not a fixed temp_dir() path): parallel test binaries
        // and concurrent CI jobs must not race on shared files.
        let dir = crate::testkit::TempDir::new("io-numbers").unwrap();
        let p = dir.file("nums.txt");
        let nums = vec![4u64, 0, 17, 3];
        save_numbers(&nums, &p).unwrap();
        assert_eq!(load_numbers(&p).unwrap(), nums);
        std::fs::write(&p, "0 1\n2 5\n").unwrap(); // gap in ids
        assert!(load_numbers(&p).is_err());
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_numbers(&p).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let g = crate::graph::gen::erdos(30, 40, 100, 1);
        let dir = crate::testkit::TempDir::new("io-graph").unwrap();
        let p = dir.file("g.tsv");
        save(&g, &p).unwrap();
        let g2 = load(&p).unwrap();
        assert_eq!(g.nu(), g2.nu());
        assert_eq!(g.nv(), g2.nv());
        assert_eq!(g.edges(), g2.edges());
    }

    #[test]
    fn duplicate_edge_lines_do_not_change_theta() {
        // Regression: a KONECT-style file with repeated `u v` lines must
        // decompose exactly like its deduplicated version — parallel
        // edges would inflate butterfly counts and shift θ.
        let clean = "% bip 3 3\n0 0\n0 1\n1 0\n1 1\n2 0\n2 1\n";
        let dup = "% bip 3 3\n0 0\n0 1\n0 1\n1 0\n1 1\n1 1\n2 0\n0 0\n2 1\n1 0\n";
        let a = parse(Cursor::new(clean)).unwrap();
        let b = parse(Cursor::new(dup)).unwrap();
        assert_eq!(a.edges(), b.edges());
        assert_eq!(a.m(), 6);
        let ta = crate::peel::bup::wing_bup(&a).theta;
        let tb = crate::peel::bup::wing_bup(&b).theta;
        assert_eq!(ta, tb);
        let bf_a = crate::count::total_butterflies(&a, 1);
        let bf_b = crate::count::total_butterflies(&b, 1);
        assert_eq!(bf_a, bf_b);
    }
}
