//! # PBNG — Parallel Bipartite Network peelinG
//!
//! A reproduction of *"Parallel Peeling of Bipartite Networks for
//! Hierarchical Dense Subgraph Discovery"* (Lakhotia, Kannan, Prasanna,
//! 2021): two-phased parallel **tip** (vertex) and **wing** (edge)
//! decomposition of bipartite graphs, with every baseline the paper
//! evaluates against (BUP, ParB, BE_Batch, BE_PC), the BE-Index
//! substrate, workload metrics (support updates, wedges, synchronization
//! rounds ρ), and an AOT-compiled XLA dense-counting offload.
//!
//! Quick start:
//!
//! ```
//! use pbng::engine::EngineConfig;
//! use pbng::graph::gen;
//! use pbng::wing::wing_pbng;
//!
//! let g = gen::paper_fig1();
//! let d = wing_pbng(&g, EngineConfig { p: 4, threads: 2, ..Default::default() });
//! assert_eq!(d.theta.len(), g.m());
//! ```
//!
//! Both decompositions run on the generic two-phase engine
//! ([`engine`]): wing and tip are thin [`engine::PeelDomain`] impls over
//! one shared CD/FD driver pair.
//!
//! ## Unsafe policy
//!
//! Unsafe code is confined to the modules that implement the paper's
//! shared-memory scatter patterns (`par`, and the domain/count/index
//! layers built on [`par::RacyCell`]/[`par::RacyBuf`]); every other
//! module carries `#[forbid(unsafe_code)]`. Every `unsafe` site must be
//! preceded by a `// SAFETY:` comment and every atomic in `par`/`obs`/
//! `serve` by an `// ORDERING:` justification — enforced by the
//! `pbng_lint` binary ([`check`]), which CI runs on every push.

// Unsafe fns get no implicit unsafe body: each pointer-deref or
// aliasing-sensitive operation inside them needs its own `unsafe {}`
// block (and its own SAFETY comment).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod beindex;
#[forbid(unsafe_code)]
pub mod bench;
#[forbid(unsafe_code)]
pub mod check;
#[forbid(unsafe_code)]
pub mod cli;
pub mod count;
#[forbid(unsafe_code)]
pub mod engine;
#[forbid(unsafe_code)]
pub mod graph;
pub mod index;
#[forbid(unsafe_code)]
pub mod ingest;
#[forbid(unsafe_code)]
pub mod jsonio;
#[forbid(unsafe_code)]
pub mod metrics;
pub mod obs;
pub mod par;
#[forbid(unsafe_code)]
pub mod hierarchy;
#[forbid(unsafe_code)]
pub mod peel;
#[forbid(unsafe_code)]
pub mod runtime;
#[forbid(unsafe_code)]
pub mod serve;
#[forbid(unsafe_code)]
pub mod testkit;
pub mod tip;
#[forbid(unsafe_code)]
pub mod wal;
pub mod wing;
