//! # PBNG — Parallel Bipartite Network peelinG
//!
//! A reproduction of *"Parallel Peeling of Bipartite Networks for
//! Hierarchical Dense Subgraph Discovery"* (Lakhotia, Kannan, Prasanna,
//! 2021): two-phased parallel **tip** (vertex) and **wing** (edge)
//! decomposition of bipartite graphs, with every baseline the paper
//! evaluates against (BUP, ParB, BE_Batch, BE_PC), the BE-Index
//! substrate, workload metrics (support updates, wedges, synchronization
//! rounds ρ), and an AOT-compiled XLA dense-counting offload.
//!
//! Quick start:
//!
//! ```
//! use pbng::engine::EngineConfig;
//! use pbng::graph::gen;
//! use pbng::wing::wing_pbng;
//!
//! let g = gen::paper_fig1();
//! let d = wing_pbng(&g, EngineConfig { p: 4, threads: 2, ..Default::default() });
//! assert_eq!(d.theta.len(), g.m());
//! ```
//!
//! Both decompositions run on the generic two-phase engine
//! ([`engine`]): wing and tip are thin [`engine::PeelDomain`] impls over
//! one shared CD/FD driver pair.

pub mod beindex;
pub mod bench;
pub mod cli;
pub mod count;
pub mod engine;
pub mod graph;
pub mod index;
pub mod jsonio;
pub mod metrics;
pub mod obs;
pub mod par;
pub mod hierarchy;
pub mod peel;
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod tip;
pub mod wing;
