//! `pbng-lint` — the crate's concurrency-correctness lint.
//!
//! Thin CLI over [`pbng::check`]: scans a source tree (default `src`,
//! so running it from `rust/` lints the crate), prints one
//! `file:line [rule] msg` line per violation, and exits non-zero when
//! anything fires. `--json` emits the machine-readable report instead.
//! CI runs this in the lint job, right after clippy.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from("src");
    let mut json = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("pbng_lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: pbng_lint [--root PATH] [--json]");
                println!("lints every .rs file under PATH (default: src)");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pbng_lint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }

    let report = match pbng::check::check_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pbng_lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report.to_json().to_pretty());
    } else {
        for d in &report.violations {
            println!("{}:{} [{}] {}", d.file, d.line, d.rule, d.msg);
        }
        println!(
            "pbng_lint: {} file(s) scanned, {} violation(s)",
            report.files_scanned,
            report.violations.len()
        );
    }
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
