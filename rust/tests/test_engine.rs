//! Engine refactor acceptance: the generic two-phase engine must be a
//! *behavior-preserving* unification of the former wing/tip drivers.
//!
//! The config matrix `P ∈ {1, 4, 64} × batch {on, off} × dynamic_deletes
//! {on, off} × threads {1, 8}` is run for both decompositions on the
//! zipf and grid generators, and every θ vector is asserted
//! **byte-identical** to the sequential BUP baseline — the same
//! Theorem 2/§3.2 correctness contract the deleted per-entity drivers
//! were tested against, now proven across the full knob cross-product in
//! one place.

use pbng::engine::EngineConfig;
use pbng::graph::{gen, BipartiteGraph, Side};
use pbng::peel::bup::wing_bup;
use pbng::tip::{tip_bup, tip_pbng};
use pbng::wing::wing_pbng;

fn graphs() -> Vec<(&'static str, BipartiteGraph)> {
    vec![
        ("zipf", gen::zipf(60, 60, 400, 1.2, 1.2, 17)),
        ("grid", gen::grid(50, 50, 4, 0.9, 18)),
    ]
}

fn matrix() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for p in [1usize, 4, 64] {
        for batch in [true, false] {
            for dynamic_deletes in [true, false] {
                for threads in [1usize, 8] {
                    cfgs.push(EngineConfig {
                        p,
                        threads,
                        batch,
                        dynamic_deletes,
                        ..Default::default()
                    });
                }
            }
        }
    }
    cfgs
}

/// θ vectors as raw bytes: "byte-identical" taken literally.
fn bytes(theta: &[u64]) -> Vec<u8> {
    theta.iter().flat_map(|t| t.to_le_bytes()).collect()
}

#[test]
fn wing_config_matrix_is_byte_identical_to_bup() {
    for (name, g) in graphs() {
        let baseline = bytes(&wing_bup(&g).theta);
        for cfg in matrix() {
            let got = bytes(&wing_pbng(&g, cfg).theta);
            assert_eq!(
                got,
                baseline,
                "wing θ diverged on {name}: P={} batch={} deletes={} threads={}",
                cfg.p,
                cfg.batch,
                cfg.dynamic_deletes,
                cfg.threads
            );
        }
    }
}

#[test]
fn tip_config_matrix_is_byte_identical_to_bup() {
    for (name, g) in graphs() {
        for side in [Side::U, Side::V] {
            let baseline = bytes(&tip_bup(&g, side).theta);
            for cfg in matrix() {
                let got = bytes(&tip_pbng(&g, side, cfg).theta);
                assert_eq!(
                    got,
                    baseline,
                    "tip θ diverged on {name} {side:?}: P={} batch={} deletes={} threads={}",
                    cfg.p,
                    cfg.batch,
                    cfg.dynamic_deletes,
                    cfg.threads
                );
            }
        }
    }
}
