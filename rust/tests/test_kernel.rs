//! Kernel equivalence integration tests (ISSUE 9 satellite): butterfly
//! counts and decomposition outputs must be byte-identical across every
//! kernel configuration — wedge-side order policy (scalar cost model),
//! SIMD dispatch, scattered vs aggregated support updates, and thread
//! counts — and must match the brute-force reference on small random
//! graphs. Any divergence here means a kernel produced *different
//! numbers*, not just different performance.

use pbng::count::{
    brute, pve_bcnt, CountOptions, Counts, KernelConfig, OrderPolicy, SimdPolicy, UpdateKernel,
};
use pbng::engine::EngineConfig;
use pbng::graph::{gen, BipartiteGraph, Side};
use pbng::tip::tip_pbng;
use pbng::wing::wing_pbng;

const ORDERS: [OrderPolicy; 4] = [
    OrderPolicy::Degree,
    OrderPolicy::SideU,
    OrderPolicy::SideV,
    OrderPolicy::Auto,
];
const SIMDS: [SimdPolicy; 2] = [SimdPolicy::Scalar, SimdPolicy::Auto];
const THREADS: [usize; 2] = [1, 8];

fn count_with(g: &BipartiteGraph, kernel: KernelConfig, threads: usize, per_edge: bool) -> Counts {
    let opts = CountOptions {
        per_edge,
        build_blooms: false,
        threads,
        kernel,
    };
    pve_bcnt(g, opts, None).0
}

#[test]
fn counting_matches_brute_force_across_all_policies() {
    // count::brute differential: every order × SIMD × thread combination
    // reproduces the quadratic reference exactly, on both the label-only
    // path (SIMD-eligible) and the per-edge path (always scalar).
    for seed in [3u64, 17, 40] {
        let g = gen::erdos(24, 30, 140, seed);
        let want = brute::brute_counts(&g);
        for order in ORDERS {
            for simd in SIMDS {
                let kernel = KernelConfig {
                    order,
                    simd,
                    ..Default::default()
                };
                for threads in THREADS {
                    let fast = count_with(&g, kernel, threads, false);
                    assert_eq!(fast.total, want.total, "total ({order:?}/{simd:?}/t{threads})");
                    assert_eq!(fast.per_u, want.per_u, "per_u ({order:?}/{simd:?}/t{threads})");
                    assert_eq!(fast.per_v, want.per_v, "per_v ({order:?}/{simd:?}/t{threads})");
                    let edged = count_with(&g, kernel, threads, true);
                    assert_eq!(
                        edged.per_edge, want.per_edge,
                        "per_edge ({order:?}/{simd:?}/t{threads})"
                    );
                }
            }
        }
    }
}

#[test]
fn per_entity_counts_byte_identical_scalar_vs_simd_vs_auto() {
    // ISSUE satellite: θ and per-entity counts byte-identical across
    // {scalar, SIMD, auto side-choice} × threads {1, 8} on zipf/grid.
    // The scalar degree-order single-thread run is the reference; every
    // other cell must reproduce its vectors bit for bit.
    let graphs = [
        gen::zipf(300, 260, 2400, 1.1, 0.9, 71),
        gen::grid(240, 240, 10, 0.5, 72),
    ];
    for g in &graphs {
        let reference = count_with(
            g,
            KernelConfig {
                order: OrderPolicy::Degree,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            },
            1,
            false,
        );
        for order in ORDERS {
            for simd in SIMDS {
                for threads in THREADS {
                    let kernel = KernelConfig {
                        order,
                        simd,
                        ..Default::default()
                    };
                    let got = count_with(g, kernel, threads, false);
                    assert_eq!(got.total, reference.total, "{order:?}/{simd:?}/t{threads}");
                    assert_eq!(got.per_u, reference.per_u, "{order:?}/{simd:?}/t{threads}");
                    assert_eq!(got.per_v, reference.per_v, "{order:?}/{simd:?}/t{threads}");
                }
            }
        }
    }
}

/// Every kernel configuration the engine can be asked to run with:
/// SIMD on/off × scattered/aggregated updates × degree/auto side-choice.
fn kernel_grid() -> Vec<KernelConfig> {
    let mut out = Vec::new();
    for simd in SIMDS {
        for updates in [UpdateKernel::Scattered, UpdateKernel::Aggregated] {
            for order in [OrderPolicy::Degree, OrderPolicy::Auto] {
                out.push(KernelConfig {
                    order,
                    simd,
                    updates,
                });
            }
        }
    }
    out
}

#[test]
fn wing_theta_invariant_under_kernel_configs() {
    let g = gen::zipf(140, 120, 900, 1.0, 0.8, 81);
    let reference = wing_pbng(
        &g,
        EngineConfig {
            p: 6,
            threads: 1,
            ..Default::default()
        },
    )
    .theta;
    for kernel in kernel_grid() {
        for threads in THREADS {
            let got = wing_pbng(
                &g,
                EngineConfig {
                    p: 6,
                    threads,
                    kernel,
                    ..Default::default()
                },
            )
            .theta;
            assert_eq!(got, reference, "wing θ diverged under {kernel:?} t{threads}");
        }
    }
}

#[test]
fn tip_theta_invariant_under_kernel_configs() {
    let g = gen::grid(120, 130, 8, 0.45, 91);
    for side in [Side::U, Side::V] {
        let reference = tip_pbng(
            &g,
            side,
            EngineConfig {
                p: 4,
                threads: 1,
                ..Default::default()
            },
        )
        .theta;
        for kernel in kernel_grid() {
            for threads in THREADS {
                let got = tip_pbng(
                    &g,
                    side,
                    EngineConfig {
                        p: 4,
                        threads,
                        kernel,
                        ..Default::default()
                    },
                )
                .theta;
                assert_eq!(
                    got, reference,
                    "tip θ ({side:?}) diverged under {kernel:?} t{threads}"
                );
            }
        }
    }
}
