// Lint fixture (never compiled): an atomic op in an ORDERING-scoped
// module with no justification. Must fire `ordering-comment` exactly
// once.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}
