// Lint fixture (never compiled): a blocking lock declared in a
// hot-path module. Must fire `hot-path-lock` exactly once.
pub struct Slot {
    pub inner: std::sync::Mutex<u64>,
}
