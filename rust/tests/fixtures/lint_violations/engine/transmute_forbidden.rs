// Lint fixture (never compiled): a transmute outside the allowlisted
// wrapper. Must fire `transmute-allowlist` exactly once (the SAFETY
// comment below keeps `safety-comment` quiet so only one rule fires).
pub fn reinterpret(x: u32) -> i32 {
    // SAFETY: fixture only — never executed; same-size integer cast.
    unsafe { std::mem::transmute::<u32, i32>(x) }
}
