// Lint fixture (never compiled): a SIMD intersection call whose
// `unsafe` has no adjacent SAFETY comment. Must fire `safety-comment`
// exactly once — the coverage the real count::kernel AVX2 path carries.
pub fn intersect_block(a: &[u32], b: &[u32]) -> u32 {
    let (pa, pb) = (a.as_ptr(), b.as_ptr());

    unsafe { cmpeq8(pa, pb, a.len().min(b.len())) }
}
