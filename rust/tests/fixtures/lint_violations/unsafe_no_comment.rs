// Lint fixture (never compiled): an unsafe block with no adjacent
// SAFETY comment. Must fire `safety-comment` exactly once.
pub fn touch(v: &mut [u64]) {
    let p = v.as_mut_ptr();

    unsafe { *p = 1 };
}
