// Lint fixture (never compiled): an unwrap on a serving path. Must
// fire `serve-unwrap` exactly once.
pub fn parse_k(arg: &str) -> u64 {
    arg.parse().unwrap()
}
