//! Differential harness for `engine::incremental` (the PR's acceptance
//! gate): for seeded random update streams — insert-only, delete-only,
//! and mixed, at batch sizes 1 / 16 / 256 — over the `testkit` preset
//! generators (`zipf`, `grid`, `planted_blocks`), the incrementally
//! maintained θ must be **byte-identical** to a from-scratch
//! `engine::decompose` of the updated graph after *every* batch, for
//! both wing and tip, at thread caps 1 and 8 (CI additionally runs the
//! whole binary under `PBNG_THREADS ∈ {1, 8}` and a 4-value `PBNG_SEED`
//! matrix — the base seed below comes from that env var).

use pbng::engine::incremental::{IncrementalConfig, TipIncremental, WingIncremental};
use pbng::engine::EngineConfig;
use pbng::graph::dynamic::{DeltaBatch, DeltaOp};
use pbng::graph::{gen, BipartiteGraph, Side};
use pbng::testkit::Rng;
use pbng::tip::tip_pbng;
use pbng::wing::wing_pbng;
use std::collections::BTreeSet;

fn base_seed() -> u64 {
    std::env::var("PBNG_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1C0FFEE)
}

fn graphs(seed: u64) -> Vec<(&'static str, BipartiteGraph)> {
    vec![
        ("zipf", gen::zipf(40, 40, 220, 1.2, 1.2, seed)),
        ("grid", gen::grid(40, 40, 3, 0.9, seed ^ 1)),
        (
            "planted_blocks",
            gen::planted_blocks(
                48,
                48,
                120,
                &[gen::Block { rows: 6, cols: 6, density: 0.9 }],
                seed ^ 2,
            ),
        ),
    ]
}

#[derive(Clone, Copy, Debug)]
enum StreamKind {
    InsertOnly,
    DeleteOnly,
    Mixed,
}

/// Generates ops against a mirror of the current edge set, so deletions
/// always target present edges and insertions absent pairs (plus a few
/// deliberate no-ops to exercise set semantics).
struct StreamGen {
    rng: Rng,
    present: BTreeSet<(u32, u32)>,
    nu: usize,
    nv: usize,
}

impl StreamGen {
    fn new(g: &BipartiteGraph, seed: u64) -> StreamGen {
        StreamGen {
            rng: Rng::new(seed),
            present: g.edges().iter().copied().collect(),
            nu: g.nu(),
            nv: g.nv(),
        }
    }

    fn insert_op(&mut self) -> Option<DeltaOp> {
        for _ in 0..64 {
            let u = self.rng.usize_below(self.nu) as u32;
            let v = self.rng.usize_below(self.nv) as u32;
            if self.present.insert((u, v)) {
                return Some(DeltaOp::Insert(u, v));
            }
        }
        None
    }

    fn delete_op(&mut self) -> Option<DeltaOp> {
        if self.present.is_empty() {
            return None;
        }
        let i = self.rng.usize_below(self.present.len());
        let &(u, v) = self.present.iter().nth(i).expect("index in range");
        self.present.remove(&(u, v));
        Some(DeltaOp::Remove(u, v))
    }

    fn batch(&mut self, kind: StreamKind, size: usize) -> DeltaBatch {
        let mut ops = Vec::with_capacity(size);
        while ops.len() < size {
            let op = match kind {
                StreamKind::InsertOnly => self.insert_op(),
                StreamKind::DeleteOnly => self.delete_op(),
                StreamKind::Mixed => {
                    if self.rng.chance(0.5) {
                        self.insert_op()
                    } else {
                        self.delete_op()
                    }
                }
            };
            match op {
                Some(op) => ops.push(op),
                None => break, // universe full / empty: shorter batch
            }
        }
        DeltaBatch::new(ops)
    }
}

/// θ vectors as raw bytes: "byte-identical" taken literally.
fn bytes(theta: &[u64]) -> Vec<u8> {
    theta.iter().flat_map(|t| t.to_le_bytes()).collect()
}

/// Drive one (graph × kind × batch size × threads) cell: after every
/// batch, wing and tip θ must be byte-identical to from-scratch runs.
fn run_cell(
    name: &str,
    g0: &BipartiteGraph,
    kind: StreamKind,
    batch: usize,
    n_batches: usize,
    threads: usize,
) {
    let ecfg = EngineConfig { p: 8, threads, ..Default::default() };
    let icfg = IncrementalConfig { engine: ecfg, fallback_fraction: 0.3 };
    let mut wing = WingIncremental::new(g0, icfg);
    let mut tip = TipIncremental::new(g0, Side::U, icfg);
    let mut stream = StreamGen::new(g0, base_seed() ^ (batch as u64) << 8);
    let mut applied = 0usize;
    for bi in 0..n_batches {
        let b = stream.batch(kind, batch);
        if b.ops.is_empty() {
            break;
        }
        let uw = wing.apply(&b);
        let ut = tip.apply(&b);
        applied += 1;
        let g = wing.graph().clone();
        assert_eq!(
            g.edges().iter().copied().collect::<BTreeSet<_>>(),
            stream.present,
            "{name}/{kind:?} b={batch} t={threads}: edge set diverged at batch {bi}"
        );
        let wing_fresh = wing_pbng(&g, ecfg).theta;
        assert_eq!(
            bytes(wing.theta()),
            bytes(&wing_fresh),
            "{name}/{kind:?} b={batch} t={threads}: wing θ diverged at batch {bi} \
             (affected {}/{}, full={})",
            uw.affected_entities,
            uw.total_entities,
            uw.full_rebuild
        );
        let tip_fresh = tip_pbng(&g, Side::U, ecfg).theta;
        assert_eq!(
            bytes(tip.theta()),
            bytes(&tip_fresh),
            "{name}/{kind:?} b={batch} t={threads}: tip θ diverged at batch {bi} \
             (affected {}/{}, full={})",
            ut.affected_entities,
            ut.total_entities,
            ut.full_rebuild
        );
    }
    // the differential loop must have actually run
    assert!(applied > 0, "{name}/{kind:?} b={batch}: no batch was applied");
}

fn run_matrix(kind: StreamKind, batch: usize, n_batches: usize) {
    for (name, g) in graphs(base_seed()) {
        for threads in [1usize, 8] {
            run_cell(name, &g, kind, batch, n_batches, threads);
        }
    }
}

#[test]
fn insert_only_batch_1() {
    run_matrix(StreamKind::InsertOnly, 1, 10);
}

#[test]
fn insert_only_batch_16() {
    run_matrix(StreamKind::InsertOnly, 16, 5);
}

#[test]
fn insert_only_batch_256() {
    run_matrix(StreamKind::InsertOnly, 256, 2);
}

#[test]
fn delete_only_batch_1() {
    run_matrix(StreamKind::DeleteOnly, 1, 10);
}

#[test]
fn delete_only_batch_16() {
    run_matrix(StreamKind::DeleteOnly, 16, 5);
}

#[test]
fn delete_only_batch_256() {
    run_matrix(StreamKind::DeleteOnly, 256, 2);
}

#[test]
fn mixed_batch_1() {
    run_matrix(StreamKind::Mixed, 1, 10);
}

#[test]
fn mixed_batch_16() {
    run_matrix(StreamKind::Mixed, 16, 5);
}

#[test]
fn mixed_batch_256() {
    run_matrix(StreamKind::Mixed, 256, 2);
}

/// ISSUE acceptance: the fallback-to-full path must be exercised and
/// stay byte-identical. `fallback_fraction = 0.0` forces it on every
/// butterfly-touching batch; `1.0` forbids it entirely.
#[test]
fn fallback_thresholds_both_paths_stay_identical() {
    let gs = graphs(base_seed());
    let g0 = &gs[0].1;
    let ecfg = EngineConfig { p: 8, threads: 8, ..Default::default() };
    for (fraction, want_full) in [(0.0f64, true), (1.0, false)] {
        let icfg = IncrementalConfig { engine: ecfg, fallback_fraction: fraction };
        let mut wing = WingIncremental::new(g0, icfg);
        let mut tip = TipIncremental::new(g0, Side::U, icfg);
        let mut stream = StreamGen::new(g0, base_seed() ^ 0xFA11);
        let mut any_full = false;
        let mut any_affected = false;
        for _ in 0..6 {
            let b = stream.batch(StreamKind::Mixed, 8);
            let uw = wing.apply(&b);
            let ut = tip.apply(&b);
            any_full |= uw.full_rebuild || ut.full_rebuild;
            any_affected |= uw.affected_entities > 0 || ut.affected_entities > 0;
            let g = wing.graph().clone();
            assert_eq!(bytes(wing.theta()), bytes(&wing_pbng(&g, ecfg).theta));
            assert_eq!(bytes(tip.theta()), bytes(&tip_pbng(&g, Side::U, ecfg).theta));
            if !want_full {
                assert!(!uw.full_rebuild && !ut.full_rebuild, "fraction 1.0 must never rebuild");
            }
        }
        if want_full {
            assert!(any_full, "fraction 0.0 never exercised the fallback path");
        } else {
            assert!(any_affected, "stream never touched a butterfly");
        }
    }
}

/// Set semantics: no-op batches (re-inserting present edges, removing
/// absent ones, remove+reinsert) leave θ, counts, and the graph alone.
#[test]
fn noop_batches_change_nothing() {
    let gs = graphs(base_seed());
    let g0 = &gs[0].1;
    let icfg = IncrementalConfig {
        engine: EngineConfig { p: 8, threads: 1, ..Default::default() },
        fallback_fraction: 0.3,
    };
    let mut wing = WingIncremental::new(g0, icfg);
    let theta0 = wing.theta().to_vec();
    let (u, v) = g0.edge(0);
    let u2 = (u + 1) % g0.nu() as u32;
    let churn = if g0.has_edge(u2, v) {
        [DeltaOp::Remove(u2, v), DeltaOp::Insert(u2, v)] // remove + re-add
    } else {
        [DeltaOp::Insert(u2, v), DeltaOp::Remove(u2, v)] // add + undo
    };
    let up = wing.apply(&DeltaBatch::new(vec![
        DeltaOp::Insert(u, v), // already present: pure no-op
        churn[0],
        churn[1],
    ]));
    assert_eq!(up.inserted + up.removed, 0);
    assert_eq!(wing.graph().edges(), g0.edges());
    assert_eq!(wing.theta(), &theta0[..]);
    let empty = wing.apply(&DeltaBatch::default());
    assert_eq!(empty.affected_entities, 0);
    assert_eq!(wing.theta(), &theta0[..]);
}

/// The delta-maintained butterfly counts must stay equal to a fresh
/// count of the updated graph (the invariant invalidation builds on).
#[test]
fn maintained_counts_match_fresh_recounts() {
    let gs = graphs(base_seed());
    let g0 = &gs[1].1;
    let icfg = IncrementalConfig {
        engine: EngineConfig { p: 8, threads: 1, ..Default::default() },
        fallback_fraction: 1.0, // keep the delta-maintained path active
    };
    let mut wing = WingIncremental::new(g0, icfg);
    let mut tip = TipIncremental::new(g0, Side::U, icfg);
    let mut stream = StreamGen::new(g0, base_seed() ^ 0xC07);
    for _ in 0..4 {
        let b = stream.batch(StreamKind::Mixed, 12);
        wing.apply(&b);
        tip.apply(&b);
        let g = wing.graph().clone();
        let (fresh, _) = pbng::count::pve_bcnt(
            &g,
            pbng::count::CountOptions { per_edge: true, build_blooms: false, threads: 1 },
            None,
        );
        assert_eq!(wing.counts(), &fresh.per_edge[..], "per-edge counts drifted");
        assert_eq!(tip.counts(), &fresh.per_u[..], "per-vertex counts drifted");
    }
}
