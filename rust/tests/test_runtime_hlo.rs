//! Integration: the AOT bridge end to end — rust loads the HLO text that
//! python/compile/aot.py lowered from the L2 jax model (with L1 Pallas
//! kernels inside), compiles it on the PJRT CPU client, executes it, and
//! cross-checks the numbers against (a) the pure-rust mirror of the math
//! and (b) the sparse-graph counting algorithm.
//!
//! Requires `make artifacts` (skips with a notice when missing).

use pbng::count::dense::DenseCounter;
use pbng::graph::gen;
use pbng::runtime::{butterfly_block_cpu, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new(&dir).ok()?;
    if rt.available_sizes().is_empty() {
        eprintln!("SKIP: no artifacts in {}; run `make artifacts`", dir.display());
        return None;
    }
    Some(rt)
}

#[test]
fn artifact_matches_cpu_mirror_on_random_blocks() {
    let Some(rt) = runtime() else { return };
    let n = rt.available_sizes()[0];
    let mut rng = pbng::testkit::Rng::new(0xA07);
    for _ in 0..3 {
        let block: Vec<f32> = (0..n * n)
            .map(|_| if rng.chance(0.2) { 1.0 } else { 0.0 })
            .collect();
        let got = rt.butterfly_block(&block, n).expect("execute artifact");
        let want = butterfly_block_cpu(&block, n, n);
        assert_eq!(got, want);
    }
}

#[test]
fn artifact_matches_sparse_counting_via_dense_counter() {
    let Some(rt) = runtime() else { return };
    let g = gen::planted_blocks(
        100,
        100,
        150,
        &[gen::Block { rows: 12, cols: 12, density: 0.9 }],
        7,
    );
    let dc = DenseCounter::with_runtime(rt);
    assert!(dc.has_accelerator());
    let us: Vec<u32> = (0..12).collect();
    let vs: Vec<u32> = (0..12).collect();
    let accel = dc.count_block(&g, &us, &vs);
    let cpu = DenseCounter::cpu_only().count_block(&g, &us, &vs);
    assert_eq!(accel, cpu);
}

#[test]
fn artifact_biclique_closed_form() {
    let Some(rt) = runtime() else { return };
    let n = rt.available_sizes()[0];
    // top-left 4x5 biclique inside the padded block
    let mut block = vec![0f32; n * n];
    for i in 0..4 {
        for j in 0..5 {
            block[i * n + j] = 1.0;
        }
    }
    let c = rt.butterfly_block(&block, n).unwrap();
    assert_eq!(c.total, 6 * 10);
    assert_eq!(c.per_u[0], 10 * 3);
    assert_eq!(c.per_edge[0], 3 * 4);
}

#[test]
fn compiled_executable_is_cached_and_reusable() {
    let Some(rt) = runtime() else { return };
    let n = rt.available_sizes()[0];
    let block = vec![0f32; n * n];
    let t0 = std::time::Instant::now();
    rt.butterfly_block(&block, n).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        rt.butterfly_block(&block, n).unwrap();
    }
    let rest = t1.elapsed() / 3;
    eprintln!("first call {first:?} (compile), warm call {rest:?}");
    assert!(rest <= first, "warm calls should not be slower than compile+run");
}
