//! Bench subsystem integration: cross-algorithm equivalence on the bench
//! suites' seeded generators, run-to-run counter determinism, report
//! round trips through disk, the regression gate end to end, and the
//! committed CI baseline.

use pbng::bench::compare::{compare, Thresholds};
use pbng::bench::report::{theta_fnv, Report};
use pbng::bench::runner::{run_suite, BenchOptions};
use pbng::bench::{find_suite, Algo};
use pbng::testkit::TempDir;
use std::path::Path;

fn one_rep() -> BenchOptions {
    BenchOptions { threads: 1, repetitions: 1, warmup: 0 }
}

fn counters_only() -> Thresholds {
    Thresholds { ignore_time: true, ..Thresholds::default() }
}

#[test]
fn cross_algorithm_equivalence_on_bench_suites() {
    // ISSUE satellite: BUP, ParB, and PBNG (all ablation configs) produce
    // identical θ vectors on the bench suites' seeded generators.
    let suite = find_suite("micro").unwrap();
    for ds in suite.datasets {
        let g = ds.build();
        let wing_ref = Algo::WingBup.run(&g, 1).theta;
        let tip_ref = Algo::TipPeel.run(&g, 1).theta;
        for &algo in suite.algos {
            let got = algo.run(&g, 2).theta;
            let want = if algo.is_wing() { &wing_ref } else { &tip_ref };
            assert_eq!(
                &got,
                want,
                "{} diverged from reference on {}",
                algo.name(),
                ds.name
            );
        }
    }
}

#[test]
fn same_seed_runs_have_byte_identical_counter_sections() {
    // ISSUE satellite: two `pbng bench` runs with the same seed produce
    // byte-identical counter sections.
    let suite = find_suite("micro").unwrap();
    let a = run_suite(suite, &one_rep());
    let b = run_suite(suite, &one_rep());
    assert_eq!(a.counters_fingerprint(), b.counters_fingerprint());
    // and the counter section of the serialized reports is identical too
    let strip_times = |r: &Report| -> String {
        let mut back = Report::parse(&r.to_json().to_pretty()).unwrap();
        for e in &mut back.entries {
            e.wall_ms.min = 0.0;
            e.wall_ms.mean = 0.0;
            e.wall_ms.max = 0.0;
            e.rep_ms.clear();
            e.phases.clear();
        }
        back.to_json().to_pretty()
    };
    assert_eq!(strip_times(&a), strip_times(&b));
}

#[test]
fn report_roundtrips_through_disk() {
    let suite = find_suite("micro").unwrap();
    let r = run_suite(suite, &one_rep());
    let dir = TempDir::new("bench").unwrap();
    let path = dir.file("BENCH_micro.json");
    r.save(&path).unwrap();
    let back = Report::load(&path).unwrap();
    assert_eq!(back.counters_fingerprint(), r.counters_fingerprint());
    assert_eq!(back.suite, "micro");
    assert_eq!(back.entries.len(), suite.datasets.len() * suite.algos.len());
    // a self-comparison of the round-tripped report passes the gate
    let cmp = compare(&r, &back, &counters_only()).unwrap();
    assert!(cmp.passed(), "{}", cmp.render());
    assert_eq!(cmp.checked, r.entries.len());
}

#[test]
fn gate_fails_on_injected_counter_regression() {
    let suite = find_suite("micro").unwrap();
    let base = run_suite(suite, &one_rep());
    let mut cur = base.clone();
    cur.entries[0].counters.updates += 1;
    let cmp = compare(&base, &cur, &counters_only()).unwrap();
    assert!(!cmp.passed());
    // θ corruption is caught even with an absurd counter tolerance
    let mut bad_theta = base.clone();
    bad_theta.entries[0].counters.theta_fnv ^= 0xFF;
    let loose = Thresholds { counter_rel_tol: 1e12, ignore_time: true, ..Thresholds::default() };
    assert!(!compare(&base, &bad_theta, &loose).unwrap().passed());
}

#[test]
fn committed_smoke_baseline_parses_and_gates() {
    // The repo-root baseline CI compares against must always be loadable,
    // and its entry keys must refer to datasets/algos that still exist.
    let base = Report::load(Path::new("../BENCH_smoke.json")).unwrap();
    assert_eq!(base.suite, "smoke");
    let suite = find_suite("smoke").unwrap();
    for e in &base.entries {
        assert!(
            suite.datasets.iter().any(|d| d.name == e.dataset),
            "baseline references unregistered dataset '{}'",
            e.dataset
        );
        assert!(
            suite.algos.iter().any(|a| a.name() == e.algo),
            "baseline references unregistered algo '{}'",
            e.algo
        );
    }
    // The actual counter gate runs in the dedicated bench-smoke CI job;
    // re-running the full smoke suite inside `cargo test` would double
    // CI time once the baseline is armed. Opt in explicitly:
    //   PBNG_BENCH_GATE=1 cargo test committed_smoke_baseline
    if !base.entries.is_empty() && std::env::var("PBNG_BENCH_GATE").is_ok() {
        let cur = run_suite(suite, &one_rep());
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(cmp.passed(), "{}", cmp.render());
    }
}

#[test]
fn committed_incremental_baseline_parses_and_gates() {
    // Same contract as the smoke baseline: the file bench-smoke compares
    // the `incremental` suite against must load, and its entries must
    // refer to registered datasets/algos (empty until CI arms it).
    let base = Report::load(Path::new("../BENCH_incremental.json")).unwrap();
    assert_eq!(base.suite, "incremental");
    let suite = find_suite("incremental").unwrap();
    for e in &base.entries {
        assert!(
            suite.datasets.iter().any(|d| d.name == e.dataset),
            "baseline references unregistered dataset '{}'",
            e.dataset
        );
        assert!(
            suite.algos.iter().any(|a| a.name() == e.algo),
            "baseline references unregistered algo '{}'",
            e.algo
        );
    }
    if !base.entries.is_empty() && std::env::var("PBNG_BENCH_GATE").is_ok() {
        let cur = run_suite(suite, &one_rep());
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(cmp.passed(), "{}", cmp.render());
    }
}

#[test]
fn committed_kernels_baseline_parses_and_gates() {
    // Same contract as the smoke/incremental baselines, for the kernel
    // suite CI arms: registered keys only, opt-in full gate.
    let base = Report::load(Path::new("../BENCH_kernels.json")).unwrap();
    assert_eq!(base.suite, "kernels");
    let suite = find_suite("kernels").unwrap();
    for e in &base.entries {
        assert!(
            suite.datasets.iter().any(|d| d.name == e.dataset),
            "baseline references unregistered dataset '{}'",
            e.dataset
        );
        assert!(
            suite.algos.iter().any(|a| a.name() == e.algo),
            "baseline references unregistered algo '{}'",
            e.algo
        );
    }
    // Armed baselines must show the count-only triple byte-identical
    // (scalar vs SIMD vs auto side-choice) per dataset.
    for ds in suite.datasets {
        let fnvs: Vec<u64> = ["kern/count-scalar", "kern/count-simd", "kern/count-auto"]
            .iter()
            .filter_map(|a| base.entry(ds.name, a))
            .map(|e| e.counters.theta_fnv)
            .collect();
        assert!(
            fnvs.windows(2).all(|w| w[0] == w[1]),
            "count kernel θ checksums diverge on {}: {fnvs:?}",
            ds.name
        );
    }
    if !base.entries.is_empty() && std::env::var("PBNG_BENCH_GATE").is_ok() {
        let cur = run_suite(suite, &one_rep());
        let cmp = compare(&base, &cur, &counters_only()).unwrap();
        assert!(cmp.passed(), "{}", cmp.render());
    }
}

#[test]
fn theta_checksum_distinguishes_algo_outputs_only_when_different() {
    let g = find_suite("micro").unwrap().datasets[0].build();
    let a = Algo::WingBup.run(&g, 1);
    let b = Algo::WingPbng.run(&g, 1);
    assert_eq!(theta_fnv(&a.theta), theta_fnv(&b.theta)); // same output
    let mut mutated = a.theta.clone();
    mutated[0] ^= 1;
    assert_ne!(theta_fnv(&a.theta), theta_fnv(&mutated));
}
