//! Serving-layer integration: the poll-based reactor end to end over
//! real TCP — concurrent v2 sessions answering byte-identically to the
//! in-process dispatcher, admission-control shedding at the connection
//! caps, MVCC snapshot hot-swaps (in-flight sessions keep their pinned
//! epoch, new sessions see the new one), the `reload` verb driving the
//! background updater, and protocol-v1 wire compatibility.

use pbng::beindex::BeIndex;
use pbng::graph::gen;
use pbng::index::query::QueryEngine;
use pbng::index::{build_wing_forest, codec, server::dispatch};
use pbng::peel::bup::wing_bup;
use pbng::serve::{ProtoVersion, Server, ServerConfig, SnapshotSource, SnapshotStore, Updater};
use pbng::testkit::TempDir;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn graph_for(seed: u64) -> pbng::graph::BipartiteGraph {
    gen::zipf(30, 28, 220, 1.2, 1.2, seed)
}

fn engine_for(g: &pbng::graph::BipartiteGraph) -> QueryEngine {
    let (idx, _) = BeIndex::build(g, 1);
    let theta = wing_bup(g).theta;
    QueryEngine::new(build_wing_forest(g, &idx, &theta, 1))
}

fn spawn(
    cfg: ServerConfig,
    store: Arc<SnapshotStore>,
) -> (SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = Server::new(cfg, store);
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run_on(listener).unwrap());
    (addr, stop, handle)
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// Read one frame: lines up to (not including) `END`.
    fn frame(&mut self) -> String {
        let mut frame = String::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line).unwrap() == 0 {
                return frame;
            }
            if line.trim_end() == "END" {
                return frame;
            }
            frame.push_str(&line);
        }
    }

    /// Send one command, return its reply frame.
    fn ask(&mut self, cmd: &str) -> String {
        writeln!(self.stream, "{cmd}").unwrap();
        self.frame()
    }

    /// `ask`, asserting the `OK <verb>` status line and stripping it.
    fn body(&mut self, cmd: &str) -> String {
        let frame = self.ask(cmd);
        let verb = cmd.split_whitespace().next().unwrap();
        let expect = format!("OK {verb}\n");
        assert!(frame.starts_with(&expect), "cmd {cmd:?} got:\n{frame}");
        frame[expect.len()..].trim_end_matches('\n').to_string()
    }
}

/// Stable verbs whose replies must match the in-process dispatcher byte
/// for byte (no cache/meter counters, which vary under concurrency).
const STABLE_CMDS: &[&str] =
    &["summary", "kwing 1", "components 2", "membership 0", "top 3", "densest 0"];

#[test]
fn concurrent_v2_sessions_answer_byte_identically() {
    let g = graph_for(40);
    let (addr, stop, handle) = spawn(ServerConfig::new(), SnapshotStore::new(engine_for(&g)));
    let reference = engine_for(&g);
    let expected: Vec<String> = STABLE_CMDS
        .iter()
        .map(|c| dispatch(&reference, c).body.unwrap())
        .collect();
    let workers: Vec<_> = (0..4)
        .map(|w| {
            let expected = expected.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let hello = c.frame();
                assert!(hello.starts_with("OK hello"), "worker {w}: {hello}");
                // interleave differently per worker to stress the reactor
                for round in 0..3 {
                    for k in 0..STABLE_CMDS.len() {
                        let i = (k + w + round) % STABLE_CMDS.len();
                        let got = c.body(STABLE_CMDS[i]);
                        assert_eq!(got, expected[i], "worker {w} cmd {:?}", STABLE_CMDS[i]);
                    }
                }
                let bye = c.ask("quit");
                assert!(bye.starts_with("OK quit"), "worker {w}: {bye}");
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}

#[test]
fn global_cap_sheds_connection_n_plus_one() {
    let g = graph_for(41);
    let (addr, stop, handle) = spawn(
        ServerConfig::new().max_conns(2),
        SnapshotStore::new(engine_for(&g)),
    );
    let mut c1 = Client::connect(addr);
    assert!(c1.frame().starts_with("OK hello"));
    let mut c2 = Client::connect(addr);
    assert!(c2.frame().starts_with("OK hello"));
    // connection 3 is over the cap: exactly one ERR busy frame, then EOF
    let mut c3 = TcpStream::connect(addr).unwrap();
    c3.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut text = String::new();
    c3.read_to_string(&mut text).unwrap();
    assert!(text.starts_with("ERR busy"), "{text}");
    assert!(text.ends_with("END\n"), "{text}");
    // the admitted sessions keep working
    assert!(c1.body("summary").starts_with("level "));
    assert!(c2.body("summary").starts_with("level "));
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}

#[test]
fn hot_swap_keeps_in_flight_sessions_on_their_pinned_epoch() {
    let ga = graph_for(42);
    let gb = graph_for(43);
    let store = SnapshotStore::new(engine_for(&ga));
    let (addr, stop, handle) = spawn(ServerConfig::new(), store.clone());
    // session A pins epoch 1
    let mut a = Client::connect(addr);
    let hello_a = a.frame();
    assert!(hello_a.contains("epoch 1"), "{hello_a}");
    let before = a.body("summary");
    // publish a different graph's engine while A is mid-session
    assert_eq!(store.publish(engine_for(&gb)), 2);
    // A still answers from its pinned snapshot, byte-identical to a
    // fresh engine over graph A
    let after = a.body("summary");
    assert_eq!(before, after);
    let fresh_a = engine_for(&ga);
    assert_eq!(after, dispatch(&fresh_a, "summary").body.unwrap());
    let stats = a.body("stats");
    assert!(stats.contains("\nepoch 1"), "pinned session reports its own epoch:\n{stats}");
    // a new session sees epoch 2 and graph B's answers
    let mut b = Client::connect(addr);
    let hello_b = b.frame();
    assert!(hello_b.contains("epoch 2"), "{hello_b}");
    let fresh_b = engine_for(&gb);
    assert_eq!(b.body("summary"), dispatch(&fresh_b, "summary").body.unwrap());
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}

#[test]
fn reload_verb_publishes_a_new_epoch_from_the_index_file() {
    let tmp = TempDir::new("serve-reload-e2e").unwrap();
    let path = tmp.path().join("g.idx");
    let ga = graph_for(44);
    let gb = graph_for(45);
    let ea = engine_for(&ga);
    codec::save(ea.forest(), &path).unwrap();
    let store = SnapshotStore::new(engine_for(&ga));
    let updater = Updater::spawn(
        SnapshotSource::IndexFile(path.clone()),
        store.clone(),
        Duration::from_millis(10),
    );
    let (addr, stop, handle) = spawn(ServerConfig::new(), store.clone());
    // rewrite the index on disk, then ask the server to reload it
    let eb = engine_for(&gb);
    codec::save(eb.forest(), &path).unwrap();
    let mut c = Client::connect(addr);
    assert!(c.frame().starts_with("OK hello"));
    let reply = c.ask("reload");
    assert!(reply.starts_with("OK reload"), "{reply}");
    // new sessions eventually greet with the next epoch and serve B
    let deadline = Instant::now() + Duration::from_secs(30);
    let fresh_b = engine_for(&gb);
    loop {
        let mut probe = Client::connect(addr);
        let hello = probe.frame();
        if !hello.contains("epoch 1") {
            assert_eq!(
                probe.body("summary"),
                dispatch(&fresh_b, "summary").body.unwrap(),
                "reloaded snapshot serves the rewritten index"
            );
            break;
        }
        probe.ask("quit");
        assert!(Instant::now() < deadline, "reload never published a new epoch");
        std::thread::sleep(Duration::from_millis(20));
    }
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
    updater.stop();
}

#[test]
fn proto_v1_stays_wire_compatible_over_the_reactor() {
    let g = graph_for(46);
    let reference = engine_for(&g);
    let (addr, stop, handle) = spawn(
        ServerConfig::new().proto(ProtoVersion::V1),
        SnapshotStore::new(engine_for(&g)),
    );
    let mut c = Client::connect(addr);
    let mut greeting = String::new();
    c.reader.read_line(&mut greeting).unwrap();
    assert!(greeting.starts_with("READY kind=wing"), "{greeting}");
    // v1 frames carry the bare dispatcher body, no OK/ERR status line
    let frame = c.ask("summary");
    assert_eq!(frame.trim_end(), dispatch(&reference, "summary").body.unwrap());
    let err = c.ask("frobnicate");
    assert!(err.starts_with("ERR unknown command"), "{err}");
    writeln!(c.stream, "quit").unwrap();
    let mut rest = String::new();
    c.reader.read_to_string(&mut rest).unwrap();
    assert_eq!(rest.trim_end(), "BYE");
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}
