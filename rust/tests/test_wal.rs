//! Durability integration: crash recovery from the last checkpoint plus
//! WAL replay must rebuild byte-identical θ — across forest kinds,
//! replay batch sizes, and thread counts — and must keep holding after
//! a torn tail record (crash mid-append) and after compaction rotates
//! the log out from under a stale reader offset. The serving layer's
//! `summary` answer over a recovered state is compared against the
//! same answer over the never-crashed state.

use pbng::engine::incremental::{IncrementalConfig, IncrementalState};
use pbng::engine::EngineConfig;
use pbng::graph::dynamic::{DeltaBatch, DeltaOp, DynGraph};
use pbng::graph::gen;
use pbng::index::ForestKind;
use pbng::testkit::{Rng, TempDir};
use pbng::wal::checkpoint::Checkpoint;
use pbng::wal::{self, Writer};

const ROUNDS: usize = 6;
const OPS_PER_ROUND: usize = 18;

fn base_graph() -> pbng::graph::BipartiteGraph {
    gen::zipf(26, 22, 150, 1.2, 1.2, 11)
}

/// Deterministic mixed stream over `g`'s universe: alternating random
/// inserts and removals of original edges (duplicates and no-ops
/// allowed — the log records intent, set semantics dedupe on apply).
fn stream(g: &pbng::graph::BipartiteGraph, seed: u64) -> Vec<Vec<DeltaOp>> {
    let mut rng = Rng::new(seed);
    let es = g.edges().to_vec();
    (0..ROUNDS)
        .map(|_| {
            (0..OPS_PER_ROUND)
                .map(|k| {
                    if k % 2 == 0 || es.is_empty() {
                        DeltaOp::Insert(
                            rng.usize_below(g.nu()) as u32,
                            rng.usize_below(g.nv()) as u32,
                        )
                    } else {
                        let (u, v) = es[rng.usize_below(es.len())];
                        DeltaOp::Remove(u, v)
                    }
                })
                .collect()
        })
        .collect()
}

fn cfg(threads: usize) -> IncrementalConfig {
    IncrementalConfig {
        engine: EngineConfig {
            p: 8,
            threads,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Ground truth: the whole stream applied round by round from the base
/// graph, no crash, no checkpoint.
fn full_state(kind: ForestKind, rounds: &[Vec<DeltaOp>], threads: usize) -> IncrementalState {
    let g = base_graph();
    let mut st = IncrementalState::new(&g, kind, cfg(threads));
    for r in rounds {
        st.apply(&DeltaBatch::new(r.clone()));
    }
    st
}

/// Write the stream as one WAL record per round (seq 1..=ROUNDS) and a
/// checkpoint capturing the graph after `ckpt_rounds` rounds.
fn write_history(
    dir: &TempDir,
    kind: ForestKind,
    rounds: &[Vec<DeltaOp>],
    ckpt_rounds: usize,
) -> (std::path::PathBuf, std::path::PathBuf) {
    let log = dir.file("stream.wal");
    let ckpt = dir.file("stream.ckpt");
    let mut w = Writer::create(&log).unwrap();
    for r in rounds {
        w.append(r).unwrap();
    }
    drop(w);
    let g = base_graph();
    let mut dg = DynGraph::from_graph(&g);
    for r in &rounds[..ckpt_rounds] {
        dg.apply_batch(&DeltaBatch::new(r.clone()));
    }
    Checkpoint::from_graph(&dg.snapshot(), kind, ckpt_rounds as u64)
        .save(&ckpt)
        .unwrap();
    (log, ckpt)
}

/// Recover exactly the way `pbng serve --wal` does: load the
/// checkpoint, replay every record with `seq > checkpoint.seq` in log
/// order, re-chunked into `batch`-sized apply batches.
fn recover(
    log: &std::path::Path,
    ckpt: &std::path::Path,
    kind: ForestKind,
    batch: usize,
    threads: usize,
) -> IncrementalState {
    let ck = Checkpoint::load(ckpt).unwrap();
    assert_eq!(ck.kind, kind);
    let mut st = IncrementalState::new(&ck.graph(), kind, cfg(threads));
    let tail = wal::replay(log).unwrap();
    let mut next = ck.seq + 1;
    let pending: Vec<DeltaOp> = tail
        .records
        .iter()
        .filter(|r| r.seq > ck.seq)
        .flat_map(|r| {
            assert_eq!(r.seq, next, "sequence gap during recovery");
            next += 1;
            r.ops.iter().copied()
        })
        .collect();
    for chunk in pending.chunks(batch.max(1)) {
        st.apply(&DeltaBatch::new(chunk.to_vec()));
    }
    st
}

fn assert_states_identical(full: &IncrementalState, rec: &IncrementalState, label: &str) {
    assert_eq!(
        full.graph().edges(),
        rec.graph().edges(),
        "{label}: recovered edge set diverged"
    );
    assert_eq!(full.theta(), rec.theta(), "{label}: recovered θ diverged");
}

/// The tentpole property: checkpoint + replay is byte-identical to the
/// never-crashed state for every (kind × batch × threads) cell.
#[test]
fn recovery_rebuilds_identical_theta_across_kinds_batches_and_threads() {
    for kind in [ForestKind::Wing, ForestKind::TipU] {
        let g = base_graph();
        let rounds = stream(&g, 0xA5A5);
        let dir = TempDir::new("wal-recovery").unwrap();
        let (log, ckpt) = write_history(&dir, kind, &rounds, ROUNDS / 2);
        for threads in [1usize, 8] {
            let full = full_state(kind, &rounds, threads);
            for batch in [1usize, 7, 64] {
                let rec = recover(&log, &ckpt, kind, batch, threads);
                let label = format!("{} batch={batch} threads={threads}", kind.name());
                assert_states_identical(&full, &rec, &label);
            }
        }
    }
}

/// A crash mid-append leaves a torn final frame; opening the log for
/// writing truncates it, and recovery equals the history up to the last
/// record that was fully durable.
#[test]
fn torn_tail_recovers_to_the_last_durable_record() {
    let kind = ForestKind::Wing;
    let g = base_graph();
    let rounds = stream(&g, 0x0BAD);
    let dir = TempDir::new("wal-torn").unwrap();
    let (log, ckpt) = write_history(&dir, kind, &rounds, 2);
    // simulate `kill -9` halfway through appending round 7: a length
    // prefix promising more bytes than were flushed
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(&[200, 0, 0, 0, 1, 2, 3]).unwrap();
    }
    let tail = wal::replay(&log).unwrap();
    assert_eq!(tail.records.len(), ROUNDS, "torn frame must not hide real records");
    assert!(tail.torn_bytes > 0);
    // a writer reopening the log truncates the torn bytes and resumes
    let (mut w, _) = Writer::open(&log).unwrap();
    assert_eq!(w.next_seq(), ROUNDS as u64 + 1);
    // recovery sees exactly the durable prefix
    let full = full_state(kind, &rounds, 1);
    let rec = recover(&log, &ckpt, kind, 7, 1);
    assert_states_identical(&full, &rec, "torn tail");
    // and the log keeps working: one more durable round extends both
    let extra = vec![DeltaOp::Insert(0, 0), DeltaOp::Insert(1, 1)];
    assert_eq!(w.append(&extra).unwrap(), ROUNDS as u64 + 1);
    drop(w);
    let mut full2 = full_state(kind, &rounds, 1);
    full2.apply(&DeltaBatch::new(extra));
    let rec2 = recover(&log, &ckpt, kind, 64, 1);
    assert_states_identical(&full2, &rec2, "torn tail + new append");
}

/// Compaction folds the prefix into a fresh checkpoint and drops those
/// records; recovery from the (checkpoint, compacted log) pair still
/// equals the never-crashed state, and a reader holding a pre-compaction
/// byte offset gets a loud `Rotated` error instead of garbage.
#[test]
fn compaction_preserves_recovery_and_rotation_is_loud() {
    let kind = ForestKind::Wing;
    let g = base_graph();
    let rounds = stream(&g, 0xF01D);
    let dir = TempDir::new("wal-compact").unwrap();
    let keep_after = 4u64;
    let (log, ckpt) = write_history(&dir, kind, &rounds, keep_after as usize);
    let old_end = wal::replay(&log).unwrap().end_offset;

    let st = wal::compact(&log, keep_after).unwrap();
    assert_eq!(st.kept, ROUNDS - keep_after as usize);
    assert_eq!(st.dropped as u64, keep_after);
    // surviving records keep their original sequence numbers
    let tail = wal::replay(&log).unwrap();
    assert_eq!(
        tail.records.iter().map(|r| r.seq).collect::<Vec<_>>(),
        (keep_after + 1..=ROUNDS as u64).collect::<Vec<_>>()
    );

    let full = full_state(kind, &rounds, 1);
    let rec = recover(&log, &ckpt, kind, 7, 1);
    assert_states_identical(&full, &rec, "post-compaction");

    // a tail reader still holding the pre-compaction end offset must be
    // told the log rotated, not handed mid-record bytes
    match wal::read_from(&log, old_end) {
        Err(wal::WalError::Rotated { .. }) => {}
        other => panic!("expected Rotated from a stale offset, got {other:?}"),
    }
}

/// Serving-layer differential: the `summary` answer over a recovered
/// engine is byte-identical to the answer over the never-crashed one.
#[test]
fn recovered_engine_serves_identical_summaries() {
    use pbng::serve::updater::engine_from_state;
    use pbng::serve::{one_shot, ProtoVersion};
    for kind in [ForestKind::Wing, ForestKind::TipU] {
        let g = base_graph();
        let rounds = stream(&g, 0x5E17);
        let dir = TempDir::new("wal-serve-diff").unwrap();
        let (log, ckpt) = write_history(&dir, kind, &rounds, 3);
        let full = full_state(kind, &rounds, 2);
        let rec = recover(&log, &ckpt, kind, 16, 2);
        for cmd in ["summary", "top 3"] {
            let want = one_shot(engine_from_state(&full, 2), ProtoVersion::V2, cmd);
            let got = one_shot(engine_from_state(&rec, 2), ProtoVersion::V2, cmd);
            assert_eq!(want, got, "{} `{cmd}` diverged after recovery", kind.name());
        }
    }
}
