//! Index subsystem integration: forest ↔ direct-materialization
//! equivalence on random and preset graphs, codec round trips, corrupt
//! input rejection, and the serving protocol end to end.

use pbng::beindex::BeIndex;
use pbng::graph::{gen, Side};
use pbng::hierarchy::{ktip_vertices, kwing_components};
use pbng::index::query::QueryEngine;
use pbng::index::{build_tip_forest, build_wing_forest, codec, server, Forest, ForestKind};
use pbng::peel::bup::wing_bup;
use pbng::testkit::{check_property, Rng};

fn tmp(name: &str) -> (pbng::testkit::TempDir, std::path::PathBuf) {
    let dir = pbng::testkit::TempDir::new("index-itest").unwrap();
    let path = dir.file(name);
    (dir, path) // keep the TempDir alive alongside the path
}

fn wing_setup(g: &pbng::graph::BipartiteGraph) -> (Forest, BeIndex, Vec<u64>) {
    let (idx, _) = BeIndex::build(g, 2);
    let theta = wing_bup(g).theta;
    let forest = build_wing_forest(g, &idx, &theta, 2);
    (forest, idx, theta)
}

/// All distinct θ levels plus the boundaries around them.
fn probe_levels(theta: &[u64]) -> Vec<u64> {
    let mut ks: Vec<u64> = theta.iter().copied().collect();
    ks.push(0);
    ks.push(theta.iter().max().copied().unwrap_or(0) + 1);
    ks.sort_unstable();
    ks.dedup();
    ks
}

#[test]
fn acceptance_preset_forest_matches_direct_at_every_level() {
    // ISSUE acceptance: on a preset graph, one forest build answers
    // `kwing k` for every level byte-identically to the per-level
    // recomputation, and a save/load round trip preserves all answers.
    let g = gen::Preset::PlantedS.build();
    let (forest, idx, theta) = wing_setup(&g);
    forest.validate().unwrap();
    let (_dir, path) = tmp("planted.idx");
    codec::save(&forest, &path).unwrap();
    let engine = QueryEngine::new(codec::load(&path).unwrap());
    for k in probe_levels(&theta) {
        let direct = kwing_components(&idx, &theta, k);
        assert_eq!(forest.components(k), direct, "forest diverged at level {k}");
        assert_eq!(*engine.components(k), direct, "reloaded index diverged at level {k}");
    }
}

#[test]
fn random_graphs_forest_and_roundtrip_match_direct() {
    check_property("index-vs-direct", 0x1DE7, 6, |seed| {
        let mut rng = Rng::new(seed);
        let g = gen::zipf(
            10 + rng.usize_below(30),
            10 + rng.usize_below(30),
            40 + rng.usize_below(260),
            1.0 + rng.f64(),
            1.0 + rng.f64(),
            seed,
        );
        let (forest, idx, theta) = wing_setup(&g);
        if let Err(e) = forest.validate() {
            return Err(e);
        }
        let (_dir, path) = tmp(&format!("rand_{seed:x}.idx"));
        codec::save(&forest, &path).map_err(|e| e.to_string())?;
        let loaded = codec::load(&path).map_err(|e| e.to_string())?;
        if loaded != forest {
            return Err("save/load changed the forest".into());
        }
        for k in probe_levels(&theta) {
            if loaded.components(k) != kwing_components(&idx, &theta, k) {
                return Err(format!("level {k} diverged after round trip"));
            }
        }
        Ok(())
    });
}

#[test]
fn tip_roundtrip_matches_ktip_vertices_both_sides() {
    let g = gen::Preset::DiStS.build();
    for (side, kind) in [(Side::U, ForestKind::TipU), (Side::V, ForestKind::TipV)] {
        let theta = pbng::tip::tip_bup(&g, side).theta;
        let forest = build_tip_forest(&theta, kind);
        forest.validate().unwrap();
        let (_dir, path) = tmp(&format!("tip_{}.idx", kind.name()));
        codec::save(&forest, &path).unwrap();
        let loaded = codec::load(&path).unwrap();
        assert_eq!(loaded, forest);
        let max = theta.iter().max().copied().unwrap_or(0);
        for k in 1..=max + 1 {
            let comps = loaded.components(k);
            let want = ktip_vertices(&theta, k);
            if want.is_empty() {
                assert!(comps.is_empty(), "side {side:?} level {k}");
            } else {
                assert_eq!(comps.len(), 1, "side {side:?} level {k}");
                assert_eq!(comps[0], want, "side {side:?} level {k}");
            }
        }
    }
}

#[test]
fn corrupted_index_files_are_rejected() {
    let g = gen::paper_fig1();
    let (forest, _, _) = wing_setup(&g);
    let (_dir, path) = tmp("corrupt_e2e.idx");
    codec::save(&forest, &path).unwrap();
    let pristine = std::fs::read(&path).unwrap();
    // every single-byte flip anywhere in the file must fail loudly or
    // decode to the identical forest (flips in dead padding only)
    let mut rng = Rng::new(0xBAD);
    for _ in 0..40 {
        let mut bytes = pristine.clone();
        let pos = rng.usize_below(bytes.len());
        bytes[pos] ^= 1 << rng.usize_below(8);
        std::fs::write(&path, &bytes).unwrap();
        if let Ok(decoded) = codec::load(&path) {
            assert_eq!(decoded, forest, "undetected corruption at byte {pos}");
        }
    }
    // truncations at arbitrary points must fail
    for cut in [0, 7, 16, pristine.len() / 2, pristine.len() - 1] {
        std::fs::write(&path, &pristine[..cut]).unwrap();
        assert!(codec::load(&path).is_err(), "truncation at {cut} accepted");
    }
}

#[test]
fn serving_protocol_answers_match_engine_state() {
    let g = gen::Preset::NestedS.build();
    let (forest, idx, theta) = wing_setup(&g);
    let engine = QueryEngine::new(forest);
    let deepest = *engine.forest().levels.last().unwrap();
    let body = match server::handle_command(&engine, &format!("kwing {deepest}")) {
        server::Reply::Body(b) => b,
        server::Reply::Quit => unreachable!(),
    };
    let direct = kwing_components(&idx, &theta, deepest);
    assert!(
        body.starts_with(&format!("components {} level {deepest}", direct.len())),
        "{body}"
    );
    // repeated level queries hit the cache
    let _ = server::handle_command(&engine, &format!("kwing {deepest}"));
    assert!(engine.meters.cache_hits.get() >= 1);
    // stats reflect the traffic
    let stats = match server::handle_command(&engine, "stats") {
        server::Reply::Body(b) => b,
        server::Reply::Quit => unreachable!(),
    };
    assert!(stats.contains("kind wing"), "{stats}");
}

#[test]
fn forest_refresh_after_incremental_update_matches_fresh_index() {
    // ISSUE satellite: after `engine::incremental` applies edge deltas,
    // rebuilding the forest from the post-update θ must answer every
    // query identically to an index built from a from-scratch
    // decomposition of the updated graph — i.e. `pbng update` + `pbng
    // index` composes with no staleness.
    use pbng::engine::incremental::{IncrementalConfig, WingIncremental};
    use pbng::engine::EngineConfig;
    use pbng::graph::dynamic::{DeltaBatch, DeltaOp};

    let g = gen::zipf(40, 40, 260, 1.2, 1.2, 0x1DF);
    let ecfg = EngineConfig { p: 6, threads: 2, ..Default::default() };
    let mut inc = WingIncremental::new(
        &g,
        IncrementalConfig { engine: ecfg, fallback_fraction: 0.5 },
    );
    // deterministic churn: drop a handful of hub edges, add fresh pairs
    let mut ops: Vec<DeltaOp> = (0..6u32)
        .map(|i| {
            let (u, v) = g.edge(i * 7 % g.m() as u32);
            DeltaOp::Remove(u, v)
        })
        .collect();
    let mut rng = Rng::new(0x1DF2);
    for _ in 0..10 {
        ops.push(DeltaOp::Insert(rng.below(40) as u32, rng.below(40) as u32));
    }
    inc.apply(&DeltaBatch::new(ops));

    let g2 = inc.graph().clone();
    let (idx2, _) = BeIndex::build(&g2, 2);
    // forest refreshed from the incrementally maintained θ ...
    let refreshed = build_wing_forest(&g2, &idx2, inc.theta(), 2);
    refreshed.validate().unwrap();
    // ... must equal the forest of a from-scratch decomposition
    let fresh_theta = wing_bup(&g2).theta;
    assert_eq!(inc.theta(), &fresh_theta[..], "incremental θ diverged");
    let fresh = build_wing_forest(&g2, &idx2, &fresh_theta, 2);
    assert_eq!(refreshed, fresh, "refreshed forest diverged from fresh build");
    // and `pbng query`-level answers must match a fresh index, level by
    // level, including through a codec round trip
    let (_dir, path) = tmp("refresh.idx");
    codec::save(&refreshed, &path).unwrap();
    let engine_refreshed = QueryEngine::new(codec::load(&path).unwrap());
    let engine_fresh = QueryEngine::new(fresh);
    for k in probe_levels(&fresh_theta) {
        let direct = kwing_components(&idx2, &fresh_theta, k);
        assert_eq!(*engine_refreshed.components(k), direct, "level {k}");
        assert_eq!(*engine_fresh.components(k), direct, "level {k}");
        let q = format!("kwing {k}");
        let a = server::handle_command(&engine_refreshed, &q);
        let b = server::handle_command(&engine_fresh, &q);
        match (a, b) {
            (server::Reply::Body(a), server::Reply::Body(b)) => {
                assert_eq!(a, b, "query answers diverged at level {k}")
            }
            _ => unreachable!("kwing never quits"),
        }
    }
}

#[test]
fn hierarchy_summary_agrees_with_forest_and_direct() {
    let g = gen::Preset::NestedS.build();
    let (forest, idx, theta) = wing_setup(&g);
    let summary = pbng::hierarchy::wing_hierarchy_summary(&g, &idx, &theta);
    assert!(!summary.is_empty());
    for l in &summary {
        let direct = kwing_components(&idx, &theta, l.k);
        assert_eq!(l.components, direct.len(), "level {}", l.k);
        assert_eq!(
            l.largest,
            direct.iter().map(|c| c.len()).max().unwrap_or(0),
            "level {}",
            l.k
        );
    }
    // and the forest's own summaries are the same table
    assert_eq!(summary, pbng::index::forest_level_summaries(&forest));
}
