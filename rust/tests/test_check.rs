//! Integration tests for the `pbng-lint` analyzer (`pbng::check` +
//! the `pbng_lint` binary): the real source tree must be clean, and the
//! fixture tree under `tests/fixtures/lint_violations/` must trip every
//! rule exactly once.

use std::process::Command;

fn lint(args: &[&str]) -> std::process::Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_pbng_lint"));
    cmd.args(args);
    cmd.output().expect("running pbng_lint")
}

fn src_root() -> String {
    format!("{}/src", env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root() -> String {
    format!("{}/tests/fixtures/lint_violations", env!("CARGO_MANIFEST_DIR"))
}

#[test]
#[cfg_attr(miri, ignore)] // spawns the lint binary — no subprocesses under Miri
fn real_tree_is_clean() {
    let out = lint(&["--root", &src_root()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "violations in the real tree:\n{stdout}");
    assert!(stdout.contains("0 violation(s)"), "{stdout}");
}

#[test]
#[cfg_attr(miri, ignore)] // spawns the lint binary — no subprocesses under Miri
fn fixture_trips_every_rule_exactly_once() {
    let out = lint(&["--root", &fixture_root()]);
    assert!(!out.status.success(), "the fixtures must fail the lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    // safety-comment has two fixtures: the generic unsafe block and the
    // SIMD-intersection shape under count/ (no allowlist widening
    // without a fixture proving the rule still covers it).
    for (rule, want) in [
        ("safety-comment", 2),
        ("ordering-comment", 1),
        ("transmute-allowlist", 1),
        ("hot-path-lock", 1),
        ("serve-unwrap", 1),
    ] {
        let n = stdout.matches(&format!("[{rule}]")).count();
        assert_eq!(n, want, "rule {rule} fired {n} times, want {want}:\n{stdout}");
    }
    assert!(stdout.contains("6 violation(s)"), "{stdout}");
}

#[test]
#[cfg_attr(miri, ignore)] // spawns the lint binary — no subprocesses under Miri
fn fixture_violations_name_file_and_line() {
    let out = lint(&["--root", &fixture_root()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("unsafe_no_comment.rs:6 [safety-comment]"), "{stdout}");
    assert!(stdout.contains("count/simd_no_safety.rs:7 [safety-comment]"), "{stdout}");
    assert!(stdout.contains("par/ordering_no_comment.rs:7 [ordering-comment]"), "{stdout}");
    assert!(stdout.contains("serve/unwrap_in_session.rs:4 [serve-unwrap]"), "{stdout}");
}

#[test]
#[cfg_attr(miri, ignore)] // spawns the lint binary — no subprocesses under Miri
fn json_report_is_parseable() {
    let out = lint(&["--root", &fixture_root(), "--json"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let v = pbng::jsonio::Value::parse(&stdout).expect("valid JSON report");
    assert_eq!(v.req_u64("count").unwrap(), 6);
    assert_eq!(v.req_u64("files_scanned").unwrap(), 6);
    let viols = v.req_arr("violations").unwrap();
    assert_eq!(viols.len(), 6);
    for d in viols {
        assert!(d.req_u64("line").unwrap() >= 1);
        assert!(!d.req_str("rule").unwrap().is_empty());
        assert!(!d.req_str("file").unwrap().is_empty());
    }
}

#[test]
#[cfg_attr(miri, ignore)] // spawns the lint binary — no subprocesses under Miri
fn bad_arguments_exit_with_usage_error() {
    let out = lint(&["--bogus"]);
    assert_eq!(out.status.code(), Some(2));
    let out = lint(&["--root", "/nonexistent/definitely-not-here"]);
    assert_eq!(out.status.code(), Some(2));
}
