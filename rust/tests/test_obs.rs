//! Observability acceptance (`pbng::obs`).
//!
//! The module's two contracts, proven end to end on real decompositions:
//!
//! 1. **Tracing never perturbs the result.** θ is byte-identical with
//!    tracing off and on, for wing and tip, single- and multi-threaded —
//!    spans are pure observers of an engine whose determinism is already
//!    guaranteed.
//! 2. **The span stream is well-formed.** Every span id has exactly one
//!    enter and one matching exit, lane ids stay below the pool
//!    capacity, and both exporters emit parseable, deterministic
//!    (modulo timestamps) documents.
//!
//! Tracing state is process-global, so every test that enables it runs
//! under one mutex — the `#[test]` harness is multi-threaded and two
//! overlapping windows would cross-contaminate their event streams.

use pbng::engine::EngineConfig;
use pbng::graph::{gen, Side};
use pbng::obs;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize tests that touch the global tracing window.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn cfg(threads: usize) -> EngineConfig {
    EngineConfig { p: 16, threads, ..Default::default() }
}

#[test]
fn theta_is_byte_identical_with_tracing_on_and_off() {
    let _g = obs_lock();
    let graph = gen::zipf(80, 80, 600, 1.2, 1.2, 23);
    for threads in [1usize, 8] {
        let wing_off = pbng::wing::wing_pbng(&graph, cfg(threads)).theta;
        let tip_off = pbng::tip::tip_pbng(&graph, Side::U, cfg(threads)).theta;
        obs::enable();
        let wing_on = pbng::wing::wing_pbng(&graph, cfg(threads)).theta;
        let tip_on = pbng::tip::tip_pbng(&graph, Side::U, cfg(threads)).theta;
        obs::disable();
        obs::clear();
        assert_eq!(wing_off, wing_on, "wing θ diverged under tracing (threads={threads})");
        assert_eq!(tip_off, tip_on, "tip θ diverged under tracing (threads={threads})");
    }
}

#[test]
fn disabled_tracing_records_nothing() {
    let _g = obs_lock();
    obs::disable();
    obs::clear();
    let graph = gen::zipf(60, 60, 400, 1.2, 1.2, 7);
    let _ = pbng::wing::wing_pbng(&graph, cfg(8));
    assert!(obs::take_events().is_empty(), "events recorded while disabled");
    assert_eq!(obs::dropped(), 0);
}

#[test]
fn span_stream_is_well_formed_across_lanes() {
    let _g = obs_lock();
    let graph = gen::zipf(80, 80, 600, 1.2, 1.2, 31);
    obs::enable();
    let _ = pbng::wing::wing_pbng(&graph, cfg(8));
    let events = obs::take_events();
    obs::disable();
    obs::check_spans(&events).expect("well-formed span stream");
    assert!(!events.is_empty());
    let lanes = obs::lane_count();
    assert!(lanes >= 1);
    for e in &events {
        assert!((e.lane as usize) < lanes, "lane {} out of range", e.lane);
    }
    // every instrumented layer shows up: counting, CD rounds, FD tasks
    for kind in [obs::Kind::CountKernel, obs::Kind::CdRound, obs::Kind::FdTask] {
        assert!(
            events.iter().any(|e| e.kind == kind),
            "no {kind:?} spans in the trace"
        );
    }
    // FD task attributes stay in range: partition < p, steal is 0/1
    for (enter, _) in obs::pair_spans(&events) {
        if enter.kind == obs::Kind::FdTask {
            assert!(enter.a < 16, "partition {} out of range", enter.a);
            assert!(enter.c <= 1, "steal flag {} not boolean", enter.c);
        }
    }
}

#[test]
fn incremental_repeel_emits_spans() {
    use pbng::engine::incremental::{IncrementalConfig, WingIncremental};
    use pbng::graph::dynamic::{DeltaBatch, DeltaOp};
    let _g = obs_lock();
    let graph = gen::zipf(60, 60, 400, 1.2, 1.2, 11);
    let icfg = IncrementalConfig { engine: cfg(1), ..Default::default() };
    let mut inc = WingIncremental::new(&graph, icfg);
    obs::enable();
    let _ = inc.apply(&DeltaBatch::new(vec![DeltaOp::Insert(0, 1), DeltaOp::Insert(2, 3)]));
    let events = obs::take_events();
    obs::disable();
    obs::check_spans(&events).expect("well-formed span stream");
    assert!(
        events.iter().any(|e| e.kind == obs::Kind::Repeel),
        "no Repeel span recorded for an incremental batch"
    );
}

#[test]
fn exports_are_deterministic_modulo_timestamps() {
    let _g = obs_lock();
    let graph = gen::zipf(60, 60, 400, 1.2, 1.2, 5);
    let run = || {
        obs::enable();
        let _ = pbng::wing::wing_pbng(&graph, cfg(1));
        let events = obs::take_events();
        obs::disable();
        events
    };
    let strip = |mut evs: Vec<obs::Event>| {
        for e in &mut evs {
            e.ts_ns = 0;
        }
        evs.sort_by_key(|e| (e.span, e.is_exit));
        evs
    };
    let a = run();
    let b = run();
    // single-threaded: same spans, ids, attributes each run (enable()
    // resets the span counter) — only timestamps differ
    assert_eq!(strip(a.clone()), strip(b.clone()));
    let chrome = obs::export::chrome_trace(&a).to_pretty();
    pbng::testkit::check_trace_json(&chrome).expect("valid chrome trace");
    pbng::testkit::check_trace_jsonl(&obs::export::jsonl(&a)).expect("valid jsonl trace");
    // the exporters themselves are deterministic for a fixed event list
    assert_eq!(chrome, obs::export::chrome_trace(&a).to_pretty());
}
